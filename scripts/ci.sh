#!/usr/bin/env bash
# Repo CI gate: tier-1 tests + the benchmark smoke/perf-regression check.
#
#   scripts/ci.sh
#
# 1. tier-1: the full pytest suite (ROADMAP "Tier-1 verify").
# 2. perf gate: benchmarks/run.py --smoke --check reruns the smoke DSE
#    bench and fails when any search method exceeds --tolerance x its
#    committed baseline (benchmarks/BENCH_dse.json), when the jitted
#    perfmodel's pool-scoring speedup over the scalar oracle drops
#    below the 10x floor (or 1/tolerance of the baseline speedup),
#    when the jitted path diverges from the oracle on the bench sample,
#    or when the seeded extreme-system search (bench_extreme) falls
#    below its committed tokens/joule baseline / the 0.276 pair floor.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke + perf-regression check =="
python -m benchmarks.run --smoke --check

echo "CI OK"
