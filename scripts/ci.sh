#!/usr/bin/env bash
# Repo CI gate: tier-1 tests + the benchmark smoke/perf-regression check.
#
#   scripts/ci.sh
#
# 0. artifact guard: fails when `git ls-files` matches Python
#    bytecode or other build artifacts (__pycache__/, *.pyc,
#    .pytest_cache/, *.egg-info/, .DS_Store) — committed bytecode
#    shadows source edits and bloats diffs, so it can never land.
# 1. tier-1: the full pytest suite (ROADMAP "Tier-1 verify").  When the
#    pytest-cov plugin is importable, tier-1 additionally enforces a
#    branch-coverage floor on the analytical core (`repro.core`); on
#    containers without the plugin (tier-1 forbids installing deps) the
#    suite runs without the floor — that degradation is the documented
#    opt-out, printed loudly below.  COV_FLOOR can be overridden per
#    invocation (e.g. COV_FLOOR=0 scripts/ci.sh to skip the floor while
#    keeping the report).
# 2. invariant lint: `python -m repro.analysis` checks the
#    source-level conventions the headline guarantees rest on
#    (seeded RNG only, no wall clock, canonical record bytes, jit
#    purity, atomic artifact writes, fault-tagged broad excepts) and
#    fails on any finding not in the committed
#    .repro-lint-baseline.json, printing per-rule counts so a
#    regression is attributable at a glance (docs/static_analysis.md).
# 3. fault/resume gate: the `fault`-marked suite (already part of
#    tier-1) is rerun by itself so the crash-safe-search guarantees —
#    seeded fault-injection convergence and byte-identical journal
#    resume — gate every run visibly even if tier-1 marker selection
#    ever changes.
# 4. acquisition microbench: the `bench`-marked suite (also part of
#    tier-1) is rerun by itself so the per-call acquisition bounds —
#    exact 3-D EHVI pool scoring and jitted GP batched predict
#    (tests/test_acquisition_bench.py) — and the compare_* verdict
#    plumbing gate every run visibly.
# 5. perf gate: benchmarks/run.py --smoke --check reruns the smoke DSE
#    bench and fails when any search method exceeds --tolerance x its
#    committed baseline (benchmarks/BENCH_dse.json), when the jitted
#    perfmodel's pool-scoring speedup over the scalar oracle drops
#    below the 10x floor (or 1/tolerance of the baseline speedup),
#    when the jitted path diverges from the oracle on the bench sample,
#    when a seeded searched-system sweep (bench_extreme's
#    extreme_system, bench_dllm's dllm_system) falls below its
#    committed tokens/joule baseline / hard floor, when the
#    fleet1000 batched headline search (bench_fleet) loses hypervolume
#    or blows past the single-digit-minutes wall-clock ceiling, or
#    when the serving-fleet search (bench_serving) stops beating naive
#    replication on tokens/joule at the same p99 SLO caps / power
#    budget, or its jitted fleet-pool scoring exceeds the wall-clock /
#    bare-path-overhead ceilings.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

COV_FLOOR="${COV_FLOOR:-70}"

echo "== committed-artifact guard (no bytecode/build caches in git) =="
bad_artifacts="$(git ls-files | grep -E \
    '(^|/)__pycache__(/|$)|\.py[co]$|(^|/)\.pytest_cache(/|$)|\.egg-info(/|$)|(^|/)\.DS_Store$' \
    || true)"
if [ -n "${bad_artifacts}" ]; then
    echo "ERROR: build artifacts are committed to git:" >&2
    echo "${bad_artifacts}" >&2
    echo "Remove them (git rm --cached <file>) — .gitignore already" \
         "excludes these patterns." >&2
    exit 1
fi

echo "== tier-1 tests =="
if python -c "import pytest_cov" >/dev/null 2>&1; then
    python -m pytest -x -q --cov=repro.core --cov-branch \
        --cov-report=term --cov-fail-under="${COV_FLOOR}"
else
    echo "pytest-cov not installed: running tier-1 WITHOUT the" \
         "repro.core branch-coverage floor (install pytest-cov to" \
         "restore it)"
    python -m pytest -x -q
fi

echo "== static-analysis invariant lint =="
python -m repro.analysis src scripts benchmarks

echo "== fault-injection + interrupt/resume smoke =="
python -m pytest -q -m fault

echo "== acquisition microbench (per-call bounds) =="
python -m pytest -q -m bench

echo "== benchmark smoke + perf-regression check =="
python -m benchmarks.run --smoke --check

echo "CI OK"
