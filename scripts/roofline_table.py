"""Render the EXPERIMENTS.md roofline table from a dry-run JSONL."""

import json
import sys

SRC = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_scan.jsonl"

rows = [json.loads(l) for l in open(SRC)]
print("| arch | shape | mesh | compute_s | memory_s | coll_s | bneck |"
      " useful_flops | roofline | note |")
print("|---|---|---|---|---|---|---|---|---|---|")
for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
    if r["status"] == "skip":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | – | – | – | – |"
              f" – | – | skip: {r['reason'][:40]} |")
        continue
    if r["status"] == "error":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | – | – | – | – |"
              f" – | – | ERROR |")
        continue
    note = "mem-proxy clamped" if r.get("mem_proxy_clamped") else ""
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
          f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
          f"| {r['collective_s']:.2e} | {r['bottleneck']} "
          f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.4f} "
          f"| {note} |")
