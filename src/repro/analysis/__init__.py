"""`repro.analysis` — AST invariant linter for the reproduction repo.

Machine-checks the source-level conventions every headline guarantee
rests on: seeded-search determinism (no global-state RNG, no wall
clock, canonical record bytes), jit purity (no Python side effects or
forced concretization under `jax.jit`/`vmap`, no process-global x64
flips), crash safety (atomic writes for shared JSON artifacts) and
exception hygiene (no silent broad excepts in the guarded core).

Run it as a CLI (the `scripts/ci.sh` lint stage does exactly this)::

    python -m repro.analysis [paths] [--baseline FILE] [--write-baseline]

or programmatically via :func:`lint_paths`.  Per-line suppressions use
``# repro-lint: disable=rule-id`` comments; grandfathered findings live
in the committed ``.repro-lint-baseline.json``.  Rule catalogue and
workflow: ``docs/static_analysis.md``.
"""

from .engine import (  # noqa: F401
    DEFAULT_BASELINE,
    DEFAULT_PATHS,
    RULES,
    Baseline,
    Finding,
    LintResult,
    ModuleContext,
    Rule,
    format_report,
    iter_py_files,
    lint_file,
    lint_paths,
    register,
)
from .engine import _load_rules as load_rules  # noqa: F401
