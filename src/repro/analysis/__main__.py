"""CLI for the repro invariant linter.

    python -m repro.analysis [paths...] [options]

Exit codes: 0 — clean (or every finding baselined/suppressed);
1 — at least one new finding or parse error; 2 — usage/IO error.
"""

from __future__ import annotations

import argparse
import os
import sys

from .engine import (DEFAULT_BASELINE, DEFAULT_PATHS, RULES, Baseline,
                     _load_rules, format_report, lint_paths)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant linter: determinism, jit purity, "
                    "crash safety, exception hygiene.")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=".",
                    help="repo root paths are resolved against "
                         "(default: cwd)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE, metavar="FILE",
                    help="baseline of grandfathered findings "
                         f"(default: {DEFAULT_BASELINE}; missing file "
                         "= empty baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file: report everything")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to the baseline "
                         "file and exit 0")
    ap.add_argument("--show-baselined", action="store_true",
                    help="also print grandfathered findings")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        _load_rules()
        for rid, rule in sorted(RULES.items()):
            print(f"{rid}: {rule.summary}")
            print(f"    protects: {rule.invariant}")
            if rule.paths:
                print(f"    scoped to: {', '.join(rule.paths)}")
            if rule.exempt:
                print(f"    exempt: {', '.join(rule.exempt)}")
        return 0

    baseline_path = (args.baseline if os.path.isabs(args.baseline)
                     else os.path.join(args.root, args.baseline))
    baseline = Baseline() if (args.no_baseline or args.write_baseline) \
        else Baseline.load(baseline_path)
    try:
        result = lint_paths(args.paths, root=args.root, baseline=baseline)
    except FileNotFoundError as exc:
        print(f"repro-lint: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline:
        Baseline.from_findings(result.findings).write(baseline_path)
        print(f"repro-lint: wrote {len(result.findings)} finding(s) to "
              f"{baseline_path}")
        return 0

    print(format_report(result, show_baselined=args.show_baselined))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
