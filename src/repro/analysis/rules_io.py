"""Crash-safety and exception-hygiene rules.

* Shared JSON artifacts (``BENCH_*.json`` baselines, journal files,
  checkpoint manifests) must never be written in place: a process
  killed mid-``json.dump`` leaves a truncated file that poisons every
  later ``--check`` gate or resume.  The sanctioned patterns are
  ``benchmarks.common.merge_bench_json`` / an explicit temp file +
  ``os.replace`` (checkpointing renames a staged directory).
* The guarded evaluation layer in ``repro.core`` is allowed broad
  excepts *only* where it re-raises or converts the failure into a
  structured fault/degradation event — a silent ``except Exception:
  pass`` swallows the very signals the fault-injection suite pins.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .engine import Finding, ModuleContext, Rule, register

_WRITE_MODES = ("w", "wt", "w+", "wb", "w+b", "x", "xt", "xb")

# a broad handler is sanctioned when it re-raises or routes the failure
# into the structured fault machinery — matched on called-name
# substrings (e.g. _emit_degradation, record_fault, quarantine_design)
_FAULT_SINKS = ("degrad", "fault", "quarantine", "warn")


def _open_write_mode(node: ast.AST) -> Optional[str]:
    """Mode string when ``node`` is a plain ``open(path, "w"...)``
    call in a write (not append) mode, else None."""
    if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "open"):
        return None
    mode = None
    if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
        mode = node.args[1].value
    for kw in node.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    return mode if isinstance(mode, str) and mode in _WRITE_MODES else None


@register
class NonatomicArtifactWrite(Rule):
    id = "nonatomic-artifact-write"
    summary = ("json.dump through a bare open(..., 'w') with no atomic "
               "rename in scope")
    invariant = ("crash safety of shared artifacts: a kill mid-write "
                 "must never truncate a BENCH_*.json baseline, journal "
                 "or manifest — stage to a temp file and os.replace, "
                 "or go through benchmarks.common.merge_bench_json")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        fn_types = (ast.FunctionDef, ast.AsyncFunctionDef)

        def walk_scope(body):
            """Yield nodes of one scope, not descending into nested
            function scopes (each function is scanned on its own —
            atomicity is judged per enclosing function)."""
            stack = list(body)
            while stack:
                node = stack.pop()
                if isinstance(node, fn_types + (ast.Lambda,)):
                    continue        # inner scope: scanned on its own
                yield node
                stack.extend(ast.iter_child_nodes(node))

        def scan(body):
            atomic = any(
                isinstance(n, ast.Call) and ctx.resolve(n.func) in (
                    "os.replace", "os.rename", "shutil.move")
                for n in walk_scope(body))
            handles = set()          # with-alias names bound to open(w)
            for node in walk_scope(body):
                if isinstance(node, (ast.With, ast.AsyncWith)):
                    for item in node.items:
                        if (_open_write_mode(item.context_expr) is not None
                                and isinstance(item.optional_vars, ast.Name)):
                            handles.add(item.optional_vars.id)
                if not (isinstance(node, ast.Call)
                        and ctx.resolve(node.func) == "json.dump"):
                    continue
                fobj = node.args[1] if len(node.args) >= 2 else None
                bare = (isinstance(fobj, ast.Name) and fobj.id in handles) \
                    or _open_write_mode(fobj) is not None
                if bare and not atomic:
                    out.append(ctx.finding(
                        node, self.id,
                        "json.dump to a plain open(..., 'w') handle "
                        "with no os.replace in this function: a crash "
                        "mid-write truncates the artifact — stage to a "
                        "temp file + os.replace (see "
                        "benchmarks.common.merge_bench_json)"))

        scan(ctx.tree.body)          # module-level statements (scripts)
        for node in ast.walk(ctx.tree):
            if isinstance(node, fn_types):
                scan(node.body)
        return out


@register
class BroadExcept(Rule):
    id = "broad-except"
    summary = ("bare `except:` anywhere; `except Exception` in "
               "repro.core that neither re-raises nor emits a "
               "structured fault/degradation event")
    invariant = ("fault attribution: the guarded evaluation layer "
                 "converts failures into tagged events the "
                 "fault-injection suite can pin; a silent broad except "
                 "erases them")
    # the Exception-breadth check is scoped to the analytical core +
    # search stack, where the structured-fault contract holds
    core_paths = ("src/repro/core",)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        in_core = any(ctx.rel.startswith(p) for p in self.core_paths)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                out.append(ctx.finding(
                    node, self.id,
                    "bare `except:` catches KeyboardInterrupt/"
                    "SystemExit and hides the failure class — name the "
                    "exception types"))
                continue
            if not in_core:
                continue
            names = []
            types = (node.type.elts if isinstance(node.type, ast.Tuple)
                     else [node.type])
            for t in types:
                dotted = ctx.resolve(t)
                if dotted:
                    names.append(dotted.rsplit(".", 1)[-1])
            if not any(n in ("Exception", "BaseException") for n in names):
                continue
            if self._sanctioned(node, ctx):
                continue
            out.append(ctx.finding(
                node, self.id,
                "over-broad `except Exception` in repro.core that "
                "neither re-raises nor emits a structured fault/"
                "degradation event — narrow to the documented "
                "exception types or tag the failure"))
        return out

    @staticmethod
    def _sanctioned(handler: ast.ExceptHandler, ctx: ModuleContext) -> bool:
        for node in ast.walk(handler):
            if isinstance(node, ast.Raise):
                return True
            if isinstance(node, ast.Call):
                dotted = ctx.resolve(node.func) or ""
                leaf = dotted.rsplit(".", 1)[-1].lower()
                if any(s in leaf for s in _FAULT_SINKS):
                    return True
        return False
