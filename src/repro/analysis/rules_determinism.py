"""Determinism rules: seeded RNG, no wall clock, canonical record bytes.

The sha-pinned search trajectories (tests/test_disagg_dse.py and
friends) and the byte-identical journal resume guarantee
(docs/search_runtime.md) only hold if every random draw is threaded
through an explicitly seeded generator and no journaled or benched
record depends on wall-clock time or hash/set iteration order.
"""

from __future__ import annotations

import ast
from typing import List

from .engine import Finding, ModuleContext, Rule, register

# numpy.random module-level (global-state) draw/seed functions.  The
# seeded Generator API (np.random.default_rng / Generator /
# SeedSequence / Philox / PCG64) is the sanctioned alternative and is
# deliberately NOT in this set.
_NP_GLOBAL_FNS = frozenset({
    "seed", "get_state", "set_state", "rand", "randn", "randint",
    "random", "random_sample", "ranf", "sample", "bytes", "choice",
    "shuffle", "permutation", "uniform", "normal", "standard_normal",
    "standard_cauchy", "standard_exponential", "standard_gamma",
    "standard_t", "beta", "binomial", "chisquare", "dirichlet",
    "exponential", "f", "gamma", "geometric", "gumbel",
    "hypergeometric", "laplace", "logistic", "lognormal", "logseries",
    "multinomial", "multivariate_normal", "negative_binomial",
    "noncentral_chisquare", "noncentral_f", "pareto", "poisson",
    "power", "rayleigh", "triangular", "vonmises", "wald", "weibull",
    "zipf",
})

# stdlib `random` module-level functions (the hidden global Mersenne
# Twister).  `random.Random(seed)` instances are fine.
_PY_RANDOM_FNS = frozenset({
    "seed", "getstate", "setstate", "getrandbits", "random", "randint",
    "randrange", "randbytes", "choice", "choices", "shuffle", "sample",
    "uniform", "gauss", "normalvariate", "lognormvariate",
    "expovariate", "vonmisesvariate", "gammavariate", "betavariate",
    "paretovariate", "weibullvariate", "triangular",
})

_WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.ctime", "time.localtime",
    "time.gmtime", "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})


@register
class UnseededRng(Rule):
    id = "unseeded-rng"
    summary = ("call to a global-state RNG function (numpy.random.* "
               "module level, stdlib random.*)")
    invariant = ("seeded-search determinism: every draw must come from "
                 "an explicitly seeded np.random.Generator / "
                 "random.Random threaded from the caller")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func)
            if dotted is None or not ctx.resolves_from_import(node.func):
                continue
            fn = dotted.rsplit(".", 1)[-1]
            if (dotted == f"numpy.random.{fn}" and fn in _NP_GLOBAL_FNS) or \
               (dotted == f"random.{fn}" and fn in _PY_RANDOM_FNS):
                out.append(ctx.finding(
                    node, self.id,
                    f"global-state RNG call `{dotted}`: thread a seeded "
                    f"generator (np.random.default_rng(seed) / "
                    f"random.Random(seed)) instead"))
        return out


@register
class WallClock(Rule):
    id = "wall-clock"
    summary = "wall-clock read (time.time, datetime.now, ...)"
    invariant = ("byte-identical journal resume and reproducible bench "
                 "records: no timestamp may reach a persisted record; "
                 "use time.perf_counter() for duration measurement")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func)
            if dotted in _WALL_CLOCK and ctx.resolves_from_import(node.func):
                out.append(ctx.finding(
                    node, self.id,
                    f"wall-clock call `{dotted}`: journaled/benched "
                    f"records must not embed host time — use "
                    f"time.perf_counter() for durations"))
        return out


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


@register
class SetIteration(Rule):
    id = "set-iteration"
    summary = "iteration over a set in unspecified (hash) order"
    invariant = ("record-byte determinism: anything feeding a journal "
                 "or bench record must iterate in a defined order — "
                 "wrap the set in sorted(...)")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node in ast.walk(ctx.tree):
            iters = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend(g.iter for g in node.generators)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id in ("list", "tuple", "enumerate")):
                iters.extend(a for a in node.args)
            for it in iters:
                if _is_set_expr(it):
                    out.append(ctx.finding(
                        it, self.id,
                        "iterating a set in hash order is "
                        "nondeterministic across processes — wrap in "
                        "sorted(...)"))
        return out


@register
class JsonSortKeys(Rule):
    id = "json-sort-keys"
    summary = "json.dump/json.dumps without sort_keys=True"
    invariant = ("canonical record bytes: the journal and every bench "
                 "artifact serialize with sorted keys so identical "
                 "state produces identical bytes")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func)
            if dotted not in ("json.dump", "json.dumps"):
                continue
            sort_kw = next((kw for kw in node.keywords
                            if kw.arg == "sort_keys"), None)
            ok = sort_kw is not None and not (
                isinstance(sort_kw.value, ast.Constant)
                and sort_kw.value.value is False)
            if not ok:
                out.append(ctx.finding(
                    node, self.id,
                    f"`{dotted}` without sort_keys=True: dict order is "
                    f"insertion order, not canonical — records differ "
                    f"across code paths producing the same state"))
        return out
