"""Reusable AST lint engine for the repo's reproducibility invariants.

Every headline guarantee this repo makes — sha-pinned seeded search
trajectories, byte-identical journal resume, jit-vs-scalar perfmodel
parity — rests on *source-level* conventions (no global-state RNG in
core paths, no wall clock in journaled records, no Python side effects
under `jax.jit`, atomic writes for shared artifacts).  The regression
tests catch a broken guarantee after the fact; this engine catches the
offending *line* before it merges.

Architecture
------------
* **Rules** subclass :class:`Rule` and register with :func:`register`.
  A rule declares an id (kebab-case, used in suppressions and the
  baseline), the invariant it protects, optional path scoping
  (``paths`` prefixes / ``exempt`` suffixes, matched against the
  lint-root-relative posix path), and implements ``check(ctx)``
  returning :class:`Finding`\\ s.
* **ModuleContext** parses a file once and shares the AST, the raw
  lines, the import-alias table (so ``np.random.randint`` resolves to
  ``numpy.random.randint`` whatever the import spelling), and the
  per-line suppression map across all rules.
* **Suppressions** — ``# repro-lint: disable=rule-id[,rule-id...]`` (or
  ``disable=all``) on the flagged line, or alone on the line directly
  above it, silences the named rules for that line.  Suppressed
  findings are still counted and reported in the summary so silent
  rot stays visible.
* **Baseline** — grandfathered findings live in a committed JSON file
  (:data:`DEFAULT_BASELINE`).  Findings are keyed by
  ``(relpath, rule, stripped source line)`` with a count, so line
  drift does not resurrect them but editing the offending line does.
  ``--write-baseline`` regenerates the file from the current findings.

The engine is dependency-free (stdlib ``ast`` only) so it can run as a
CI stage before any heavyweight import.
"""

from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

DEFAULT_PATHS: Tuple[str, ...] = ("src", "scripts", "benchmarks")
DEFAULT_BASELINE = ".repro-lint-baseline.json"

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)")


# --------------------------------------------------------------------------
# findings
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    path: str            # lint-root-relative posix path
    line: int            # 1-based
    col: int             # 0-based
    rule: str            # rule id, e.g. "unseeded-rng"
    message: str
    text: str = ""       # stripped source line — the baseline key

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: stable under pure line movement."""
        return (self.path, self.rule, self.text)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


# --------------------------------------------------------------------------
# module context
# --------------------------------------------------------------------------

class ModuleContext:
    """Parsed module shared by every rule: AST, lines, import aliases,
    suppression map."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.aliases = _collect_aliases(self.tree)
        self.suppressions = _collect_suppressions(source)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted path of a Name/Attribute chain with import aliases
        substituted at the root (``np.random.randint`` ->
        ``numpy.random.randint``); None for anything unresolvable
        (calls, subscripts, literals)."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(self.aliases.get(node.id, node.id))
        return ".".join(reversed(parts))

    def resolves_from_import(self, node: ast.AST) -> bool:
        """True when the chain's root name is a tracked import alias —
        distinguishes the stdlib ``random`` module from a local object
        that happens to be named ``random``."""
        while isinstance(node, ast.Attribute):
            node = node.value
        return isinstance(node, ast.Name) and node.id in self.aliases

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        lineno = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(path=self.rel, line=lineno, col=col, rule=rule,
                       message=message, text=self.line_text(lineno))


def _collect_aliases(tree: ast.Module) -> Dict[str, str]:
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _collect_suppressions(source: str) -> Dict[int, set]:
    """line -> set of rule ids disabled on that line.  A suppression
    comment covers its own line; a comment-only line also covers the
    next line (for statements too long to share a line with it)."""
    out: Dict[int, set] = {}
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return out
    lines = source.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        lineno = tok.start[0]
        out.setdefault(lineno, set()).update(rules)
        line = lines[lineno - 1] if lineno <= len(lines) else ""
        if line.strip().startswith("#"):        # comment-only line
            out.setdefault(lineno + 1, set()).update(rules)
    return out


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

class Rule:
    """Base class for lint rules.  Subclass, set the class attributes,
    implement ``check``, and decorate with :func:`register`."""

    id: str = ""
    summary: str = ""          # one-line: what the rule flags
    invariant: str = ""        # the repo guarantee it protects
    paths: Tuple[str, ...] = ()    # rel-path prefixes; empty = everywhere
    exempt: Tuple[str, ...] = ()   # rel-path suffixes exempt from the rule

    def applies_to(self, rel: str) -> bool:
        rel = rel.replace(os.sep, "/")
        if any(rel.endswith(suf) for suf in self.exempt):
            return False
        if self.paths and not any(rel.startswith(p) for p in self.paths):
            return False
        return True

    def check(self, ctx: ModuleContext) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError


RULES: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule (instance) to the global registry."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if inst.id in RULES:
        raise ValueError(f"duplicate rule id {inst.id!r}")
    RULES[inst.id] = inst
    return cls


def _load_rules() -> None:
    """Import the rule modules (idempotent — registration is by id)."""
    from . import rules_determinism, rules_io, rules_jit  # noqa: F401


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------

class Baseline:
    """Grandfathered findings: ``(path, rule, line text) -> count``."""

    def __init__(self, counts: Optional[Dict[Tuple[str, str, str], int]] = None):
        self.counts: Dict[Tuple[str, str, str], int] = dict(counts or {})

    @classmethod
    def load(cls, path: str) -> "Baseline":
        try:
            with open(path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            return cls()
        counts: Dict[Tuple[str, str, str], int] = {}
        for row in doc.get("findings", []):
            key = (row["path"], row["rule"], row.get("text", ""))
            counts[key] = counts.get(key, 0) + int(row.get("count", 1))
        return cls(counts)

    @classmethod
    def from_findings(cls, findings: Iterable[Finding]) -> "Baseline":
        counts: Dict[Tuple[str, str, str], int] = {}
        for f in findings:
            counts[f.key] = counts.get(f.key, 0) + 1
        return cls(counts)

    def to_doc(self) -> dict:
        rows = [{"path": p, "rule": r, "text": t, "count": c}
                for (p, r, t), c in sorted(self.counts.items())]
        return {"version": 1, "findings": rows}

    def write(self, path: str) -> None:
        """Atomic write (temp file + os.replace) — the baseline is a
        shared artifact and obeys the same rule it enforces."""
        import tempfile
        doc = self.to_doc()
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=os.path.basename(path) + ".",
                                   suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    def split(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding]]:
        """(new, grandfathered) — consumes baseline counts in order."""
        budget = dict(self.counts)
        new: List[Finding] = []
        old: List[Finding] = []
        for f in findings:
            if budget.get(f.key, 0) > 0:
                budget[f.key] -= 1
                old.append(f)
            else:
                new.append(f)
        return new, old


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)     # actionable
    baselined: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    errors: List[Finding] = field(default_factory=list)       # parse failures
    n_files: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def rule_counts(self) -> Dict[str, Dict[str, int]]:
        counts = {rid: {"new": 0, "baselined": 0, "suppressed": 0}
                  for rid in sorted(RULES)}
        for bucket, name in ((self.findings, "new"),
                             (self.baselined, "baselined"),
                             (self.suppressed, "suppressed")):
            for f in bucket:
                counts.setdefault(
                    f.rule, {"new": 0, "baselined": 0, "suppressed": 0}
                )[name] += 1
        return counts


def iter_py_files(paths: Sequence[str], root: str = ".") -> List[str]:
    """Expand files/directories into a sorted list of .py files
    (lint-root-relative)."""
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            out.append(os.path.relpath(full, root))
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__",))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(os.path.relpath(
                            os.path.join(dirpath, fn), root))
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
    return sorted(set(out))


def lint_file(path: str, root: str = ".") -> Tuple[List[Finding], List[Finding]]:
    """Lint one file: (findings, suppressed).  Parse failures surface
    as a single ``parse-error`` finding (a file the engine cannot see
    is a file the invariants cannot protect)."""
    rel = os.path.relpath(path if os.path.isabs(path)
                          else os.path.join(root, path), root)
    rel = rel.replace(os.sep, "/")
    full = os.path.join(root, rel)
    with open(full, encoding="utf-8") as f:
        source = f.read()
    try:
        ctx = ModuleContext(full, rel, source)
    except SyntaxError as exc:
        return [Finding(path=rel, line=exc.lineno or 1, col=0,
                        rule="parse-error",
                        message=f"cannot parse: {exc.msg}")], []
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for rule in RULES.values():
        if not rule.applies_to(rel):
            continue
        for f in rule.check(ctx):
            disabled = ctx.suppressions.get(f.line, set())
            if "all" in disabled or f.rule in disabled:
                suppressed.append(f)
            else:
                findings.append(f)
    order = lambda f: (f.path, f.line, f.col, f.rule)  # noqa: E731
    return sorted(findings, key=order), sorted(suppressed, key=order)


def lint_paths(paths: Sequence[str] = DEFAULT_PATHS, root: str = ".",
               baseline: Optional[Baseline] = None) -> LintResult:
    """Lint every .py file under ``paths`` (relative to ``root``)."""
    _load_rules()
    baseline = baseline or Baseline()
    result = LintResult()
    all_findings: List[Finding] = []
    for rel in iter_py_files(paths, root):
        findings, suppressed = lint_file(rel, root)
        result.n_files += 1
        result.suppressed.extend(suppressed)
        for f in findings:
            (result.errors if f.rule == "parse-error"
             else all_findings).append(f)
    result.findings, result.baselined = baseline.split(all_findings)
    return result


def format_report(result: LintResult, show_baselined: bool = False) -> str:
    """Human-readable report: one line per actionable finding, then the
    per-rule count table (new / baselined / suppressed) so regressions
    are attributable at a glance."""
    lines: List[str] = []
    for f in result.errors + result.findings:
        lines.append(f.format())
    if show_baselined:
        for f in result.baselined:
            lines.append(f"{f.format()} (baselined)")
    counts = result.rule_counts()
    width = max(len(r) for r in counts) if counts else 10
    lines.append(f"repro-lint: {result.n_files} file(s), "
                 f"{len(result.findings)} new finding(s), "
                 f"{len(result.baselined)} baselined, "
                 f"{len(result.suppressed)} suppressed, "
                 f"{len(result.errors)} parse error(s)")
    lines.append(f"  {'rule'.ljust(width)}  new  baselined  suppressed")
    for rid, c in counts.items():
        lines.append(f"  {rid.ljust(width)}  "
                     f"{str(c['new']).rjust(3)}  "
                     f"{str(c['baselined']).rjust(9)}  "
                     f"{str(c['suppressed']).rjust(10)}")
    return "\n".join(lines)
