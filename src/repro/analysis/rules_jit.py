"""Jit-purity rules: no Python side effects under `jax.jit`/`vmap`.

The jitted perfmodel (`core/perfmodel_jit.py`), the GP hot path
(`core/dse/gp.py`) and the Pallas kernel wrappers rely on traced
functions being *pure*: a `print` traces once and then lies, `.item()`
or `float()` on a traced value either breaks the trace
(ConcretizationTypeError) or silently forces a host sync, and mutating
a closure container leaks trace-time state into runtime.  The x64
precision contract additionally requires `jax.experimental.enable_x64`
*scoped* contexts, never the process-global flag flip — a global flip
changes every caller's dtypes and breaks the jit-vs-scalar parity
tests.

Detection is intentionally static and conservative:

* A function is a **jit entry** when it is decorated with
  `jax.jit`/`jax.vmap`/`jax.pmap` (directly, as a call, or via
  `functools.partial(jax.jit, ...)`), or passed by name/lambda to one
  of those transforms anywhere in the module.
* The checked **closure** is the entry body plus every same-module
  function reachable from it through direct-name calls (memoized,
  cycle-safe).  `print` and `.item()` are flagged anywhere in the
  closure; `float()`/`int()`/`bool()` are flagged only on expressions
  rooted at the *entry* function's own parameters (minus
  `static_argnames`, which are concrete by contract) — deeper
  traced-ness is undecidable statically and would drown the signal in
  false positives.
* Mutation is flagged for `.append`/`.extend`/... on names the
  function neither binds locally nor takes as a parameter (a local
  accumulator unrolls fine at trace time; a closure one is a leak).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .engine import Finding, ModuleContext, Rule, register

_TRANSFORMS = ("jax.jit", "jax.vmap", "jax.pmap")
_MUTATORS = frozenset({"append", "extend", "insert", "pop", "remove",
                       "clear", "add", "discard", "update", "setdefault",
                       "popitem"})

_FnNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def _static_argnames(call: ast.Call) -> Set[str]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return {e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str):
                return {kw.value.value}
    return set()


def _transform_call(ctx: ModuleContext, node: ast.Call
                    ) -> Optional[Set[str]]:
    """If ``node`` is a call to a jit-like transform (possibly through
    functools.partial), return its static argnames, else None."""
    dotted = ctx.resolve(node.func)
    if dotted in _TRANSFORMS:
        return _static_argnames(node)
    if dotted == "functools.partial" and node.args:
        inner = ctx.resolve(node.args[0])
        if inner in _TRANSFORMS:
            return _static_argnames(node)
    return None


def _params(fn) -> Set[str]:
    if isinstance(fn, ast.Lambda):
        a = fn.args
    else:
        a = fn.args
    names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _local_bindings(fn) -> Set[str]:
    """Names assigned anywhere inside ``fn`` (incl. for/with targets)."""
    out: Set[str] = set()

    def bind(target):
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                out.add(n.id)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                bind(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            bind(node.target)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            bind(node.target)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars:
                    bind(item.optional_vars)
        elif isinstance(node, ast.comprehension):
            bind(node.target)
        elif isinstance(node, _FnNode):
            out.add(node.name)
    return out


@register
class JitImpurity(Rule):
    id = "jit-impurity"
    summary = ("Python side effect or host sync inside a function "
               "traced by jax.jit/vmap/pmap")
    invariant = ("trace purity: jitted code runs the Python body once; "
                 "prints/mutation/forced concretization diverge from "
                 "the compiled computation")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        # function name -> def nodes (same-module resolution target)
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, _FnNode):
                defs.setdefault(node.name, []).append(node)

        # (entry node, static argnames) from decorators and call sites
        entries: List[Tuple[ast.AST, Set[str]]] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, _FnNode):
                for dec in node.decorator_list:
                    if ctx.resolve(dec) in _TRANSFORMS:
                        entries.append((node, set()))
                    elif isinstance(dec, ast.Call):
                        static = _transform_call(ctx, dec)
                        if static is not None:
                            entries.append((node, static))
            elif isinstance(node, ast.Call):
                static = _transform_call(ctx, node)
                if static is None or not node.args:
                    continue
                target = node.args[0]
                if ctx.resolve(node.func) == "functools.partial":
                    if len(node.args) < 2:
                        continue        # bare partial(jax.jit, ...) factory
                    target = node.args[1]
                if isinstance(target, ast.Lambda):
                    entries.append((target, static))
                elif isinstance(target, ast.Name):
                    for d in defs.get(target.id, []):
                        entries.append((d, static))

        out: List[Finding] = []
        flagged: Set[Tuple[int, int, str]] = set()

        def emit(node, message):
            key = (node.lineno, node.col_offset, message)
            if key not in flagged:
                flagged.add(key)
                out.append(ctx.finding(node, self.id, message))

        def check_fn(fn, traced_params: Set[str], seen: Set[ast.AST]):
            if fn in seen:
                return
            seen.add(fn)
            local = _local_bindings(fn) if not isinstance(
                fn, ast.Lambda) else set()
            params = _params(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                func = node.func
                if isinstance(func, ast.Name) and func.id == "print":
                    emit(node, "print() under jit traces once and then "
                               "never again — use jax.debug.print")
                elif (isinstance(func, ast.Attribute)
                      and func.attr == "item" and not node.args):
                    emit(node, ".item() under jit forces host "
                               "concretization of a traced value")
                elif (isinstance(func, ast.Name)
                      and func.id in ("float", "int", "bool")
                      and node.args):
                    root = _root_name(node.args[0])
                    if root is not None and root in traced_params:
                        emit(node, f"{func.id}() on traced argument "
                                   f"`{root}` breaks the trace "
                                   f"(ConcretizationTypeError)")
                elif (isinstance(func, ast.Attribute)
                      and func.attr in _MUTATORS):
                    root = _root_name(func.value)
                    if (root is not None and root not in local
                            and root not in params):
                        emit(node, f"mutating closure object `{root}."
                                   f"{func.attr}(...)` under jit leaks "
                                   f"trace-time state")
                elif isinstance(func, ast.Name) and func.id in defs:
                    for d in defs[func.id]:
                        # deeper frames: param traced-ness unknowable,
                        # so only closure-wide checks apply there
                        check_fn(d, set(), seen)

        for fn, static in entries:
            check_fn(fn, _params(fn) - static, set())
        return out


@register
class GlobalX64Toggle(Rule):
    id = "global-x64"
    summary = 'process-global jax.config.update("jax_enable_x64", ...)'
    invariant = ("jit-vs-scalar parity: float64 sections run under the "
                 "scoped jax.experimental.enable_x64 helpers in "
                 "perfmodel_jit.py/gp.py; a global flip changes every "
                 "caller's dtypes")
    # the sanctioned scoped helpers live here (they use the
    # enable_x64() context manager; the files stay exempt so the
    # sanctioned pattern can evolve without lint churn)
    exempt = ("repro/core/perfmodel_jit.py", "repro/core/dse/gp.py")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if ctx.resolve(node.func) != "jax.config.update":
                continue
            if (node.args and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value == "jax_enable_x64"):
                out.append(ctx.finding(
                    node, self.id,
                    'global jax.config.update("jax_enable_x64") flips '
                    "dtypes for the whole process — use the scoped "
                    "`with jax.experimental.enable_x64():` pattern "
                    "(see perfmodel_jit.py / gp.py)"))
        return out
