"""MX block quantization kernel (Pallas, TPU target).

Quantizes a tensor to MXINT8-style blocks: 32 consecutive elements share
one power-of-two scale (stored as f32 for simplicity; 8-bit exponent in
the format spec).  Used by the quantized-KV-cache path and the traffic
model's bits-per-element accounting; the kernel form keeps quantization
on-chip so writing a cache block costs int8 bytes, not bf16.

Tiling: [BLOCK_N x D] row tiles in VMEM; lane dim D stays contiguous and
MXU/VPU aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MX_BLOCK = 32
DEFAULT_BLOCK_N = 256
QMAX = 127.0


def _quant_kernel(x_ref, q_ref, s_ref, *, d: int):
    x = x_ref[...].astype(jnp.float32)              # [bn, d]
    bn = x.shape[0]
    xb = x.reshape(bn, d // MX_BLOCK, MX_BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    amax = jnp.where(amax == 0, 1.0, amax)
    exp = jnp.ceil(jnp.log2(amax / QMAX))
    scale = jnp.exp2(exp)
    q = jnp.clip(jnp.round(xb / scale), -QMAX, QMAX)
    q_ref[...] = q.reshape(bn, d).astype(jnp.int8)
    s_ref[...] = scale[..., 0].astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref, *, d: int):
    q = q_ref[...].astype(jnp.float32)
    bn = q.shape[0]
    qb = q.reshape(bn, d // MX_BLOCK, MX_BLOCK)
    x = qb * s_ref[...][..., None]
    x_ref[...] = x.reshape(bn, d).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mx_quantize(x: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N,
                interpret: bool = True) -> tuple:
    """x: [N, D] (D % 32 == 0) -> (int8 [N, D], scales f32 [N, D/32])."""
    n, d = x.shape
    if d % MX_BLOCK:
        raise ValueError(f"D={d} must be a multiple of {MX_BLOCK}")
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"N={n} must divide block_n={bn}")
    grid = (n // bn,)
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, d=d),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                   pl.BlockSpec((bn, d // MX_BLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.int8),
                   jax.ShapeDtypeStruct((n, d // MX_BLOCK), jnp.float32)],
        interpret=interpret,
    )(x)
    return q, s


@functools.partial(jax.jit, static_argnames=("block_n", "dtype", "interpret"))
def mx_dequantize(q: jnp.ndarray, s: jnp.ndarray,
                  block_n: int = DEFAULT_BLOCK_N, dtype=jnp.float32,
                  interpret: bool = True) -> jnp.ndarray:
    n, d = q.shape
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"N={n} must divide block_n={bn}")
    return pl.pallas_call(
        functools.partial(_dequant_kernel, d=d),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, d // MX_BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), dtype),
        interpret=interpret,
    )(q, s)
