"""Single-token decode attention kernel (Pallas, TPU target).

Decode is the paper's memory-bound phase: per step the whole KV cache
streams HBM -> VMEM once while compute is a rank-1 update.  The kernel
keeps the (grouped) query vector and the online-softmax state in VMEM
and streams the cache in BLOCK_K-token blocks; supports an int8
quantized cache (the paper's KV-precision axis) by fusing dequant into
the stream — which is exactly how the KV-bytes term of the analytic
model drops with kv_bits.

q: [B, Hq, Dh] (one token per sequence); cache k/v: [B, S, Hkv, Dh];
valid length t masks the unwritten tail.  Grid: (B * Hkv, n_kv_blocks),
the group's G query heads ride along the sublane dim of one block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import TPUCompilerParams

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, sm_scale: float, block_k: int, n_kv_blocks: int,
                   window: int, ring: bool):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    t = t_ref[0]
    q = q_ref[0].astype(jnp.float32)            # [G, Dh]
    k = k_ref[0].astype(jnp.float32)            # [bk, Dh]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                            # [G, bk]

    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    if ring:
        # ring buffer: all slots valid once wrapped
        valid = (k_pos <= t) | (t >= n_kv_blocks * block_k)
    else:
        valid = k_pos <= t
        if window > 0:
            valid &= k_pos > t - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_kv_heads", "window", "ring", "block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     t: jnp.ndarray, *, n_kv_heads: int, window: int = 0,
                     ring: bool = False, block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, Dh]; k/v cache: [B, S, Hkv, Dh]; t: scalar int32 current
    position.  Returns [B, Hq, Dh]."""
    b, hq, dh = q.shape
    skv = k.shape[1]
    group = hq // n_kv_heads
    sm_scale = 1.0 / (dh ** 0.5)
    bk = min(block_k, skv)
    if skv % bk:
        raise ValueError(f"cache length {skv} must divide block {bk}")
    n_k = skv // bk

    # [B, Hkv, G, Dh]: the group's queries share one grid row
    qf = q.reshape(b, n_kv_heads, group, dh).reshape(
        b * n_kv_heads, group, dh)
    kf = k.swapaxes(1, 2).reshape(b * n_kv_heads, skv, dh)
    vf = v.swapaxes(1, 2).reshape(b * n_kv_heads, skv, dh)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, sm_scale=sm_scale, block_k=bk, n_kv_blocks=n_k,
        window=window, ring=ring)
    out = pl.pallas_call(
        kernel,
        grid=(b * n_kv_heads, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, group, dh), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, dh), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * n_kv_heads, group, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(t_arr, qf, kf, vf)
    return out.reshape(b, n_kv_heads * group, dh)
