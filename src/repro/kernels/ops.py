"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute through the Pallas
interpreter (`interpret=True`, bit-faithful to the kernel body); on TPU
set REPRO_PALLAS_INTERPRET=0 to compile through Mosaic.
"""

from __future__ import annotations

import os

import jax

from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .mx_quant import mx_dequantize, mx_quantize


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def flash_attention_op(q, k, v, *, n_kv_heads, causal=True, window=0,
                       block_q=128, block_k=128):
    return flash_attention(q, k, v, n_kv_heads=n_kv_heads, causal=causal,
                           window=window, block_q=block_q, block_k=block_k,
                           interpret=_interpret_default())


def decode_attention_op(q, k, v, t, *, n_kv_heads, window=0, ring=False,
                        block_k=512):
    return decode_attention(q, k, v, t, n_kv_heads=n_kv_heads,
                            window=window, ring=ring, block_k=block_k,
                            interpret=_interpret_default())


def mx_quantize_op(x, block_n=256):
    return mx_quantize(x, block_n=block_n, interpret=_interpret_default())


def mx_dequantize_op(q, s, block_n=256, dtype=None):
    import jax.numpy as jnp
    return mx_dequantize(q, s, block_n=block_n,
                         dtype=dtype or jnp.float32,
                         interpret=_interpret_default())
