"""Flash attention forward kernel (Pallas, TPU target).

This is the paper's "activations stay on-chip" prefill insight made
TPU-native: Q/accumulator tiles are pinned in VMEM while K/V stream
HBM -> VMEM block by block, so the S x S score matrix NEVER touches HBM
(the XLA fallback materializes q-chunk score tiles; see
models/layers.sdpa_chunked).  Online softmax with running (m, l, acc)
scratch carried across the innermost (KV) grid dimension.

Tiling: q blocks (BLOCK_Q x head_dim) x kv blocks (BLOCK_K x head_dim);
MXU-aligned (multiples of 128 for seq blocks; head_dim 64/128/512 per
the assigned archs).  Grid: (batch*q_heads, n_q_blocks, n_kv_blocks),
dimension semantics (parallel, parallel, arbitrary) — scratch persists
across the sequential KV dimension.

GQA is handled in the index maps: kv block row = (b * n_kv_heads +
q_head // group) — K/V are NOT repeated in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import TPUCompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, block_q: int,
                  block_k: int, n_kv_blocks: int, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # [bq, dh]
    k = k_ref[0].astype(jnp.float32)          # [bk, dh]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                          # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "n_kv_heads",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, n_kv_heads: int, causal: bool = True,
                    window: int = 0, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, S, Hq, Dh]; k/v: [B, S, Hkv, Dh] -> [B, S, Hq, Dh].

    interpret=True validates on CPU (this environment); on a real TPU
    pass interpret=False to compile through Mosaic.
    """
    b, s, hq, dh = q.shape
    skv = k.shape[1]
    group = hq // n_kv_heads
    sm_scale = 1.0 / (dh ** 0.5)

    bq = min(block_q, s)
    bk = min(block_k, skv)
    n_q = -(-s // bq)
    n_k = -(-skv // bk)
    if s % bq or skv % bk:
        raise ValueError(f"seq {s}/{skv} must divide blocks {bq}/{bk}")

    qf = q.swapaxes(1, 2).reshape(b * hq, s, dh)
    kf = k.swapaxes(1, 2).reshape(b * n_kv_heads, skv, dh)
    vf = v.swapaxes(1, 2).reshape(b * n_kv_heads, skv, dh)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // hq) * n_kv_heads + (bh % hq) // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, block_q=bq,
        block_k=bk, n_kv_blocks=n_k, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_map),
            pl.BlockSpec((1, bk, dh), kv_map),
            pl.BlockSpec((1, bk, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, dh).swapaxes(1, 2)
