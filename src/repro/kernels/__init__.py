# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

"""Version-tolerant Pallas TPU shims.

JAX renamed the Pallas TPU compiler-parameter dataclass across releases
(`pltpu.CompilerParams` in newer builds, `pltpu.TPUCompilerParams` in the
0.4.x line this container ships).  All kernels import the name from here
so one shim tracks the rename in both directions.
"""

from jax.experimental.pallas import tpu as _pltpu

# Prefer the 0.4.x name (what this container ships); fall back to the
# newer spelling so the kernels keep working across a JAX upgrade.
TPUCompilerParams = getattr(_pltpu, "TPUCompilerParams", None) \
    or getattr(_pltpu, "CompilerParams")

__all__ = ["TPUCompilerParams"]
