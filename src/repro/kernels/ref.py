"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

MX_BLOCK = 32
QMAX = 127.0


def flash_attention_ref(q, k, v, *, n_kv_heads: int, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """q: [B, S, Hq, Dh]; k/v: [B, Skv, Hkv, Dh] -> [B, S, Hq, Dh]."""
    b, s, hq, dh = q.shape
    skv = k.shape[1]
    g = hq // n_kv_heads
    qf = q.reshape(b, s, n_kv_heads, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(dh))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((s, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, dh).astype(q.dtype)


def decode_attention_ref(q, k, v, t, *, n_kv_heads: int, window: int = 0,
                         ring: bool = False) -> jnp.ndarray:
    """q: [B, Hq, Dh]; cache [B, S, Hkv, Dh]; t scalar -> [B, Hq, Dh]."""
    b, hq, dh = q.shape
    skv = k.shape[1]
    g = hq // n_kv_heads
    qf = q.reshape(b, n_kv_heads, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qf,
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(dh))
    kpos = jnp.arange(skv)
    valid = kpos <= t
    if ring:
        valid = valid | (t >= skv)
    elif window > 0:
        valid &= kpos > t - window
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, dh).astype(q.dtype)


def mx_quantize_ref(x) -> tuple:
    n, d = x.shape
    xb = x.astype(jnp.float32).reshape(n, d // MX_BLOCK, MX_BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    amax = jnp.where(amax == 0, 1.0, amax)
    scale = jnp.exp2(jnp.ceil(jnp.log2(amax / QMAX)))
    q = jnp.clip(jnp.round(xb / scale), -QMAX, QMAX)
    return (q.reshape(n, d).astype(jnp.int8),
            scale[..., 0].astype(jnp.float32))


def mx_dequantize_ref(q, s, dtype=jnp.float32) -> jnp.ndarray:
    n, d = q.shape
    qb = q.astype(jnp.float32).reshape(n, d // MX_BLOCK, MX_BLOCK)
    return (qb * s[..., None]).reshape(n, d).astype(dtype)
