"""Architecture registry: the 10 assigned architectures (+ the paper's
own workload models in paper_models.py).

Usage:  from repro.configs import get_arch, ARCHS
        cfg = get_arch("qwen3-4b")
"""

from repro.models.config import SHAPES, ArchConfig, ShapeConfig

from . import (hymba_1_5b, internlm2_1_8b, llama3_2_1b,
               llama4_scout_17b_a16e, llama_3_2_vision_11b,
               phi3_5_moe_42b_a6_6b, qwen1_5_110b, qwen3_4b,
               seamless_m4t_medium, xlstm_1_3b)

ARCHS: dict[str, ArchConfig] = {
    m.ARCH.name: m.ARCH
    for m in [seamless_m4t_medium, internlm2_1_8b, qwen3_4b, llama3_2_1b,
              qwen1_5_110b, llama4_scout_17b_a16e, phi3_5_moe_42b_a6_6b,
              hymba_1_5b, llama_3_2_vision_11b, xlstm_1_3b]
}

# long_500k sliding window for the hybrid arch (SSM carries long range)
LONG_WINDOWS = {"hymba-1.5b": hymba_1_5b.LONG_CONTEXT_WINDOW}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "needs sub-quadratic attention (full-attention arch)"
    return True, ""
