"""The paper's own evaluation models, as analytical-workload configs
(Section 5.1: LLaMA-3.3-70B, Qwen3-32B; Section 5.4: LLaDA-8B diffusion,
Qwen3.5-397B-A17B MoE)."""

from repro.core.workload import Family, ModelDims

LLAMA33_70B = ModelDims(
    name="llama3.3-70b", family=Family.DENSE, n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672, vocab=128256,
)

QWEN3_32B = ModelDims(
    name="qwen3-32b", family=Family.DENSE, n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=25600, vocab=151936,
    qk_norm=True,
)

LLADA_8B = ModelDims(
    name="llada-8b", family=Family.DLLM, n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, head_dim=128, d_ff=12288, vocab=126464,
    diffusion_steps_per_token=0.25,
)

# 64 experts x 60 layers x 3*5120*6400 ~= 377B total, top-2 active ~17B
QWEN35_397B_A17B = ModelDims(
    name="qwen3.5-397b-a17b", family=Family.MOE, n_layers=60, d_model=5120,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=6400, vocab=152064,
    n_experts=64, top_k=2,
)

PAPER_MODELS = {m.name: m for m in
                [LLAMA33_70B, QWEN3_32B, LLADA_8B, QWEN35_397B_A17B]}
