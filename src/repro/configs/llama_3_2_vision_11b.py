"""llama-3.2-vision-11b [vlm]: cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  Vision frontend is a
stub: inputs include precomputed patch embeddings."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, cross_attn_every=5, cross_len=1600,
    modality="vision", rope_theta=500_000.0,
)
