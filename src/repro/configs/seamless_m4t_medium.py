"""seamless-m4t-medium [audio]: enc-dec multimodal backbone
[arXiv:2308.11596; hf].  Speech frontend is a stub: inputs are
precomputed frame embeddings."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, head_dim=64, d_ff=4096, vocab=256206,
    gated_ffn=False, rope_theta=10_000.0, modality="audio",
    cross_len=4096,
    notes="enc-dec; decoder self+cross attention; audio frontend stubbed",
)
