"""qwen3-4b [dense]: qk_norm + GQA + decoupled head_dim
[hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
)
