"""xlstm-1.3b [ssm]: alternating sLSTM + mLSTM blocks
[arXiv:2405.04517; unverified].  d_ff=0: blocks carry their own
projections.  O(1)/token decode -> runs long_500k."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304, rope_theta=10_000.0,
)
