"""hymba-1.5b [hybrid]: parallel attention + Mamba heads
[arXiv:2411.13676; hf].  long_500k serves with a 2048-token sliding
window on the attention half (SSM carries long-range state)."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, ssm_state=16, rope_theta=10_000.0,
    attn_window=0,   # full attention by default; long_500k overrides
    notes="sliding-window 2048 for long_500k (see launch/dryrun.py)",
)

LONG_CONTEXT_WINDOW = 2048
