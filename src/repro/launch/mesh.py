"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") —
data parallelism across pods (the paper's disaggregated fleets are
replicated groups; `pod` also carries prefill/decode roles in serve.py).

`make_production_mesh` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
