import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, with memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--scan] [--out results.jsonl]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

The first two lines above MUST stay first: jax fixes the device count at
first initialization.  Skipped cells (long_500k on full-attention archs)
are reported as `skip` rows, per DESIGN.md section 4.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, cell_supported, get_arch       # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.specs import build_lowering                   # noqa: E402
from repro.models.config import SHAPES                          # noqa: E402
from repro.roofline import hlo as hlo_mod                       # noqa: E402
from repro.roofline.report import (RooflineCell,                # noqa: E402
                                   model_flops_for_cell)


def run_cell(arch: str, shape: str, multi_pod: bool,
             unroll_layers: bool = True, kv_quant=None,
             extra_opts=None, verbose: bool = True,
             moe_blocks=None, cache_mode: str = "dh",
             microbatches=None, seq_parallel: bool = False) -> dict:
    """Lower+compile one cell; returns the result row (or skip/error)."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_arch(arch)
    ok, why = cell_supported(cfg, SHAPES[shape])
    base = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if not ok:
        return {**base, "status": "skip", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    try:
        spec = build_lowering(arch, shape, mesh,
                              unroll_layers=unroll_layers,
                              kv_quant=kv_quant, extra_opts=extra_opts,
                              moe_blocks=moe_blocks, cache_mode=cache_mode,
                              microbatches=microbatches,
                              seq_parallel=seq_parallel)
        jf = jax.jit(spec.step, out_shardings=spec.out_shardings,
                     donate_argnums=spec.donate)
        with mesh:
            lowered = jf.lower(*spec.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        return {**base, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    text = compiled.as_text()
    totals = hlo_mod.analyze(text)
    n_chips = 512 if multi_pod else 256
    # memory traffic: XLA's fusion-accurate per-device 'bytes accessed'
    # (loop bodies x1) scaled by the text-derived loop amplification.
    # Deeply nested scans (xLSTM's layer x 4096-timestep sLSTM) blow the
    # aggregate-ratio estimator up; clamp and flag (EXPERIMENTS.md notes).
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    amp = totals.mem_amplification()
    mem_bytes = xla_bytes * min(amp, 200.0)
    cell = RooflineCell(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=totals.dot_flops, hlo_bytes=mem_bytes,
        coll_bytes=totals.coll_bytes,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
        model_flops_global=model_flops_for_cell(cfg, SHAPES[shape]),
        arg_bytes=float(mem.argument_size_in_bytes),
        temp_bytes=float(mem.temp_size_in_bytes),
        coll_by_kind=totals.coll_by_kind,
        n_whiles=totals.n_whiles,
    )
    row = {**base, "status": "ok", **cell.row(),
           "coll_by_kind": totals.coll_by_kind,
           "alias_gb_per_dev": mem.alias_size_in_bytes / 1e9,
           "out_gb_per_dev": mem.output_size_in_bytes / 1e9,
           "xla_flops_per_dev": cell.xla_flops,
           "xla_bytes_per_dev": cell.xla_bytes,
           "mem_amp_raw": amp,
           "mem_proxy_clamped": amp > 200.0,
           "n_whiles": totals.n_whiles,
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
    if verbose:
        print(f"[{arch} x {shape} @ {mesh_name}] "
              f"compile={t_compile:.1f}s "
              f"args={mem.argument_size_in_bytes/1e9:.2f}GB/dev "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB/dev "
              f"flops/dev={totals.dot_flops:.3e} "
              f"coll/dev={totals.coll_bytes:.3e}B "
              f"bneck={cell.bottleneck} "
              f"roofline={cell.roofline_fraction:.3f}")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (cell.xla_flops, cell.xla_bytes))
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--scan", action="store_true",
                    help="scan-over-layers lowering (fast; loop-aware "
                         "analysis still applies)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells")
    ap.add_argument("--out", default=None, help="append JSONL results")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rows = []
    for arch, shape in cells:
        for mp in meshes:
            row = run_cell(arch, shape, mp,
                           unroll_layers=not args.scan,
                           kv_quant=args.kv_quant or None)
            rows.append(row)
            if row["status"] == "error":
                print(f"[{arch} x {shape} @ "
                      f"{'2x16x16' if mp else '16x16'}] ERROR: "
                      f"{row['error']}")
            elif row["status"] == "skip":
                print(f"[{arch} x {shape}] SKIP: {row['reason']}")
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(row, sort_keys=True) + "\n")
    n_err = sum(r["status"] == "error" for r in rows)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skip, {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
