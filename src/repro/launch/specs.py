"""Dry-run input specs: ShapeDtypeStruct stand-ins (no allocation) with
shardings for every (arch x shape) step function.

input_specs() covers the assignment's modality stubs: [audio] archs get
precomputed frame embeddings, [vlm] archs get patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import LONG_WINDOWS, cell_supported, get_arch
from repro.models import encdec, hymba, transformer, xlstm
from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.models.transformer import ForwardOptions
from repro.runtime.optim import AdamWConfig, init_opt_state
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step, model_fns)
from repro.sharding.partition import (cache_shardings, input_spec,
                                      param_shardings)

# decoder prompt length used for enc-dec prefill cells (the 32k/500k
# sequence budget belongs to the encoder frames)
ENCDEC_PREFILL_DEC_LEN = 1


def _with_shardings(struct_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, sharding_tree)


def param_structs(cfg: ArchConfig, mesh: Mesh):
    mf = model_fns(cfg)
    shapes = jax.eval_shape(mf.init, jax.random.key(0))
    return _with_shardings(shapes, param_shardings(shapes, mesh))


def opt_structs(cfg: ArchConfig, mesh: Mesh):
    params = jax.eval_shape(model_fns(cfg).init, jax.random.key(0))
    opt = jax.eval_shape(init_opt_state, params)
    from repro.sharding.partition import opt_state_shardings
    return _with_shardings(opt, opt_state_shardings(opt, mesh))


def _sds(mesh: Mesh, shape: tuple, dtype, batch_sharded: bool = True):
    spec = input_spec(mesh, shape[0], len(shape)) if batch_sharded \
        else P(*([None] * len(shape)))
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_structs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    """Training / prefill batch ShapeDtypeStructs (the `input_specs()`
    of the assignment: token ids + stub frame/patch embeddings)."""
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.family == "encdec":
        dec_len = s if shape.kind == "train" else ENCDEC_PREFILL_DEC_LEN
        out["frames"] = _sds(mesh, (b, s, cfg.d_model), cfg.jax_dtype)
        out["tokens"] = _sds(mesh, (b, dec_len), jnp.int32)
        if shape.kind == "train":
            out["targets"] = _sds(mesh, (b, dec_len), jnp.int32)
        return out
    out["tokens"] = _sds(mesh, (b, s), jnp.int32)
    if shape.kind == "train":
        out["targets"] = _sds(mesh, (b, s), jnp.int32)
    if cfg.family == "vlm":
        out["patches"] = _sds(mesh, (b, cfg.cross_len, cfg.d_model),
                              cfg.jax_dtype)
    return out


def cache_structs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """Decode-state ShapeDtypeStructs with cache shardings."""
    b, s = shape.global_batch, shape.seq_len
    window = LONG_WINDOWS.get(cfg.name) if shape.name == "long_500k" else None
    if cfg.family == "hybrid":
        shapes = jax.eval_shape(
            lambda: hymba.empty_cache(cfg, b, s, window))
        return cache_shardings_tree(shapes, mesh)
    if cfg.family == "ssm":
        shapes = jax.eval_shape(lambda: xlstm.empty_cache(cfg, b))
        return cache_shardings_tree(shapes, mesh)
    if cfg.family == "encdec":
        def mk():
            self_cache = encdec.empty_cache(cfg, b, s)
            ck = jnp.zeros((cfg.n_layers, b, cfg.cross_len, cfg.n_kv_heads,
                            cfg.head_dim_), cfg.jax_dtype)
            return {"self": self_cache, "cross_k": ck, "cross_v": ck}
        shapes = jax.eval_shape(mk)
        return cache_shardings_tree(shapes, mesh)
    shapes = jax.eval_shape(lambda: transformer.empty_cache(cfg, b, s))
    return cache_shardings_tree(shapes, mesh)


_CACHE_MODE = "dh"


def cache_shardings_tree(shapes, mesh: Mesh):
    shards = cache_shardings(shapes, mesh, batch_axis=1, mode=_CACHE_MODE)
    return _with_shardings(shapes, shards)


@dataclasses.dataclass
class LoweringSpec:
    """Everything needed to lower one (arch x shape) cell."""

    name: str
    step: Callable
    args: tuple
    donate: tuple
    out_shardings: object


TRAIN_MICROBATCHES = 4   # grad-accumulation chunks for train_4k cells


def build_lowering(arch_name: str, shape_name: str, mesh: Mesh,
                   unroll_layers: bool = False,
                   kv_quant: Optional[bool] = None,
                   extra_opts: Optional[dict] = None,
                   microbatches: Optional[int] = None,
                   moe_blocks: Optional[int] = None,
                   cache_mode: str = "dh",
                   seq_parallel: bool = False) -> LoweringSpec:
    """Construct the jit-able step + ShapeDtypeStruct args for one cell."""
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"cell skipped: {arch_name} x {shape_name}: {why}")
    if kv_quant is not None:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    if moe_blocks is not None:
        cfg = dataclasses.replace(cfg, moe_blocks=moe_blocks)
    global _CACHE_MODE
    _CACHE_MODE = cache_mode
    window = LONG_WINDOWS.get(cfg.name) if shape.name == "long_500k" else None
    from repro.sharding.partition import batch_axes
    opts = ForwardOptions(unroll_layers=unroll_layers,
                          window_override=window,
                          seq_shard_axes=(batch_axes(mesh)
                                          if seq_parallel else None),
                          **(extra_opts or {}))
    params = param_structs(cfg, mesh)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else TRAIN_MICROBATCHES
        step = make_train_step(cfg, AdamWConfig(), opts, microbatches=mb)
        opt = opt_structs(cfg, mesh)
        batch = batch_structs(cfg, shape, mesh)
        out_shardings = (
            repl,
            jax.tree.map(lambda x: x.sharding, params),
            jax.tree.map(lambda x: x.sharding, opt),
            {"grad_norm": repl, "lr": repl},
        )
        return LoweringSpec(
            name=f"{arch_name}|{shape_name}",
            step=step, args=(params, opt, batch),
            donate=(0, 1), out_shardings=out_shardings)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, s_max=shape.seq_len, opts=opts,
                                 window=window)
        batch = batch_structs(cfg, shape, mesh)
        cache_sh = jax.tree.map(lambda x: x.sharding,
                                cache_structs(cfg, shape, mesh))
        logits_sh = NamedSharding(
            mesh, input_spec(mesh, shape.global_batch, 2))
        return LoweringSpec(
            name=f"{arch_name}|{shape_name}",
            step=step, args=(params, batch),
            donate=(), out_shardings=(logits_sh, cache_sh))

    # decode
    step = make_decode_step(cfg, opts)
    cache = cache_structs(cfg, shape, mesh)
    token = _sds(mesh, (shape.global_batch,), jnp.int32)
    t = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
    logits_sh = NamedSharding(mesh, input_spec(mesh, shape.global_batch, 2))
    cache_sh = jax.tree.map(lambda x: x.sharding, cache)
    return LoweringSpec(
        name=f"{arch_name}|{shape_name}",
        step=step, args=(params, cache, token, t),
        donate=(1,), out_shardings=(logits_sh, cache_sh))


