"""Serving launcher: prefill + batched decode for any registered arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --batch 4 --prompt 48 --gen 32 [--kv-quant]

The prefill and decode phases print separate timings — the host-scale
analogue of the paper's PD disaggregation (on a real deployment the two
jits run on different pods; see launch/mesh.py and core/disagg.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.runtime.data import DataConfig, batch_for_step
from repro.runtime.steps import make_decode_step, make_prefill_step, model_fns
from repro.sharding.partition import param_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, vocab=1024)
    if args.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    mesh = make_host_mesh(args.model_parallel)
    mf = model_fns(cfg)
    with mesh:
        params = mf.init(jax.random.key(0))
        params = jax.tree.map(lambda a, s: jax.device_put(a, s), params,
                              param_shardings(params, mesh))

    s_max = args.prompt + args.gen
    prefill = jax.jit(make_prefill_step(cfg, s_max=s_max))
    decode = jax.jit(make_decode_step(cfg))

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt,
                    global_batch=args.batch, seed=0)
    frames = args.prompt if cfg.family == "encdec" else 0
    raw = batch_for_step(dc, 0, with_frames=frames, d_model=cfg.d_model)
    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    if cfg.family == "encdec":
        batch["frames"] = batch["frames"].astype(cfg.jax_dtype)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((args.batch, cfg.cross_len,
                                      cfg.d_model), cfg.jax_dtype)

    print(f"== {cfg.name}{' (reduced)' if args.reduced else ''}: "
          f"batch={args.batch} prompt={args.prompt} gen={args.gen} "
          f"kv_quant={cfg.kv_quant} ==")
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill: {1e3*(time.perf_counter()-t0):.1f} ms "
          f"({args.batch*args.prompt} tokens)")

    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    dec_start = (batch["tokens"].shape[1] if cfg.family != "encdec"
                 else batch["tokens"].shape[1])
    t0 = time.perf_counter()
    for step in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(dec_start + step))
        tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"decode: {args.gen-1} steps, {1e3*dt/(args.gen-1):.1f} ms/step, "
          f"{args.batch*(args.gen-1)/dt:.0f} tok/s aggregate")
    print("sample:", np.stack(out, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
