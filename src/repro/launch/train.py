"""Training launcher: any registered arch on the available devices.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 100 --batch 16 --seq 64 --ckpt-dir /tmp/ck

On real hardware run the FULL config under the production mesh; on this
CPU container use --reduced.  The loop composes the whole runtime:
sharded params (DP x TP), ZeRO-1 moments, microbatching, deterministic
step-indexed data, periodic checkpoints, straggler detection and
retry-with-restore.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import DataConfig, batch_for_step
from repro.runtime.fault import (RetryPolicy, StragglerDetector,
                                 TrainSupervisor)
from repro.runtime.optim import AdamWConfig, init_opt_state
from repro.runtime.steps import make_train_step, model_fns
from repro.sharding.partition import (input_spec, opt_state_shardings,
                                      param_shardings)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, vocab=1024)
    mesh = make_host_mesh(args.model_parallel)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({'reduced' if args.reduced else 'FULL'})")

    mf = model_fns(cfg)
    with mesh:
        params = mf.init(jax.random.key(0))
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, s), params,
            param_shardings(params, mesh))
        opt = init_opt_state(params)
        opt = jax.tree.map(lambda a, s: jax.device_put(a, s), opt,
                           opt_state_shardings(opt, mesh))
    start_step = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            template = jax.eval_shape(lambda: {"params": params, "opt": opt})
            state, start_step = ckpt.restore(args.ckpt_dir, last, template)
            params, opt = state["params"], state["opt"]
            start_step += 1
            print(f"resumed from step {start_step - 1}")

    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=args.lr), microbatches=args.microbatches))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, seed=0)
    state = {"params": params, "opt": opt}

    def save(step):
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, step, state)

    sup = TrainSupervisor(retry=RetryPolicy(), straggler=StragglerDetector(),
                          checkpoint_every=args.ckpt_every,
                          checkpoint_fn=save)
    t0 = time.perf_counter()
    for i in range(start_step, args.steps):
        frames = args.seq if cfg.family == "encdec" else 0
        raw = batch_for_step(dc, i, with_frames=frames, d_model=cfg.d_model)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.family == "encdec":
            batch["frames"] = batch["frames"].astype(cfg.jax_dtype)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((args.batch, cfg.cross_len,
                                          cfg.d_model), cfg.jax_dtype)

        def one(b):
            loss, p2, o2, m = step_fn(state["params"], state["opt"], b)
            state["params"], state["opt"] = p2, o2
            return float(loss), float(m["grad_norm"])

        loss, gnorm = sup.run_step(i, one, batch)
        if i % 10 == 0 or i == args.steps - 1:
            rate = (i - start_step + 1) / (time.perf_counter() - t0)
            print(f"step {i:5d}  loss={loss:7.4f}  gnorm={gnorm:7.3f}  "
                  f"{rate:5.2f} it/s  median={sup.straggler.median()*1e3:.0f}ms")
    print("done.")


if __name__ == "__main__":
    main()
