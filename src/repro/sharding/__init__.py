from .partition import (batch_axes, cache_shardings, cache_spec, input_spec,
                        param_shardings, param_spec, replicated)
