"""Sharding rules: parameter/cache/input PartitionSpecs by leaf name.

Tensor parallelism shards the *merged* projection dims over `model`
(robust to head counts not divisible by the axis, e.g. Hymba's 25
heads); KV caches shard batch over ("pod", "data") and head_dim over
`model` (head-dim TP: dh in {64,128,512} for every assigned arch, all
divisible by 16).  Any dim not divisible by its axis size falls back to
replication — the guard that keeps every (arch x shape x mesh) cell
compiling.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# leaf name -> dim index (negative = from the end) that shards on "model"
_MODEL_DIM_BY_NAME = {
    "wq": -1, "wk": -1, "wv": -1, "wo": -2,
    "bq": -1, "bk": -1, "bv": -1,
    "w_up": -1, "w_gate": -1, "w_down": -2,
    "w_in": -1, "w_out": -2, "w_bc": -2, "w_dt": -2,
    "log_a": -2, "d_skip": -1,
    "w_q": -1, "w_k": -1, "w_v": -1, "w_if": -1, "w_o": -2,
    "r_in": -1,
    "embed": 0, "lm_head": -1,
    "router": None,
    "q_norm": None, "k_norm": None,
    "ln": None, "ln1": None, "ln2": None, "ln_x": None,
    "final_norm": None, "enc_norm": None,
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def param_spec(path, leaf, mesh: Mesh) -> P:
    name = _leaf_name(path)
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if ndim == 0:
        return P()
    # sLSTM blocks are strictly sequential over time: TP-sharding their
    # (small) weights costs a reshard per timestep x seq_len x layers —
    # replicate instead (perf iteration C, EXPERIMENTS.md section Perf)
    path_str = "/".join(str(getattr(e, "key", "")) for e in path)
    if "slstm" in path_str:
        return P(*([None] * ndim))
    dim = _MODEL_DIM_BY_NAME.get(name, "unknown")
    if dim == "unknown":
        # default: shard the last dim if it looks like a projection
        dim = -1 if ndim >= 2 else None
    if dim is None:
        return P(*([None] * ndim))
    axis = dim if dim >= 0 else ndim + dim
    size = leaf.shape[axis]
    model_size = mesh.shape.get("model", 1)
    if size % model_size != 0:
        return P(*([None] * ndim))     # divisibility guard -> replicate
    spec = [None] * ndim
    spec[axis] = "model"
    return P(*spec)


def param_shardings(params, mesh: Mesh):
    """Pytree of NamedShardings matching `params` (works on shapes too)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        params)


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes carrying data parallelism."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def opt_spec(path, leaf, mesh: Mesh) -> P:
    """ZeRO-1: optimizer moments take the parameter sharding PLUS the
    first still-replicated, dp-divisible dim sharded over (pod, data) —
    without this, large-model moments replicate across the whole DP
    group (e.g. 50 GB/device for the 100B MoE)."""
    base = param_spec(path, leaf, mesh)
    axes = batch_axes(mesh)
    if not axes:
        return base
    dp = int(np.prod([mesh.shape[a] for a in axes]))
    ndim = leaf.ndim if hasattr(leaf, "ndim") else np.ndim(leaf)
    spec = list(base) + [None] * (ndim - len(base))
    for d in range(ndim):
        if spec[d] is None and leaf.shape[d] % dp == 0:
            spec[d] = axes
            break
    return P(*spec)


def opt_state_shardings(opt_state, mesh: Mesh):
    """Shardings for the optimizer state: ZeRO-1 for the moment trees,
    replicated step counter."""
    def one(path, leaf):
        ndim = leaf.ndim if hasattr(leaf, "ndim") else np.ndim(leaf)
        if ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, opt_spec(path, leaf, mesh))

    return jax.tree_util.tree_map_with_path(one, opt_state)


def input_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """[B, ...] input: shard batch over (pod, data) when divisible."""
    axes = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % dp == 0:
        return P(axes, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def cache_spec(mesh: Mesh, shape: tuple, batch_axis: int,
               dh_axis: int = -1, mode: str = "dh") -> P:
    """KV-cache / SSM-state sharding: batch over (pod,data) plus one
    model-sharded dim.

    mode="dh": head_dim over model (baseline; works for every arch since
        dh in {64,128,512}).
    mode="seq": the sequence dim (axis batch_axis+1 for [L,B,S,H,Dh]
        buffers) over model — flash-decode style; decode attention then
        reduces partial softmax stats over model instead of resharding
        q/cache per layer (perf iteration B).
    Falls back to dh (then replication) when non-divisible.
    """
    axes = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    model = mesh.shape.get("model", 1)
    spec = [None] * len(shape)
    if axes and shape[batch_axis] % dp == 0:
        spec[batch_axis] = axes
    if mode == "seq" and len(shape) >= batch_axis + 3:
        sa = batch_axis + 1
        if shape[sa] % model == 0:
            spec[sa] = "model"
            return P(*spec)
    da = dh_axis if dh_axis >= 0 else len(shape) + dh_axis
    if shape[da] % model == 0 and da != (batch_axis % len(shape)):
        spec[da] = "model"
    return P(*spec)


def cache_shardings(cache_shapes, mesh: Mesh, batch_axis: int = 1,
                    mode: str = "dh"):
    """Shardings for a stacked cache pytree of ShapeDtypeStructs.

    Leaves: [L, B, S, H, Dh] KV buffers, [L, B, ..., N] SSM states,
    [L, B, S, H, 1] scale tensors.  Batch is axis `batch_axis`; head_dim
    is the last axis (scales replicate on their singleton axis).
    """
    def one(leaf):
        return NamedSharding(mesh, cache_spec(mesh, leaf.shape, batch_axis,
                                              mode=mode))

    return jax.tree.map(one, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
