"""Elastic scaling: remesh a running job onto a different device count.

Scale events (node loss, capacity change) follow checkpoint -> remesh ->
resharded restore: `plan_mesh` factorizes the surviving device count
into (data, model) (pods folded into data), `reshard` device_puts a host
pytree under the new mesh's shardings.  Because the data pipeline is
step-indexed and stateless (runtime/data.py), the resumed trajectory is
deterministic.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding.partition import param_shardings


def plan_mesh(n_devices: int, model_parallel: Optional[int] = None,
              max_model: int = 16) -> tuple:
    """Factorize n_devices -> (data, model); model <= max_model and
    divides the device count (largest power-of-two fit by default)."""
    if model_parallel is not None:
        if n_devices % model_parallel:
            raise ValueError(f"{model_parallel=} !| {n_devices=}")
        return (n_devices // model_parallel, model_parallel)
    model = 1
    while (model * 2 <= max_model and n_devices % (model * 2) == 0):
        model *= 2
    return (n_devices // model, model)


def make_mesh_for(n_devices: int,
                  model_parallel: Optional[int] = None) -> Mesh:
    data, model = plan_mesh(n_devices, model_parallel)
    devs = np.array(jax.devices()[:n_devices]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def reshard(tree, mesh: Mesh):
    """Host/global pytree -> arrays sharded for `mesh` by the standard
    parameter rules."""
    shardings = param_shardings(tree, mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)


def rescale_from_checkpoint(directory: str, step: int, template,
                            new_mesh: Mesh):
    """checkpoint @ old mesh -> live pytree on new mesh."""
    from .checkpoint import restore
    shardings = param_shardings(template, new_mesh)
    return restore(directory, step, template, shardings=shardings)
