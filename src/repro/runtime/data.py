"""Deterministic synthetic data pipeline.

Stateless, step-indexed sampling: batch(step) is a pure function of
(seed, step), so restarting from a checkpoint at step k reproduces the
exact stream without pipeline state — the fault-tolerance property the
trainer relies on.  A Zipfian token marginal + shifted-window structure
give the LM a learnable signal (loss decreases), unlike uniform noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _rng_for_step(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xB10C]))


def batch_for_step(cfg: DataConfig, step: int,
                   with_frames: int = 0, d_model: int = 0) -> dict:
    """{"tokens": [B, S] int32, "targets": [B, S] int32, ...}.

    Target = next token of a structured stream: zipf-distributed tokens
    with a periodic copy pattern (t_i depends on t_{i-1}) so that the
    model can learn and the loss visibly drops.
    """
    rng = _rng_for_step(cfg, step)
    b, s = cfg.global_batch, cfg.seq_len
    base = rng.zipf(cfg.zipf_a, size=(b, s + 1)) % cfg.vocab
    # inject determinism: every 4th token repeats the previous one
    idx = np.arange(s + 1) % 4 == 3
    base[:, idx] = base[:, np.roll(idx, -1)]
    tokens = base[:, :-1].astype(np.int32)
    targets = base[:, 1:].astype(np.int32)
    out = {"tokens": tokens, "targets": targets}
    if with_frames and d_model:
        out["frames"] = rng.standard_normal(
            (b, with_frames, d_model)).astype(np.float32)
    return out


def decode_tokens_for_step(cfg: DataConfig, step: int) -> np.ndarray:
    rng = _rng_for_step(cfg, step)
    return (rng.zipf(cfg.zipf_a, size=(cfg.global_batch,))
            % cfg.vocab).astype(np.int32)
