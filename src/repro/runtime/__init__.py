"""Distributed runtime: steps, optimizer, data, checkpointing, fault
tolerance, elastic rescale, gradient compression."""

from .checkpoint import latest_step, restore, save
from .data import DataConfig, batch_for_step, decode_tokens_for_step
from .elastic import make_mesh_for, plan_mesh, rescale_from_checkpoint, reshard
from .fault import (HeartbeatMonitor, RetryPolicy, StepFailure,
                    StragglerDetector, TrainSupervisor)
from .optim import AdamWConfig, adamw_update, global_norm, init_opt_state
from .steps import (ModelFns, make_decode_step, make_prefill_step,
                    make_train_step, model_fns)
