"""Gradient compression with error feedback (distributed-optimization
trick for DP all-reduce traffic).

int8 symmetric quantization per tensor with an error-feedback residual:
the quantization error of step t is added back into the gradient of
step t+1, preserving convergence (Karimireddy et al.).  8x reduction of
the DP all-reduce payload; off by default, enabled per train run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grad: jnp.ndarray) -> tuple:
    """fp gradient -> (int8 payload, fp scale)."""
    g = grad.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads, error_state) -> tuple:
    """Returns (decompressed grads as seen after all-reduce, new error
    state).  The all-reduce itself is XLA's; in the training step the
    int8 payload is what crosses the DP axis."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compress(corrected)
        deq = decompress(q, scale)
        return deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_grads, new_err
