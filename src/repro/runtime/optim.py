"""AdamW optimizer (hand-rolled: optax is not vendored here).

Moments are fp32 regardless of parameter dtype; update math in fp32 with
a cast back at the end.  Supports global-norm clipping and decoupled
weight decay.  State pytree mirrors params so the parameter sharding
rules apply verbatim.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict:
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state) -> tuple:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = _schedule(cfg, state["step"])

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
