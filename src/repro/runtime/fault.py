"""Fault-tolerance primitives for the training/serving loops.

At thousand-node scale the failure model is: (a) step-level transient
errors (preempted host, flaky interconnect) -> retry with backoff and
restore-from-checkpoint; (b) straggling workers -> detect via step-time
statistics and quarantine; (c) hard node loss -> elastic rescale
(elastic.py) from the last checkpoint.  This module provides the
host-side machinery; it is exercised by unit tests with injected
failures and wired into launch/train.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional


class StepFailure(RuntimeError):
    """A step failed in a retryable way."""


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 0.5
    backoff_factor: float = 2.0
    sleep: Callable[[float], None] = time.sleep

    def run(self, fn: Callable, *args, on_retry: Optional[Callable] = None):
        """Run fn with retries; on_retry(attempt, exc) can restore state."""
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args)
            except StepFailure as exc:
                if attempt == self.max_retries:
                    raise
                if on_retry is not None:
                    on_retry(attempt, exc)
                self.sleep(delay)
                delay *= self.backoff_factor
        raise AssertionError("unreachable")


@dataclasses.dataclass
class StragglerDetector:
    """Flags steps (or workers) whose time exceeds k x rolling median."""

    window: int = 32
    threshold: float = 2.0

    def __post_init__(self):
        self.times = deque(maxlen=self.window)

    def observe(self, seconds: float) -> bool:
        """Record a step time; returns True if it straggles."""
        is_straggler = False
        if len(self.times) >= max(4, self.window // 4):
            med = sorted(self.times)[len(self.times) // 2]
            is_straggler = seconds > self.threshold * med
        self.times.append(seconds)
        return is_straggler

    def median(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks worker heartbeats; quarantines silent/flagged workers.

    In a real deployment heartbeats arrive over RPC; tests and the
    single-process trainer drive `beat()` directly.
    """

    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.last_beat: dict = {}
        self.quarantined: set = set()

    def register(self, worker: str):
        """Start tracking a worker before its first heartbeat.

        Seeds `last_beat` with the registration time, so a worker that
        hangs before ever beating lapses and quarantines like one that
        went silent later — previously such a worker was invisible to
        `check()` forever.  A no-op for already-tracked workers (the
        registration time must not mask a lapsing heartbeat)."""
        if worker not in self.last_beat and worker not in self.quarantined:
            self.last_beat[worker] = self.clock()

    def beat(self, worker: str):
        if worker not in self.quarantined:
            self.last_beat[worker] = self.clock()

    def check(self) -> list:
        """Quarantine workers whose heartbeat lapsed; returns new ones."""
        now = self.clock()
        newly = [w for w, t in self.last_beat.items()
                 if now - t > self.timeout_s and w not in self.quarantined]
        self.quarantined.update(newly)
        return newly

    def quarantine(self, worker: str):
        self.quarantined.add(worker)

    def healthy(self) -> list:
        return [w for w in self.last_beat if w not in self.quarantined]


@dataclasses.dataclass
class TrainSupervisor:
    """Composes retry + straggler detection + periodic checkpointing around
    a step function.  `checkpoint_fn(step)` persists; `restore_fn()` rolls
    back state after a failed step."""

    retry: RetryPolicy
    straggler: StragglerDetector
    checkpoint_every: int = 100
    checkpoint_fn: Optional[Callable] = None
    restore_fn: Optional[Callable] = None
    clock: Callable[[], float] = time.monotonic

    def run_step(self, step: int, fn: Callable, *args):
        def attempt(*a):
            t0 = self.clock()
            out = fn(*a)
            self.straggler.observe(self.clock() - t0)
            return out

        def on_retry(attempt_i, exc):
            if self.restore_fn is not None:
                self.restore_fn()

        out = self.retry.run(attempt, *args, on_retry=on_retry)
        if (self.checkpoint_fn is not None and self.checkpoint_every > 0
                and (step + 1) % self.checkpoint_every == 0):
            self.checkpoint_fn(step)
        return out
