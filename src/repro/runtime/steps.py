"""Family-dispatched step functions: init / train / prefill / decode.

One uniform interface over the four model families so the launcher,
dry-run, serving loop and tests never branch on architecture:

    mf = model_fns(cfg)
    params = mf.init(key)
    loss, params, opt = mf.train_step(params, opt, batch)   (via make_*)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hymba, transformer, xlstm
from repro.models.config import ArchConfig
from repro.models.transformer import ForwardOptions

from .optim import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class ModelFns:
    cfg: ArchConfig
    init: Callable
    loss: Callable          # (params, batch, opts) -> scalar
    prefill: Callable       # (params, batch, s_max, opts) -> (logits, cache)
    decode: Callable        # (params, cache, token, t, opts) -> (logits, cache)


def _batch_inputs(cfg: ArchConfig, batch: dict):
    """The model's prompt input: tokens, or stub embeddings for [audio]."""
    if cfg.family == "encdec":
        return batch["frames"], batch["tokens"]
    return (batch["tokens"],)


def model_fns(cfg: ArchConfig) -> ModelFns:
    if cfg.family == "encdec":
        return ModelFns(
            cfg=cfg,
            init=partial(encdec.init_params, cfg),
            loss=lambda p, b, opts=ForwardOptions(): encdec.loss_fn(
                cfg, p, b["frames"], b["tokens"], b["targets"], opts),
            prefill=lambda p, b, s_max, opts=ForwardOptions():
                encdec.prefill(cfg, p, b["frames"], b["tokens"], s_max, opts),
            decode=lambda p, c, tok, t, opts=ForwardOptions():
                encdec.decode_step(cfg, p, c, tok, t, opts),
        )
    if cfg.family == "hybrid":
        return ModelFns(
            cfg=cfg,
            init=partial(hymba.init_params, cfg),
            loss=lambda p, b, opts=ForwardOptions(): hymba.loss_fn(
                cfg, p, b["tokens"], b["targets"], opts),
            prefill=lambda p, b, s_max, opts=ForwardOptions(), window=None:
                hymba.prefill(cfg, p, b["tokens"], s_max, window, opts),
            decode=lambda p, c, tok, t, opts=ForwardOptions():
                hymba.decode_step(cfg, p, c, tok, t, opts),
        )
    if cfg.family == "ssm":
        return ModelFns(
            cfg=cfg,
            init=partial(xlstm.init_params, cfg),
            loss=lambda p, b, opts=ForwardOptions(): xlstm.loss_fn(
                cfg, p, b["tokens"], b["targets"], opts),
            prefill=lambda p, b, s_max=None, opts=ForwardOptions():
                xlstm.prefill(cfg, p, b["tokens"], opts),
            decode=lambda p, c, tok, t, opts=ForwardOptions():
                xlstm.decode_step(cfg, p, c, tok, t, opts),
        )
    # dense / moe / vlm share the decoder-only implementation
    def _tf_loss(p, b, opts=ForwardOptions()):
        ctx = b.get("patches") if cfg.family == "vlm" else None
        return transformer.loss_fn(cfg, p, b["tokens"], b["targets"],
                                   opts, context=ctx)

    def _tf_prefill(p, b, s_max, opts=ForwardOptions()):
        ctx = b.get("patches") if cfg.family == "vlm" else None
        return transformer.prefill(cfg, p, b["tokens"], s_max,
                                   context=ctx, opts=opts)

    def _tf_decode(p, c, tok, t, opts=ForwardOptions(), ctx=None):
        return transformer.decode_step(cfg, p, c, tok, t, context=ctx,
                                       opts=opts)

    return ModelFns(
        cfg=cfg,
        init=partial(transformer.init_params, cfg),
        loss=_tf_loss,
        prefill=_tf_prefill,
        decode=_tf_decode,
    )


def make_train_step(cfg: ArchConfig, adamw: AdamWConfig = AdamWConfig(),
                    opts: ForwardOptions = ForwardOptions(),
                    microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (loss, params, opt_state, metrics).

    microbatches > 1 accumulates gradients over batch slices under a scan
    (memory relief for the train_4k shapes).
    """
    mf = model_fns(cfg)

    def loss_fn(params, batch):
        return mf.loss(params, batch, opts)

    def step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, mbatch):
                loss_i, g_i = jax.value_and_grad(loss_fn)(params, mbatch)
                loss_a, g_a = carry
                return (loss_a + loss_i,
                        jax.tree.map(jnp.add, g_a, g_i)), ()

            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss_sum, grads), _ = jax.lax.scan(acc, zero, mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params2, opt2, metrics = adamw_update(adamw, params, grads,
                                              opt_state)
        return loss, params2, opt2, metrics

    return step


def make_prefill_step(cfg: ArchConfig, s_max: int,
                      opts: ForwardOptions = ForwardOptions(),
                      window: Optional[int] = None) -> Callable:
    mf = model_fns(cfg)

    def step(params, batch):
        if cfg.family == "hybrid":
            return mf.prefill(params, batch, s_max, opts, window=window)
        if cfg.family == "ssm":
            return mf.prefill(params, batch, None, opts)
        return mf.prefill(params, batch, s_max, opts)

    return step


def make_decode_step(cfg: ArchConfig,
                     opts: ForwardOptions = ForwardOptions()) -> Callable:
    mf = model_fns(cfg)

    def step(params, cache, token, t):
        return mf.decode(params, cache, token, t, opts)

    return step
