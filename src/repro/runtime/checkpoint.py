"""Checkpointing: deterministic msgpack+zstd pytree snapshots.

Layout:  <dir>/step_<k>/
            manifest.json       tree structure, shapes, dtypes, step
            arrays.msgpack.zst  flat arrays by path key
Writes are atomic (tmp dir + rename); `restore` validates shapes/dtypes
against a template pytree, enabling elastic resharding: restored host
arrays are device_put with whatever sharding the *new* mesh prescribes.
Retention keeps the last N steps.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Optional

import jax
import numpy as np


def _codecs():
    """Lazy import of the optional serialization deps.

    `zstandard` and `msgpack` are only needed when checkpoints are
    actually written or read; importing them at module scope would make
    `import repro.runtime` fail on minimal installs.
    """
    try:
        import msgpack
        import zstandard
    except ImportError as e:
        raise ImportError(
            "checkpointing requires the optional 'msgpack' and 'zstandard' "
            "packages; install them to save/restore checkpoints "
            f"(missing: {e.name})") from e
    return msgpack, zstandard


def codecs_available() -> bool:
    """True when the optional checkpoint codecs can be imported."""
    try:
        _codecs()
        return True
    except ImportError:
        return False


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(directory: str, step: int, tree, keep_last: int = 3) -> str:
    """Atomic checkpoint write; returns the final path."""
    msgpack, zstandard = _codecs()
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    packed = msgpack.packb(
        {k: v.tobytes() for k, v in flat.items()}, use_bin_type=True)
    with open(os.path.join(tmp, "arrays.msgpack.zst"), "wb") as f:
        f.write(zstandard.ZstdCompressor(level=3).compress(packed))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep_last)
    return final


def _retain(directory: str, keep_last: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return steps[-1] if steps else None


def restore(directory: str, step: int, template,
            shardings=None):
    """Restore into the structure of `template`; device_put with
    `shardings` (a matching pytree) when given — this is the elastic-
    rescale entry point (same checkpoint, different mesh)."""
    msgpack, zstandard = _codecs()
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "arrays.msgpack.zst"), "rb") as f:
        packed = zstandard.ZstdDecompressor().decompress(f.read())
    raw = msgpack.unpackb(packed, raw=False)

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for tpath, leaf in flat_template:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in tpath)
        meta = manifest["arrays"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = np.frombuffer(raw[key], dtype=np.dtype(meta["dtype"])) \
            .reshape(meta["shape"])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != template "
                f"{want_shape}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(
        treedef.treedef if hasattr(treedef, "treedef") else treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]
