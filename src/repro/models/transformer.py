"""Decoder-only transformer LM (dense / MoE / VLM cross-attention).

Layer-stacked parameters + `jax.lax.scan` keep tracing and compilation
O(1) in depth; `unroll_layers=True` lowers the scan fully unrolled for
exact HLO cost analysis in the dry-run.  The same forward serves:

  * train: full-sequence forward -> mean token cross-entropy
  * prefill: full prompt -> last-token logits + populated KV cache
  * decode: one token against the cache (quantizable int8 KV)

VLM configs (cross_attn_every > 0) scan over GROUPS: each group is
(cross_attn_every - 1) self-attention layers plus one cross-attention
layer attending to the (stub-precomputed) vision/audio embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (AttnSpec, KVQuantizer, attention, attn_init, dense_init,
                     mlp, mlp_init, moe, moe_init, rmsnorm, rmsnorm_init)


def attn_spec(cfg: ArchConfig, window_override: Optional[int] = None,
              causal: bool = True) -> AttnSpec:
    return AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim_, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta,
        window=cfg.attn_window if window_override is None else window_override,
        causal=causal)


@dataclasses.dataclass(frozen=True)
class ForwardOptions:
    unroll_layers: bool = False
    window_override: Optional[int] = None   # e.g. force sliding window
    # sequence parallelism (perf iteration A2): constrain the residual
    # stream to shard its sequence dim over `model` between layers, so
    # XLA lowers TP all-reduces as reduce-scatter + all-gather (half the
    # bytes on the critical dim). Value: the mesh's batch axes tuple.
    seq_shard_axes: Optional[tuple] = None


def _sp_constrain(h, opts):
    if opts.seq_shard_axes is None:
        return h
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        h, P(opts.seq_shard_axes, "model", None))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ArchConfig, key, dtype) -> dict:
    ks = jax.random.split(key, 2)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ks[0], attn_spec(cfg), dtype),
    }
    if cfg.n_experts > 1:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                            dtype, cfg.gated_ffn)
    elif cfg.d_ff > 0:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype,
                            cfg.gated_ffn)
    return p


def _cross_layer_init(cfg: ArchConfig, key, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ks[0], attn_spec(cfg, causal=False), dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.gated_ffn),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    """Stacked-parameter pytree.  jax.eval_shape(init_params, cfg, key)
    yields allocation-free shapes for the dry-run."""
    dtype = cfg.jax_dtype
    k_emb, k_layers, k_head, k_cross = jax.random.split(key, 4)
    params = {
        "embed": dense_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype),
    }
    if cfg.cross_attn_every:
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1
        self_keys = jax.random.split(
            k_layers, n_groups * n_self).reshape(n_groups, n_self)
        params["layers"] = jax.vmap(jax.vmap(
            lambda k: _layer_init(cfg, k, dtype)))(self_keys)
        cross_keys = jax.random.split(k_cross, n_groups)
        params["cross_layers"] = jax.vmap(
            lambda k: _cross_layer_init(cfg, k, dtype))(cross_keys)
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(cfg, k, dtype))(keys)
    return params


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------

def _ffn_apply(cfg: ArchConfig, p: dict, h: jnp.ndarray) -> tuple:
    aux = jnp.float32(0.0)
    if cfg.n_experts > 1:
        out, aux = moe(p["moe"], h, cfg.top_k, dp_blocks=cfg.moe_blocks)
    elif cfg.d_ff > 0:
        out = mlp(p["mlp"], h)
    else:
        return jnp.zeros_like(h), aux
    return out, aux


def _self_layer(cfg: ArchConfig, p: dict, h: jnp.ndarray,
                positions: jnp.ndarray, cache=None, cache_index=None,
                kv_quant=None, window_override=None) -> tuple:
    spec = attn_spec(cfg, window_override)
    a, new_cache = attention(p["attn"], spec, rmsnorm(h, p["ln1"]),
                             positions, kv_cache=cache,
                             cache_index=cache_index, kv_quant=kv_quant)
    h = h + a
    f, aux = _ffn_apply(cfg, p, rmsnorm(h, p["ln2"]))
    return h + f, new_cache, aux


def _cross_layer(cfg: ArchConfig, p: dict, h: jnp.ndarray,
                 context: jnp.ndarray) -> jnp.ndarray:
    spec = attn_spec(cfg, causal=False)
    a, _ = attention(p["attn"], spec, rmsnorm(h, p["ln1"]),
                     positions=jnp.zeros(h.shape[:2], jnp.int32),
                     context=context)
    h = h + a
    return h + mlp(p["mlp"], rmsnorm(h, p["ln2"]))


# ---------------------------------------------------------------------------
# Layer-stack drivers (separate cache / no-cache paths for clarity)
# ---------------------------------------------------------------------------

def _run_layers_nocache(cfg: ArchConfig, params: dict, h: jnp.ndarray,
                        positions: jnp.ndarray, context, opts) -> tuple:
    def body(carry, p):
        hh, aux = carry
        hn, _, aux1 = _self_layer(cfg, p, hh, positions,
                                  window_override=opts.window_override)
        hn = _sp_constrain(hn, opts)
        return (hn, aux + aux1), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body

    if cfg.cross_attn_every:
        def group_body(carry, xs):
            hh, aux = carry
            group_self, group_cross = xs
            (hh, aux), _ = jax.lax.scan(body_fn, (hh, aux), group_self,
                                        unroll=opts.unroll_layers)
            hh = _cross_layer(cfg, group_cross, hh, context)
            return (hh, aux), ()

        gfn = jax.checkpoint(group_body) if cfg.remat else group_body
        (h, aux), _ = jax.lax.scan(
            gfn, (h, jnp.float32(0.0)),
            (params["layers"], params["cross_layers"]),
            unroll=opts.unroll_layers)
        return h, aux

    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.float32(0.0)),
                               params["layers"], unroll=opts.unroll_layers)
    return h, aux


def _run_layers_cached(cfg: ArchConfig, params: dict, h: jnp.ndarray,
                       positions: jnp.ndarray, cache: tuple,
                       cache_index, kv_quant, context, opts) -> tuple:
    ck, cv = cache

    def body(carry, xs):
        hh, aux = carry
        p, lk, lv = xs
        hn, nc, aux1 = _self_layer(cfg, p, hh, positions, cache=(lk, lv),
                                   cache_index=cache_index,
                                   kv_quant=kv_quant,
                                   window_override=opts.window_override)
        return (hn, aux + aux1), nc

    if cfg.cross_attn_every:
        def group_body(carry, xs):
            hh, aux = carry
            group_self, group_cross, gk, gv = xs
            (hh, aux), nc = jax.lax.scan(body, (hh, aux),
                                         (group_self, gk, gv),
                                         unroll=opts.unroll_layers)
            hh = _cross_layer(cfg, group_cross, hh, context)
            return (hh, aux), nc

        (h, aux), new_cache = jax.lax.scan(
            group_body, (h, jnp.float32(0.0)),
            (params["layers"], params["cross_layers"], ck, cv),
            unroll=opts.unroll_layers)
    else:
        (h, aux), new_cache = jax.lax.scan(
            body, (h, jnp.float32(0.0)), (params["layers"], ck, cv),
            unroll=opts.unroll_layers)
    # new_cache is a pytree of stacked (k, v) leaves in body order
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def empty_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None) -> tuple:
    """Stacked KV cache (k, v), each [L, B, S_max, Hkv, Dh] (int8 container
    when cfg.kv_quant)."""
    dtype = dtype or cfg.jax_dtype
    if cfg.cross_attn_every:
        n_groups = cfg.n_layers // cfg.cross_attn_every
        shape = (n_groups, cfg.cross_attn_every - 1, batch, s_max,
                 cfg.n_kv_heads, cfg.head_dim_)
    else:
        shape = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim_)

    def one():
        if cfg.kv_quant:
            return {"q": jnp.zeros(shape, jnp.int8),
                    "scale": jnp.zeros((*shape[:-1], 1), jnp.float32)}
        return jnp.zeros(shape, dtype)

    return (one(), one())


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params: dict, tokens_or_embeds: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None,
            cache: Optional[tuple] = None,
            cache_index: Optional[jnp.ndarray] = None,
            context: Optional[jnp.ndarray] = None,
            opts: ForwardOptions = ForwardOptions(),
            last_token_only: bool = False) -> tuple:
    """Returns (logits, new_cache, aux_loss).

    tokens_or_embeds: int tokens [B, S] or precomputed embeddings
    [B, S, D] (modality frontends are stubs per the assignment).
    """
    if jnp.issubdtype(tokens_or_embeds.dtype, jnp.integer):
        h = params["embed"][tokens_or_embeds]
    else:
        h = tokens_or_embeds
    b, s = h.shape[:2]
    if positions is None:
        base = cache_index if cache_index is not None else 0
        positions = base + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    if cfg.cross_attn_every and context is None:
        # frontend stub: zero vision/audio embeddings (supplied externally
        # in real serving; input_specs() provides them for the dry-run)
        context = jnp.zeros((b, cfg.cross_len, cfg.d_model), h.dtype)

    if cache is None:
        h, aux = _run_layers_nocache(cfg, params, h, positions, context, opts)
        new_cache = None
    else:
        kvq = KVQuantizer(cfg.jax_dtype) if cfg.kv_quant else None
        idx = cache_index if cache_index is not None else jnp.int32(0)
        h, new_cache, aux = _run_layers_cached(
            cfg, params, h, positions, cache, idx, kvq, context, opts)

    h = rmsnorm(h, params["final_norm"])
    if last_token_only:
        h = h[:, -1:, :]
    logits = h @ params["lm_head"]
    return logits, new_cache, aux


def prefill(cfg: ArchConfig, params: dict, tokens_or_embeds: jnp.ndarray,
            s_max: int, context: Optional[jnp.ndarray] = None,
            opts: ForwardOptions = ForwardOptions()) -> tuple:
    """Prompt pass: returns (last_token_logits [B, V], populated cache)."""
    b = tokens_or_embeds.shape[0]
    cache = empty_cache(cfg, b, s_max)
    logits, cache, _ = forward(cfg, params, tokens_or_embeds,
                               cache=cache, cache_index=jnp.int32(0),
                               context=context, opts=opts,
                               last_token_only=True)
    return logits[:, 0], cache


def decode_step(cfg: ArchConfig, params: dict, cache: tuple,
                token: jnp.ndarray, t: jnp.ndarray,
                context: Optional[jnp.ndarray] = None,
                opts: ForwardOptions = ForwardOptions()) -> tuple:
    """One decode step. token: [B] int32; t: scalar current cache length."""
    logits, cache, _ = forward(cfg, params, token[:, None],
                               cache=cache, cache_index=t, context=context,
                               opts=opts, last_token_only=True)
    return logits[:, 0], cache


def loss_fn(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            targets: jnp.ndarray, opts: ForwardOptions = ForwardOptions(),
            context: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross-entropy (padded vocab masked out)."""
    logits, _, aux = forward(cfg, params, tokens, context=context, opts=opts)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + 0.01 * aux
