"""Architecture configuration shared by the JAX models, the launchers and
the analytical core (convertible to core.workload.ModelDims)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | encdec | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    gated_ffn: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE FFN in 1 of every `moe_every` layers
    moe_blocks: int = 1          # DP-block-local dispatch (perf iter A)
    # enc-dec / VLM
    n_encoder_layers: int = 0
    cross_attn_every: int = 0
    cross_len: int = 1024        # encoder/vision sequence length (stub)
    modality: str = "text"       # text | audio | vision
    # SSM / hybrid
    ssm_state: int = 0
    attn_window: int = 0         # sliding window for long-context shapes
    # training/serving knobs
    dtype: str = "bfloat16"
    vocab_align: int = 256
    remat: bool = True
    scan_layers: bool = True
    kv_quant: bool = False       # int8 KV cache serving path
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, self.vocab_align)

    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path available (SSM state / sliding window)."""
        return self.family in ("hybrid", "ssm")

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs decode (enc-dec has a decoder)

    def reduced(self, n_layers: int = 2, d_model: int = 64,
                vocab: int = 512) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(heads, self.n_kv_heads))
        hd = max(8, d_model // heads)
        enc = min(self.n_encoder_layers, n_layers) if self.n_encoder_layers \
            else 0
        cross = 2 if self.cross_attn_every else 0
        nl = n_layers if not self.cross_attn_every else 2 * max(1, cross)
        return dataclasses.replace(
            self,
            n_layers=nl,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=0 if self.d_ff == 0 else d_model * 2,
            vocab=vocab,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_encoder_layers=enc,
            cross_attn_every=cross,
            cross_len=16,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            attn_window=min(self.attn_window, 32) if self.attn_window else 0,
            dtype="float32",
            vocab_align=64,
            remat=False,
        )

    def to_model_dims(self):
        """Adapter to the analytical core's ModelDims."""
        from repro.core.workload import Family, ModelDims
        fam = {"dense": Family.DENSE, "moe": Family.MOE,
               "encdec": Family.ENCDEC, "vlm": Family.VLM,
               "hybrid": Family.HYBRID, "ssm": Family.SSM,
               "dllm": Family.DLLM}[self.family]
        return ModelDims(
            name=self.name, family=fam, n_layers=self.n_layers,
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim_,
            d_ff=self.d_ff, vocab=self.vocab, gated_ffn=self.gated_ffn,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
            n_experts=self.n_experts, top_k=self.top_k,
            n_encoder_layers=self.n_encoder_layers,
            cross_attn_every=self.cross_attn_every, cross_len=self.cross_len,
            ssm_state=self.ssm_state, attn_window=self.attn_window,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
