"""xLSTM LM (arXiv 2405.04517): alternating mLSTM and sLSTM blocks.

mLSTM: matrix-memory linear attention with exponential input gates and
sigmoid forget gates.  We use the chunkwise-parallel formulation
(O(T * d^2), sub-quadratic) — chunk-local quadratic attention plus a
recurrent inter-chunk state [B, H, Dk, Dv], carried by `lax.scan` over
chunks.  Decode is a single fused state update (O(1) per token) — this
is the assignment's long_500k sub-quadratic path.

sLSTM: scalar-memory recurrence per head with exponential gating and a
normalizer/stabilizer state, scanned over time.

d_ff == 0 per the assigned config: blocks carry their own up/down
projections, no separate FFN.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init, rmsnorm, rmsnorm_init
from .transformer import ForwardOptions

CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_init(cfg: ArchConfig, key, dtype) -> dict:
    d, qd = cfg.d_model, cfg.q_dim
    ks = jax.random.split(key, 6)
    return {
        "ln": rmsnorm_init(d, dtype),
        "w_q": dense_init(ks[0], d, qd, dtype),
        "w_k": dense_init(ks[1], d, qd, dtype),
        "w_v": dense_init(ks[2], d, qd, dtype),
        "w_if": dense_init(ks[3], d, 2 * cfg.n_heads, dtype),  # i/f gates
        "w_o": dense_init(ks[4], qd, d, dtype),
        "w_gate": dense_init(ks[5], d, qd, dtype),
    }


def _mlstm_heads(cfg: ArchConfig, p: dict, x: jnp.ndarray):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim_
    q = (x @ p["w_q"]).reshape(b, s, h, dh) / jnp.sqrt(jnp.float32(dh)).astype(x.dtype)
    k = (x @ p["w_k"]).reshape(b, s, h, dh)
    v = (x @ p["w_v"]).reshape(b, s, h, dh)
    gates = (x @ p["w_if"]).astype(jnp.float32).reshape(b, s, h, 2)
    log_f = jax.nn.log_sigmoid(gates[..., 0] + 4.0)     # forget, biased open
    log_i = gates[..., 1] - 4.0                         # exponential input
    return q, k, v, log_f, log_i


def mlstm_forward(cfg: ArchConfig, p: dict, x_in: jnp.ndarray,
                  state: Optional[dict] = None) -> tuple:
    """Chunkwise-parallel mLSTM. x_in: [B, S, D] (pre-norm inside)."""
    x = rmsnorm(x_in, p["ln"])
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim_
    q, k, v, log_f, log_i = _mlstm_heads(cfg, p, x)
    if state is None:
        state = mlstm_empty_state(cfg, b)
    # pad to a whole number of chunks
    pad = (-s) % CHUNK
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
    n_chunks = q.shape[1] // CHUNK

    def split(a):
        return a.reshape(b, n_chunks, CHUNK, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = split(q), split(k), split(v)
    fc, ic = split(log_f), split(log_i)

    def chunk_body(carry, xs):
        S, n = carry                        # S: [B,H,Dk,Dv], n: [B,H,Dk]
        qj, kj, vj, fj, ij = xs             # [B,C,H,*]
        # cumulative forget within chunk (inclusive)
        cf = jnp.cumsum(fj, axis=1)                       # [B,C,H]
        total_f = cf[:, -1]                               # [B,H]
        # decay from chunk start to position t (exclusive of t's own f? use
        # inclusive: state contribution uses product of f_1..f_t)
        decay_in = jnp.exp(cf)                            # [B,C,H]
        # intra-chunk attention: D[t,u] = exp(cf_t - cf_u + i_u), u <= t
        lt = cf[:, :, None, :] - cf[:, None, :, :] + ij[:, None, :, :]
        mask = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        lt = jnp.where(mask[None, :, :, None], lt, -1e30)  # [B,C,C,H]
        w = jnp.exp(jnp.clip(lt, -60.0, 20.0)).astype(qj.dtype)
        scores = jnp.einsum("bthd,buhd->btuh", qj, kj) * w.transpose(
            0, 1, 2, 3)
        intra = jnp.einsum("btuh,buhd->bthd", scores, vj)
        # inter-chunk: q_t decayed against carried state
        inter = jnp.einsum("bthd,bhde->bthe",
                           qj * decay_in[..., None].astype(qj.dtype),
                           S.astype(qj.dtype))
        # normalizer (denominator) for stability
        norm_intra = jnp.einsum("btuh,buhd->bthd", scores,
                                jnp.ones_like(vj))[..., :1]
        norm_inter = jnp.einsum(
            "bthd,bhd->bth", qj * decay_in[..., None].astype(qj.dtype),
            n.astype(qj.dtype))[..., None]
        denom = jnp.maximum(jnp.abs(norm_intra + norm_inter), 1.0)
        out = (intra + inter) / denom
        # state update: S' = f_total * S + sum_u exp(total_f - cf_u + i_u) k_u v_u^T
        g = jnp.exp(jnp.clip(total_f[:, None] - cf + ij, -60.0, 20.0))
        S_new = (jnp.exp(jnp.clip(total_f, -60.0, 20.0))[..., None, None]
                 * S
                 + jnp.einsum("buh,buhd,buhe->bhde",
                              g, kc_cur(kj), vj.astype(jnp.float32)))
        n_new = (jnp.exp(jnp.clip(total_f, -60.0, 20.0))[..., None] * n
                 + jnp.einsum("buh,buhd->bhd", g, kc_cur(kj)))
        return (S_new, n_new), out

    def kc_cur(kj):
        return kj.astype(jnp.float32)

    (S_f, n_f), outs = jax.lax.scan(
        chunk_body, (state["S"], state["n"]), (qc, kc, vc, fc, ic))
    out = outs.swapaxes(0, 1).reshape(b, n_chunks * CHUNK, h, dh)[:, :s]
    out = out.reshape(b, s, h * dh)
    out = out * jax.nn.silu(x @ p["w_gate"])
    return x_in + out @ p["w_o"], {"S": S_f, "n": n_f}


def mlstm_step(cfg: ArchConfig, p: dict, x_in: jnp.ndarray,
               state: dict) -> tuple:
    """O(1) decode update. x_in: [B, 1, D]."""
    x = rmsnorm(x_in, p["ln"])
    q, k, v, log_f, log_i = _mlstm_heads(cfg, p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                   # [B,H,Dh]
    f = jnp.exp(jnp.clip(log_f[:, 0], -60.0, 0.0))        # [B,H]
    i = jnp.exp(jnp.clip(log_i[:, 0], -60.0, 20.0))
    S = (f[..., None, None] * state["S"]
         + jnp.einsum("bh,bhd,bhe->bhde", i, k.astype(jnp.float32),
                      v.astype(jnp.float32)))
    n = f[..., None] * state["n"] + i[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), S)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32),
                                         n))[..., None], 1.0)
    out = (num / den).astype(x.dtype).reshape(x.shape[0], 1, -1)
    out = out * jax.nn.silu(x @ p["w_gate"])
    return x_in + out @ p["w_o"], {"S": S, "n": n}


def mlstm_empty_state(cfg: ArchConfig, batch: int) -> dict:
    h, dh = cfg.n_heads, cfg.head_dim_
    return {"S": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def _slstm_dims(cfg: ArchConfig) -> tuple:
    """(dp, n_heads, head_width): projection factor 1 and block-diagonal
    per-head recurrence (the real sLSTM keeps R head-local)."""
    dp = cfg.d_model
    h = cfg.n_heads
    return dp, h, dp // h


def slstm_init(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    dp, h, hw = _slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    scale = (2.0 / (hw + 4 * hw)) ** 0.5
    r_in = (jax.random.normal(ks[1], (h, hw, 4 * hw), jnp.float32)
            * scale).astype(dtype)
    return {
        "ln": rmsnorm_init(d, dtype),
        "w_in": dense_init(ks[0], d, 4 * dp, dtype),   # z, i, f, o preacts
        "r_in": r_in,                                  # block-diag recurrence
        "w_down": dense_init(ks[2], dp, d, dtype),
    }


def _recurrent_pre(p: dict, h_state, dtype):
    """Block-diagonal recurrent preactivation: [B, dp] -> [B, 4*dp] in
    the z/i/f/o-concatenated layout of w_in."""
    n_h, hw, _ = p["r_in"].shape
    b = h_state.shape[0]
    hh = h_state.astype(dtype).reshape(b, n_h, hw)
    pre = jnp.einsum("bhw,hwf->bhf", hh, p["r_in"])     # [B, H, 4*hw]
    pre = pre.reshape(b, n_h, 4, hw).swapaxes(1, 2).reshape(b, 4 * n_h * hw)
    return pre


def slstm_empty_state(cfg: ArchConfig, batch: int) -> dict:
    dp, _, _ = _slstm_dims(cfg)
    z = jnp.zeros((batch, dp), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def _slstm_cell(pre: jnp.ndarray, st: dict) -> dict:
    """Stabilized sLSTM cell (exponential gating with max-state m)."""
    z, i, f, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f + 1.0)
    m_new = jnp.maximum(log_f + st["m"], i)
    i_e = jnp.exp(i - m_new)
    f_e = jnp.exp(log_f + st["m"] - m_new)
    c = f_e * st["c"] + i_e * jnp.tanh(z)
    n = f_e * st["n"] + i_e
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(cfg: ArchConfig, p: dict, x_in: jnp.ndarray,
                  state: Optional[dict] = None) -> tuple:
    x = rmsnorm(x_in, p["ln"])
    b, s, _ = x.shape
    if state is None:
        state = slstm_empty_state(cfg, b)
    pre_all = x @ p["w_in"]                               # [B,S,4dp]

    def step(st, pre_t):
        pre = pre_t + _recurrent_pre(p, st["h"], x.dtype)
        st2 = _slstm_cell(pre, st)
        return st2, st2["h"]

    state_f, hs = jax.lax.scan(step, state, pre_all.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)                # [B,S,dp]
    return x_in + hs @ p["w_down"], state_f


def slstm_step(cfg: ArchConfig, p: dict, x_in: jnp.ndarray,
               state: dict) -> tuple:
    x = rmsnorm(x_in, p["ln"])
    pre = (x[:, 0] @ p["w_in"]) + _recurrent_pre(p, state["h"], x.dtype)
    st2 = _slstm_cell(pre, state)
    h = st2["h"].astype(x.dtype)[:, None]
    return x_in + h @ p["w_down"], st2


# ---------------------------------------------------------------------------
# Full model: alternating (mLSTM, sLSTM) pairs scanned over depth
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.jax_dtype
    n_pairs = cfg.n_layers // 2
    k_emb, k_m, k_s, k_head = jax.random.split(key, 4)
    mk = jax.random.split(k_m, n_pairs)
    sk = jax.random.split(k_s, n_pairs)
    return {
        "embed": dense_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype),
        "mlstm": jax.vmap(lambda k: mlstm_init(cfg, k, dtype))(mk),
        "slstm": jax.vmap(lambda k: slstm_init(cfg, k, dtype))(sk),
    }


def empty_cache(cfg: ArchConfig, batch: int) -> dict:
    n_pairs = cfg.n_layers // 2
    stack = lambda tree: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_pairs, *x.shape)), tree)
    return {"mlstm": stack(mlstm_empty_state(cfg, batch)),
            "slstm": stack(slstm_empty_state(cfg, batch))}


def forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            cache: Optional[dict] = None,
            opts: ForwardOptions = ForwardOptions(),
            last_token_only: bool = False) -> tuple:
    h = params["embed"][tokens]
    s = h.shape[1]
    single = (s == 1 and cache is not None)

    def body(carry, xs):
        hh = carry
        pm, ps, ms, ss = xs
        if single:
            hh, ms2 = mlstm_step(cfg, pm, hh, ms)
            hh, ss2 = slstm_step(cfg, ps, hh, ss)
        else:
            hh, ms2 = mlstm_forward(cfg, pm, hh, ms)
            hh, ss2 = slstm_forward(cfg, ps, hh, ss)
        return hh, {"mlstm": ms2, "slstm": ss2}

    if cache is None:
        b = h.shape[0]
        cache = empty_cache(cfg, b)
    body_fn = jax.checkpoint(body) if (cfg.remat and not single) else body
    h, new_cache = jax.lax.scan(
        body_fn, h,
        (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]),
        unroll=opts.unroll_layers)
    h = rmsnorm(h, params["final_norm"])
    if last_token_only:
        h = h[:, -1:, :]
    logits = h @ params["lm_head"]
    return logits, new_cache


def loss_fn(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            targets: jnp.ndarray,
            opts: ForwardOptions = ForwardOptions()) -> jnp.ndarray:
    logits, _ = forward(cfg, params, tokens, opts=opts)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            opts: ForwardOptions = ForwardOptions()) -> tuple:
    logits, cache = forward(cfg, params, tokens, cache=None, opts=opts,
                            last_token_only=True)
    return logits[:, 0], cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, t: jnp.ndarray = None,
                opts: ForwardOptions = ForwardOptions()) -> tuple:
    logits, cache = forward(cfg, params, token[:, None], cache=cache,
                            opts=opts, last_token_only=True)
    return logits[:, 0], cache
