"""Hymba-style hybrid LM: every layer runs attention heads and a Mamba
SSM branch IN PARALLEL on the same input, outputs averaged (arXiv
2411.13676's parallel-head design), followed by the FFN.

The SSM branch carries long-range state, so the attention half can use a
sliding window for the `long_500k` shape (window from the config or a
ForwardOptions override) — the sub-quadratic path required by the
assignment.

Serving state per layer = (attention KV cache, SSM state); the KV cache
is window-sized under sliding-window mode.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (KVQuantizer, attention, attn_init, dense_init, mlp,
                     mlp_init, rmsnorm, rmsnorm_init)
from .ssm import ssm_forward, ssm_init, ssm_step
from .transformer import ForwardOptions, attn_spec


def _layer_init(cfg: ArchConfig, key, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ks[0], attn_spec(cfg), dtype),
        "ssm": ssm_init(ks[1], cfg.d_model, cfg.q_dim, cfg.ssm_state, dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.gated_ffn),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.jax_dtype
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "embed": dense_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype),
        "layers": jax.vmap(lambda k: _layer_init(cfg, k, dtype))(keys),
    }


def empty_cache(cfg: ArchConfig, batch: int, s_max: int,
                window: Optional[int] = None) -> dict:
    """(KV cache, SSM state) stacked over layers.  Under sliding-window
    serving the KV buffer only needs `window` slots."""
    dtype = cfg.jax_dtype
    s_kv = min(s_max, window) if window else s_max
    kv_shape = (cfg.n_layers, batch, s_kv, cfg.n_kv_heads, cfg.head_dim_)
    if cfg.kv_quant:
        k = {"q": jnp.zeros(kv_shape, jnp.int8),
             "scale": jnp.zeros((*kv_shape[:-1], 1), jnp.float32)}
        v = {"q": jnp.zeros(kv_shape, jnp.int8),
             "scale": jnp.zeros((*kv_shape[:-1], 1), jnp.float32)}
    else:
        k = jnp.zeros(kv_shape, dtype)
        v = jnp.zeros(kv_shape, dtype)
    ssm_state = jnp.zeros((cfg.n_layers, batch, cfg.q_dim, cfg.ssm_state),
                          jnp.float32)
    return {"k": k, "v": v, "ssm": ssm_state}


def _layer(cfg: ArchConfig, p: dict, h: jnp.ndarray, positions, kv=None,
           ssm_state=None, cache_index=None, kv_quant=None, mask_index=None,
           opts: ForwardOptions = ForwardOptions()) -> tuple:
    spec = attn_spec(cfg, opts.window_override)
    x = rmsnorm(h, p["ln1"])
    a_out, new_kv = attention(p["attn"], spec, x, positions, kv_cache=kv,
                              cache_index=cache_index, kv_quant=kv_quant,
                              mask_index=mask_index)
    if x.shape[1] == 1 and ssm_state is not None:
        s_out, new_state = ssm_step(p["ssm"], x, ssm_state)
    else:
        s_out, new_state = ssm_forward(p["ssm"], x, ssm_state)
    h = h + 0.5 * (a_out + s_out)          # parallel heads, averaged
    h = h + mlp(p["mlp"], rmsnorm(h, p["ln2"]))
    return h, new_kv, new_state


def forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            cache: Optional[dict] = None,
            cache_index: Optional[jnp.ndarray] = None,
            mask_index: Optional[jnp.ndarray] = None,
            opts: ForwardOptions = ForwardOptions(),
            last_token_only: bool = False) -> tuple:
    h = params["embed"][tokens]
    b, s = h.shape[:2]
    base = (mask_index if mask_index is not None
            else cache_index if cache_index is not None else 0)
    positions = base + jnp.arange(s, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))
    kvq = KVQuantizer(cfg.jax_dtype) if (cfg.kv_quant and cache is not None) \
        else None

    if cache is None:
        def body(carry, p):
            hh, aux = carry
            hn, _, _ = _layer(cfg, p, hh, positions, opts=opts)
            return (hn, aux), ()
        body_fn = jax.checkpoint(body) if cfg.remat else body
        (h, _), _ = jax.lax.scan(body_fn, (h, jnp.float32(0.0)),
                                 params["layers"], unroll=opts.unroll_layers)
        new_cache = None
    else:
        def body(carry, xs):
            hh = carry
            p, lk, lv, lstate = xs
            hn, (nk, nv), nstate = _layer(
                cfg, p, hh, positions, kv=(lk, lv), ssm_state=lstate,
                cache_index=cache_index, kv_quant=kvq,
                mask_index=mask_index, opts=opts)
            return hn, {"k": nk, "v": nv, "ssm": nstate}
        h, new_cache = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"], cache["ssm"]),
            unroll=opts.unroll_layers)

    h = rmsnorm(h, params["final_norm"])
    if last_token_only:
        h = h[:, -1:, :]
    logits = h @ params["lm_head"]
    return logits, new_cache


def loss_fn(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            targets: jnp.ndarray,
            opts: ForwardOptions = ForwardOptions()) -> jnp.ndarray:
    logits, _ = forward(cfg, params, tokens, opts=opts)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, s_max: int,
            window: Optional[int] = None,
            opts: ForwardOptions = ForwardOptions()) -> tuple:
    b = tokens.shape[0]
    cache = empty_cache(cfg, b, s_max, window)
    logits, cache = forward(cfg, params, tokens, cache=cache,
                            cache_index=jnp.int32(0), opts=opts,
                            last_token_only=True)
    return logits[:, 0], cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, t: jnp.ndarray,
                opts: ForwardOptions = ForwardOptions()) -> tuple:
    """One decode step.  Under sliding-window serving the cache write
    index wraps modulo the window (ring buffer); the causal mask uses the
    logical position."""
    s_kv = (cache["k"]["q"] if cfg.kv_quant else cache["k"]).shape[2]
    idx = jnp.mod(t, s_kv)
    logits, cache = forward(cfg, params, token[:, None], cache=cache,
                            cache_index=idx, mask_index=t, opts=opts,
                            last_token_only=True)
    return logits[:, 0], cache
