"""Encoder-decoder transformer (Seamless-M4T medium backbone).

[audio] modality: the speech frontend is a STUB per the assignment —
inputs are precomputed frame embeddings [B, S_enc, D].  The text decoder
is standard: self-attention (cached) + cross-attention over the encoder
output + FFN.  Cross-attention K/V are computed once per request at
prefill and reused for every decode step (their own cache).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (KVQuantizer, attention, attn_init, dense_init, mlp,
                     mlp_init, rmsnorm, rmsnorm_init)
from .transformer import ForwardOptions, attn_spec


def _enc_layer_init(cfg: ArchConfig, key, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ks[0], attn_spec(cfg, causal=False), dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.gated_ffn),
    }


def _dec_layer_init(cfg: ArchConfig, key, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln_x": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "self_attn": attn_init(ks[0], attn_spec(cfg), dtype),
        "cross_attn": attn_init(ks[1], attn_spec(cfg, causal=False), dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.gated_ffn),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.jax_dtype
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
    ek = jax.random.split(k_enc, cfg.n_encoder_layers)
    dk = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": dense_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype),
        "encoder": jax.vmap(lambda k: _enc_layer_init(cfg, k, dtype))(ek),
        "decoder": jax.vmap(lambda k: _dec_layer_init(cfg, k, dtype))(dk),
    }


def encode(cfg: ArchConfig, params: dict, frames: jnp.ndarray,
           opts: ForwardOptions = ForwardOptions()) -> jnp.ndarray:
    """Bidirectional encoder over precomputed frame embeddings."""
    spec = attn_spec(cfg, causal=False)
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32)[None, :],
        frames.shape[:2])

    def body(h, p):
        a, _ = attention(p["attn"], spec, rmsnorm(h, p["ln1"]), positions)
        h = h + a
        h = h + mlp(p["mlp"], rmsnorm(h, p["ln2"]))
        return h, ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, frames, params["encoder"],
                        unroll=opts.unroll_layers)
    return rmsnorm(h, params["enc_norm"])


def empty_cache(cfg: ArchConfig, batch: int, s_max: int) -> dict:
    dtype = cfg.jax_dtype
    shape = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim_)

    def one():
        if cfg.kv_quant:
            return {"q": jnp.zeros(shape, jnp.int8),
                    "scale": jnp.zeros((*shape[:-1], 1), jnp.float32)}
        return jnp.zeros(shape, dtype)

    return {"k": one(), "v": one()}


def _cross_kv(cfg: ArchConfig, params: dict, enc_out: jnp.ndarray) -> tuple:
    """Precompute cross-attention K/V for all decoder layers: [L,B,Se,H,D]."""
    b, se, _ = enc_out.shape

    def body(_, p):
        k = (enc_out @ p["cross_attn"]["wk"]).reshape(
            b, se, cfg.n_kv_heads, cfg.head_dim_)
        v = (enc_out @ p["cross_attn"]["wv"]).reshape(
            b, se, cfg.n_kv_heads, cfg.head_dim_)
        return (), (k, v)

    _, (ks, vs) = jax.lax.scan(body, (), params["decoder"])
    return ks, vs


def _decoder_pass(cfg: ArchConfig, params: dict, h: jnp.ndarray,
                  positions, cross_k, cross_v, cache=None, cache_index=None,
                  opts: ForwardOptions = ForwardOptions()) -> tuple:
    spec = attn_spec(cfg)
    spec_x = attn_spec(cfg, causal=False)
    kvq = KVQuantizer(cfg.jax_dtype) if (cfg.kv_quant and cache is not None) \
        else None
    from .layers import sdpa

    def body(carry, xs):
        hh = carry
        p, ck, cv, lk, lv = xs
        a, new_kv = attention(p["self_attn"], spec, rmsnorm(hh, p["ln1"]),
                              positions, kv_cache=(lk, lv) if lk is not None
                              else None,
                              cache_index=cache_index, kv_quant=kvq)
        hh = hh + a
        # cross attention against precomputed K/V
        xq = rmsnorm(hh, p["ln_x"])
        b, s, _ = xq.shape
        q = (xq @ p["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads,
                                                 cfg.head_dim_)
        xo = sdpa(q, ck, cv, None, cfg.n_heads // cfg.n_kv_heads)
        hh = hh + xo.reshape(b, s, -1) @ p["cross_attn"]["wo"]
        hh = hh + mlp(p["mlp"], rmsnorm(hh, p["ln2"]))
        return hh, new_kv

    if cache is None:
        def nb(carry, xs):
            p, ck, cv = xs
            hh, _ = body(carry, (p, ck, cv, None, None))
            return hh, ()
        nb_fn = jax.checkpoint(nb) if cfg.remat else nb
        h, _ = jax.lax.scan(nb_fn, h,
                            (params["decoder"], cross_k, cross_v),
                            unroll=opts.unroll_layers)
        return h, None
    h, new_cache = jax.lax.scan(
        body, h, (params["decoder"], cross_k, cross_v,
                  cache["k"], cache["v"]),
        unroll=opts.unroll_layers)
    return h, {"k": new_cache[0], "v": new_cache[1]}


def forward(cfg: ArchConfig, params: dict, frames: jnp.ndarray,
            tokens: jnp.ndarray, cache: Optional[dict] = None,
            cache_index: Optional[jnp.ndarray] = None,
            opts: ForwardOptions = ForwardOptions(),
            last_token_only: bool = False) -> tuple:
    """Teacher-forced enc-dec forward (training)."""
    enc_out = encode(cfg, params, frames, opts)
    cross_k, cross_v = _cross_kv(cfg, params, enc_out)
    h = params["embed"][tokens]
    b, s = h.shape[:2]
    base = cache_index if cache_index is not None else 0
    positions = jnp.broadcast_to(
        base + jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    h, new_cache = _decoder_pass(cfg, params, h, positions, cross_k, cross_v,
                                 cache=cache, cache_index=cache_index,
                                 opts=opts)
    h = rmsnorm(h, params["final_norm"])
    if last_token_only:
        h = h[:, -1:, :]
    return h @ params["lm_head"], new_cache


def loss_fn(cfg: ArchConfig, params: dict, frames: jnp.ndarray,
            tokens: jnp.ndarray, targets: jnp.ndarray,
            opts: ForwardOptions = ForwardOptions()) -> jnp.ndarray:
    logits, _ = forward(cfg, params, frames, tokens, opts=opts)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ArchConfig, params: dict, frames: jnp.ndarray,
            tokens: jnp.ndarray, s_max: int,
            opts: ForwardOptions = ForwardOptions()) -> tuple:
    """Encode + teacher-forced prompt pass.  Returns (last logits, state)
    where state carries the self-attn cache AND the cross-K/V cache."""
    enc_out = encode(cfg, params, frames, opts)
    cross_k, cross_v = _cross_kv(cfg, params, enc_out)
    b, s = tokens.shape
    cache = empty_cache(cfg, b, s_max)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :],
                                 (b, s))
    h = params["embed"][tokens]
    h, cache = _decoder_pass(cfg, params, h, positions, cross_k, cross_v,
                             cache=cache, cache_index=jnp.int32(0), opts=opts)
    h = rmsnorm(h[:, -1:], params["final_norm"])
    logits = h @ params["lm_head"]
    return logits[:, 0], {"self": cache, "cross_k": cross_k,
                          "cross_v": cross_v}


def decode_step(cfg: ArchConfig, params: dict, state: dict,
                token: jnp.ndarray, t: jnp.ndarray,
                opts: ForwardOptions = ForwardOptions()) -> tuple:
    b = token.shape[0]
    h = params["embed"][token[:, None]]
    positions = jnp.broadcast_to(t + jnp.zeros((b, 1), jnp.int32), (b, 1))
    h, cache = _decoder_pass(cfg, params, h, positions,
                             state["cross_k"], state["cross_v"],
                             cache=state["self"], cache_index=t, opts=opts)
    h = rmsnorm(h, params["final_norm"])
    logits = h @ params["lm_head"]
    return logits[:, 0], {"self": cache, "cross_k": state["cross_k"],
                          "cross_v": state["cross_v"]}
