"""JAX model zoo: shared layers + the four family implementations."""
