"""Shared transformer building blocks (pure JAX, functional style).

All layer functions operate on UNSTACKED single-layer parameter dicts;
models stack parameters along a leading layer axis and drive these
functions through `jax.lax.scan`.  Initializers mirror the forward
structure so `jax.eval_shape(init, ...)` yields allocation-free
ShapeDtypeStructs for the multi-pod dry-run.

Attention covers every assigned-architecture variant through flags:
GQA (n_kv_heads < n_heads), decoupled head_dim (Qwen3), per-head q/k
RMSNorm (Qwen3), QKV bias (Qwen1.5-110B), sliding windows (Hymba long
context), cross-attention (Seamless decoder / Llama-3.2-Vision), and a
quantizable KV cache (int8 + per-block scales, the paper's KV-precision
axis as a real serving feature).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

DType = jnp.dtype


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Normalization / rotary embedding
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [B, S, H, Dh]; positions: [B, S] (int)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs    # [B, S, Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: int = 0              # 0 = full attention
    causal: bool = True
    rope: bool = True

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def attn_init(key, spec: AttnSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], spec.d_model, spec.q_dim, dtype),
        "wk": dense_init(ks[1], spec.d_model, spec.kv_dim, dtype),
        "wv": dense_init(ks[2], spec.d_model, spec.kv_dim, dtype),
        "wo": dense_init(ks[3], spec.q_dim, spec.d_model, dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((spec.q_dim,), dtype)
        p["bk"] = jnp.zeros((spec.kv_dim,), dtype)
        p["bv"] = jnp.zeros((spec.kv_dim,), dtype)
    if spec.qk_norm:
        p["q_norm"] = rmsnorm_init(spec.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(spec.head_dim, dtype)
    return p


def _split_heads(x: jnp.ndarray, n: int, dh: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask: Optional[jnp.ndarray], n_rep: int) -> jnp.ndarray:
    """Grouped scaled-dot-product attention.

    q: [B, Sq, Hq, Dh]; k/v: [B, Skv, Hkv, Dh]; mask: [B, 1, Sq, Skv] bool
    (True = attend) or None.  Returns [B, Sq, Hq, Dh].
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, sq, hkv, n_rep, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    if mask is not None:
        scores = jnp.where(mask[:, :, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


Q_CHUNK = 512          # query-chunk size for the memory-sane SDPA path
CHUNKED_THRESHOLD = 2048   # q_len at which attention switches to chunking


def sdpa_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 n_rep: int, q_start, *, causal: bool,
                 window: int = 0, ring_full: bool = False) -> jnp.ndarray:
    """Query-chunked attention: scan over q chunks so the live score tile
    is [B, Hq, q_chunk, Skv] instead of [B, Hq, Sq, Skv].

    This is the XLA-level analogue of flash attention's on-chip tiling
    (the Pallas kernel in repro/kernels is the TPU-native version; this
    path keeps dry-run memory analysis faithful for 32k-500k sequences).

    q_start: absolute position of q[0] (int or traced scalar).
    ring_full: sliding-window ring buffer where every K slot is valid.
    """
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    qc = min(Q_CHUNK, sq)
    pad = (-sq) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = q.shape[1] // qc
    qs = q.reshape(b, n_chunks, qc, hq, dh).swapaxes(0, 1)
    kpos = jnp.arange(skv)

    # flash-style remat: probabilities are recomputed in the backward
    # pass instead of stashing an [B, H, qc, Skv] residual per chunk
    @jax.checkpoint
    def chunk(carry, xs):
        qj, j = xs
        qpos = q_start + j * qc + jnp.arange(qc)
        if causal and not ring_full:
            m = kpos[None, :] <= qpos[:, None]
            if window > 0:
                m &= kpos[None, :] > qpos[:, None] - window
        elif ring_full:
            m = (kpos[None, :] <= qpos[:, None]) | (qpos[:, None] >= skv)
        else:
            m = jnp.ones((qc, skv), bool)
        out = sdpa(qj, k, v, m[None, None], n_rep)
        return carry, out

    _, outs = jax.lax.scan(chunk, (),
                           (qs, jnp.arange(n_chunks)))
    out = outs.swapaxes(0, 1).reshape(b, n_chunks * qc, hq, dh)
    return out[:, :sq]


def causal_mask(sq: int, skv: int, window: int = 0,
                offset: int = 0) -> jnp.ndarray:
    """[1, 1, sq, skv] boolean mask; offset = absolute position of query 0."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None]


def attention(params: dict, spec: AttnSpec, x: jnp.ndarray,
              positions: jnp.ndarray,
              kv_cache: Optional[tuple] = None,
              cache_index: Optional[jnp.ndarray] = None,
              kv_quant: Optional["KVQuantizer"] = None,
              context: Optional[jnp.ndarray] = None,
              mask_index: Optional[jnp.ndarray] = None) -> tuple:
    """Self- or cross-attention with optional KV cache.

    x: [B, S, D].  context: [B, Sc, D] for cross-attention (no cache
    update, no causal mask).  kv_cache: (k, v) stacked buffers
    [B, S_max, Hkv, Dh] (possibly quantized containers).  cache_index:
    scalar write offset.  mask_index: logical position used for the
    causal mask when it differs from the physical write offset (ring-
    buffer sliding-window serving).  Returns (out, new_cache or None).
    """
    b, s, _ = x.shape
    q = x @ params["wq"]
    if spec.qkv_bias:
        q = q + params["bq"]
    q = _split_heads(q, spec.n_heads, spec.head_dim)

    n_rep = spec.n_heads // spec.n_kv_heads
    if context is not None:
        k = _split_heads(context @ params["wk"], spec.n_kv_heads,
                         spec.head_dim)
        v = _split_heads(context @ params["wv"], spec.n_kv_heads,
                         spec.head_dim)
        if spec.qk_norm:
            q = rmsnorm(q, params["q_norm"])
            k = rmsnorm(k, params["k_norm"])
        if s >= CHUNKED_THRESHOLD:
            out = sdpa_chunked(q, k, v, n_rep, 0, causal=False)
        else:
            out = sdpa(q, k, v, None, n_rep)
        return out.reshape(b, s, -1) @ params["wo"], None

    k = _split_heads(x @ params["wk"] + (params["bk"] if spec.qkv_bias else 0),
                     spec.n_kv_heads, spec.head_dim)
    v = _split_heads(x @ params["wv"] + (params["bv"] if spec.qkv_bias else 0),
                     spec.n_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    if spec.rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)

    if kv_cache is None:
        if s >= CHUNKED_THRESHOLD:
            out = sdpa_chunked(q, k, v, n_rep, 0, causal=spec.causal,
                               window=spec.window)
        else:
            mask = causal_mask(s, s, spec.window) if spec.causal else None
            out = sdpa(q, k, v, mask, n_rep)
        return out.reshape(b, s, -1) @ params["wo"], (k, v)

    # cached decode / chunked prefill: write new K/V at cache_index
    ck, cv = kv_cache

    def update(cache, new):
        if kv_quant is not None:
            nq = kv_quant.quantize(new)
            return {
                "q": jax.lax.dynamic_update_slice_in_dim(
                    cache["q"], nq["q"], cache_index, axis=1),
                "scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["scale"], nq["scale"], cache_index, axis=1),
            }
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), cache_index, axis=1)

    ck = update(ck, k)
    cv = update(cv, v)
    k_full = (kv_quant.dequantize(ck) if kv_quant is not None else ck)
    v_full = (kv_quant.dequantize(cv) if kv_quant is not None else cv)
    s_max = k_full.shape[1]
    logical = cache_index if mask_index is None else mask_index
    if s >= CHUNKED_THRESHOLD:
        out = sdpa_chunked(q, k_full.astype(q.dtype),
                           v_full.astype(q.dtype), n_rep, logical,
                           causal=True, window=spec.window,
                           ring_full=mask_index is not None)
    else:
        kpos = jnp.arange(s_max)[None, :]
        qpos = logical + jnp.arange(s)[:, None]
        m = (kpos[None] <= qpos[None])            # [1, sq, s_max]
        if mask_index is not None:
            # ring buffer: once wrapped, every physical slot is in-window
            m = m | (qpos[None] >= s_max)
        elif spec.window > 0:
            m = m & (kpos[None] > qpos[None] - spec.window)
        out = sdpa(q, k_full.astype(q.dtype), v_full.astype(q.dtype),
                   m[:, None], n_rep)
    return out.reshape(b, s, -1) @ params["wo"], (ck, cv)


# ---------------------------------------------------------------------------
# Quantized KV cache (the paper's KV-precision axis as a serving feature)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVQuantizer:
    """Symmetric int8 KV quantization with per-(token, head) scales."""

    dtype: DType = jnp.bfloat16

    def quantize(self, x: jnp.ndarray) -> dict:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        scale = jnp.where(amax == 0, 1.0, amax / 127.0)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return {"q": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}

    def dequantize(self, c) -> jnp.ndarray:
        if isinstance(c, dict):
            return (c["q"].astype(jnp.float32) * c["scale"]).astype(self.dtype)
        return c

    def empty(self, shape, dtype=None) -> dict:
        return {"q": jnp.zeros(shape, jnp.int8),
                "scale": jnp.zeros((*shape[:-1], 1), jnp.float32)}


# ---------------------------------------------------------------------------
# Feed-forward: dense (gated / plain) and Mixture-of-Experts
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = jax.nn.silu(x @ params["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ params["w_down"]


def moe_init(key, d_model: int, d_ff: int, n_experts: int, dtype,
             gated: bool = True) -> dict:
    ks = jax.random.split(key, 4)
    scale = (2.0 / (d_model + d_ff)) ** 0.5

    def ew(k, a, b):
        return (jax.random.normal(k, (n_experts, a, b), jnp.float32)
                * scale).astype(dtype)

    p = {"router": dense_init(ks[0], d_model, n_experts, dtype),
         "w_up": ew(ks[1], d_model, d_ff),
         "w_down": ew(ks[2], d_ff, d_model)}
    if gated:
        p["w_gate"] = ew(ks[3], d_model, d_ff)
    return p


def _moe_tokens(params: dict, tokens: jnp.ndarray, top_k: int,
                capacity_factor: float) -> tuple:
    """GShard-style capacity dispatch for a flat token chunk [T, D]."""
    t, d = tokens.shape
    n_exp = params["router"].shape[-1]
    logits = (tokens @ params["router"]).astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    # capacity floor min(T, 64) makes small chunks (decode steps) dropless
    cap = max(1, int(capacity_factor * t * top_k / n_exp), min(t, 64))

    gates, picks = jax.lax.top_k(probs, top_k)                 # [T, k]
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    dispatch = jnp.zeros((t, n_exp, cap), tokens.dtype)
    combine = jnp.zeros((t, n_exp, cap), jnp.float32)
    base = jnp.zeros((n_exp,), jnp.int32)    # slots used by earlier ranks
    for slot in range(top_k):
        e = picks[:, slot]                                     # [T]
        onehot = jax.nn.one_hot(e, n_exp, dtype=jnp.int32)     # [T, E]
        rank = jnp.cumsum(onehot, axis=0) * onehot             # 1-based
        pos_t = jnp.sum((rank + base[None, :] - 1) * onehot, axis=1)
        keep = (pos_t < cap) & (pos_t >= 0)
        oh_cap = jax.nn.one_hot(pos_t, cap) * keep[:, None]
        upd = onehot[:, :, None] * oh_cap[:, None, :]
        dispatch = dispatch + upd.astype(tokens.dtype)
        combine = combine + upd * gates[:, slot][:, None, None]
        base = base + jnp.sum(onehot, axis=0)

    xe = jnp.einsum("td,tec->ecd", tokens, dispatch)           # [E, C, D]
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    if "w_gate" in params:
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                    params["w_gate"])) * up
    else:
        up = jax.nn.gelu(up)
    ye = jnp.einsum("ecf,efd->ecd", up, params["w_down"])      # [E, C, D]
    out = jnp.einsum("ecd,tec->td", ye, combine.astype(ye.dtype))

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(picks[:, 0], n_exp), axis=0)
    aux = n_exp * jnp.sum(me * ce)
    return out.astype(tokens.dtype), aux


# Bound on tokens per dispatch chunk: the [T, E, C] dispatch tensor is
# O(T^2 k / E); chunking the sequence keeps it ~O(T_MAX^2) regardless of
# global batch (the chunks run under lax.scan, so peak memory is 1 chunk).
MOE_CHUNK_TOKENS = 16_384


def moe(params: dict, x: jnp.ndarray, top_k: int,
        capacity_factor: float = 1.25, dp_blocks: int = 1) -> tuple:
    """Capacity-based MoE over [B, S, D], sequence-chunked (see above).

    Tokens beyond an expert's capacity are dropped (residual passes
    through), keeping compute at tokens * top_k * expert_ffn — the
    paper's N_active accounting.

    dp_blocks > 1 (perf iteration A): tokens are dispatched in
    `dp_blocks` independent blocks matching the data-parallel sharding,
    via vmap over a leading block axis.  The dispatch/combine einsums
    then contract within a block instead of across the token-sharded
    dim, removing the [E, C, D] partial-sum all-reduce across the DP
    axis that dominates MoE training collectives.
    """
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    total = b * s

    if dp_blocks > 1 and total % dp_blocks == 0 \
            and (total // dp_blocks) % 128 == 0:
        blocks = tokens.reshape(dp_blocks, total // dp_blocks, d)

        @jax.checkpoint
        def one_block(blk):
            per = blk.shape[0]
            if per > MOE_CHUNK_TOKENS and per % MOE_CHUNK_TOKENS == 0:
                chunks = blk.reshape(per // MOE_CHUNK_TOKENS,
                                     MOE_CHUNK_TOKENS, d)

                def body(carry, chunk):
                    o, a = _moe_tokens(params, chunk, top_k,
                                       capacity_factor)
                    return carry + a, o

                a, outs = jax.lax.scan(body, jnp.float32(0.0), chunks)
                return outs.reshape(per, d), a
            return _moe_tokens(params, blk, top_k, capacity_factor)

        outs, auxs = jax.vmap(one_block)(blocks)
        return (outs.reshape(b, s, d), jnp.mean(auxs))

    if total <= MOE_CHUNK_TOKENS:
        out, aux = _moe_tokens(params, tokens, top_k, capacity_factor)
        return out.reshape(b, s, d), aux
    # pad to a whole number of chunks, scan over them
    n_chunks = -(-total // MOE_CHUNK_TOKENS)
    pad = n_chunks * MOE_CHUNK_TOKENS - total
    padded = jnp.pad(tokens, ((0, pad), (0, 0)))
    chunks = padded.reshape(n_chunks, MOE_CHUNK_TOKENS, d)

    # remat the dispatch: the [T, E, C] one-hot tensors are recomputed in
    # the backward pass instead of being saved per chunk (without this,
    # grad-of-scan stashes ~C x tokens x E residuals per layer)
    @jax.checkpoint
    def body(carry, chunk):
        out, aux = _moe_tokens(params, chunk, top_k, capacity_factor)
        return carry + aux, out

    aux_sum, outs = jax.lax.scan(body, jnp.float32(0.0), chunks)
    out = outs.reshape(n_chunks * MOE_CHUNK_TOKENS, d)[:total]
    return out.reshape(b, s, d), aux_sum / n_chunks
