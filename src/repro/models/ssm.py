"""Mamba-style selective SSM branch (for the Hymba hybrid architecture).

Linear time-varying recurrence  h_t = a_t * h_{t-1} + b_t  evaluated with
`jax.lax.associative_scan` (parallel prefix) for sequence inputs and a
single fused update for decode.  State: [B, d_inner, ssm_state].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def ssm_init(key, d_model: int, d_inner: int, state: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "w_out": dense_init(ks[1], d_inner, d_model, dtype),
        "w_bc": dense_init(ks[2], d_inner, 2 * state, dtype),
        "w_dt": dense_init(ks[3], d_inner, 1, dtype),
        # log-spaced stable decay rates (S4/Mamba init)
        "log_a": jnp.log(jnp.linspace(1.0, float(state), state))[None, :]
        .astype(jnp.float32) * jnp.ones((d_inner, 1), jnp.float32),
        "d_skip": jnp.ones((d_inner,), dtype),
    }


def _gates(params: dict, x_in: jnp.ndarray):
    """x_in: [..., d_inner] -> (a [..., d_inner, N], bu, c)."""
    bc = x_in @ params["w_bc"]
    b, c = jnp.split(bc, 2, axis=-1)                       # [..., N]
    dt = jax.nn.softplus((x_in @ params["w_dt"]))          # [..., 1]
    a = jnp.exp(-dt[..., None] * jnp.exp(params["log_a"])
                .astype(jnp.float32))                      # [..., d, N]
    bu = (dt * x_in)[..., None] * b[..., None, :]          # [..., d, N]
    return a, bu, c


def ssm_forward(params: dict, x: jnp.ndarray,
                state: jnp.ndarray = None) -> tuple:
    """x: [B, S, D] -> ([B, S, D], final_state [B, d_inner, N])."""
    bsz, s, _ = x.shape
    xz = x @ params["w_in"]
    x_in, z = jnp.split(xz, 2, axis=-1)                    # [B, S, d]
    x_in = jax.nn.silu(x_in)
    a, bu, c = _gates(params, x_in)                        # [B,S,d,N]
    a = a.astype(jnp.float32)
    bu = bu.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((bsz, a.shape[2], a.shape[3]), jnp.float32)
    # prepend the carried state as step 0: h_0' = state (a=1)
    a_full = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    bu_full = jnp.concatenate([state[:, None], bu], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a_full, bu_full), axis=1)
    h = h[:, 1:]                                           # [B,S,d,N]
    y = jnp.einsum("bsdn,bsn->bsd", h, c.astype(jnp.float32))
    y = y.astype(x.dtype) + x_in * params["d_skip"]
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], h[:, -1]


def ssm_step(params: dict, x: jnp.ndarray, state: jnp.ndarray) -> tuple:
    """One decode step: x [B, 1, D], state [B, d_inner, N]."""
    xz = x @ params["w_in"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = jax.nn.silu(x_in)
    a, bu, c = _gates(params, x_in[:, 0])                  # [B,d,N]
    new_state = a.astype(jnp.float32) * state + bu.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", new_state, c.astype(jnp.float32))
    y = y.astype(x.dtype) + x_in[:, 0] * params["d_skip"]
    y = y * jax.nn.silu(z[:, 0])
    return (y @ params["w_out"])[:, None], new_state
