"""Loop-aware HLO text analysis for the roofline terms.

XLA's HloCostAnalysis counts while-loop bodies ONCE (verified in this
environment); our models keep inner scans even when the layer stack is
unrolled (sLSTM over time, MoE dispatch chunks, chunked attention).
This module parses compiled HLO text, builds the computation call graph,
extracts per-computation dot-FLOPs / memory-traffic proxy / collective
bytes, and multiplies while bodies by their trip counts.

Facts the parser relies on (verified against this XLA version):
  * instruction operands are referenced by %name; shapes come from a
    per-computation symbol table (SSA order: defs precede uses);
  * while ops carry backend_config={"known_trip_count":{"n":"N"}}
    (fallback: the max integer constant in the condition computation);
  * fusion interiors live in separate computations reached via
    `calls=`; we count fusions at the call site (operands + result
    bytes) and do NOT walk into them;
  * memory traffic proxy = operand + result buffer bytes of every
    top-level op except layout/tuple plumbing — an upper-bound HBM
    proxy given XLA's fusion boundaries.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "iota"}
# tuple result types may embed /*index=k*/ comments (which contain '=');
# they never contain parentheses, so `\([^()]*\)` spans them safely.
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{$")


def shape_bytes(text: str) -> float:
    """Sum buffer bytes of every `dtype[dims]` shape literal in text."""
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)    # walked x1
    whiles: list = dataclasses.field(default_factory=list)   # (body, trips)
    max_constant: int = 0


def _matching_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def parse_hlo(text: str) -> tuple:
    """-> (comps dict, entry_name)."""
    comps: dict[str, CompStats] = {}
    symbols: dict[str, str] = {}
    current: Optional[str] = None
    entry_name = None
    cond_consts: dict[str, int] = {}

    for raw in text.splitlines():
        line = raw.strip()
        if line.endswith("{") and "->" in line:
            h = _HEADER_RE.match(line)
            if h:
                current = h.group(2)
                comps[current] = CompStats()
                symbols = {}
                if h.group(1):
                    entry_name = current
                continue
        if current is None:
            continue
        if line.startswith("}"):
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, result_type, opcode = im.groups()
        symbols[name] = result_type
        st = comps[current]
        for c in re.finditer(r"constant\((\d+)\)", line):
            st.max_constant = max(st.max_constant, int(c.group(1)))
        # operand list: between the opcode's paren and its match
        op_start = im.end() - 1
        op_end = _matching_paren(line, op_start)
        operands = re.findall(r"%([\w\.\-]+)", line[op_start:op_end])
        tail = line[op_end:]
        operand_bytes = sum(shape_bytes(symbols.get(o, "")) for o in operands)
        result_bytes = shape_bytes(result_type)

        if opcode in _COLLECTIVES:
            b = operand_bytes if operand_bytes else result_bytes
            st.coll_bytes += b
            st.coll_by_kind[opcode] = st.coll_by_kind.get(opcode, 0.0) + b
        elif opcode == "while":
            mbody = re.search(r"body=%?([\w\.\-]+)", tail)
            trips = None
            mt = re.search(r'known_trip_count[":{]+n["\s:]+"?(\d+)', tail)
            if mt:
                trips = int(mt.group(1))
            mcond = re.search(r"condition=%?([\w\.\-]+)", tail)
            if mbody:
                st.whiles.append((mbody.group(1),
                                  mcond.group(1) if mcond else None, trips))
        elif opcode == "dot":
            lhs = operands[0] if operands else None
            lhs_dims = _shape_dims(symbols.get(lhs, "")) if lhs else ()
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", tail)
            contract = 1
            if mc and mc.group(1):
                for d in mc.group(1).split(","):
                    di = int(d)
                    contract *= lhs_dims[di] if di < len(lhs_dims) else 1
            n_out = 1
            for d in _shape_dims(result_type):
                n_out *= d
            st.dot_flops += 2.0 * n_out * contract
            st.mem_bytes += operand_bytes + result_bytes
        elif opcode in ("call", "conditional"):
            for mm in re.finditer(r"(?:calls|to_apply|branch_computations)"
                                  r"=\{?%?([\w\.\-]+)", tail):
                st.calls.append(mm.group(1))
            st.mem_bytes += 0.0
        elif opcode in _SKIP_BYTES_OPS:
            pass
        elif opcode == "dynamic-slice":
            # physical traffic = the slice, not the sliced-from buffer
            st.mem_bytes += 2.0 * result_bytes
        elif opcode == "dynamic-update-slice":
            # physical traffic = the update (in-place buffer write)
            upd = sum(shape_bytes(symbols.get(o, "")) for o in operands[1:2])
            st.mem_bytes += 2.0 * (upd if upd else result_bytes)
        else:
            # fusion / custom-call / elementwise / reduce / copy
            st.mem_bytes += operand_bytes + result_bytes
    return comps, entry_name


@dataclasses.dataclass
class HLOTotals:
    dot_flops: float
    mem_bytes: float
    coll_bytes: float
    coll_by_kind: dict
    n_whiles: int
    trip_counts: list
    # loops-counted-once variants (to scale XLA cost_analysis aggregates)
    dot_flops_x1: float = 0.0
    mem_bytes_x1: float = 0.0
    coll_bytes_x1: float = 0.0

    def mem_amplification(self) -> float:
        """Loop amplification of memory traffic: multiply XLA's
        (fusion-accurate, loops-x1) 'bytes accessed' by this."""
        return self.mem_bytes / self.mem_bytes_x1 if self.mem_bytes_x1 \
            else 1.0


def analyze(text: str) -> HLOTotals:
    """Whole-module totals with while-body trip multipliers (and the
    loops-x1 variant from the same walk)."""
    comps, entry = parse_hlo(text)
    memo: dict[str, tuple] = {}
    trip_counts: list = []
    state = {"n_whiles": 0}

    def walk(name: str, depth: int = 0) -> tuple:
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if st is None or depth > 60:
            return (0.0, 0.0, 0.0, {}, 0.0, 0.0, 0.0)
        flops, mem, coll = st.dot_flops, st.mem_bytes, st.coll_bytes
        f1, m1, c1 = st.dot_flops, st.mem_bytes, st.coll_bytes
        kinds = dict(st.coll_by_kind)
        for callee in st.calls:
            f, m, c, k, fx, mx, cx = walk(callee, depth + 1)
            flops += f
            mem += m
            coll += c
            f1 += fx
            m1 += mx
            c1 += cx
            for kk, vv in k.items():
                kinds[kk] = kinds.get(kk, 0.0) + vv
        for body, cond, trips in st.whiles:
            if trips is None:
                cst = comps.get(cond) if cond else None
                trips = max(1, cst.max_constant if cst else 1)
            state["n_whiles"] += 1
            trip_counts.append(trips)
            f, m, c, k, fx, mx, cx = walk(body, depth + 1)
            flops += trips * f
            mem += trips * m
            coll += trips * c
            f1 += fx
            m1 += mx
            c1 += cx
            for kk, vv in k.items():
                kinds[kk] = kinds.get(kk, 0.0) + trips * vv
        memo[name] = (flops, mem, coll, kinds, f1, m1, c1)
        return memo[name]

    if entry:
        flops, mem, coll, kinds, f1, m1, c1 = walk(entry)
    else:
        flops = mem = coll = f1 = m1 = c1 = 0.0
        kinds = {}
    return HLOTotals(dot_flops=flops, mem_bytes=mem, coll_bytes=coll,
                     coll_by_kind=kinds, n_whiles=state["n_whiles"],
                     trip_counts=trip_counts, dot_flops_x1=f1,
                     mem_bytes_x1=m1, coll_bytes_x1=c1)
