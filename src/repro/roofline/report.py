"""Roofline terms from a compiled dry-run cell (TPU v5e constants).

  compute term    = HLO_FLOPs / (chips x peak FLOP/s)
  memory term     = HLO_bytes / (chips x HBM bandwidth)
  collective term = collective bytes / (chips x ICI link bandwidth)

Two FLOP sources are reported: XLA cost_analysis (per-device, loop bodies
x1 — kept for reference) and the loop-aware HLO-text analysis (per-device
x trip counts — used for the terms).  MODEL_FLOPS = 6*N_active*D flags
remat/dispatch overhead through the useful-compute ratio.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# TPU v5e class hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (effective per-chip)


@dataclasses.dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device quantities (collected from the compiled module)
    hlo_flops: float              # loop-aware dot flops
    hlo_bytes: float              # loop-aware memory traffic (see below)
    coll_bytes: float             # loop-aware collective operand bytes
    xla_flops: float              # cost_analysis (loops x1), reference
    xla_bytes: float
    model_flops_global: float     # 6*N_active*D for the step
    arg_bytes: float              # per-device argument residency
    temp_bytes: float             # per-device temp residency
    coll_by_kind: dict
    n_whiles: int = 0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs."""
        total = self.hlo_flops * self.n_chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization at the modeled step time (MFU-like):
        MODEL_FLOPS / (step_s * chips * peak)."""
        denom = self.step_s * self.n_chips * PEAK_FLOPS_BF16
        return self.model_flops_global / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "model_flops_global": self.model_flops_global,
            "arg_gb_per_dev": self.arg_bytes / 1e9,
            "temp_gb_per_dev": self.temp_bytes / 1e9,
        }


def model_flops_for_cell(arch_cfg, shape_cfg) -> float:
    """MODEL_FLOPS = 6*N_active*D (train: x3 fwd+bwd via the 6; decode:
    2*N_active per token) + attention context FLOPs."""
    dims = arch_cfg.to_model_dims()
    n_active = dims.active_params_per_token()
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    # attention flops (per token ~ 2 * layers * kv_len * (q_dim + kv... ):
    # use 4*dh*heads*kv_len per layer per token (scores + PV, causal /2)
    if shape_cfg.kind == "train":
        tokens = b * s
        base = 6.0 * n_active * tokens
        attn = (dims.n_layers * 4.0 * dims.n_heads * dims.head_dim
                * tokens * (s / 2) * 3.0)   # x3 for fwd+bwd
    elif shape_cfg.kind == "prefill":
        tokens = b * s
        base = 2.0 * n_active * tokens
        attn = dims.n_layers * 4.0 * dims.n_heads * dims.head_dim \
            * tokens * (s / 2)
    else:  # decode: one token per sequence
        tokens = b
        kv = min(s, dims.attn_window) if dims.attn_window else s
        if dims.family.name == "SSM":
            kv = 0
        base = 2.0 * n_active * tokens
        attn = dims.n_layers * 4.0 * dims.n_heads * dims.head_dim \
            * tokens * kv
    return base + attn


def format_table(rows: list) -> str:
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s",
            "collective_s", "bottleneck", "useful_flop_ratio",
            "roofline_fraction"]
    out = [" | ".join(f"{c:>18s}" for c in cols)]
    for r in rows:
        vals = []
        for c in cols:
            v = r[c]
            vals.append(f"{v:18.3e}" if isinstance(v, float)
                        else f"{str(v):>18s}")
        out.append(" | ".join(vals))
    return "\n".join(out)
