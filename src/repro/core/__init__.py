"""MemExplorer core: unified memory modeling + NPU co-design DSE.

The paper's primary contribution, as a composable library:

  memtech     Table 1 technology catalog (unified abstraction)
  hierarchy   Eq. 1 shoreline bound + Eqs. 2-5 double-buffered transfer model
  compute     PLENA-style systolic/vector analytical compute model
  power       Eq. 6 memory power + parametric compute power
  dataflow    Section 4.2 software strategies (WS/IS/OS, storage, BW priority)
  workload    Section 4.3 per-phase operator traffic for all model families
  perfmodel   phase evaluation -> throughput/power/token-per-joule
  npu         one co-design point (Table 2) incl. the paper's Table 6 configs
  emulator    transaction-level cross-validation (Section 5.6)
  disagg      N-device disaggregated system model: Role/SystemTopology
              composition from plain PD pairs to extreme-heterogeneity
              layer-group + decode-phase splits (Sections 5.3/5.5)
  dse         Sobol + GP/EHVI MOBO + NSGA-II + MO-TPE + random (Section 4.4)
  quant       MX formats + accuracy proxy (Table 3)
  calibration measured Pallas-kernel factors -> CalibrationTable threaded
              through gemm_cycles/perfmodel/perfmodel_jit (identity by
              default; see docs/calibration.md)
"""

from .calibration import (CalibrationTable, CalSample, fit_table,
                          geometry_class, measure_all)
from .compute import ComputeConfig, Dataflow, gemm_cycles, vector_seconds
from .dataflow import (BandwidthPriority, SoftwareStrategy, StoragePriority,
                       place_data)
from .disagg import (DLLM_3ROLE, EXTREME_4ROLE, PD_PAIR, DisaggResult, Role,
                     SystemResult, SystemTopology, evaluate_disagg_batch,
                     evaluate_disaggregated, evaluate_system,
                     evaluate_system_batch)
from .hierarchy import (MemoryHierarchy, MemoryLevel, ShorelineError,
                        max_stacks)
from .memtech import CATALOG, MemKind, MemoryTechnology
from .memtech import get as get_tech
from .npu import (NPUConfig, baseline_npu, d1_npu, d2_npu, make_hierarchy,
                  p1_npu, p2_npu)
from .perfmodel import (InfeasibleConfig, PhaseResult, evaluate,
                        evaluate_decode, evaluate_prefill, max_decode_batch)
from .power import compute_power_w, memory_power_w, system_tdp_w
from .quant.formats import FORMATS, MXFormat, QuantConfig, quantize_dequantize
from .workload import (BFCL_DLLM, BFCL_WEB_SEARCH, CHATBOT, GSM8K_DLLM,
                       OSWORLD_DLLM, OSWORLD_LIBREOFFICE, Family, ModelDims,
                       Phase, Trace, layer_traffic, weight_footprint_gb)
