"""PLENA-style analytical compute model.

The paper builds on PLENA's configurable compute abstraction: a systolic
matrix engine of R x C processing elements plus a VLEN-wide vector unit.
We model GEMM latency under the three dataflow strategies (weight-, input-,
output-stationary) with explicit tiling over the PE array, and vector-op
latency over VLEN lanes.  These cycle counts combine with the memory
transfer model (hierarchy.py) in perfmodel.py: compute and (double-
buffered) memory streams overlap, the slower one dominating.

Conventions: a GEMM is (M x K) @ (K x N).  For transformer inference the
"weights" operand is K x N, activations are M x K.  MACs = M*K*N.
"""

from __future__ import annotations

import dataclasses
import enum
import math


class Dataflow(enum.Enum):
    WEIGHT_STATIONARY = "WS"
    INPUT_STATIONARY = "IS"
    OUTPUT_STATIONARY = "OS"


@dataclasses.dataclass(frozen=True)
class ComputeConfig:
    """Compute-side design choices (Table 2: PE Array Dim, VLEN)."""

    pe_rows: int = 128
    pe_cols: int = 128
    vlen: int = 2048
    clock_ghz: float = 1.0

    @property
    def n_pe(self) -> int:
        return self.pe_rows * self.pe_cols

    @property
    def peak_macs_per_s(self) -> float:
        return self.n_pe * self.clock_ghz * 1e9

    @property
    def peak_flops(self) -> float:
        return 2.0 * self.peak_macs_per_s

    @property
    def peak_vector_ops_per_s(self) -> float:
        return self.vlen * self.clock_ghz * 1e9


@dataclasses.dataclass(frozen=True)
class GemmTiming:
    cycles: float
    utilization: float      # ideal MAC-cycles / (cycles * n_pe)
    macs: float
    seconds: float


def gemm_cycles(cfg: ComputeConfig, m: int, k: int, n: int,
                dataflow: Dataflow = Dataflow.WEIGHT_STATIONARY,
                count: float = 1.0, eff_factor: float = 1.0,
                setup_cycles: float = 0.0) -> GemmTiming:
    """Systolic GEMM latency under a dataflow strategy.

    The stationary operand is double-buffered inside the array (ping-pong
    weight registers, TPU-style), so tile swaps overlap with the previous
    tile's streaming phase; one pipeline fill/drain is paid per GEMM pass
    rather than per tile.  Resident-tile *loading* bandwidth is accounted
    by the memory model (the weight stream), not here — charging it in
    both places would double-count.

    `count` independent same-shape GEMMs (batched heads / experts) may be
    packed along the row dimension of the array when the natural row
    extent is smaller than the array: floor(R / rows) instances execute
    simultaneously on disjoint row bands (GQA attention with head_dim 64
    on a 2048-row array packs 32 heads per pass).

    `eff_factor` / `setup_cycles` apply a measured calibration
    (core.calibration): cycles = analytical * eff_factor + setup_cycles.
    The identity (1.0, 0.0) is bit-exact — `x * 1.0 + 0.0 == x` for the
    non-negative counts here — and degenerate GEMMs skip calibration
    entirely (zero work costs zero regardless of per-pass setup).
    """
    if min(m, k, n) <= 0 or count <= 0:
        return GemmTiming(0.0, 1.0, 0.0, 0.0)
    r, c = cfg.pe_rows, cfg.pe_cols
    fill = r + c  # pipeline skew in + drain out, once per pass

    if dataflow is Dataflow.WEIGHT_STATIONARY:
        rows = k                      # K maps to array rows
    elif dataflow is Dataflow.INPUT_STATIONARY:
        rows = m
    else:                             # OUTPUT_STATIONARY
        rows = m
    pack = max(1, min(int(count), r // max(1, rows)))
    eff_count = math.ceil(count / pack)
    rows_used = rows * pack

    if dataflow is Dataflow.WEIGHT_STATIONARY:
        tiles = math.ceil(rows_used / r) * math.ceil(n / c)
        stream = m                    # activation rows per tile
    elif dataflow is Dataflow.INPUT_STATIONARY:
        tiles = math.ceil(rows_used / r) * math.ceil(k / c)
        stream = n
    else:  # OUTPUT_STATIONARY
        tiles = math.ceil(rows_used / r) * math.ceil(n / c)
        stream = k
    cycles = (float(tiles) * stream + fill) * eff_count
    cycles = cycles * eff_factor + setup_cycles
    macs = float(m) * k * n * count
    util = min(1.0, macs / (cycles * cfg.n_pe))
    return GemmTiming(cycles=cycles, utilization=util, macs=macs,
                      seconds=cycles / (cfg.clock_ghz * 1e9))


def dataflow_traffic_multipliers(
        cfg: ComputeConfig, m: int, k: int, n: int, dataflow: Dataflow,
        a_bytes_per_elt: float, b_bytes_per_elt: float,
        out_bytes_per_elt: float,
        stage_a_bytes: float, stage_b_bytes: float,
        stage_out_bytes: float) -> tuple[float, float]:
    """(a_mult, b_mult): re-stream factors for an (m,k)@(k,n) GEMM.

    Capacity-aware (Timeloop-style) staging model: the dataflow picks which
    operand is *stationary*; the on-chip bytes available to stage it
    (`stage_*`, from the storage-priority placement) set the chunk size, and
    the other operand is re-streamed once per chunk:

      WS: weights stationary, chunked into stage_b-sized pieces; the full
          activation panel is re-read per chunk: a_mult = ceil(K*N*b / S_b).
      IS: activations stationary: b_mult = ceil(M*K*a / S_a).
      OS: an output tile (t x t, t = sqrt(S_out/o)) is stationary; both
          operands are re-read per tile row/column.

    Staging can never be smaller than one PE-array tile (the array itself
    holds that much), so multipliers are capped at the array-level passes.
    """
    r, c = cfg.pe_rows, cfg.pe_cols
    a_cap = float(math.ceil(n / c))        # worst case: re-read per col tile
    b_cap = float(math.ceil(m / r))        # worst case: re-read per row tile
    if dataflow is Dataflow.WEIGHT_STATIONARY:
        stage = max(stage_b_bytes, r * c * b_bytes_per_elt)
        a_mult = min(a_cap, math.ceil(k * n * b_bytes_per_elt / stage))
        return float(max(1.0, a_mult)), 1.0
    if dataflow is Dataflow.INPUT_STATIONARY:
        stage = max(stage_a_bytes, r * c * a_bytes_per_elt)
        b_mult = min(b_cap, math.ceil(m * k * a_bytes_per_elt / stage))
        return 1.0, float(max(1.0, b_mult))
    # OUTPUT_STATIONARY
    stage = max(stage_out_bytes, r * c * out_bytes_per_elt)
    t = math.sqrt(stage / max(out_bytes_per_elt, 1e-9))
    a_mult = min(a_cap, math.ceil(n / max(t, c)))
    b_mult = min(b_cap, math.ceil(m / max(t, r)))
    return float(max(1.0, a_mult)), float(max(1.0, b_mult))


def vector_cycles(cfg: ComputeConfig, elements: float,
                  ops_per_element: float = 1.0) -> float:
    """Vector-unit cycles for an elementwise/reduction op."""
    if elements <= 0:
        return 0.0
    return math.ceil(elements / cfg.vlen) * ops_per_element


def vector_seconds(cfg: ComputeConfig, elements: float,
                   ops_per_element: float = 1.0) -> float:
    return vector_cycles(cfg, elements, ops_per_element) / (cfg.clock_ghz * 1e9)
