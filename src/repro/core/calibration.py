"""Kernel-measured calibration of the analytical compute model.

The perfmodel's GEMM term (`compute.gemm_cycles`) is a first-principles
systolic-array count.  This module closes the model-vs-silicon loop the
WSE-2 way (SNIPPETS.md: measured / pure-FMACS cycles = one overhead
factor + a per-pass setup constant predicts real cycles within 1.5%):

  1. run the repo's Pallas kernels (flash_attention, decode_attention,
     mx_quant) plus an XLA matmul proxy for weight GEMMs across the
     geometries `LayerTraffic` actually emits for the bundled traces
     (interpret mode on CPU, Mosaic on TPU);
  2. fit, per *geometry class*, measured_cycles ~= efficiency *
     analytical_cycles + setup_cycles by least squares (efficiency
     clamped >= 1, setup >= 0 — the model is a lower bound);
  3. package the factors as a `CalibrationTable` that
     `perfmodel`/`perfmodel_jit` thread through `gemm_cycles`.

Identity convention: the default table (and `calibration=None`
everywhere downstream) applies efficiency 1.0 / setup 0.0, and
`x * 1.0 + 0.0 == x` exactly in IEEE-754 for the non-negative cycle
counts involved — so jit-vs-scalar parity and every sha-pinned search
trajectory survive byte-identically unless a caller opts into a fitted
table (per-`Objective`; see docs/calibration.md).

Geometry classes key on what distinguishes kernels, not exact shapes:
the operand data classes decide the role (weight GEMM / attention QK /
attention PV / other activation GEMM) and the M extent decides the
narrow-vs-wide bucket (decode-style single-token panels vs prefill
panels).  Factors measured on one shape of a class transfer to the
rest of the class; classes never measured stay identity.

Measurement timing uses `time.perf_counter` (the one timer the
`repro.analysis` wall-clock rule sanctions) around `block_until_ready`,
after a warmup call that eats compilation.  On CPU the kernels run
through the Pallas interpreter, so fitted factors are orders of
magnitude above 1 — they validate the harness end-to-end; factors that
anchor tok/J claims to silicon need a TPU run (docs/calibration.md).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import time
from typing import Optional, Sequence

import numpy as np

from .compute import ComputeConfig, Dataflow, gemm_cycles, vector_cycles
from .workload import (CLASS_CODES, DataClass, GemmOp, ModelDims, Phase,
                       Trace, layer_traffic_cached, lm_head_traffic_cached)

__all__ = [
    "NARROW_M", "CalibrationTable", "CalSample", "geometry_class",
    "geometry_class_of_gemm", "fit_table", "trace_geometry_classes",
    "measure_flash_attention", "measure_decode_attention",
    "measure_matmul", "measure_mx_quant", "measure_all",
]

# M extents below this are "narrow" (decode-style single-token panels);
# at/above it "wide" (prefill panels).  64 splits the bundled traces'
# decode GEMMs (m = batch or group_size) from every prefill panel.
NARROW_M = 64

_WEIGHT = CLASS_CODES[DataClass.WEIGHT]     # 0
_ACT = CLASS_CODES[DataClass.ACT]           # 1
_KV = CLASS_CODES[DataClass.KV]             # 2
_SCRATCH = CLASS_CODES[DataClass.SCRATCH]   # 3

_ALL_DATAFLOWS = (Dataflow.WEIGHT_STATIONARY, Dataflow.INPUT_STATIONARY,
                  Dataflow.OUTPUT_STATIONARY)

# Side class for the MX quantization kernel: it is vector-unit work,
# not a GEMM, so no `geometry_class` output ever collides with it —
# its fitted factors ride along in the table for reporting only.
MX_QUANT_CLASS = "mx_quant"


def geometry_class(m: float, k: float, n: float, count: float = 1.0,
                   a_code: int = _ACT, b_code: int = _WEIGHT,
                   out_code: int = _ACT) -> str:
    """Geometry-class key for one (m x k) @ (k x n) GEMM.

    Role from the operand data classes (the same codes
    `LayerTraffic.gemm_geometry` exports):

      wgemm     B is a weight matrix (projections, FFN, router, lm head)
      attn_qk   scores GEMM: KV-class B, scratch-class output
      attn_pv   probs @ V: scratch-class A
      actgemm   anything else (act @ act, e.g. xLSTM state updates)

    Bucket from the M extent: "narrow" below `NARROW_M`, else "wide".
    """
    del k, n, count
    if b_code == _WEIGHT:
        role = "wgemm"
    elif b_code == _KV and out_code == _SCRATCH:
        role = "attn_qk"
    elif a_code == _SCRATCH:
        role = "attn_pv"
    else:
        role = "actgemm"
    bucket = "narrow" if m < NARROW_M else "wide"
    return f"{role}/{bucket}"


def geometry_class_of_gemm(g: GemmOp) -> str:
    return geometry_class(g.m, g.k, g.n, g.count,
                          CLASS_CODES[g.a_class], CLASS_CODES[g.b_class],
                          CLASS_CODES[g.out_class])


@dataclasses.dataclass(frozen=True)
class CalibrationTable:
    """Per-geometry-class (efficiency, setup_cycles) factors.

    `entries` is a name-sorted tuple of (class_name, efficiency,
    setup_cycles) triples — hashable, so tables key lru caches and
    journal fingerprints.  Classes absent from `entries` are identity:
    efficiency 1.0, setup 0.0 (`x * 1.0 + 0.0 == x` bit-exactly for
    the non-negative cycle counts `gemm_cycles` produces).

    Calibrated cycles = analytical_cycles * efficiency + setup_cycles,
    with efficiency >= 1 and setup >= 0 enforced at construction: the
    analytical count is a lower bound, a fit below it is noise.
    """

    entries: tuple = ()
    source: str = "identity"

    def __post_init__(self):
        norm = []
        seen = set()
        for name, eff, setup in self.entries:
            if name in seen:
                raise ValueError(f"duplicate calibration class {name!r}")
            seen.add(name)
            eff = float(eff)
            setup = float(setup)
            if not (eff >= 1.0) or not np.isfinite(eff):
                raise ValueError(
                    f"efficiency for {name!r} must be finite >= 1.0 "
                    f"(got {eff})")
            if not (setup >= 0.0) or not np.isfinite(setup):
                raise ValueError(
                    f"setup_cycles for {name!r} must be finite >= 0.0 "
                    f"(got {setup})")
            norm.append((str(name), eff, setup))
        norm.sort()
        object.__setattr__(self, "entries", tuple(norm))
        object.__setattr__(self, "_by_name",
                           {e[0]: (e[1], e[2]) for e in norm})

    @classmethod
    def identity(cls) -> "CalibrationTable":
        return cls()

    @classmethod
    def from_factors(cls, factors: dict,
                     source: str = "fit") -> "CalibrationTable":
        """factors: {class_name: (efficiency, setup_cycles)}."""
        entries = tuple((name, eff, setup)
                        for name, (eff, setup) in sorted(factors.items()))
        return cls(entries=entries, source=source)

    @property
    def is_identity(self) -> bool:
        return all(eff == 1.0 and setup == 0.0
                   for _, eff, setup in self.entries)

    def factors_for(self, class_name: str) -> tuple:
        """(efficiency, setup_cycles) for a class; identity if absent."""
        return self._by_name.get(class_name, (1.0, 0.0))

    def factors_for_geometry(self, m, k, n, count=1.0, a_code=_ACT,
                             b_code=_WEIGHT, out_code=_ACT) -> tuple:
        return self.factors_for(
            geometry_class(m, k, n, count, a_code, b_code, out_code))

    def factors_for_gemm(self, g: GemmOp) -> tuple:
        return self.factors_for(geometry_class_of_gemm(g))

    def to_json(self) -> str:
        """Canonical sorted-key JSON (round-trips via `from_json`)."""
        return json.dumps(
            {"source": self.source,
             "entries": {name: {"efficiency": eff, "setup_cycles": setup}
                         for name, eff, setup in self.entries}},
            sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationTable":
        doc = json.loads(text)
        entries = tuple(
            (name, rec["efficiency"], rec["setup_cycles"])
            for name, rec in sorted(doc.get("entries", {}).items()))
        return cls(entries=entries, source=doc.get("source", "identity"))

    def digest(self) -> str:
        """Content hash — pins a table in journals / bench rows."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class CalSample:
    """One measured kernel run attributed to one geometry class."""

    class_name: str
    model_cycles: float      # analytical gemm_cycles at the fit config
    measured_cycles: float   # wall time * clock (apportioned if fused)
    detail: str = ""         # shape provenance, e.g. "flash b1 s256"


def fit_table(samples: Sequence[CalSample],
              source: str = "fit") -> tuple:
    """Least-squares (efficiency, setup) per class -> (table, report).

    Per class: measured ~= eff * model + setup, solved by `np.linalg
    .lstsq` (single-sample classes get a pure ratio), then clamped to
    the table's eff >= 1 / setup >= 0 domain.  The report carries the
    post-clamp normalized residual per class — ||pred - y|| / ||y||,
    which stays bounded when a class's smallest shapes are dispatch-
    overhead-dominated — and its max (`fit_err`), the number the
    `calibration` bench row gates.
    """
    by_class: dict = {}
    for s in samples:
        by_class.setdefault(s.class_name, []).append(s)
    factors = {}
    classes_report = {}
    fit_err = 0.0
    for name in sorted(by_class):
        grp = by_class[name]
        x = np.array([s.model_cycles for s in grp], dtype=np.float64)
        y = np.array([s.measured_cycles for s in grp], dtype=np.float64)
        if len(grp) == 1:
            eff = float(y[0] / x[0])
            setup = 0.0
        else:
            a_mat = np.stack([x, np.ones_like(x)], axis=1)
            coef, _, _, _ = np.linalg.lstsq(a_mat, y, rcond=None)
            eff, setup = float(coef[0]), float(coef[1])
        if setup < 0.0:
            # refit slope through the origin before clamping it away
            eff = float(np.sum(x * y) / np.sum(x * x))
            setup = 0.0
        if eff < 1.0:
            eff = 1.0
            setup = max(0.0, float(np.mean(y - x)))
        pred = eff * x + setup
        rel_rms = float(np.sqrt(np.sum((pred - y) ** 2)
                                / np.sum(y ** 2)))
        factors[name] = (eff, setup)
        classes_report[name] = {
            "efficiency": eff, "setup_cycles": setup,
            "n_samples": len(grp), "rel_rms": rel_rms,
        }
        fit_err = max(fit_err, rel_rms)
    table = CalibrationTable.from_factors(factors, source=source)
    report = {"classes": classes_report, "fit_err": fit_err,
              "n_samples": len(samples), "source": source}
    return table, report


def trace_geometry_classes(dims: ModelDims, trace: Trace, quant,
                           batches: Sequence[int] = (1, 8)) -> dict:
    """{class_name: GEMM count} a bundled (model, trace) emits across
    prefill + decode layer passes and the lm head — the coverage map
    the bench reports against the measured classes."""
    out: dict = {}

    def tally(traffic):
        for g in traffic.gemms:
            name = geometry_class_of_gemm(g)
            out[name] = out.get(name, 0) + 1

    for b in batches:
        tally(layer_traffic_cached(dims, Phase.PREFILL, int(b),
                                   trace.prompt_tokens, quant))
        tally(layer_traffic_cached(
            dims, Phase.DECODE, int(b),
            trace.prompt_tokens + trace.gen_tokens // 2, quant))
        tally(lm_head_traffic_cached(dims, int(b), 1, quant))
    return dict(sorted(out.items()))


# ---------------------------------------------------------------------------
# Measurement harness
# ---------------------------------------------------------------------------

def _interpret_flag(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return bool(interpret)
    from ..kernels.ops import _interpret_default
    return _interpret_default()


def _best_seconds(fn, args, repeat: int) -> float:
    """min-of-`repeat` wall seconds for fn(*args), after one warmup
    call that absorbs compilation; `time.perf_counter` is the
    repro.analysis-sanctioned timer."""
    import jax
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, repeat)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _min_cycles(cfg: ComputeConfig, m: int, k: int, n: int,
                count: float) -> float:
    """Best-dataflow analytical cycles — mirrors the perfmodel's
    attention-GEMM argmin (`_gemm_dataflow`)."""
    return min(gemm_cycles(cfg, m, k, n, df, count=count).cycles
               for df in _ALL_DATAFLOWS)


def _attention_samples(cfg: ComputeConfig, kind: str, seconds: float,
                       qk: tuple, pv: tuple, detail: str) -> list:
    """Apportion one fused attention kernel's measured time between its
    QK and PV GEMM classes by analytical-cycle share (the softmax is
    vector-unit work the matrix-side factors deliberately absorb)."""
    del kind
    measured = seconds * cfg.clock_ghz * 1e9
    x_qk = _min_cycles(cfg, *qk[:3], qk[3])
    x_pv = _min_cycles(cfg, *pv[:3], pv[3])
    share = x_qk / (x_qk + x_pv)
    qk_cls = geometry_class(qk[0], qk[1], qk[2], qk[3],
                            a_code=_ACT, b_code=_KV, out_code=_SCRATCH)
    pv_cls = geometry_class(pv[0], pv[1], pv[2], pv[3],
                            a_code=_SCRATCH, b_code=_KV, out_code=_ACT)
    return [
        CalSample(qk_cls, x_qk, measured * share, detail=detail + " qk"),
        CalSample(pv_cls, x_pv, measured * (1.0 - share),
                  detail=detail + " pv"),
    ]


# (batch, seq) prefill shapes: seq must divide block_q = block_k = 128.
FLASH_SHAPES = ((1, 128), (1, 256), (1, 384))
# (batch, cache_len) decode shapes: cache_len must divide block_k = 512.
DECODE_SHAPES = ((1, 512), (1, 1024), (1, 2048))
# (m, k=n) weight-GEMM proxy shapes per bucket.
MATMUL_NARROW_SHAPES = ((16, 512), (16, 1024), (16, 1536))
MATMUL_WIDE_SHAPES = ((256, 512), (256, 1024), (256, 1536))
# (rows, cols) MX quantization shapes: cols % 32 == 0.
MX_SHAPES = ((256, 512), (512, 1024), (1024, 2048))


def measure_flash_attention(cfg: ComputeConfig,
                            shapes: Sequence[tuple] = FLASH_SHAPES,
                            *, n_q_heads: int = 4, n_kv_heads: int = 2,
                            head_dim: int = 64,
                            interpret: Optional[bool] = None,
                            repeat: int = 3, seed: int = 0) -> list:
    """Prefill SDPA: attn_qk/wide + attn_pv/wide samples.

    The workload model's causal-prefill GEMM pair for q_len = kv_len =
    S is (m = group*S/2, dh, S) and (m, S, dh), count = batch*Hkv —
    the measured kernel time covers both plus the online softmax.
    """
    import functools as _ft

    import jax

    from ..kernels.flash_attention import flash_attention
    interp = _interpret_flag(interpret)
    fn = jax.jit(_ft.partial(flash_attention, n_kv_heads=n_kv_heads,
                             causal=True, interpret=interp))
    rng = np.random.default_rng(seed)
    group = n_q_heads // n_kv_heads
    out = []
    for b, s in shapes:
        q = rng.standard_normal((b, s, n_q_heads, head_dim),
                                dtype=np.float32)
        k = rng.standard_normal((b, s, n_kv_heads, head_dim),
                                dtype=np.float32)
        v = rng.standard_normal((b, s, n_kv_heads, head_dim),
                                dtype=np.float32)
        sec = _best_seconds(fn, (q, k, v), repeat)
        m = int(group * s * 0.5)
        count = float(b * n_kv_heads)
        out += _attention_samples(
            cfg, "flash", sec,
            (m, head_dim, s, count), (m, s, head_dim, count),
            detail=f"flash b{b} s{s}")
    return out


def measure_decode_attention(cfg: ComputeConfig,
                             shapes: Sequence[tuple] = DECODE_SHAPES,
                             *, n_q_heads: int = 8, n_kv_heads: int = 2,
                             head_dim: int = 64,
                             interpret: Optional[bool] = None,
                             repeat: int = 3, seed: int = 0) -> list:
    """Decode SDPA: attn_qk/narrow + attn_pv/narrow samples (m = the
    GQA group size, well under NARROW_M)."""
    import functools as _ft

    import jax

    from ..kernels.decode_attention import decode_attention
    interp = _interpret_flag(interpret)
    fn = jax.jit(_ft.partial(decode_attention, n_kv_heads=n_kv_heads,
                             interpret=interp))
    rng = np.random.default_rng(seed)
    group = n_q_heads // n_kv_heads
    out = []
    for b, t in shapes:
        q = rng.standard_normal((b, n_q_heads, head_dim),
                                dtype=np.float32)
        k = rng.standard_normal((b, t, n_kv_heads, head_dim),
                                dtype=np.float32)
        v = rng.standard_normal((b, t, n_kv_heads, head_dim),
                                dtype=np.float32)
        ts = np.full((b,), t, dtype=np.int32)
        sec = _best_seconds(fn, (q, k, v, ts), repeat)
        count = float(b * n_kv_heads)
        out += _attention_samples(
            cfg, "decode", sec,
            (group, head_dim, t, count), (group, t, head_dim, count),
            detail=f"decode b{b} t{t}")
    return out


def measure_matmul(cfg: ComputeConfig,
                   shapes: Optional[Sequence[tuple]] = None,
                   *, interpret: Optional[bool] = None,
                   repeat: int = 3, seed: int = 0) -> list:
    """Weight-GEMM proxy (wgemm/narrow + wgemm/wide): a jitted XLA
    matmul — the repo has no Pallas GEMM kernel, and on-TPU XLA GEMMs
    are the MXU path the analytical weight term models.  `interpret`
    is accepted for signature symmetry and ignored."""
    import jax
    import jax.numpy as jnp
    del interpret
    fn = jax.jit(lambda a, b: jnp.dot(a, b))
    rng = np.random.default_rng(seed)
    out = []
    all_shapes = (tuple(shapes) if shapes is not None
                  else MATMUL_NARROW_SHAPES + MATMUL_WIDE_SHAPES)
    for m, kn in all_shapes:
        a = rng.standard_normal((m, kn), dtype=np.float32)
        b = rng.standard_normal((kn, kn), dtype=np.float32)
        sec = _best_seconds(fn, (a, b), repeat)
        # weight GEMMs run the strategy dataflow; WS is the canonical
        # default every bundled strategy uses for weights
        x = gemm_cycles(cfg, m, kn, kn,
                        Dataflow.WEIGHT_STATIONARY).cycles
        out.append(CalSample(
            geometry_class(m, kn, kn, b_code=_WEIGHT),
            x, sec * cfg.clock_ghz * 1e9,
            detail=f"matmul m{m} k{kn} n{kn}"))
    return out


def measure_mx_quant(cfg: ComputeConfig,
                     shapes: Sequence[tuple] = MX_SHAPES,
                     *, interpret: Optional[bool] = None,
                     repeat: int = 3, seed: int = 0) -> list:
    """MX quantization kernel under the side class `mx_quant` (vector
    work — never keyed by a GEMM, reported for kernel coverage).  The
    analytical proxy charges ~6 vector lane-ops per element (absmax
    reduce, log2/scale, clip, round)."""
    import jax

    from ..kernels.mx_quant import mx_quantize
    interp = _interpret_flag(interpret)
    fn = jax.jit(lambda x: mx_quantize(x, interpret=interp))
    rng = np.random.default_rng(seed)
    out = []
    for rows, cols in shapes:
        x = rng.standard_normal((rows, cols), dtype=np.float32)
        sec = _best_seconds(fn, (x,), repeat)
        model = vector_cycles(cfg, float(rows * cols), 6.0)
        out.append(CalSample(
            MX_QUANT_CLASS, model, sec * cfg.clock_ghz * 1e9,
            detail=f"mx_quant {rows}x{cols}"))
    return out


def measure_all(cfg: Optional[ComputeConfig] = None, *,
                smoke: bool = False,
                interpret: Optional[bool] = None,
                seed: int = 0) -> list:
    """All kernels' samples at the default shape ladders.

    `smoke` drops to min-of-2 timing (the warmup call still eats
    compilation); the shape ladders stay — the fit needs >= 3 points
    per class for the residual to mean anything.
    """
    cfg = cfg or ComputeConfig()
    repeat = 2 if smoke else 5
    samples = []
    samples += measure_flash_attention(cfg, interpret=interpret,
                                       repeat=repeat, seed=seed)
    samples += measure_decode_attention(cfg, interpret=interpret,
                                        repeat=repeat, seed=seed)
    samples += measure_matmul(cfg, repeat=repeat, seed=seed)
    samples += measure_mx_quant(cfg, interpret=interpret,
                                repeat=repeat, seed=seed)
    return samples


@functools.lru_cache(maxsize=None)
def _identity_arrays(nb: int, g: int) -> tuple:
    eff = np.ones((nb, g), dtype=np.float64)
    eff.setflags(write=False)
    setup = np.zeros((nb, g), dtype=np.float64)
    setup.setflags(write=False)
    return eff, setup


def calibration_arrays(calibration: Optional[CalibrationTable],
                       gm_num: np.ndarray,
                       gm_cls: np.ndarray) -> tuple:
    """(efficiency [NB, G], setup [NB, G]) arrays for a phase table's
    per-batch-choice GEMM geometry — the numpy-side gather that feeds
    the jitted program (perfmodel_jit indexes them with the dynamic
    batch choice).  Identity (ones/zeros) when `calibration` is None.
    """
    nb, g = gm_num.shape[0], gm_num.shape[1]
    if calibration is None or calibration.is_identity:
        return _identity_arrays(nb, g)
    eff = np.ones((nb, g), dtype=np.float64)
    setup = np.zeros((nb, g), dtype=np.float64)
    for bi in range(nb):
        for gi in range(g):
            m, k, n, count, _ = gm_num[bi, gi]
            a_c, b_c, o_c = gm_cls[gi]
            eff[bi, gi], setup[bi, gi] = calibration.factors_for_geometry(
                m, k, n, count, int(a_c), int(b_c), int(o_c))
    return eff, setup
