"""NPU system configuration: compute + memory hierarchy + software strategy
+ quantization.  One point in the co-design space (paper Table 2 / Fig. 2)."""

from __future__ import annotations

import dataclasses
from typing import Optional

from .compute import ComputeConfig
from .dataflow import SoftwareStrategy
from .hierarchy import MemoryHierarchy, MemoryLevel
from .memtech import get as get_tech
from .power import system_tdp_w
from .quant.formats import QuantConfig


@dataclasses.dataclass(frozen=True)
class NPUConfig:
    name: str
    compute: ComputeConfig
    hierarchy: MemoryHierarchy
    strategy: SoftwareStrategy
    quant: QuantConfig

    def tdp_w(self) -> float:
        return system_tdp_w(self.compute, self.hierarchy)

    def describe(self) -> str:
        return (f"{self.name}: PE {self.compute.pe_rows}x{self.compute.pe_cols}"
                f" VLEN {self.compute.vlen} | {self.hierarchy.describe()}"
                f" | {self.strategy.describe()} | {self.quant.describe()}")


def make_hierarchy(spec: list[tuple[str, int]],
                   validate_shoreline: bool = True) -> MemoryHierarchy:
    """Build a hierarchy from [('3D-SRAM', 3), ('HBM4', 2), ('HBF', 1)]."""
    levels = [MemoryLevel(get_tech(name), stacks) for name, stacks in spec]
    return MemoryHierarchy(levels, validate_shoreline=validate_shoreline)


def baseline_npu(quant: Optional[QuantConfig] = None) -> NPUConfig:
    """The paper's Base configuration (Table 6): PE 2048x128, VLEN 2048,
    SRAM x1 on-chip, HBM3E x4 off-chip, Equal/OS/Equal software."""
    from .compute import Dataflow
    from .dataflow import BandwidthPriority, StoragePriority
    return NPUConfig(
        name="Base",
        compute=ComputeConfig(pe_rows=2048, pe_cols=128, vlen=2048),
        hierarchy=make_hierarchy([("SRAM", 1), ("HBM3E", 4)]),
        strategy=SoftwareStrategy(
            dataflow=Dataflow.OUTPUT_STATIONARY,
            storage_priority=StoragePriority.EQUAL,
            bw_priority=BandwidthPriority.EQUAL,
        ),
        quant=quant or QuantConfig(),
    )


def p1_npu() -> NPUConfig:
    """Paper Table 6 P1 (prefill-optimized)."""
    from .compute import Dataflow
    from .dataflow import BandwidthPriority, StoragePriority
    return NPUConfig(
        name="P1",
        compute=ComputeConfig(pe_rows=2048, pe_cols=256, vlen=2048),
        hierarchy=make_hierarchy([("3D-SRAM", 3), ("HBM4", 2), ("HBF", 1)]),
        strategy=SoftwareStrategy(
            dataflow=Dataflow.WEIGHT_STATIONARY,
            storage_priority=StoragePriority.ACTIVATION,
            bw_priority=BandwidthPriority.MATRIX,
        ),
        quant=QuantConfig(),
    )


def d1_npu() -> NPUConfig:
    """Paper Table 6 D1 (decode-optimized)."""
    from .compute import Dataflow
    from .dataflow import BandwidthPriority, StoragePriority
    return NPUConfig(
        name="D1",
        compute=ComputeConfig(pe_rows=2048, pe_cols=64, vlen=1024),
        hierarchy=make_hierarchy([("SRAM", 1), ("HBM3E", 2), ("HBF", 1)]),
        strategy=SoftwareStrategy(
            dataflow=Dataflow.WEIGHT_STATIONARY,
            storage_priority=StoragePriority.ACTIVATION,
            bw_priority=BandwidthPriority.MATRIX,
        ),
        quant=QuantConfig(),
    )


def p2_npu() -> NPUConfig:
    """Paper Table 6 P2 (prefill, efficiency-leaning)."""
    from .compute import Dataflow
    from .dataflow import BandwidthPriority, StoragePriority
    return NPUConfig(
        name="P2",
        compute=ComputeConfig(pe_rows=1024, pe_cols=512, vlen=2048),
        hierarchy=make_hierarchy([("3D-SRAM", 2), ("HBM4", 2),
                                  ("LPDDR5X", 8), ("LPDDR5X", 8)]),
        strategy=SoftwareStrategy(
            dataflow=Dataflow.WEIGHT_STATIONARY,
            storage_priority=StoragePriority.EQUAL,
            bw_priority=BandwidthPriority.EQUAL,
        ),
        quant=QuantConfig(),
    )


def d2_npu() -> NPUConfig:
    """Paper Table 6 D2 (decode, efficiency-leaning)."""
    from .compute import Dataflow
    from .dataflow import BandwidthPriority, StoragePriority
    return NPUConfig(
        name="D2",
        compute=ComputeConfig(pe_rows=1024, pe_cols=64, vlen=1024),
        hierarchy=make_hierarchy([("3D-SRAM", 1), ("HBM4", 2), ("HBF", 2),
                                  ("LPDDR5X", 8)]),
        strategy=SoftwareStrategy(
            dataflow=Dataflow.WEIGHT_STATIONARY,
            storage_priority=StoragePriority.ACTIVATION,
            bw_priority=BandwidthPriority.MATRIX,
        ),
        quant=QuantConfig(),
    )
