"""Workload specialization (paper Section 4.3).

Turns an LLM architecture + trace (prompt/generated token counts, batch)
into per-layer operator lists and memory-traffic aggregates for the
prefill and decode phases.  These feed the analytical performance model
(perfmodel.py) and the transaction emulator (emulator.py).

Each GEMM op carries the data class of its operands so the data-movement
model can apply dataflow-dependent traffic inflation (weight-stationary
re-streams activations; input/output-stationary re-stream weights) and
route each stream through the placement-derived hierarchy path.

Families covered (the 10 assigned architectures + the paper's own models):
dense / GQA transformers, MoE, encoder-decoder, cross-attention VLM,
hybrid attention+SSM (Hymba), xLSTM (mLSTM/sLSTM), and diffusion LMs
(full-sequence iterative denoising, Section 5.4.1).
"""

from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Optional

from .quant.formats import QuantConfig


class Phase(enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"


class Family(enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    ENCDEC = "encdec"
    VLM = "vlm"
    HYBRID = "hybrid"   # parallel attention + SSM heads
    SSM = "ssm"         # fully recurrent (xLSTM)
    DLLM = "dllm"       # diffusion LM


class DataClass(enum.Enum):
    WEIGHT = "weight"
    ACT = "act"
    KV = "kv"
    SCRATCH = "scratch"   # fused intermediates (attention scores): never
                          # leave on-chip memory (flash-attention style)


# Integer codes for the structure-of-arrays traffic export
# (perfmodel_jit): indices match the stream order used by the
# data-movement model (dataflow.WEIGHTS/ACTS/KV) plus SCRATCH = 3.
CLASS_CODES = {DataClass.WEIGHT: 0, DataClass.ACT: 1,
               DataClass.KV: 2, DataClass.SCRATCH: 3}


@dataclasses.dataclass(frozen=True)
class ModelDims:
    """Architecture dimensions, the analytic model's view of a model."""

    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    gated_ffn: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # enc-dec / VLM
    n_encoder_layers: int = 0
    cross_attn_every: int = 0        # 1 cross-attn layer per this many layers
    cross_len: int = 1024            # encoder / vision-token length
    # SSM / hybrid
    ssm_state: int = 0
    attn_window: int = 0             # sliding window (0 = full attention)
    # diffusion
    diffusion_steps_per_token: float = 0.25   # denoise steps per generated token
    # Layer-group restriction (paper Section 5.5, Fig. 9 left): a device
    # dedicated to one sub-workload of every layer.  "all" is the whole
    # model; "attn" keeps attention/SSM (+ KV cache + embeddings/head) and
    # drops the FFN; "ffn" keeps only the FFN experts (plus the sampling
    # head it still has to run).  Role dims are built with
    # `dataclasses.replace(dims, layer_groups=...)` so every downstream
    # cache (traffic, footprints, jitted phase tables) keys on the group.
    layer_groups: str = "all"

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def group_size(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 1 and self.top_k >= 1

    def ffn_weight_params(self) -> int:
        if self.d_ff <= 0 or self.layer_groups == "attn":
            return 0
        per_expert = (3 if self.gated_ffn else 2) * self.d_model * self.d_ff
        if self.is_moe:
            return self.n_experts * per_expert + self.d_model * self.n_experts
        return per_expert

    def attn_weight_params(self) -> int:
        if self.layer_groups == "ffn":
            return 0
        return (self.d_model * (self.q_dim + 2 * self.kv_dim)
                + self.q_dim * self.d_model)

    def ssm_weight_params(self) -> int:
        if self.layer_groups == "ffn":
            return 0
        if self.family is Family.SSM:
            return 4 * self.d_model * self.q_dim + 2 * self.d_model
        if self.family is Family.HYBRID:
            d_inner = self.q_dim
            return (2 * self.d_model * d_inner + 4 * d_inner
                    + 2 * d_inner * self.ssm_state)
        return 0

    def layer_weight_params(self) -> int:
        p = 0
        if self.family is not Family.SSM:
            p += self.attn_weight_params()
        p += self.ssm_weight_params()
        p += self.ffn_weight_params()
        p += 2 * self.d_model  # norms
        return p

    def total_params(self) -> int:
        body = self.n_layers * self.layer_weight_params()
        if self.n_encoder_layers:
            enc = self.n_encoder_layers * (self.attn_weight_params()
                                           + self.ffn_weight_params())
            body += enc + self.n_layers * self.attn_weight_params()  # cross
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            body += n_cross * self.attn_weight_params()
        emb = self.vocab * self.d_model * 2   # embedding + untied head
        return body + emb

    def active_params_per_token(self) -> int:
        """N_active for MODEL_FLOPS = 6*N_active*D (MoE routes top_k)."""
        if not self.is_moe:
            return self.total_params() - self.vocab * self.d_model
        per_expert = (3 if self.gated_ffn else 2) * self.d_model * self.d_ff
        dense_part = self.n_layers * (self.attn_weight_params()
                                      + 2 * self.d_model
                                      + self.d_model * self.n_experts)
        return dense_part + self.n_layers * self.top_k * per_expert \
            + self.vocab * self.d_model

    def kv_bytes_per_token(self, quant: QuantConfig) -> float:
        if self.family is Family.SSM or self.layer_groups == "ffn":
            return 0.0
        per_layer = 2 * self.kv_dim * quant.kv_bytes
        if self.n_encoder_layers:
            per_layer *= 2      # decoder self-attn + cross-attn K/V
        return self.n_layers * per_layer

    def ssm_state_bytes(self, batch: int, quant: QuantConfig) -> float:
        if self.layer_groups == "ffn":
            return 0.0
        if self.family is Family.SSM:
            per_layer = self.n_heads * (self.head_dim * self.head_dim
                                        + 2 * self.head_dim)
            return self.n_layers * batch * per_layer * quant.activation_bytes
        if self.family is Family.HYBRID:
            d_inner = self.q_dim
            per_layer = d_inner * self.ssm_state + 4 * d_inner
            return self.n_layers * batch * per_layer * quant.activation_bytes
        return 0.0


@dataclasses.dataclass(frozen=True)
class Trace:
    """An agentic workload trace: token usage of one request class."""

    name: str
    prompt_tokens: int
    gen_tokens: int


# Representative traces from the paper (Section 5.1).
BFCL_WEB_SEARCH = Trace("bfcl-web-search", 114_000, 5_000)
OSWORLD_LIBREOFFICE = Trace("osworld-libreoffice", 90_000, 8_000)
GSM8K_DLLM = Trace("gsm8k-dllm", 1_400, 200)
CHATBOT = Trace("chatbot", 1_400, 200)

# Agentic-length diffusion-LM traces (Section 5.4.1 workload at the
# Section 5.1 agentic scale): every denoise step reprocesses the whole
# conversation, so OSWorld/BFCL-scale prompts stress decode bandwidth
# and capacity far harder than the short GSM8K math trace — these feed
# the searched `dllm_system` bench row and the DLLM decode-role tests.
OSWORLD_DLLM = Trace("osworld-dllm", 90_000, 8_000)
BFCL_DLLM = Trace("bfcl-dllm", 114_000, 5_000)


@dataclasses.dataclass(frozen=True)
class GemmOp:
    """(m x k) @ (k x n), `count` independent instances.

    a_class / b_class / out_class: data classes of the operands, used by
    the data-movement model for placement-aware, dataflow-inflated traffic.

    a_chunks: the A panel is processed as this many independent M-chunks
    (per-request panels in a batched prefill).  Re-read inflation is
    assessed per chunk: a chunk that fits the on-chip staging allocation
    re-reads from on-chip memory, not from the hierarchy.
    """

    m: int
    k: int
    n: int
    count: float = 1.0
    a_class: DataClass = DataClass.ACT
    b_class: DataClass = DataClass.WEIGHT
    out_class: DataClass = DataClass.ACT
    a_chunks: int = 1

    @property
    def macs(self) -> float:
        return float(self.m) * self.k * self.n * self.count


@dataclasses.dataclass
class LayerTraffic:
    """Per-layer compute ops + non-GEMM traffic (bytes)."""

    gemms: list = dataclasses.field(default_factory=list)
    vector_elems: float = 0.0          # lane-op count for the vector unit
    act_extra_bytes: float = 0.0       # residual/norm streams outside GEMMs
    kv_write_bytes: float = 0.0

    def total_macs(self) -> float:
        return sum(g.macs for g in self.gemms)

    def scale(self, f: float) -> "LayerTraffic":
        return LayerTraffic(
            gemms=[dataclasses.replace(g, count=g.count * f) for g in self.gemms],
            vector_elems=self.vector_elems * f,
            act_extra_bytes=self.act_extra_bytes * f,
            kv_write_bytes=self.kv_write_bytes * f,
        )

    def merge(self, other: "LayerTraffic"):
        self.gemms += other.gemms
        self.vector_elems += other.vector_elems
        self.act_extra_bytes += other.act_extra_bytes
        self.kv_write_bytes += other.kv_write_bytes

    def gemm_geometry(self) -> tuple:
        """(numeric [G, 5] (m, k, n, count, a_chunks), class [G, 3]
        (a_class, b_class, out_class) as `CLASS_CODES` ints) — the
        structure-of-arrays view consumed by the jitted batch perfmodel.
        The GEMM list order is preserved (evaluation sums follow it)."""
        import numpy as np
        num = np.array([[g.m, g.k, g.n, g.count, g.a_chunks]
                        for g in self.gemms], dtype=np.float64)
        cls = np.array([[CLASS_CODES[g.a_class], CLASS_CODES[g.b_class],
                         CLASS_CODES[g.out_class]] for g in self.gemms],
                       dtype=np.int32)
        return num.reshape(len(self.gemms), 5), cls.reshape(
            len(self.gemms), 3)


def _attn_ops(dims: ModelDims, batch: int, q_len: int, kv_len: int,
              quant: QuantConfig, t: LayerTraffic, *, causal: bool = True):
    """Attention block: projections + grouped SDPA + out projection."""
    d, qd, kvd, dh = dims.d_model, dims.q_dim, dims.kv_dim, dims.head_dim
    g = dims.group_size
    tokens = batch * q_len
    eff_kv = min(kv_len, dims.attn_window) if dims.attn_window else kv_len
    # projections (weights); per-request panels chunk the batch
    t.gemms.append(GemmOp(tokens, d, qd + 2 * kvd, a_chunks=batch))
    t.gemms.append(GemmOp(tokens, qd, d, a_chunks=batch))
    # SDPA, one GEMM per (batch, kv-head): the g query heads of a group
    # stack along M and share the K/V matrices (GQA-aware traffic).
    frac = 0.5 if (causal and q_len > 1 and q_len == kv_len) else 1.0
    t.gemms.append(GemmOp(int(g * q_len * frac), dh, eff_kv,
                          count=batch * dims.n_kv_heads,
                          a_class=DataClass.ACT, b_class=DataClass.KV,
                          out_class=DataClass.SCRATCH))
    t.gemms.append(GemmOp(int(g * q_len * frac), eff_kv, dh,
                          count=batch * dims.n_kv_heads,
                          a_class=DataClass.SCRATCH, b_class=DataClass.KV))
    # fused online softmax: single-pass max/exp/accumulate on dedicated
    # activation pipelines -> ~1 vector lane-op per score element
    t.vector_elems += batch * dims.n_heads * q_len * eff_kv * frac * 1.0
    t.vector_elems += tokens * (qd + kvd)          # rope
    t.vector_elems += tokens * d * 4.0             # rmsnorm
    if dims.qk_norm:
        t.vector_elems += tokens * (qd + kvd) * 4.0
    t.kv_write_bytes += batch * q_len * 2 * kvd * quant.kv_bytes
    t.act_extra_bytes += 2 * tokens * d * quant.activation_bytes


def _ffn_ops(dims: ModelDims, batch: int, q_len: int, quant: QuantConfig,
             t: LayerTraffic):
    d, ff = dims.d_model, dims.d_ff
    if ff <= 0:
        return
    tokens = batch * q_len
    up_n = 2 * ff if dims.gated_ffn else ff
    if dims.is_moe:
        routed = tokens * dims.top_k
        t.gemms.append(GemmOp(tokens, d, dims.n_experts, a_chunks=batch))  # router
        t.vector_elems += tokens * dims.n_experts * 4.0
        # expert GEMMs: routed tokens spread over touched experts; each
        # touched expert streams its own weights.
        experts_touched = min(dims.n_experts, max(1, int(routed)))
        m_per = max(1, int(routed // experts_touched))
        t.gemms.append(GemmOp(m_per, d, up_n, count=experts_touched,
                              a_chunks=max(1, m_per * batch // max(1, tokens))))
        t.gemms.append(GemmOp(m_per, ff, d, count=experts_touched,
                              a_chunks=max(1, m_per * batch // max(1, tokens))))
    else:
        t.gemms.append(GemmOp(tokens, d, up_n, a_chunks=batch))
        t.gemms.append(GemmOp(tokens, ff, d, a_chunks=batch))
    t.vector_elems += tokens * ff * 2.0            # activation (+ gate mul)
    t.vector_elems += tokens * d * 4.0             # norm
    t.act_extra_bytes += 2 * tokens * d * quant.activation_bytes


def _ssm_ops(dims: ModelDims, batch: int, q_len: int, quant: QuantConfig,
             t: LayerTraffic):
    """SSM / linear-recurrent branch ops."""
    d = dims.d_model
    tokens = batch * q_len
    if dims.family is Family.SSM:
        qd, dh, nh = dims.q_dim, dims.head_dim, dims.n_heads
        t.gemms.append(GemmOp(tokens, d, 4 * qd, a_chunks=batch))
        # mLSTM chunkwise state update + readout: ~2 dh x dh matmuls/token/head
        t.gemms.append(GemmOp(dh, 1, dh, count=tokens * nh * 2,
                              a_class=DataClass.ACT, b_class=DataClass.ACT))
        t.vector_elems += tokens * nh * dh * 6.0
        state = dims.ssm_state_bytes(batch, quant) / max(1, dims.n_layers)
        t.kv_write_bytes += state
        t.act_extra_bytes += state   # state read-back
    else:  # HYBRID Mamba branch
        d_inner = dims.q_dim
        s = dims.ssm_state
        t.gemms.append(GemmOp(tokens, d, 2 * d_inner, a_chunks=batch))
        t.gemms.append(GemmOp(tokens, d_inner, d, a_chunks=batch))
        t.vector_elems += tokens * d_inner * s * 4.0   # selective scan
        state = dims.ssm_state_bytes(batch, quant) / max(1, dims.n_layers)
        t.kv_write_bytes += state
        t.act_extra_bytes += state
    t.act_extra_bytes += 2 * tokens * d * quant.activation_bytes


def layer_traffic(dims: ModelDims, phase: Phase, batch: int,
                  context: int, quant: QuantConfig,
                  q_len: Optional[int] = None) -> LayerTraffic:
    """Ops + traffic for ONE decoder layer of `dims` in `phase`.

    context: total KV length (prompt + generated so far).  PREFILL
    processes q_len (default: the whole context) query tokens; DECODE
    processes 1 token against the cache.
    """
    t = LayerTraffic()
    if phase is Phase.PREFILL:
        q = q_len if q_len is not None else context
        kv = context
    else:
        q = 1
        kv = context
    # layer-group restriction (Section 5.5): an "attn" device runs the
    # attention/SSM sub-workload of every layer, a "ffn" device only the
    # FFN experts — the split that extreme-heterogeneity prefill assigns
    # to two differently-provisioned devices.
    do_attn = dims.layer_groups != "ffn"
    do_ffn = dims.layer_groups != "attn"

    if dims.family is Family.SSM:
        if do_attn:
            _ssm_ops(dims, batch, q, quant, t)
        if do_ffn:
            _ffn_ops(dims, batch, q, quant, t)
        return t

    if dims.family is Family.HYBRID:
        if do_attn:
            _attn_ops(dims, batch, q, kv, quant, t)
            _ssm_ops(dims, batch, q, quant, t)
        if do_ffn:
            _ffn_ops(dims, batch, q, quant, t)
        return t

    if do_attn:
        _attn_ops(dims, batch, q, kv, quant, t)
        if dims.cross_attn_every and dims.cross_attn_every > 0:
            tc = LayerTraffic()
            _attn_ops(dims, batch, q, dims.cross_len, quant, tc, causal=False)
            t.merge(tc.scale(1.0 / dims.cross_attn_every))
    if do_ffn:
        _ffn_ops(dims, batch, q, quant, t)
    return t


@functools.lru_cache(maxsize=8192)
def layer_traffic_cached(dims: ModelDims, phase: Phase, batch: int,
                         context: int, quant: QuantConfig,
                         q_len: Optional[int] = None) -> LayerTraffic:
    """Memoized `layer_traffic` keyed on (dims, phase, batch, ctx, quant).

    The DSE evaluates thousands of designs against the same workload;
    designs sharing a quantization assignment and batch rebuild identical
    operator lists.  Callers MUST treat the returned object as immutable
    (use `layer_traffic` for a private copy).
    """
    return layer_traffic(dims, phase, batch, context, quant, q_len=q_len)


def lm_head_traffic(dims: ModelDims, batch: int, tokens: int,
                    quant: QuantConfig) -> LayerTraffic:
    t = LayerTraffic()
    t.gemms.append(GemmOp(batch * tokens, dims.d_model, dims.vocab,
                          a_chunks=batch))
    t.vector_elems += batch * tokens * dims.vocab * 3.0   # softmax/sample
    t.act_extra_bytes += batch * tokens * dims.d_model * quant.activation_bytes
    return t


@functools.lru_cache(maxsize=8192)
def lm_head_traffic_cached(dims: ModelDims, batch: int, tokens: int,
                           quant: QuantConfig) -> LayerTraffic:
    """Memoized `lm_head_traffic`; treat the result as immutable."""
    return lm_head_traffic(dims, batch, tokens, quant)


# ---------------------------------------------------------------------------
# Footprints (capacity planning; paper Section 4.3 decode max-batch rule)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8192)
def weight_footprint_gb(dims: ModelDims, quant: QuantConfig) -> float:
    return dims.total_params() * quant.weight_bytes / 1e9


@functools.lru_cache(maxsize=65536)
def kv_footprint_gb(dims: ModelDims, batch: int, context: int,
                    quant: QuantConfig) -> float:
    ctx = min(context, dims.attn_window) if dims.attn_window else context
    kv = dims.kv_bytes_per_token(quant) * batch * ctx
    kv += dims.ssm_state_bytes(batch, quant)
    return kv / 1e9


@functools.lru_cache(maxsize=65536)
def activation_footprint_gb(dims: ModelDims, batch: int, q_len: int,
                            quant: QuantConfig) -> float:
    """Resident activation state: every request's residual-stream panel
    plus ONE active request's widest transient (the d_ff intermediate) —
    requests are processed panel-at-a-time through each layer."""
    resident = batch * q_len * dims.d_model
    width = dims.d_ff if (dims.d_ff and not dims.is_moe
                          and dims.layer_groups != "attn") else dims.d_model
    active = q_len * max(width, dims.d_model)
    return (resident + active) * quant.activation_bytes / 1e9
