"""Analytical GPU baselines (A100 / H100) for the paper's comparisons.

The paper measures vLLM on real GPUs; this environment has no CUDA, so the
GPU baselines are *modeled* through the same roofline-style evaluator the
NPU uses: time = max(compute, HBM traffic), power = activity-weighted TDP.
Constants are public datasheet specs.  Documented deviation (DESIGN.md 8.3).
"""

from __future__ import annotations

import dataclasses

from .quant.formats import QuantConfig
from .workload import (ModelDims, Phase, Trace, layer_traffic,
                       kv_footprint_gb, weight_footprint_gb,
                       activation_footprint_gb)


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str
    fp16_tflops: float          # dense tensor-core TFLOP/s
    int8_tops: float            # dense int8 TOPS
    hbm_gb: float
    hbm_tbps: float
    tdp_w: float
    mfu: float = 0.45           # achievable fraction of peak in serving
    mbu: float = 0.70           # achievable fraction of HBM bandwidth


A100 = GPUSpec("A100-80G-SXM", fp16_tflops=312.0, int8_tops=624.0,
               hbm_gb=80.0, hbm_tbps=2.039, tdp_w=400.0)
H100 = GPUSpec("H100-80G-SXM", fp16_tflops=989.0, int8_tops=1979.0,
               hbm_gb=80.0, hbm_tbps=3.35, tdp_w=700.0)


@dataclasses.dataclass(frozen=True)
class GPUPhaseResult:
    latency_s: float
    tokens: float
    throughput_tps: float
    avg_power_w: float
    energy_per_token_j: float
    batch: int

    @property
    def tokens_per_joule(self) -> float:
        return 1.0 / self.energy_per_token_j if self.energy_per_token_j else 0.0


def _phase_flops_bytes(dims: ModelDims, phase: Phase, batch: int,
                       context: int, quant: QuantConfig) -> tuple[float, float]:
    t = layer_traffic(dims, phase, batch, context, quant)
    flops = 2.0 * t.total_macs() * dims.n_layers
    # GPU traffic: weights + KV once per pass; activations have good L2 reuse
    wb = weight_footprint_gb(dims, quant) * 1e9
    kv_read = sum(g.k * g.n * g.count for g in t.gemms
                  if g.b_class.name == "KV") * quant.kv_bytes * dims.n_layers
    bytes_ = wb + kv_read + t.act_extra_bytes * dims.n_layers
    return flops, bytes_


def evaluate_gpu(spec: GPUSpec, dims: ModelDims, trace: Trace, phase: Phase,
                 quant: QuantConfig, n_gpus: int = 4,
                 batch: int | None = None) -> GPUPhaseResult:
    """Roofline evaluation of `n_gpus` (tensor-parallel) GPUs."""
    ctx_full = trace.prompt_tokens + trace.gen_tokens
    cap = spec.hbm_gb * n_gpus
    w = weight_footprint_gb(dims, quant)
    if batch is None:
        batch = 0
        for b in [1, 2, 4, 8, 16, 32, 64, 128, 256]:
            ctx = trace.prompt_tokens if phase is Phase.PREFILL else ctx_full
            need = (w + kv_footprint_gb(dims, b, ctx, quant)
                    + activation_footprint_gb(
                        dims, b, trace.prompt_tokens
                        if phase is Phase.PREFILL else 1, quant))
            if need <= cap:
                batch = b
        if batch == 0:
            raise ValueError(f"{dims.name} does not fit {n_gpus}x{spec.name}")

    context = (trace.prompt_tokens if phase is Phase.PREFILL
               else trace.prompt_tokens + trace.gen_tokens // 2)
    flops, nbytes = _phase_flops_bytes(dims, phase, batch, context, quant)
    int8 = quant.weight_bytes <= 1.3 and quant.activation_bytes <= 1.3
    peak = (spec.int8_tops if int8 else spec.fp16_tflops) * 1e12 * n_gpus
    bw = spec.hbm_tbps * 1e12 * n_gpus
    t_compute = flops / (peak * spec.mfu)
    t_mem = nbytes / (bw * spec.mbu)
    latency = max(t_compute, t_mem)
    tokens = float(batch * (trace.prompt_tokens if phase is Phase.PREFILL
                            else 1))
    # activity-weighted power: compute-bound phases run near TDP, memory-
    # bound phases draw ~60% TDP (typical measured decode draw)
    util = t_compute / latency
    power = n_gpus * spec.tdp_w * (0.55 + 0.45 * util)
    energy = power * latency
    return GPUPhaseResult(
        latency_s=latency, tokens=tokens,
        throughput_tps=tokens / latency if latency else 0.0,
        avg_power_w=power,
        energy_per_token_j=energy / tokens if tokens else 0.0,
        batch=batch)
