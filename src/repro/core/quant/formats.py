"""Microscaling (MX) data-format definitions and bit-exact JAX emulation.

The paper's accuracy-aware quantization simulation supports the full MX
family: a block of B elements shares one scale with S exponent bits, each
element stores either an INT (MXINT: sign + mantissa) or a minifloat
(MXFP: sign + E exponent bits + M mantissa bits).  Parameterization is
(M, E, S, B) following the paper / MASE.

`quantize`/`dequantize` are pure-JAX, differentiable-through (straight-
through on round) emulations used both by the accuracy proxy and by the
quantized-KV-cache serving path; `bits_per_element` feeds the analytic
traffic/storage model.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MXFormat:
    name: str
    mantissa_bits: int        # M: mantissa bits (excl. sign, excl. implicit 1)
    exponent_bits: int        # E: per-element exponent bits (0 => MXINT)
    scale_bits: int = 8       # S: shared scale exponent bits
    block_size: int = 32      # B: elements per shared scale

    @property
    def is_int(self) -> bool:
        return self.exponent_bits == 0

    @property
    def element_bits(self) -> int:
        # sign + mantissa (+ exponent for fp)
        return 1 + self.mantissa_bits + self.exponent_bits

    @property
    def bits_per_element(self) -> float:
        return self.element_bits + self.scale_bits / self.block_size

    @property
    def bytes_per_element(self) -> float:
        return self.bits_per_element / 8.0


# Catalog used in Table 2 / Table 3. Element bit budget matches the names:
# MXINTk: 1 sign + (k-1) mantissa; MXFPk uses OCP-style splits.
FORMATS: dict[str, MXFormat] = {
    "MXINT4": MXFormat("MXINT4", mantissa_bits=3, exponent_bits=0),
    "MXINT8": MXFormat("MXINT8", mantissa_bits=7, exponent_bits=0),
    "MXINT16": MXFormat("MXINT16", mantissa_bits=15, exponent_bits=0),
    "MXFP4": MXFormat("MXFP4", mantissa_bits=1, exponent_bits=2),
    "MXFP8": MXFormat("MXFP8", mantissa_bits=3, exponent_bits=4),   # e4m3
    "MXFP16": MXFormat("MXFP16", mantissa_bits=10, exponent_bits=5),
    "FP16": MXFormat("FP16", mantissa_bits=10, exponent_bits=5, scale_bits=0,
                     block_size=1),
    "BF16": MXFormat("BF16", mantissa_bits=7, exponent_bits=8, scale_bits=0,
                     block_size=1),
}


def get(name: str) -> MXFormat:
    try:
        return FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown MX format {name!r}; known: {sorted(FORMATS)}")


# ---------------------------------------------------------------------------
# Bit-exact emulation
# ---------------------------------------------------------------------------

def _blockify(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    """Reshape the trailing axis into blocks, padding with zeros."""
    *lead, last = x.shape
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    return x.reshape(*lead, -1, block), pad


def _shared_scale(blocks: jnp.ndarray, fmt: MXFormat) -> jnp.ndarray:
    """Power-of-two shared scale per block (S exponent bits)."""
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    amax = jnp.where(amax == 0, 1.0, amax)
    if fmt.is_int:
        qmax = 2.0 ** fmt.mantissa_bits - 1.0  # symmetric int range
        target = qmax
    else:
        # largest representable minifloat magnitude
        emax = 2 ** (fmt.exponent_bits - 1) - 1
        target = (2.0 - 2.0 ** (-fmt.mantissa_bits)) * 2.0 ** emax
    # scale = 2^ceil(log2(amax/target)), clipped to the S-bit exponent range
    exp = jnp.ceil(jnp.log2(amax / target))
    if fmt.scale_bits > 0:
        lim = 2.0 ** (fmt.scale_bits - 1) - 1
        exp = jnp.clip(exp, -lim, lim)
    return 2.0 ** exp


def _quantize_int(v: jnp.ndarray, fmt: MXFormat) -> jnp.ndarray:
    qmax = 2.0 ** fmt.mantissa_bits - 1.0
    return jnp.clip(jnp.round(v), -qmax, qmax)


def _quantize_fp(v: jnp.ndarray, fmt: MXFormat) -> jnp.ndarray:
    """Round to the nearest (E, M) minifloat value (with denormals)."""
    emax = 2 ** (fmt.exponent_bits - 1) - 1
    emin = 1 - emax
    maxval = (2.0 - 2.0 ** (-fmt.mantissa_bits)) * 2.0 ** emax
    sign = jnp.sign(v)
    mag = jnp.abs(v)
    mag = jnp.minimum(mag, maxval)
    # exponent of each value, clamped into [emin, emax]
    e = jnp.floor(jnp.log2(jnp.where(mag == 0, 1.0, mag)))
    e = jnp.clip(e, emin, emax)
    step = 2.0 ** (e - fmt.mantissa_bits)
    q = jnp.round(mag / step) * step
    return sign * q


@functools.partial(jax.jit, static_argnames=("fmt_name",))
def quantize_dequantize(x: jnp.ndarray, fmt_name: str) -> jnp.ndarray:
    """Fake-quantize x through the MX format (same shape/dtype out)."""
    fmt = get(fmt_name)
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    if fmt.block_size == 1 and fmt.scale_bits == 0:
        # plain fp16/bf16 style cast
        out = _quantize_fp(xf, fmt) if not fmt.is_int else _quantize_int(xf, fmt)
        return out.astype(orig_dtype)
    last = x.shape[-1]
    blocks, pad = _blockify(xf, fmt.block_size)
    scale = _shared_scale(blocks, fmt)
    v = blocks / scale
    q = _quantize_int(v, fmt) if fmt.is_int else _quantize_fp(v, fmt)
    out = (q * scale).reshape(*x.shape[:-1], -1)
    out = out[..., :last]
    return out.astype(orig_dtype)


def quantization_error(x: jnp.ndarray, fmt_name: str) -> float:
    """Relative L2 error of fake-quantization (accuracy-proxy building block)."""
    q = quantize_dequantize(x, fmt_name)
    num = jnp.linalg.norm((q - x).astype(jnp.float32))
    den = jnp.linalg.norm(x.astype(jnp.float32)) + 1e-12
    return float(num / den)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Per-tensor-class precision assignment (Table 2 rows)."""

    weight: str = "MXINT8"
    activation: str = "MXINT8"
    kv_cache: str = "MXINT8"

    @property
    def weight_bytes(self) -> float:
        return get(self.weight).bytes_per_element

    @property
    def activation_bytes(self) -> float:
        return get(self.activation).bytes_per_element

    @property
    def kv_bytes(self) -> float:
        return get(self.kv_cache).bytes_per_element

    @property
    def matrix_rate_scale(self) -> float:
        """Datapath throughput multiplier vs a 16-bit MAC array: narrow
        operands double/quadruple MACs per PE per cycle (W8A8 -> 2x)."""
        bits = max(get(self.weight).element_bits,
                   get(self.activation).element_bits)
        return max(1.0, 16.0 / bits)

    @property
    def vector_rate_scale(self) -> float:
        bits = get(self.activation).element_bits
        return max(1.0, 16.0 / bits)

    def describe(self) -> str:
        return f"W:{self.weight}/A:{self.activation}/KV:{self.kv_cache}"


FP16_CONFIG = QuantConfig("FP16", "FP16", "FP16")
Q8_CONFIG = QuantConfig("MXINT8", "MXINT8", "MXINT8")
Q4_CONFIG = QuantConfig("MXINT4", "MXINT4", "MXINT4")
