"""Accuracy-aware quantization simulation (paper Table 3 proxy).

The paper scores W/A/KV bit-width configs on real agentic benchmarks
(BFCL success rate).  Those harnesses cannot run offline, so the quality
axis is proxied by comparing a REAL model forward in full precision vs
with fake-quantized weights/activations/KV: logit KL divergence and
top-1 agreement over synthetic batches.  The proxy reproduces the
paper's selection signal (8/8/8 ~ fp baseline, 4/4/4 collapses); the
traffic/storage columns of Table 3 are exact (formats.py).
Documented deviation: DESIGN.md section 8.2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.formats import QuantConfig, quantize_dequantize


def _quantize_params(params, fmt: str):
    def q(x):
        if x.ndim >= 2:
            return quantize_dequantize(x, fmt)
        return x
    return jax.tree.map(q, params)


def quantization_quality_proxy(cfg, quant: QuantConfig, batches: int = 4,
                               batch: int = 4, seq: int = 32,
                               seed: int = 0) -> dict:
    """Run a reduced arch fp32 vs quantized; return quality metrics."""
    from repro.runtime.steps import model_fns
    from repro.models import transformer as tf

    mf = model_fns(cfg)
    params = mf.init(jax.random.key(seed))
    qparams = _quantize_params(params, quant.weight)

    kls, agree = [], []
    for i in range(batches):
        toks = jax.random.randint(jax.random.key(100 + i),
                                  (batch, seq), 0, cfg.vocab)
        logits_fp, _, _ = tf.forward(cfg, params, toks)
        # activation fake-quantization: quantize the embedding inputs
        # (per-layer act quant emulation folds into weights for this
        # proxy; KV precision exercised via the serving path tests)
        emb = params["embed"][toks]
        emb_q = quantize_dequantize(emb, quant.activation)
        logits_q, _, _ = tf.forward(cfg, qparams, emb_q)
        p = jax.nn.log_softmax(logits_fp.astype(jnp.float32), axis=-1)
        q = jax.nn.log_softmax(logits_q.astype(jnp.float32), axis=-1)
        kl = jnp.sum(jnp.exp(p) * (p - q), axis=-1)
        kls.append(float(jnp.mean(kl)))
        agree.append(float(jnp.mean(
            (jnp.argmax(p, -1) == jnp.argmax(q, -1)))))
    return {"logit_kl": sum(kls) / len(kls),
            "top1_agreement": sum(agree) / len(agree),
            "config": quant.describe()}
