"""Transaction-level memory-hierarchy emulator (paper Section 5.6).

Cross-validates the analytic model: where perfmodel.py computes closed-form
phase latencies, this emulator *schedules individual transfer and compute
transactions* on an event timeline with explicit double-buffering, chunked
transfers, and per-boundary bandwidth occupancy.  It is deliberately
independent code (different formulation, same physics) so agreement is
meaningful — the reproduction of the paper's Table 9, where the analytic
model lands within ~10-20% of the (slower) emulator.

Model: a layer pass is a pipeline of CHUNKS.  Each chunk needs its share
of the matrix stream (weights+KV), its share of the vector stream (acts),
and its compute time.  Chunk transfers traverse the hierarchy level by
level (deepest resident level -> level 0) as discrete transactions; each
boundary is a resource that serializes its transactions (bandwidth
occupancy), and compute for chunk i overlaps transfers for chunk i+1
(double buffering).
"""

from __future__ import annotations

import dataclasses

from .dataflow import ACTS, KV, WEIGHTS
from .perfmodel import class_traffic_bytes, _placement_for
from .npu import NPUConfig
from .workload import LayerTraffic, ModelDims, Phase, Trace, layer_traffic
from .compute import gemm_cycles, vector_seconds


@dataclasses.dataclass
class EmulationResult:
    total_s: float
    n_chunks: int
    boundary_busy_s: list      # per-boundary occupied time
    compute_busy_s: float

    @property
    def utilization(self) -> float:
        return self.compute_busy_s / self.total_s if self.total_s else 0.0


def _chunk_stream_times(npu: NPUConfig, nbytes: float, alphas: list,
                        share: float, n_chunks: int) -> list:
    """Per-chunk transaction times at each boundary for one stream.

    Returns [(boundary_index, seconds), ...] for ONE chunk; the chunk's
    bytes start at their resident level and hop boundary by boundary.
    """
    h = npu.hierarchy
    effs = [b * share * 1e9 for b in h.effective_bandwidths_gbps()]
    lams = [l.latency_s for l in h.levels]
    per_chunk = nbytes / n_chunks
    txns = []
    remaining = 1.0   # fraction of the chunk still arriving from deeper
    for i, a in enumerate(alphas):
        # fraction resident at level i crosses boundaries i, i-1, ..., 0
        frac_here = remaining * a
        if frac_here <= 1e-15:
            continue
        for b in range(i, -1, -1):
            txns.append((b, lams[b] + per_chunk * frac_here / effs[b]))
        remaining -= frac_here
        if remaining <= 1e-15:
            break
    return txns


def emulate_layer(npu: NPUConfig, dims: ModelDims, phase: Phase, batch: int,
                  context: int, n_chunks: int = 8) -> EmulationResult:
    """Event-driven emulation of one layer pass split into n_chunks."""
    traffic = layer_traffic(dims, phase, batch, context, npu.quant)
    q_len = context if phase is Phase.PREFILL else 1
    placement = _placement_for(npu, dims, batch, context, q_len)
    cls_bytes = class_traffic_bytes(npu, traffic, placement)
    mx_share, vec_share = npu.strategy.bw_split()

    # compute time per chunk (matrix + vector engines in parallel)
    t_gemm = sum(gemm_cycles(npu.compute, g.m, g.k, g.n,
                             npu.strategy.dataflow, count=g.count).seconds
                 for g in traffic.gemms) / npu.quant.matrix_rate_scale
    t_vec = (vector_seconds(npu.compute, traffic.vector_elems)
             / npu.quant.vector_rate_scale)
    compute_per_chunk = max(t_gemm, t_vec) / n_chunks

    # on-chip scratch stream rides with compute (flash-style fusion)
    from .memtech import MemKind
    onchip_bw = max(sum(l.bandwidth_gbps for l in npu.hierarchy.levels
                        if l.tech.kind is MemKind.ON_CHIP),
                    npu.hierarchy.levels[0].bandwidth_gbps) * 1e9
    scratch_per_chunk = cls_bytes[3] / onchip_bw / n_chunks
    compute_per_chunk = max(compute_per_chunk, scratch_per_chunk)

    # per-chunk transfer transactions per stream
    streams = []
    for cls, share in ((WEIGHTS, mx_share), (KV, mx_share), (ACTS, vec_share)):
        if cls_bytes[cls] <= 0:
            continue
        alphas = placement.resident_fraction_chain(cls)
        streams.append(_chunk_stream_times(npu, cls_bytes[cls], alphas,
                                           share, n_chunks))

    # event timeline: boundary b is busy until boundary_free[b]; compute
    # for chunk i starts when its transfers land AND the previous chunk's
    # compute finished (double buffer depth 2).
    n_bounds = len(npu.hierarchy.levels)
    boundary_free = [0.0] * n_bounds
    boundary_busy = [0.0] * n_bounds
    compute_free = 0.0
    compute_busy = 0.0
    chunk_ready = 0.0
    for _ in range(n_chunks):
        # schedule this chunk's transactions (deep boundaries first)
        arrive = 0.0
        for txns in streams:
            for b, dt in sorted(txns, key=lambda t: -t[0]):
                start = boundary_free[b]
                boundary_free[b] = start + dt
                boundary_busy[b] += dt
                arrive = max(arrive, boundary_free[b])
        # compute starts when data arrived and engine free
        start = max(arrive, compute_free)
        compute_free = start + compute_per_chunk
        compute_busy += compute_per_chunk
        chunk_ready = compute_free
    return EmulationResult(total_s=chunk_ready, n_chunks=n_chunks,
                           boundary_busy_s=boundary_busy,
                           compute_busy_s=compute_busy)


def emulate_layer_seconds(npu: NPUConfig, dims: ModelDims, phase: Phase,
                          batch: int, context: int,
                          n_chunks: int = 8) -> float:
    return emulate_layer(npu, dims, phase, batch, context, n_chunks).total_s


def analytic_layer_seconds(npu: NPUConfig, dims: ModelDims, phase: Phase,
                           batch: int, context: int) -> float:
    """The analytic model's per-layer time (for Table 9 comparison)."""
    from .perfmodel import _layer_time_and_energy
    traffic = layer_traffic(dims, phase, batch, context, npu.quant)
    q_len = context if phase is Phase.PREFILL else 1
    placement = _placement_for(npu, dims, batch, context, q_len)
    t, _, _, _ = _layer_time_and_energy(npu, traffic, placement)
    return t
