"""Datacenter serving layer: traffic mixes, queueing delay, p99 SLOs.

The single-system evaluators (`disagg.evaluate_system*`) report
steady-state tokens/joule of ONE system instance on ONE static request
class.  Production serving is provisioned differently: a *traffic mix*
of heterogeneous request classes arrives at given rates, each role is
*replicated* `n_r` times, decode traffic is *routed* across the decode
roles, and the fleet must meet tail-latency SLOs — p99 TTFT/TPOT per
class — inside a datacenter power budget.  This module turns the
per-role throughput numbers of `perfmodel_jit` into those fleet-level
metrics, twice:

* `evaluate_serving` — the scalar reference oracle (pure Python over
  `perfmodel.evaluate`, mirrors `disagg._combine_system` per class);
* `FleetEvaluator` — the batched/jitted hot path: per-role metric rows
  are computed once per *distinct device half* (replica and routing
  genes never change a role's hierarchy, so they are cache keys, not
  rebuild triggers) and a single `jax.jit` program folds a whole
  [n-designs] fleet pool into p99/efficiency arrays.

Queueing model (documented closed forms, so the whole thing stays
jit/vmap-friendly — see docs/serving.md for the derivations):

* Each role is an M/M/n_r station.  A class-c request occupies a
  replica of role r for ``occ[r][c]`` seconds (prefill: its share of
  one batched pass, ``latency_s / batch``; decode: its routed share of
  the generation, ``phi[c][j] * gen_c / throughput_tps``).  Utilization
  ``rho_r = sum_c lam_c * occ[r][c] / n_r`` must stay < 1.
* Mean queueing wait is Sakasegawa's (1977) M/M/n approximation
  ``Wq_r = tau_r * rho_r**(sqrt(2*(n_r+1)) - 1) / (n_r * (1 - rho_r))``
  with ``tau_r`` the arrival-weighted mean occupancy; at n_r = 1 this
  is exactly the M/M/1 ``rho * tau / (1 - rho)``.  The p99 wait uses
  the exponential-tail factor ``ln(100) * Wq``.
* ``TTFT_p99[c] = TTFT_0[c] + ln(100) * sum(prefill Wq)`` where
  TTFT_0 is the zero-load prefill chain + hand-offs (identical
  arithmetic to `_combine_system`); ``TPOT_p99[c]`` inflates each
  decode step by the processor-sharing factor ``1 / (1 - rho_r)`` —
  it diverges monotonically as any routed decode role saturates.
* Tokens/joule is per unit of *work* and therefore load-independent:
  at any stable utilization the fleet spends the same marginal energy
  per generated token, so the zero-load limit equals the single-system
  steady-state number exactly (`tests/test_serving.py` pins this).
  Fleet power is utilization-aware: every provisioned replica pays its
  static power, dynamic power scales with carried load.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .disagg import (NVLINK_GBPS, NVLINK_PJ_PER_BIT, SystemTopology,
                     _act_handoff_bytes, _link_seconds, kv_transfer_seconds)
from .perfmodel import InfeasibleConfig, evaluate
from .perfmodel_jit import NPUTable, evaluate_batch_arrays
from .workload import Family, ModelDims, Trace

# p99 of an exponential residual-wait tail: P(W > t) = exp(-t / Wq)
LN100 = math.log(100.0)


# ---------------------------------------------------------------------------
# Traffic mixes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One request class of a traffic mix: a `Trace` arriving at
    `rate_rps` requests/second under optional per-class p99 SLO caps
    (heterogeneous prompts need heterogeneous TTFT budgets — a 1.4k
    chatbot turn and a 114k agent context cannot share one cap)."""

    trace: Trace
    rate_rps: float
    ttft_p99_slo_s: Optional[float] = None
    tpot_p99_slo_s: Optional[float] = None

    def __post_init__(self):
        if not self.rate_rps > 0.0:
            raise ValueError(f"request class {self.trace.name!r} needs a "
                             f"positive arrival rate, got {self.rate_rps}")


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """A named tuple of `RequestClass`es — the serving workload unit.

    The mix is part of a serving search's identity: resuming a journal
    against a different mix must be refused, so `identity()` feeds
    `dse.journal.objective_identity`.
    """

    name: str
    classes: tuple

    def __post_init__(self):
        if not self.classes:
            raise ValueError("a TrafficMix needs at least one request class")

    @property
    def total_rate_rps(self) -> float:
        return sum(c.rate_rps for c in self.classes)

    @property
    def token_rate_tps(self) -> float:
        """Generated tokens/second the mix demands at full service."""
        return sum(c.rate_rps * c.trace.gen_tokens for c in self.classes)

    def identity(self) -> dict:
        return {
            "name": self.name,
            "classes": [{
                "trace": c.trace.name,
                "prompt_tokens": int(c.trace.prompt_tokens),
                "gen_tokens": int(c.trace.gen_tokens),
                "rate_rps": float(c.rate_rps),
                "ttft_p99_slo_s": None if c.ttft_p99_slo_s is None
                else float(c.ttft_p99_slo_s),
                "tpot_p99_slo_s": None if c.tpot_p99_slo_s is None
                else float(c.tpot_p99_slo_s),
            } for c in self.classes],
        }


def topology_routing(topology: SystemTopology, n_classes: int) -> tuple:
    """The topology's static decode split as per-class routing rows —
    what a serving evaluation of an unrouted system uses."""
    row = tuple(topology.roles[i].gen_frac
                for i in topology.decode_indices())
    return tuple(row for _ in range(n_classes))


# ---------------------------------------------------------------------------
# Queueing primitives (scalar forms; the jitted program mirrors them)
# ---------------------------------------------------------------------------

def mm_n_wait_s(tau_s: float, rho: float, n: int) -> float:
    """Sakasegawa M/M/n mean queueing wait (seconds); inf at rho >= 1."""
    if rho >= 1.0:
        return math.inf
    return (tau_s * rho ** (math.sqrt(2.0 * (n + 1.0)) - 1.0)
            / (n * (1.0 - rho)))


def _ps_inflation(rho: float) -> float:
    """Processor-sharing latency inflation of a decode step; inf at
    saturation (the monotone divergence the SLO gate rides on)."""
    if rho >= 1.0:
        return math.inf
    return 1.0 / (1.0 - rho)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingResult:
    """Fleet-level metrics of one (devices, replicas, routing) design on
    a traffic mix.  Per-class tuples are ordered like `mix.classes`,
    per-role tuples like `topology.roles`."""

    feasible: bool              # every (role, class) runs AND rho < 1
    slo_ok: bool                # feasible AND every per-class p99 cap met
    tokens_per_joule: float     # fleet work efficiency (load-independent)
    fleet_power_w: float        # static per provisioned replica + dynamic
    busy_power_w: float         # all-replicas-busy (100% utilization) power
    token_rate_tps: float       # generated tokens/s the mix demands
    ttft_p99_s: tuple
    tpot_p99_s: tuple
    ttft0_s: tuple              # zero-load TTFT (the `_combine_system` chain)
    tpot0_s: tuple
    rho: tuple                  # per-role utilization
    wq_s: tuple                 # per-role mean queueing wait
    replicas: tuple
    phi: tuple                  # per-class decode routing fractions
    topology: SystemTopology
    mix: TrafficMix


def _infeasible_result(topology: SystemTopology, mix: TrafficMix,
                       replicas: tuple, phi: tuple) -> ServingResult:
    c = len(mix.classes)
    return ServingResult(
        feasible=False, slo_ok=False, tokens_per_joule=0.0,
        fleet_power_w=0.0, busy_power_w=0.0,
        token_rate_tps=mix.token_rate_tps,
        ttft_p99_s=(math.inf,) * c, tpot_p99_s=(math.inf,) * c,
        ttft0_s=(math.inf,) * c, tpot0_s=(math.inf,) * c,
        rho=(math.inf,) * topology.k, wq_s=(math.inf,) * topology.k,
        replicas=tuple(replicas), phi=tuple(phi),
        topology=topology, mix=mix)


# ---------------------------------------------------------------------------
# Scalar reference path
# ---------------------------------------------------------------------------

def _check_phi(phi, n_classes: int, n_decode: int) -> list:
    phi = [[float(v) for v in row] for row in phi]
    if len(phi) != n_classes or any(len(row) != n_decode for row in phi):
        raise ValueError(f"routing needs [{n_classes} x {n_decode}] "
                         f"fractions")
    for row in phi:
        if abs(sum(row) - 1.0) > 1e-9 or any(v < 0.0 for v in row):
            raise ValueError(f"routing row {row} is not a simplex point")
    return phi


def _serving_from_results(topo: SystemTopology, res: list, quants: list,
                          static_w: list, dims: ModelDims, mix: TrafficMix,
                          replicas, phi) -> ServingResult:
    """Fold per-(role, class) PhaseResults + queueing into a
    ServingResult.  The per-class zero-load chain is line-for-line
    `disagg._combine_system` with the routing fractions `phi[c]` in
    place of the topology's static `gen_frac` — a single-class mix with
    the topology routing reproduces `SystemResult` exactly."""
    pre_idx = topo.prefill_indices()
    dec_idx = topo.decode_indices()
    n_cls = len(mix.classes)
    replicas = [int(v) for v in replicas]
    phi = _check_phi(phi, n_cls, len(dec_idx))
    if any(r < 1 for r in replicas) or len(replicas) != topo.k:
        raise ValueError(f"{topo.name} needs {topo.k} replica counts >= 1")
    if any(res[r][c] is None for r in range(topo.k) for c in range(n_cls)):
        return _infeasible_result(topo, mix, tuple(replicas),
                                  tuple(map(tuple, phi)))

    # --- occupancy (seconds of one replica per request) and utilization ---
    occ = [[0.0] * n_cls for _ in range(topo.k)]
    for c, rc in enumerate(mix.classes):
        for r in pre_idx:
            p = res[r][c]
            occ[r][c] = p.latency_s / p.batch
        for j, r in enumerate(dec_idx):
            d = res[r][c]
            occ[r][c] = phi[c][j] * rc.trace.gen_tokens / d.throughput_tps
    lam = [rc.rate_rps for rc in mix.classes]
    lam_tot = sum(lam)
    rho, wq = [], []
    for r in range(topo.k):
        load = sum(lam[c] * occ[r][c] for c in range(n_cls))
        rho_r = load / replicas[r]
        rho.append(rho_r)
        wq.append(mm_n_wait_s(load / lam_tot, rho_r, replicas[r]))
    stable = all(v < 1.0 for v in rho)
    wq_pre = sum(wq[r] for r in pre_idx)

    # --- per-class zero-load chain + tail inflation ---
    ttft0, tpot0, ttft99, tpot99, e_tok = [], [], [], [], []
    for c, rc in enumerate(mix.classes):
        trace = rc.trace
        gen = trace.gen_tokens
        t0 = 0.0
        e_req = 0.0
        for j, r in enumerate(pre_idx):
            p = res[r][c]
            if j > 0:
                t_a, e_a = _link_seconds(_act_handoff_bytes(
                    dims, trace, quants[pre_idx[j - 1]]))
                t0 += t_a
                e_req += e_a
            t0 += p.latency_s / p.batch
            e_req += p.avg_power_w * p.latency_s / p.batch
        t_kv, e_kv = kv_transfer_seconds(
            dims, trace, 1, quants[topo.kv_producer_index()])
        t0 += t_kv
        e_req += e_kv
        step0 = 0.0
        step99 = 0.0
        e_dec = 0.0
        mig = 0.0
        cum = 0.0
        for j, r in enumerate(dec_idx):
            d = res[r][c]
            if j > 0:
                ctx = trace.prompt_tokens + cum * gen
                t_m, e_m = _link_seconds(
                    dims.kv_bytes_per_token(quants[dec_idx[j - 1]]) * ctx)
                mig += t_m
                e_req += e_m
            step_s = (d.latency_s / gen if dims.family is Family.DLLM
                      else d.latency_s)
            f = phi[c][j]
            step0 += f * step_s
            step99 += f * step_s * _ps_inflation(rho[r])
            e_dec += f * d.energy_per_token_j
            cum += f
        e_tok.append(e_req / gen + e_dec)
        ttft0.append(t0)
        tpot0.append(step0 + mig / gen)
        ttft99.append(t0 + LN100 * wq_pre)
        tpot99.append(step99 + mig / gen)

    # --- SLOs ---
    slo = stable
    for c, rc in enumerate(mix.classes):
        if rc.ttft_p99_slo_s is not None and \
                not ttft99[c] <= rc.ttft_p99_slo_s:
            slo = False
        if rc.tpot_p99_slo_s is not None and \
                not tpot99[c] <= rc.tpot_p99_slo_s:
            slo = False

    # --- fleet efficiency + power ---
    work = sum(lam[c] * mix.classes[c].trace.gen_tokens
               for c in range(n_cls))
    joule_rate = sum(lam[c] * mix.classes[c].trace.gen_tokens * e_tok[c]
                     for c in range(n_cls))
    fleet_p = 0.0
    busy_p = 0.0
    for r in range(topo.k):
        load = sum(lam[c] * occ[r][c] for c in range(n_cls))
        dyn = sum(lam[c] * occ[r][c] * (res[r][c].avg_power_w - static_w[r])
                  for c in range(n_cls))
        fleet_p += replicas[r] * static_w[r] + dyn
        if load > 0.0:
            busy = sum(lam[c] * occ[r][c] * res[r][c].avg_power_w
                       for c in range(n_cls)) / load
        else:
            busy = static_w[r]
        busy_p += replicas[r] * busy
    return ServingResult(
        feasible=stable, slo_ok=slo,
        tokens_per_joule=work / joule_rate if joule_rate else 0.0,
        fleet_power_w=fleet_p, busy_power_w=busy_p,
        token_rate_tps=work,
        ttft_p99_s=tuple(ttft99), tpot_p99_s=tuple(tpot99),
        ttft0_s=tuple(ttft0), tpot0_s=tuple(tpot0),
        rho=tuple(rho), wq_s=tuple(wq),
        replicas=tuple(replicas), phi=tuple(map(tuple, phi)),
        topology=topo, mix=mix)


def _phase_results(npus: list, topo: SystemTopology, dims: ModelDims,
                   mix: TrafficMix) -> list:
    """[K][C] PhaseResults (None where a (role, class) is infeasible)."""
    res = [[None] * len(mix.classes) for _ in range(topo.k)]
    for r, role in enumerate(topo.roles):
        for c, rc in enumerate(mix.classes):
            try:
                res[r][c] = evaluate(
                    npus[r], role.dims_for(dims), rc.trace, role.phase,
                    context_override=role.context_for(rc.trace))
            except InfeasibleConfig:
                pass
    return res


def evaluate_serving(npus: list, replicas, phi, topology: SystemTopology,
                     dims: ModelDims, mix: TrafficMix) -> ServingResult:
    """Scalar fleet evaluation of one (devices, replicas, routing) design
    — the reference oracle the jitted `FleetEvaluator` is parity-tested
    against (same role model, `perfmodel.evaluate` per (role, class))."""
    if len(npus) != topology.k:
        raise ValueError(f"{topology.name} needs {topology.k} devices, "
                         f"got {len(npus)}")
    res = _phase_results(npus, topology, dims, mix)
    table = NPUTable.from_configs(list(npus))
    return _serving_from_results(
        topology, res, [n.quant for n in npus],
        [float(v) for v in table.static_w], dims, mix, replicas, phi)


def naive_replication(npus: list, topology: SystemTopology,
                      dims: ModelDims, mix: TrafficMix,
                      power_budget_w: float,
                      levels: Optional[tuple] = None
                      ) -> Optional[ServingResult]:
    """The baseline a searched fleet must beat: one fixed system,
    topology-default routing, uniformly replicated at the *smallest*
    level that meets every per-class p99 SLO inside the provisioned
    power budget (`sum(replicas * tdp)`).  Returns None when no level
    does.  Per-(role, class) throughput is evaluated once; only the
    queueing fold reruns per level."""
    if levels is None:
        from .dse.space import REPLICA_CHOICES
        levels = REPLICA_CHOICES
    phi = topology_routing(topology, len(mix.classes))
    res = _phase_results(npus, topology, dims, mix)
    table = NPUTable.from_configs(list(npus))
    static_w = [float(v) for v in table.static_w]
    quants = [n.quant for n in npus]
    peak_w = sum(n.tdp_w() for n in npus)
    for lvl in sorted({int(v) for v in levels}):
        if lvl * peak_w > power_budget_w:
            return None
        r = _serving_from_results(topology, res, quants, static_w, dims,
                                  mix, (lvl,) * topology.k, phi)
        if r.feasible and r.slo_ok:
            return r
    return None


# ---------------------------------------------------------------------------
# Jitted fleet program
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _fleet_program(pre_idx: tuple, dec_idx: tuple, kvp: int,
                   n_classes: int, dllm: bool):
    """One compiled queueing fold per (topology signature, class count).

    Role/class loops are unrolled at trace time (K and C are single
    digits); everything else is elementwise over the design axis, so a
    bucket-padded pool is one fused XLA program.  The arithmetic — term
    order included — mirrors `_serving_from_results` so the jitted and
    scalar paths agree to float64 rounding."""
    k = len(pre_idx) + len(dec_idx)
    pre_set = frozenset(pre_idx)

    @jax.jit
    def run(d):
        lat, bat, tps = d["lat"], d["bat"], d["tps"]
        pwr, ept = d["pwr"], d["ept"]
        static, abytes, kvptok = d["static"], d["abytes"], d["kvptok"]
        nrep, phi = d["nrep"], d["phi"]
        lam, gen, prompt = d["lam"], d["gen"], d["prompt"]
        hb2, d_model = d["hb2"], d["d_model"]
        safe_bat = jnp.maximum(bat, 1.0)
        safe_tps = jnp.where(tps > 0.0, tps, 1.0)

        # occupancy [n, K, C] and M/M/n station stats [n, K]
        occ_cols = []
        for r in range(k):
            if r in pre_set:
                occ_cols.append(lat[:, r, :] / safe_bat[:, r, :])
            else:
                j = dec_idx.index(r)
                occ_cols.append(phi[:, :, j] * gen[None, :]
                                / safe_tps[:, r, :])
        occ = jnp.stack(occ_cols, axis=1)
        load = jnp.sum(lam[None, None, :] * occ, axis=2)
        rho = load / nrep
        tau = load / jnp.sum(lam)
        stable = jnp.all(rho < 1.0, axis=1)
        one_m = jnp.where(rho < 1.0, 1.0 - rho, 1.0)
        wq = jnp.where(
            rho < 1.0,
            tau * rho ** (jnp.sqrt(2.0 * (nrep + 1.0)) - 1.0)
            / (nrep * one_m),
            jnp.inf)
        infl = jnp.where(rho < 1.0, 1.0 / one_m, jnp.inf)
        wq_pre = jnp.zeros_like(wq[:, 0])
        for r in pre_idx:
            wq_pre = wq_pre + wq[:, r]

        # per-class zero-load chains (the `_combine_system` fold)
        ttft0_c, tpot0_c, ttft99_c, tpot99_c, e_tok_c = [], [], [], [], []
        for c in range(n_classes):
            t0 = jnp.zeros_like(lat[:, 0, 0])
            e_req = jnp.zeros_like(t0)
            for j, r in enumerate(pre_idx):
                if j > 0:
                    hb = hb2 * prompt[c] * d_model * abytes[:, pre_idx[j - 1]]
                    t0 = t0 + hb / (NVLINK_GBPS * 1e9)
                    e_req = e_req + NVLINK_PJ_PER_BIT * hb * 8.0 * 1e-12
                t0 = t0 + lat[:, r, c] / safe_bat[:, r, c]
                e_req = e_req + (pwr[:, r, c] * lat[:, r, c]
                                 / safe_bat[:, r, c])
            kvb = kvptok[:, kvp] * prompt[c]
            t0 = t0 + kvb / (NVLINK_GBPS * 1e9)
            e_req = e_req + NVLINK_PJ_PER_BIT * kvb * 8.0 * 1e-12
            step0 = jnp.zeros_like(t0)
            step99 = jnp.zeros_like(t0)
            e_dec = jnp.zeros_like(t0)
            mig = jnp.zeros_like(t0)
            cum = jnp.zeros_like(t0)
            for j, r in enumerate(dec_idx):
                if j > 0:
                    ctx = prompt[c] + cum * gen[c]
                    mb = kvptok[:, dec_idx[j - 1]] * ctx
                    mig = mig + mb / (NVLINK_GBPS * 1e9)
                    e_req = e_req + NVLINK_PJ_PER_BIT * mb * 8.0 * 1e-12
                s = lat[:, r, c] / gen[c] if dllm else lat[:, r, c]
                f = phi[:, c, j]
                step0 = step0 + f * s
                step99 = step99 + f * s * infl[:, r]
                e_dec = e_dec + f * ept[:, r, c]
                cum = cum + f
            e_tok_c.append(e_req / gen[c] + e_dec)
            ttft0_c.append(t0)
            tpot0_c.append(step0 + mig / gen[c])
            ttft99_c.append(t0 + LN100 * wq_pre)
            tpot99_c.append(step99 + mig / gen[c])
        ttft0 = jnp.stack(ttft0_c, axis=1)
        tpot0 = jnp.stack(tpot0_c, axis=1)
        ttft99 = jnp.stack(ttft99_c, axis=1)
        tpot99 = jnp.stack(tpot99_c, axis=1)
        e_tok = jnp.stack(e_tok_c, axis=1)

        feasible = jnp.all(d["feas"].reshape(d["feas"].shape[0], -1) > 0.5,
                           axis=1) & stable
        slo_ok = feasible & jnp.all(
            (ttft99 <= d["ttft_cap"][None, :])
            & (tpot99 <= d["tpot_cap"][None, :]), axis=1)

        work = jnp.sum(lam * gen)
        joule_rate = jnp.sum((lam * gen)[None, :] * e_tok, axis=1)
        tokj = work / jnp.where(joule_rate > 0.0, joule_rate, 1.0)
        dyn = jnp.sum(lam[None, None, :] * occ
                      * (pwr - static[:, :, None]), axis=2)
        fleet_p = jnp.sum(nrep * static + dyn, axis=1)
        busy_num = jnp.sum(lam[None, None, :] * occ * pwr, axis=2)
        busy = jnp.where(load > 0.0,
                         busy_num / jnp.where(load > 0.0, load, 1.0),
                         static)
        busy_p = jnp.sum(nrep * busy, axis=1)
        return {"feasible": feasible, "slo_ok": slo_ok,
                "tokens_per_joule": tokj, "fleet_power_w": fleet_p,
                "busy_power_w": busy_p, "ttft_p99_s": ttft99,
                "tpot_p99_s": tpot99, "ttft0_s": ttft0, "tpot0_s": tpot0,
                "rho": rho, "wq_s": wq}

    return run


class FleetEvaluator:
    """Batched serving evaluation of encoded `ServingSpace` gene rows.

    Two-level structure, built for search loops where device halves
    repeat across candidates and replica/routing genes vary freely:

    1. **Per-role metric cache** — each distinct 17-gene half is decoded
       (`dse.space.decode_batch`) and scored by `perfmodel_jit
       .evaluate_batch_arrays` once per (role, class); the cached row
       is (feasible, latency, batch, tps, power, energy/token) per
       class plus the half's device-level constants (static power,
       activation/KV byte widths).  Replica and routing genes are NOT
       part of the key, so sweeping them is pure cache hits —
       `n_table_builds` / `n_role_evals` expose the build counts the
       cache-reuse tests pin.
    2. **One jitted queueing fold** (`_fleet_program`) over the whole
       [n, K, C] metric block — scoring a 10k+ fleet pool is a handful
       of per-role jit calls on the miss set plus one fold dispatch.
    """

    def __init__(self, topology: SystemTopology, dims: ModelDims,
                 mix: TrafficMix):
        self.topology = topology
        self.dims = dims
        self.mix = mix
        self._metric_cache = [dict() for _ in topology.roles]
        self.n_table_builds = 0
        self.n_role_evals = 0
        lam = np.array([c.rate_rps for c in mix.classes])
        gen = np.array([float(c.trace.gen_tokens) for c in mix.classes])
        prompt = np.array([float(c.trace.prompt_tokens)
                           for c in mix.classes])
        caps_t = np.array([math.inf if c.ttft_p99_slo_s is None
                           else float(c.ttft_p99_slo_s)
                           for c in mix.classes])
        caps_p = np.array([math.inf if c.tpot_p99_slo_s is None
                           else float(c.tpot_p99_slo_s)
                           for c in mix.classes])
        self._consts = {
            "lam": lam, "gen": gen, "prompt": prompt,
            "ttft_cap": caps_t, "tpot_cap": caps_p,
            "hb2": np.float64(2.0 * (dims.n_layers
                                     + dims.n_encoder_layers)),
            "d_model": np.float64(dims.d_model),
        }

    def _role_rows(self, role_i: int, halves: np.ndarray) -> tuple:
        """Cached [(C, 6) metrics, (3,) device constants] rows for the
        distinct halves of one role, gathered per design."""
        from .dse import space as sp
        role = self.topology.roles[role_i]
        cache = self._metric_cache[role_i]
        uniq, inv = np.unique(halves, axis=0, return_inverse=True)
        keys = [row.tobytes() for row in uniq]
        missing = [i for i, key in enumerate(keys) if key not in cache]
        if missing:
            table = sp.decode_batch(uniq[missing])
            self.n_table_builds += 1
            rdims = role.dims_for(self.dims)
            met = np.zeros((len(missing), len(self.mix.classes), 6))
            for ci, rc in enumerate(self.mix.classes):
                arr = evaluate_batch_arrays(
                    table, rdims, rc.trace, role.phase,
                    context_override=role.context_for(rc.trace))
                self.n_role_evals += 1
                met[:, ci, 0] = arr["feasible"]
                met[:, ci, 1] = arr["latency_s"]
                met[:, ci, 2] = arr["batch"]
                met[:, ci, 3] = arr["throughput_tps"]
                met[:, ci, 4] = arr["avg_power_w"]
                met[:, ci, 5] = arr["energy_per_token_j"]
            kvptok = np.array([self.dims.kv_bytes_per_token(q)
                               for q in table.quants])[table.quant_idx]
            for mi, ui in enumerate(missing):
                cache[keys[ui]] = (met[mi], np.array(
                    [table.static_w[mi], table.a_bytes[mi], kvptok[mi]]))
        u_met = np.empty((len(uniq), len(self.mix.classes), 6))
        u_dev = np.empty((len(uniq), 3))
        for i, key in enumerate(keys):
            m, dev = cache[key]
            u_met[i] = m
            u_dev[i] = dev
        return u_met[inv], u_dev[inv]

    def evaluate_genes(self, xs: np.ndarray) -> dict:
        """Score [n, n_dims] encoded serving designs; returns the
        `_fleet_program` output dict as numpy arrays of length n.  Rows
        must be `ServingSpace.valid_mask`-valid (undefined metrics, not
        exceptions, otherwise — same contract as `decode_batch`)."""
        from .dse import space as sp
        topo = self.topology
        xs = np.asarray(xs, dtype=np.int64)
        n = xs.shape[0]
        n_cls = len(self.mix.classes)
        dev_genes = topo.k * sp.N_DIMS
        met = np.empty((n, topo.k, n_cls, 6))
        dev = np.empty((n, topo.k, 3))
        for r in range(topo.k):
            half = xs[:, r * sp.N_DIMS:(r + 1) * sp.N_DIMS]
            met[:, r], dev[:, r] = self._role_rows(r, half)
        nrep = np.asarray(sp.REPLICA_CHOICES, dtype=np.float64)[
            xs[:, dev_genes:dev_genes + topo.k]]
        route = xs[:, dev_genes + topo.k:].reshape(
            n, n_cls, len(topo.decode_indices()))
        phi = sp.routing_fractions(route)
        d = {
            "feas": met[..., 0], "lat": met[..., 1], "bat": met[..., 2],
            "tps": met[..., 3], "pwr": met[..., 4], "ept": met[..., 5],
            "static": dev[..., 0], "abytes": dev[..., 1],
            "kvptok": dev[..., 2], "nrep": nrep, "phi": phi,
        }
        # bucket-pad the design axis (power of two, floor 64) so varying
        # pool sizes reuse one compiled fold per bucket
        bucket = 64
        while bucket < n:
            bucket *= 2
        if bucket != n:
            pad = np.concatenate([np.arange(n),
                                  np.zeros(bucket - n, dtype=np.int64)])
            d = {key: v[pad] for key, v in d.items()}
        d.update(self._consts)
        prog = _fleet_program(
            tuple(topo.prefill_indices()), tuple(topo.decode_indices()),
            topo.kv_producer_index(), n_cls,
            self.dims.family is Family.DLLM)
        with enable_x64():
            out = prog(d)
            return {key: np.asarray(v)[:n] for key, v in out.items()}
