"""N-device disaggregated system model (paper Sections 5.3, 5.5).

A disaggregated serving system assigns each *role* of the inference
pipeline to a dedicated device with its own memory system; finished
stages hand their state to the next device over an interconnect (the
paper models NVLink, following LLMCompass).

Role / topology model
---------------------
`Role` names one pipeline stage and how the full-model workload is
restricted for the device serving it:

  * ``phase`` — PREFILL or DECODE (which per-phase evaluator scores it);
  * ``groups`` — layer-group restriction ("all" | "attn" | "ffn"): the
    Section 5.5 prefill split by layer group (Fig. 9 left), realized as
    `ModelDims.layer_groups` so footprints, traffic and the jitted
    phase tables all see the restricted sub-model;
  * ``ctx_frac`` — decode-phase restriction (Fig. 9 right): per-step
    traffic evaluated at context = prompt + num/den of the generated
    tokens (capacity stays at the full context), via the same
    `context_override` the scalar `decode_phase_profile` uses;
  * ``gen_frac`` — the share of each request's generated tokens this
    decode role produces (0 for prefill roles).

`SystemTopology` is an ordered tuple of roles.  Composition rules
(generalizing the original prefill+decode pair arithmetic):

  * prefill roles chain *serially* per request: TTFT sums their
    per-request latencies plus the per-link activation hand-offs
    (devices pipeline across requests, so all stay busy in steady
    state);
  * the last prefill role ships the prompt KV to the first decode role
    (`kv_transfer_seconds`);
  * decode roles chain by generation progress: a request generates
    ``gen_frac`` of its tokens on each role, migrating its KV at every
    switch; energy per generated token is the gen_frac-weighted sum,
    and the aggregate token rate is bottlenecked by
    ``min(role_tps / gen_frac)``;
  * total system power and per-request energy sum over all roles and
    links.

`PD_PAIR` (plain prefill + decode) reproduces the original pair model
bit-for-bit; `EXTREME_4ROLE` is the Section 5.5 extreme-heterogeneity
system (prefill-attn, prefill-ffn, decode-early, decode-late).  After
this layer, "add a role" is a data change — a new `Role` row — not a
code change.

`evaluate_system` scores one hand-picked device tuple;
`evaluate_system_batch` scores whole DSE candidate batches by
deduplicating the per-role halves and routing them through the jitted
`perfmodel.evaluate_batch` with per-(role, phase) memoization — the
system-search hot path behind `dse.runner.SystemObjective`.  The
original pair entry points (`evaluate_disaggregated`,
`evaluate_disagg_batch`) are thin wrappers over the K=2 topology.

End-to-end metrics:

  TTFT  = prefill chain latency + KV transfer time
  TPS   = decode tokens/s (per request and aggregate)
  token/J across all devices + transfer energy
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .npu import NPUConfig
from .perfmodel import (InfeasibleConfig, PhaseResult, evaluate,
                        evaluate_batch, evaluate_decode, evaluate_prefill)
from .workload import Family, ModelDims, Phase, Trace, layer_traffic

# NVLink-class chip-to-chip interconnect (LLMCompass-style constants)
NVLINK_GBPS = 450.0         # effective per-direction bandwidth
NVLINK_PJ_PER_BIT = 10.0    # link + serdes energy


@dataclasses.dataclass(frozen=True)
class DisaggResult:
    ttft_s: float
    decode_tps_per_request: float
    decode_tps_aggregate: float
    kv_transfer_s: float
    total_power_w: float
    tokens_per_joule: float
    prefill: PhaseResult
    decode: PhaseResult


def kv_transfer_seconds(dims: ModelDims, trace: Trace, batch: int,
                        quant) -> tuple[float, float]:
    """(seconds, joules) to move one batch's prompt KV to the decode device."""
    kv_bytes = dims.kv_bytes_per_token(quant) * trace.prompt_tokens * batch
    t = kv_bytes / (NVLINK_GBPS * 1e9)
    e = NVLINK_PJ_PER_BIT * kv_bytes * 8.0 * 1e-12
    return t, e


def _link_seconds(nbytes: float) -> tuple[float, float]:
    """(seconds, joules) to move `nbytes` over the NVLink-class link."""
    return (nbytes / (NVLINK_GBPS * 1e9),
            NVLINK_PJ_PER_BIT * nbytes * 8.0 * 1e-12)


# ---------------------------------------------------------------------------
# Roles and topologies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Role:
    """One pipeline stage of a disaggregated system (see module doc)."""

    name: str
    phase: Phase
    groups: str = "all"                         # "all" | "attn" | "ffn"
    ctx_frac: Optional[tuple] = None            # (num, den) of gen tokens
    gen_frac: float = 0.0                       # share of generated tokens

    def dims_for(self, dims: ModelDims) -> ModelDims:
        """The (possibly layer-group-restricted) model this role runs."""
        if self.groups == "all":
            return dims
        return dataclasses.replace(dims, layer_groups=self.groups)

    def context_for(self, trace: Trace) -> Optional[int]:
        """Decode-traffic context override, or None for the trace average.

        Uses the same integer arithmetic as `decode_phase_profile`
        (prompt + num * gen // den)."""
        if self.ctx_frac is None:
            return None
        num, den = self.ctx_frac
        return trace.prompt_tokens + num * trace.gen_tokens // den


@dataclasses.dataclass(frozen=True)
class SystemTopology:
    """An ordered tuple of `Role`s; prefill roles must precede decode
    roles, and the decode roles' `gen_frac` must sum to 1."""

    name: str
    roles: tuple

    def __post_init__(self):
        phases = [r.phase for r in self.roles]
        n_pre = sum(p is Phase.PREFILL for p in phases)
        if any(p is Phase.PREFILL for p in phases[n_pre:]):
            raise ValueError("prefill roles must precede decode roles")
        if n_pre == len(phases):
            raise ValueError("topology needs at least one decode role")
        if n_pre == 0:
            raise ValueError("topology needs at least one prefill role")
        for r in self.roles:
            if r.phase is Phase.PREFILL and r.gen_frac != 0.0:
                raise ValueError(
                    f"prefill role {r.name!r} cannot have gen_frac")
            if r.phase is Phase.DECODE and not (0.0 <= r.gen_frac <= 1.0):
                raise ValueError(
                    f"decode role {r.name!r} gen_frac {r.gen_frac} "
                    "outside [0, 1]")
        gf = sum(r.gen_frac for r in self.roles if r.phase is Phase.DECODE)
        if abs(gf - 1.0) > 1e-9:
            raise ValueError(f"decode gen_frac must sum to 1, got {gf}")

    @property
    def k(self) -> int:
        return len(self.roles)

    def prefill_indices(self) -> list:
        return [i for i, r in enumerate(self.roles)
                if r.phase is Phase.PREFILL]

    def decode_indices(self) -> list:
        return [i for i, r in enumerate(self.roles)
                if r.phase is Phase.DECODE]

    def kv_producer_index(self) -> int:
        """The prefill role that builds (and ships) the KV cache: the
        first one whose layer group holds KV state."""
        for i in self.prefill_indices():
            if self.roles[i].groups != "ffn":
                return i
        return self.prefill_indices()[0]


# The original PD pair: the K=2 specialization every existing caller
# and test pins down (byte-identical composition arithmetic).
PD_PAIR = SystemTopology("pd-pair", (
    Role("prefill", Phase.PREFILL),
    Role("decode", Phase.DECODE, gen_frac=1.0),
))

# Section 5.5 extreme heterogeneity: prefill split by layer group,
# decode split by generation phase (early/late context at the same
# quartile points Fig. 9 profiles).
EXTREME_4ROLE = SystemTopology("extreme-4role", (
    Role("prefill-attn", Phase.PREFILL, groups="attn"),
    Role("prefill-ffn", Phase.PREFILL, groups="ffn"),
    Role("decode-early", Phase.DECODE, ctx_frac=(1, 4), gen_frac=0.5),
    Role("decode-late", Phase.DECODE, ctx_frac=(3, 4), gen_frac=0.5),
))

# Diffusion-LM serving fleet (Section 5.4.1 workload as a searched
# scenario): one prompt-prefill device feeding an early/late denoise
# split.  A DLLM decode role's ctx_frac sets the sequence length each
# denoise step reprocesses (capacity stays at the full context) — the
# same quartile points as the autoregressive decode split, but the
# traffic is a full PREFILL-geometry pass per step, so early and late
# devices diverge far harder than in the autoregressive case.
DLLM_3ROLE = SystemTopology("dllm-3role", (
    Role("prefill", Phase.PREFILL),
    Role("denoise-early", Phase.DECODE, ctx_frac=(1, 4), gen_frac=0.5),
    Role("denoise-late", Phase.DECODE, ctx_frac=(3, 4), gen_frac=0.5),
))

# Fleet-scale topology for the batched-acquisition benchmark: layer-group
# prefill split plus a four-way decode-phase split at the octile context
# points.  Six roles put `SystemSpace(6)` at 102 genes — the 100+-gene
# regime the ROADMAP's replication/placement work will live in — which
# is what the `fleet1000` bench row (1000-eval seeded q-EHVI search)
# exercises end-to-end.
FLEET_6ROLE = SystemTopology("fleet-6role", (
    Role("prefill-attn", Phase.PREFILL, groups="attn"),
    Role("prefill-ffn", Phase.PREFILL, groups="ffn"),
    Role("decode-p1", Phase.DECODE, ctx_frac=(1, 8), gen_frac=0.25),
    Role("decode-p2", Phase.DECODE, ctx_frac=(3, 8), gen_frac=0.25),
    Role("decode-p3", Phase.DECODE, ctx_frac=(5, 8), gen_frac=0.25),
    Role("decode-p4", Phase.DECODE, ctx_frac=(7, 8), gen_frac=0.25),
))


@dataclasses.dataclass(frozen=True)
class SystemResult:
    """End-to-end metrics of one K-role system (field names shared with
    `DisaggResult` so objective wrappers and benches read either)."""

    ttft_s: float
    decode_tps_per_request: float
    decode_tps_aggregate: float
    kv_transfer_s: float
    total_power_w: float
    tokens_per_joule: float
    topology: SystemTopology
    roles: tuple                     # one PhaseResult per topology role


def _act_handoff_bytes(dims: ModelDims, trace: Trace, quant) -> float:
    """Activation bytes one request ships between two prefill layer-group
    devices: the d_model residual panel crosses the link twice per layer
    (attn -> ffn and back)."""
    n_layers = dims.n_layers + dims.n_encoder_layers
    return (2.0 * n_layers * trace.prompt_tokens * dims.d_model
            * quant.activation_bytes)


def _combine_system(topo: SystemTopology, results: list, quants: list,
                    dims: ModelDims, trace: Trace) -> SystemResult:
    """Fold per-role PhaseResults into end-to-end system metrics.

    This is THE composition rule (module doc): for `PD_PAIR` the
    accumulation order reproduces the original pair arithmetic
    bit-for-bit (the sha-pinned paired search trajectories depend on
    it), and every K-role topology is the same loop over more roles.
    """
    gen = trace.gen_tokens
    pre_idx = topo.prefill_indices()
    dec_idx = topo.decode_indices()

    # --- prefill chain: serial per request, activation links between ---
    ttft = 0.0
    e_req = 0.0                     # per-request energy up to decode
    for j, i in enumerate(pre_idx):
        p = results[i]
        if j > 0:                   # hand-off from the previous stage
            t_a, e_a = _link_seconds(
                _act_handoff_bytes(dims, trace, quants[pre_idx[j - 1]]))
            ttft += t_a
            e_req += e_a
        ttft += p.latency_s / p.batch
        e_req += p.avg_power_w * p.latency_s / p.batch

    # --- prompt-KV hand-off to the first decode role ---
    kv_quant = quants[topo.kv_producer_index()]
    t_kv, e_kv = kv_transfer_seconds(dims, trace, 1, kv_quant)
    ttft += t_kv
    e_req += e_kv

    # --- decode chain: generation-phase split with KV migration ---
    step_per_token = 0.0            # gen_frac-weighted per-step latency
    e_per_token_dec = 0.0
    agg_tps = float("inf")
    mig_s = 0.0
    cum_frac = 0.0
    for j, i in enumerate(dec_idx):
        r, d = topo.roles[i], results[i]
        if j > 0:                   # migrate the KV grown so far
            ctx_switch = trace.prompt_tokens + cum_frac * gen
            prev_q = quants[dec_idx[j - 1]]
            t_m, e_m = _link_seconds(
                dims.kv_bytes_per_token(prev_q) * ctx_switch)
            mig_s += t_m
            e_req += e_m
        # an autoregressive decode role's latency_s is one step = one
        # token per request; a DLLM role has no step — its latency_s is
        # the whole generation's denoise time, so normalize to
        # per-generated-token units before the gen_frac-weighted fold
        step_s = (d.latency_s / gen if dims.family is Family.DLLM
                  else d.latency_s)
        step_per_token += r.gen_frac * step_s
        e_per_token_dec += r.gen_frac * d.energy_per_token_j
        if r.gen_frac > 0:
            agg_tps = min(agg_tps, d.throughput_tps / r.gen_frac)
        cum_frac += r.gen_frac

    # steady state: all devices busy; energy per generated token counts
    # the amortized prefill+link energy per request's gen_tokens plus
    # the weighted decode energy.
    e_per_gen_token = e_req / gen + e_per_token_dec
    step_req = step_per_token + mig_s / gen      # incl. amortized migration
    power = 0.0
    for d in results:
        power += d.avg_power_w
    return SystemResult(
        ttft_s=ttft,
        decode_tps_per_request=1.0 / step_req if step_req else 0.0,
        decode_tps_aggregate=agg_tps if dec_idx else 0.0,
        kv_transfer_s=t_kv,
        total_power_w=power,
        tokens_per_joule=1.0 / e_per_gen_token if e_per_gen_token else 0.0,
        topology=topo, roles=tuple(results))


def _pair_result(sys_r: SystemResult) -> DisaggResult:
    """SystemResult -> the original pair record (K=2 compatibility)."""
    pre, dec = sys_r.roles
    return DisaggResult(
        ttft_s=sys_r.ttft_s,
        decode_tps_per_request=sys_r.decode_tps_per_request,
        decode_tps_aggregate=sys_r.decode_tps_aggregate,
        kv_transfer_s=sys_r.kv_transfer_s,
        total_power_w=sys_r.total_power_w,
        tokens_per_joule=sys_r.tokens_per_joule,
        prefill=pre, decode=dec)


def _combine_phase_results(pre: PhaseResult, dec: PhaseResult,
                           dims: ModelDims, trace: Trace,
                           prefill_quant) -> DisaggResult:
    """Fold one prefill + one decode PhaseResult into end-to-end metrics
    (the `PD_PAIR` instance of `_combine_system`; kept as the pair
    evaluators' entry point so scalar and batched numbers agree
    exactly).  The KV transfer is quantified at the prefill device's KV
    format (the pair constraint in dse.space.PairedSpace guarantees the
    decode device consumes the same format)."""
    return _pair_result(_combine_system(
        PD_PAIR, [pre, dec], [prefill_quant, prefill_quant], dims, trace))


def evaluate_system(npus: list, topo: SystemTopology, dims: ModelDims,
                    trace: Trace, calibration=None) -> SystemResult:
    """End-to-end K-role evaluation of one device tuple (scalar path;
    raises InfeasibleConfig when any role cannot run its sub-workload).
    `calibration` threads a measured GEMM-factor table
    (core.calibration) into every role's evaluation; None = identity."""
    if len(npus) != topo.k:
        raise ValueError(f"{topo.name} needs {topo.k} devices, "
                         f"got {len(npus)}")
    results = [
        evaluate(npu, role.dims_for(dims), trace, role.phase,
                 context_override=role.context_for(trace),
                 calibration=calibration)
        for role, npu in zip(topo.roles, npus)
    ]
    return _combine_system(topo, results, [n.quant for n in npus],
                           dims, trace)


def evaluate_system_batch(systems: list, topo: SystemTopology,
                          dims: ModelDims, trace: Trace,
                          caches: Optional[list] = None,
                          calibration=None) -> list:
    """Batched `evaluate_system` over K-device tuples.

    Built on `perfmodel.evaluate_batch` (the jitted structure-of-arrays
    path): each role's unique device set is scored by one `jax.jit`
    call against that role's restricted workload (layer group /
    context override), then the per-system combination is pure
    arithmetic — DSE candidate pools share halves heavily (crossover
    children, TPE proposals), so the per-role evaluation count is the
    number of distinct halves, not the number of systems.  Returns one
    SystemResult per tuple, with None for systems infeasible in any
    role instead of raising.

    Configs are deduplicated by `NPUConfig.name`; DSE-decoded designs
    embed their genes in the name so this is exact for search batches
    (hand-built configs must use distinct names, as the Table 6 ones
    do).  Passing `caches` (one dict per role) memoizes per-(role,
    phase) results across calls — `dse.runner.SystemObjective` threads
    its role caches through every generation.  `calibration` threads a
    measured GEMM-factor table into every role's evaluation; role
    caches memoize by config name only, so a caller mixing tables must
    supply per-table caches (`SystemObjective` holds one table for the
    life of its caches).
    """
    caches = [{} for _ in topo.roles] if caches is None else caches
    if len(caches) != topo.k:
        raise ValueError(f"{topo.name} needs {topo.k} caches")
    for ri, role in enumerate(topo.roles):
        cache = caches[ri]
        miss = {s[ri].name: s[ri] for s in systems
                if s[ri].name not in cache}
        evaluate_batch(list(miss.values()), role.dims_for(dims), trace,
                       role.phase, context_override=role.context_for(trace),
                       keys=list(miss), cache=cache,
                       calibration=calibration)
    out = []
    for s in systems:
        results = [caches[ri][cfg.name] for ri, cfg in enumerate(s)]
        out.append(None if any(r is None for r in results)
                   else _combine_system(topo, results,
                                        [cfg.quant for cfg in s],
                                        dims, trace))
    return out


# ---------------------------------------------------------------------------
# Pair entry points: K=2 wrappers over the system layer
# ---------------------------------------------------------------------------

def evaluate_disaggregated(prefill_npu: NPUConfig, decode_npu: NPUConfig,
                           dims: ModelDims, trace: Trace) -> DisaggResult:
    """End-to-end PD-disaggregated evaluation (paper Fig. 8)."""
    pre = evaluate_prefill(prefill_npu, dims, trace)
    dec = evaluate_decode(decode_npu, dims, trace)
    return _combine_phase_results(pre, dec, dims, trace, prefill_npu.quant)


def evaluate_disagg_batch(pairs: list, dims: ModelDims, trace: Trace,
                          pre_cache: Optional[dict] = None,
                          dec_cache: Optional[dict] = None,
                          calibration=None) -> list:
    """Batched `evaluate_disaggregated` over (prefill, decode) NPU pairs:
    `evaluate_system_batch` on the `PD_PAIR` topology, returning
    DisaggResults (None for infeasible pairs).  `pre_cache`/`dec_cache`
    are the two role caches."""
    caches = [{} if pre_cache is None else pre_cache,
              {} if dec_cache is None else dec_cache]
    out = evaluate_system_batch(pairs, PD_PAIR, dims, trace, caches=caches,
                                calibration=calibration)
    return [None if r is None else _pair_result(r) for r in out]


# ---------------------------------------------------------------------------
# Extreme heterogeneity profiling (Section 5.5, Fig. 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerGroupSplit:
    """Prefill split at the layer level: Attention vs FFN sub-workloads."""

    attn_seconds: float
    ffn_seconds: float
    attn_bottleneck: str
    ffn_bottleneck: str


def prefill_layer_group_profile(npu: NPUConfig, dims: ModelDims,
                                trace: Trace, batch: int = 1) -> LayerGroupSplit:
    """Evaluate Attention and FFN layer groups separately (Fig. 9 left) by
    zeroing out the other group's ops."""
    from .perfmodel import _layer_time_and_energy, _placement_for
    S = trace.prompt_tokens
    placement = _placement_for(npu, dims, batch, S, S)
    full = layer_traffic(dims, Phase.PREFILL, batch, S, npu.quant)
    attn_only = dataclasses.replace(
        dims, d_ff=0) if dims.d_ff else dims
    t_attn_traffic = layer_traffic(attn_only, Phase.PREFILL, batch, S,
                                   npu.quant)
    t_attn, _, b_attn, _ = _layer_time_and_energy(npu, t_attn_traffic,
                                                  placement)
    # FFN group = full minus attention ops (rebuild with attention removed)
    ffn_traffic = layer_traffic(dims, Phase.PREFILL, batch, S, npu.quant)
    ffn_traffic.gemms = [g for g in full.gemms
                         if g not in t_attn_traffic.gemms]
    t_ffn, _, b_ffn, _ = _layer_time_and_energy(npu, ffn_traffic, placement)
    return LayerGroupSplit(attn_seconds=t_attn, ffn_seconds=t_ffn,
                           attn_bottleneck=b_attn, ffn_bottleneck=b_ffn)


@dataclasses.dataclass(frozen=True)
class DecodePhaseSplit:
    """Decode split by generation progress (Fig. 9 right)."""

    early_step_s: float      # context = prompt + 25% of gen
    late_step_s: float       # context = prompt + 75% of gen
    early_bottleneck: str
    late_bottleneck: str


def decode_phase_profile(npu: NPUConfig, dims: ModelDims,
                         trace: Trace,
                         batch: Optional[int] = None) -> DecodePhaseSplit:
    early = evaluate_decode(npu, dims, trace, batch=batch,
                            context_override=trace.prompt_tokens
                            + trace.gen_tokens // 4)
    late = evaluate_decode(npu, dims, trace, batch=batch,
                           context_override=trace.prompt_tokens
                           + 3 * trace.gen_tokens // 4)
    return DecodePhaseSplit(
        early_step_s=early.latency_s, late_step_s=late.latency_s,
        early_bottleneck=early.bottleneck, late_bottleneck=late.bottleneck)


def best_per_phase(npus: list[NPUConfig], dims: ModelDims, trace: Trace,
                   phase: Phase,
                   context_override: Optional[int] = None
                   ) -> tuple[NPUConfig, PhaseResult]:
    """Pick the best device of an enumerated list for one (sub-)phase.

    Scores the whole candidate list through the batched/jitted
    `perfmodel.evaluate_batch` (infeasible devices come back as None
    and are skipped; genuine bugs — AttributeError, TypeError on a
    malformed config — still propagate from table construction).

    This enumeration is deliberately narrow: it is the cheap
    warm-start that seeds `SystemSpace` searches with a good
    per-role device (`dse.runner.system_warm_start`), not the search
    itself — the co-search over the full space is `SystemObjective` +
    the dse runners.
    """
    results = evaluate_batch(npus, dims, trace, phase,
                             context_override=context_override)
    best = None
    for npu, r in zip(npus, results):
        if r is None:
            continue
        if best is None or r.tokens_per_joule > best[1].tokens_per_joule:
            best = (npu, r)
    if best is None:
        raise ValueError("no feasible device for phase")
    return best
