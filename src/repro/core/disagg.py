"""Prefill/Decode-disaggregated system model (paper Sections 5.3, 5.5).

A disaggregated serving system pairs a prefill-optimized device (or fleet)
with a decode-optimized one; finished prefills hand their KV cache to the
decode device over an interconnect (the paper models NVLink, following
LLMCompass).  End-to-end metrics:

  TTFT  = prefill latency + KV transfer time
  TPS   = decode tokens/s (per request and aggregate)
  token/J across both devices + transfer energy

`evaluate_disaggregated` scores one hand-picked pair;
`evaluate_disagg_batch` scores whole DSE candidate batches by
deduplicating the prefill/decode halves and routing them through
`perfmodel.evaluate_batch` — the paired-search hot path behind
`dse.runner.DisaggObjective`.

Extreme heterogeneity (Section 5.5) further splits the pipeline:
  * prefill by layer group — attention-heavy vs FFN-heavy layers may use
    different configurations (Fig. 9 left), evaluated per-group;
  * decode by generation phase — early decode (short context) vs late
    decode (long context) have different memory profiles (Fig. 9 right).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .npu import NPUConfig
from .perfmodel import (InfeasibleConfig, PhaseResult, evaluate_batch,
                        evaluate_decode, evaluate_prefill)
from .workload import ModelDims, Phase, Trace, layer_traffic

# NVLink-class chip-to-chip interconnect (LLMCompass-style constants)
NVLINK_GBPS = 450.0         # effective per-direction bandwidth
NVLINK_PJ_PER_BIT = 10.0    # link + serdes energy


@dataclasses.dataclass(frozen=True)
class DisaggResult:
    ttft_s: float
    decode_tps_per_request: float
    decode_tps_aggregate: float
    kv_transfer_s: float
    total_power_w: float
    tokens_per_joule: float
    prefill: PhaseResult
    decode: PhaseResult


def kv_transfer_seconds(dims: ModelDims, trace: Trace, batch: int,
                        quant) -> tuple[float, float]:
    """(seconds, joules) to move one batch's prompt KV to the decode device."""
    kv_bytes = dims.kv_bytes_per_token(quant) * trace.prompt_tokens * batch
    t = kv_bytes / (NVLINK_GBPS * 1e9)
    e = NVLINK_PJ_PER_BIT * kv_bytes * 8.0 * 1e-12
    return t, e


def _combine_phase_results(pre: PhaseResult, dec: PhaseResult,
                           dims: ModelDims, trace: Trace,
                           prefill_quant) -> DisaggResult:
    """Fold one prefill + one decode PhaseResult into end-to-end metrics.

    Shared by the scalar and batched evaluators so their numbers agree
    exactly.  The KV transfer is quantified at the prefill device's KV
    format (the pair constraint in dse.space.PairedSpace guarantees the
    decode device consumes the same format)."""
    t_kv, e_kv = kv_transfer_seconds(dims, trace, 1, prefill_quant)
    ttft = pre.latency_s / pre.batch + t_kv   # per-request TTFT
    # steady state: both devices busy; energy per generated token counts the
    # amortized prefill energy per request's gen_tokens plus decode energy.
    e_prefill_per_req = (pre.avg_power_w * pre.latency_s) / pre.batch
    e_decode_per_tok = dec.energy_per_token_j
    e_per_gen_token = (e_prefill_per_req + e_kv) / trace.gen_tokens \
        + e_decode_per_tok
    power = pre.avg_power_w + dec.avg_power_w
    return DisaggResult(
        ttft_s=ttft,
        decode_tps_per_request=1.0 / dec.latency_s if dec.latency_s else 0.0,
        decode_tps_aggregate=dec.throughput_tps,
        kv_transfer_s=t_kv,
        total_power_w=power,
        tokens_per_joule=1.0 / e_per_gen_token if e_per_gen_token else 0.0,
        prefill=pre, decode=dec)


def evaluate_disaggregated(prefill_npu: NPUConfig, decode_npu: NPUConfig,
                           dims: ModelDims, trace: Trace) -> DisaggResult:
    """End-to-end PD-disaggregated evaluation (paper Fig. 8)."""
    pre = evaluate_prefill(prefill_npu, dims, trace)
    dec = evaluate_decode(decode_npu, dims, trace)
    return _combine_phase_results(pre, dec, dims, trace, prefill_npu.quant)


def evaluate_disagg_batch(pairs: list, dims: ModelDims, trace: Trace,
                          pre_cache: Optional[dict] = None,
                          dec_cache: Optional[dict] = None) -> list:
    """Batched `evaluate_disaggregated` over (prefill, decode) NPU pairs.

    Built on `perfmodel.evaluate_batch` (since PR 3 the jitted
    structure-of-arrays path: each side's unique-half miss set is
    scored by one `jax.jit` call): each side's unique configurations
    are evaluated once per call, then the per-pair combination is pure
    arithmetic — the DSE's paired candidate pools share halves heavily
    (crossover children, TPE proposals), so the per-phase evaluation
    count is the number of distinct halves, not the number of pairs.
    Returns one DisaggResult per pair, with None for pairs infeasible
    in either phase instead of raising.

    Configs are deduplicated by `NPUConfig.name`; DSE-decoded designs
    embed their genes in the name so this is exact for search batches
    (hand-built configs must use distinct names, as the Table 6 ones
    do).  Passing `pre_cache` / `dec_cache` dicts memoizes per-phase
    results across calls — `dse.runner.DisaggObjective` threads its
    half caches through every generation.
    """
    pre_cache = {} if pre_cache is None else pre_cache
    dec_cache = {} if dec_cache is None else dec_cache
    pre_miss = {p.name: p for p, _ in pairs if p.name not in pre_cache}
    evaluate_batch(list(pre_miss.values()), dims, trace, Phase.PREFILL,
                   keys=list(pre_miss), cache=pre_cache)
    dec_miss = {d.name: d for _, d in pairs if d.name not in dec_cache}
    evaluate_batch(list(dec_miss.values()), dims, trace, Phase.DECODE,
                   keys=list(dec_miss), cache=dec_cache)
    out = []
    for p, d in pairs:
        pre, dec = pre_cache[p.name], dec_cache[d.name]
        out.append(None if pre is None or dec is None
                   else _combine_phase_results(pre, dec, dims, trace,
                                               p.quant))
    return out


# ---------------------------------------------------------------------------
# Extreme heterogeneity (Section 5.5)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerGroupSplit:
    """Prefill split at the layer level: Attention vs FFN sub-workloads."""

    attn_seconds: float
    ffn_seconds: float
    attn_bottleneck: str
    ffn_bottleneck: str


def prefill_layer_group_profile(npu: NPUConfig, dims: ModelDims,
                                trace: Trace, batch: int = 1) -> LayerGroupSplit:
    """Evaluate Attention and FFN layer groups separately (Fig. 9 left) by
    zeroing out the other group's ops."""
    from .perfmodel import _layer_time_and_energy, _placement_for
    S = trace.prompt_tokens
    placement = _placement_for(npu, dims, batch, S, S)
    full = layer_traffic(dims, Phase.PREFILL, batch, S, npu.quant)
    attn_only = dataclasses.replace(
        dims, d_ff=0) if dims.d_ff else dims
    t_attn_traffic = layer_traffic(attn_only, Phase.PREFILL, batch, S,
                                   npu.quant)
    t_attn, _, b_attn, _ = _layer_time_and_energy(npu, t_attn_traffic,
                                                  placement)
    # FFN group = full minus attention ops (rebuild with attention removed)
    ffn_traffic = layer_traffic(dims, Phase.PREFILL, batch, S, npu.quant)
    ffn_traffic.gemms = [g for g in full.gemms
                         if g not in t_attn_traffic.gemms]
    t_ffn, _, b_ffn, _ = _layer_time_and_energy(npu, ffn_traffic, placement)
    return LayerGroupSplit(attn_seconds=t_attn, ffn_seconds=t_ffn,
                           attn_bottleneck=b_attn, ffn_bottleneck=b_ffn)


@dataclasses.dataclass(frozen=True)
class DecodePhaseSplit:
    """Decode split by generation progress (Fig. 9 right)."""

    early_step_s: float      # context = prompt + 25% of gen
    late_step_s: float       # context = prompt + 75% of gen
    early_bottleneck: str
    late_bottleneck: str


def decode_phase_profile(npu: NPUConfig, dims: ModelDims,
                         trace: Trace,
                         batch: Optional[int] = None) -> DecodePhaseSplit:
    early = evaluate_decode(npu, dims, trace, batch=batch,
                            context_override=trace.prompt_tokens
                            + trace.gen_tokens // 4)
    late = evaluate_decode(npu, dims, trace, batch=batch,
                           context_override=trace.prompt_tokens
                           + 3 * trace.gen_tokens // 4)
    return DecodePhaseSplit(
        early_step_s=early.latency_s, late_step_s=late.latency_s,
        early_bottleneck=early.bottleneck, late_bottleneck=late.bottleneck)


def best_per_phase(npus: list[NPUConfig], dims: ModelDims, trace: Trace,
                   phase: Phase) -> tuple[NPUConfig, PhaseResult]:
    """Pick the best device for a (sub-)phase — the Section 5.5 search."""
    best = None
    for npu in npus:
        try:
            r = (evaluate_prefill(npu, dims, trace)
                 if phase is Phase.PREFILL
                 else evaluate_decode(npu, dims, trace))
        except (InfeasibleConfig, ValueError):
            # infeasible device for this phase; non-ValueError bugs
            # (AttributeError, TypeError, ...) propagate instead of
            # being silently read as "device skipped"
            continue
        if best is None or r.tokens_per_joule > best[1].tokens_per_joule:
            best = (npu, r)
    if best is None:
        raise ValueError("no feasible device for phase")
    return best
