"""End-to-end analytical performance/power model.

Combines the PLENA-style compute model (compute.py), the hierarchical
double-buffered memory model (hierarchy.py), and the data-movement model
(dataflow.py) to evaluate one NPU configuration on one workload phase —
the `f(x)` that the DSE optimizes.

Traffic derivation: every GEMM operand is routed through the memory
hierarchy according to (a) its data class's placement (storage priority)
and (b) the dataflow strategy's re-streaming multiplier.  Re-streamed
operands that the storage priority pinned on-chip only consume on-chip
bandwidth — this coupling is the paper's core co-design observation
(Table 4/5: WS + activation-priority wins prefill).

Phase evaluation (paper Section 4.3):
  * PREFILL: single large batch; per-layer time = max(compute, matrix
    stream, vector stream) (double-buffered overlap); TTFT and token/J.
  * DECODE: batch maximized under the capacity constraint (weights + KV at
    full context + activations must fit); per-step time at the average
    context length; TPS and token/J.

Scalar-as-oracle convention: the per-config functions in this module
(`evaluate`, `evaluate_prefill`, `evaluate_decode`, `max_*_batch`,
`class_traffic_bytes`, `_layer_time_and_energy`) are the REFERENCE
implementation — plain float64 Python, one design at a time, raising
`InfeasibleConfig`.  The DSE hot path (`evaluate_batch`) routes through
the structure-of-arrays jax.jit program in perfmodel_jit.py, which
replicates this arithmetic op-for-op and encodes infeasibility as a
mask; tests/test_perfmodel_jit.py property-tests the two against each
other (rtol 1e-5, identical feasibility).  Since the denoise-step
tables landed, the jitted path covers EVERY (family, phase) pair —
diffusion-LM decode included — so the oracle's remaining duties are
parity testing and explicit opt-out, never routing.  Behavioral
changes MUST land in the scalar oracle first and be mirrored in
perfmodel_jit, never the other way around.  Set
REPRO_PERFMODEL_SCALAR=1 (or pass `use_jit=False`) to force batch
evaluation through the oracle.

Degradation convention (the crash-safe search runtime): the jitted
path in `evaluate_batch` runs behind `runtime.fault.RetryPolicy`
(`JIT_RETRY`) — a transient jit failure is retried, a persistent one
degrades per-chunk to the scalar oracle, and non-finite jit results
are re-scored through the oracle (still non-finite -> quarantined as
infeasible).  Every degradation emits a structured event
(`degradation_events()`, `on_degradation` hook) instead of killing the
search; a long DSE sweep survives evaluator trouble observably.
"""

from __future__ import annotations

import dataclasses
import math
import os
from collections import deque
from typing import Optional

from ..runtime.fault import RetryPolicy
from .compute import (Dataflow, dataflow_traffic_multipliers, gemm_cycles,
                      vector_seconds)
from .dataflow import ACTS, KV, WEIGHTS, Placement, place_data
from .hierarchy import MemoryHierarchy
from .memtech import MemKind
from .npu import NPUConfig
from .power import E_MAC_PJ, E_VECTOR_OP_PJ, P_BASE_W, compute_power_w
from .quant.formats import QuantConfig
from .workload import (DataClass, Family, LayerTraffic, ModelDims, Phase,
                       Trace, activation_footprint_gb, kv_footprint_gb,
                       layer_traffic_cached, lm_head_traffic_cached,
                       weight_footprint_gb)

_CLS_INDEX = {DataClass.WEIGHT: WEIGHTS, DataClass.ACT: ACTS, DataClass.KV: KV}

_ALL_DATAFLOWS = (Dataflow.WEIGHT_STATIONARY, Dataflow.INPUT_STATIONARY,
                  Dataflow.OUTPUT_STATIONARY)


def _gemm_dataflow(npu: NPUConfig, g) -> "Dataflow":
    """The software strategy's dataflow governs weight-bearing GEMMs;
    attention-internal GEMMs (scores/PV — no weight operand) run as a
    fused kernel mapped for best array utilization."""
    if g.b_class is DataClass.WEIGHT:
        return npu.strategy.dataflow
    return min(_ALL_DATAFLOWS,
               key=lambda df: gemm_cycles(npu.compute, g.m, g.k, g.n, df,
                                          count=g.count).cycles)


class InfeasibleConfig(ValueError):
    """Configuration cannot run the workload (capacity/shoreline/etc.)."""


@dataclasses.dataclass(frozen=True)
class PhaseResult:
    phase: Phase
    batch: int
    latency_s: float            # TTFT (prefill) or per-step latency (decode)
    tokens: float               # tokens produced/processed per `latency_s`
    throughput_tps: float
    avg_power_w: float
    energy_per_token_j: float
    compute_time_s: float
    memory_time_s: float
    bottleneck: str             # "compute" | "matrix_mem" | "vector_mem"
    mem_breakdown: dict

    @property
    def tokens_per_joule(self) -> float:
        return 1.0 / self.energy_per_token_j if self.energy_per_token_j else 0.0


SCRATCH = 3   # extra stream index: on-chip-only fused intermediates


def class_traffic_bytes(npu: NPUConfig, traffic: LayerTraffic,
                        placement: Placement) -> dict:
    """Bytes streamed per data class, with capacity-aware dataflow
    inflation.

    The storage-priority placement decides how much on-chip staging each
    class gets, which sets the re-stream factors.  Re-reads of a panel
    whose chunk fits its on-chip staging never leave the chip: they are
    accounted to the SCRATCH (on-chip-only) stream instead of the
    hierarchy stream — this is the coupling that makes WS + activation-
    priority the prefill winner (paper Table 4) and lets larger on-chip
    capacity convert re-read traffic into cheap on-chip bandwidth
    (paper Table 5).
    """
    q = npu.quant
    bytes_of = {
        DataClass.WEIGHT: q.weight_bytes,
        DataClass.ACT: q.activation_bytes,
        DataClass.KV: q.kv_bytes,
        DataClass.SCRATCH: q.activation_bytes,
    }
    h = npu.hierarchy
    min_stage = npu.compute.n_pe * q.activation_bytes
    stage = {
        DataClass.WEIGHT: placement.on_chip_bytes(WEIGHTS, h),
        DataClass.ACT: placement.on_chip_bytes(ACTS, h),
        DataClass.KV: placement.on_chip_bytes(KV, h),
        DataClass.SCRATCH: max(placement.on_chip_bytes(ACTS, h), min_stage),
    }
    out = {WEIGHTS: 0.0, ACTS: 0.0, KV: 0.0, SCRATCH: 0.0}

    def idx(cls: DataClass) -> int:
        return SCRATCH if cls is DataClass.SCRATCH else _CLS_INDEX[cls]

    def add(cls: DataClass, first_bytes: float, reread_bytes: float,
            panel_bytes: float):
        """First pass goes through the class's hierarchy path.  Re-reads
        hit on-chip memory only for producer-resident classes (ACT /
        SCRATCH: activations are produced on-chip and can stay while
        their panel fits).  Weight/KV re-reads always traverse the
        hierarchy: static placement pins *which* bytes live on-chip, it
        is not a rotating per-layer staging buffer."""
        out[idx(cls)] += first_bytes
        if reread_bytes <= 0:
            return
        if cls is DataClass.SCRATCH or (
                cls is DataClass.ACT and panel_bytes <= stage[cls] + 1e-9):
            out[SCRATCH] += reread_bytes
        else:
            out[idx(cls)] += reread_bytes

    for g in traffic.gemms:
        a_mult, b_mult = dataflow_traffic_multipliers(
            npu.compute, g.m, g.k, g.n, _gemm_dataflow(npu, g),
            bytes_of[g.a_class], bytes_of[g.b_class], bytes_of[g.out_class],
            stage[g.a_class], stage[g.b_class], stage[g.out_class])
        a_once = g.m * g.k * g.count * bytes_of[g.a_class]
        b_once = g.k * g.n * g.count * bytes_of[g.b_class]
        a_panel = g.m * g.k * bytes_of[g.a_class] / max(1, g.a_chunks)
        b_panel = g.k * g.n * bytes_of[g.b_class]
        add(g.a_class, a_once, a_once * (a_mult - 1.0), a_panel)
        add(g.b_class, b_once, b_once * (b_mult - 1.0), b_panel)
        out[idx(g.out_class)] += g.m * g.n * g.count * bytes_of[g.out_class]
    out[ACTS] += traffic.act_extra_bytes
    out[KV] += traffic.kv_write_bytes
    return out


def _layer_time_and_energy(npu: NPUConfig, traffic: LayerTraffic,
                           placement: Placement,
                           calibration=None) -> tuple[float, float, str, dict]:
    """One layer pass: (seconds, joules, bottleneck, breakdown).

    `calibration` (core.calibration.CalibrationTable or None) applies
    measured per-geometry-class efficiency/setup factors to each GEMM's
    cycle count.  None (and the identity table) reproduces the
    uncalibrated arithmetic bit-for-bit; the dataflow argmin for
    attention GEMMs stays uncalibrated by design — per-class factors
    shift every candidate dataflow equally, so they cannot change the
    argmin, only its cost.
    """
    h = npu.hierarchy
    mx_share, vec_share = npu.strategy.bw_split()

    # --- compute time ------------------------------------------------------
    # narrow-precision datapaths execute more MACs per PE per cycle
    def _gemm_seconds(g) -> float:
        eff, setup = ((1.0, 0.0) if calibration is None
                      else calibration.factors_for_gemm(g))
        return gemm_cycles(npu.compute, g.m, g.k, g.n,
                           _gemm_dataflow(npu, g), count=g.count,
                           eff_factor=eff, setup_cycles=setup).seconds

    t_gemm = sum(
        _gemm_seconds(g) for g in traffic.gemms
    ) / npu.quant.matrix_rate_scale
    t_vec = (vector_seconds(npu.compute, traffic.vector_elems)
             / npu.quant.vector_rate_scale)
    t_compute = max(t_gemm, t_vec)   # matrix & vector engines run in parallel

    # --- memory time (per stream, double-buffered against compute) ---------
    cls_bytes = class_traffic_bytes(npu, traffic, placement)
    t_streams = {}
    for cls, name, share in ((WEIGHTS, "weights", mx_share),
                             (KV, "kv", mx_share),
                             (ACTS, "acts", vec_share)):
        nbytes = cls_bytes[cls]
        if nbytes <= 0:
            t_streams[name] = 0.0
            continue
        alphas = placement.resident_fraction_chain(cls)
        br = h.transfer_time_s(nbytes, resident_fractions=alphas,
                               bw_share=share)
        t_streams[name] = br.total_s
    # scratch never leaves the chip: charged at full on-chip bandwidth
    # (the off-chip BW-priority split does not apply on-chip)
    scratch_bytes = cls_bytes[SCRATCH]
    onchip_bw = sum(l.bandwidth_gbps for l in h.levels
                    if l.tech.kind is MemKind.ON_CHIP) * 1e9
    onchip_bw = max(onchip_bw, h.levels[0].bandwidth_gbps * 1e9)
    t_streams["scratch"] = (scratch_bytes / onchip_bw
                            if scratch_bytes > 0 else 0.0)
    t_matrix = t_streams["weights"] + t_streams["kv"]
    t_vector_mem = t_streams["acts"] + t_streams["scratch"]

    # double buffering overlaps compute with both streams (Section 2.2)
    t_layer = max(t_compute, t_matrix, t_vector_mem)
    if t_layer == t_compute:
        bneck = "compute"
    elif t_layer == t_matrix:
        bneck = "matrix_mem"
    else:
        bneck = "vector_mem"

    # --- energy -------------------------------------------------------------
    macs = traffic.total_macs()
    e_compute = (E_MAC_PJ * macs + E_VECTOR_OP_PJ * traffic.vector_elems) * 1e-12
    # memory dynamic energy: each class's bytes are read at the levels that
    # hold them (placement fractions); KV writes and activation spills write.
    e_mem = 0.0
    for cls in (WEIGHTS, ACTS, KV):
        nbytes = cls_bytes[cls]
        if nbytes <= 0:
            continue
        wr_frac = 0.5 if cls == ACTS else (
            min(1.0, traffic.kv_write_bytes / nbytes) if cls == KV else 0.0)
        fr = [lv[cls] for lv in placement.fractions]
        for level, f in zip(h.levels, fr):
            bits = nbytes * f * 8.0
            e_mem += level.tech.e_read_pj_per_bit * bits * (1 - wr_frac) * 1e-12
            e_mem += level.tech.e_write_pj_per_bit * bits * wr_frac * 1e-12
    # scratch: on-chip reads+writes at the innermost level's energy
    if scratch_bytes > 0:
        t0 = h.levels[0].tech
        e_mem += ((t0.e_read_pj_per_bit + t0.e_write_pj_per_bit) / 2.0
                  * scratch_bytes * 8.0 * 1e-12)
    static_w = h.background_power_w() + compute_power_w(npu.compute, 0.0, 0.0)
    e_static = static_w * t_layer
    breakdown = {"compute_s": t_compute, "matrix_s": t_matrix,
                 "vector_s": t_vector_mem, "scratch_s": t_streams["scratch"],
                 "bytes_weights": cls_bytes[WEIGHTS],
                 "bytes_acts": cls_bytes[ACTS],
                 "bytes_kv": cls_bytes[KV],
                 "bytes_scratch": scratch_bytes}
    return t_layer, e_compute + e_mem + e_static, bneck, breakdown


def _placement_for(npu: NPUConfig, dims: ModelDims, batch: int,
                   context: int, q_len: int) -> Placement:
    sizes = [
        weight_footprint_gb(dims, npu.quant),
        activation_footprint_gb(dims, batch, q_len, npu.quant),
        kv_footprint_gb(dims, batch, context, npu.quant),
    ]
    try:
        return place_data(npu.hierarchy, npu.strategy, sizes)
    except ValueError as e:
        raise InfeasibleConfig(str(e)) from None


def max_prefill_batch(npu: NPUConfig, dims: ModelDims, trace: Trace,
                      batch_choices: Optional[list[int]] = None) -> int:
    """Largest prefill batch fitting weights + prompt-KV + activations.

    This reproduces the paper's Table 6 'Batch' column (Base 1, P1 16 ...):
    prefill batches amortize weight streaming across requests when the
    hierarchy has the capacity for their KV and activations.
    """
    choices = batch_choices or [1, 2, 4, 8, 16, 32, 64, 128]
    S = trace.prompt_tokens
    w = weight_footprint_gb(dims, npu.quant)
    cap = npu.hierarchy.total_capacity_gb()
    best = 0
    for b in choices:
        need = (w + kv_footprint_gb(dims, b, S, npu.quant)
                + activation_footprint_gb(dims, b, S, npu.quant))
        if need <= cap:
            best = b
    if best == 0:
        raise InfeasibleConfig(
            f"prefill infeasible: weights {w:.1f} GB + batch-1 state exceed "
            f"capacity {cap:.1f} GB ({npu.hierarchy.describe()})")
    return best


def evaluate_prefill(npu: NPUConfig, dims: ModelDims, trace: Trace,
                     batch: Optional[int] = None,
                     calibration=None) -> PhaseResult:
    """Prefill-only throughput at the capacity-maximal batch."""
    S = trace.prompt_tokens
    batch = batch if batch is not None else max_prefill_batch(npu, dims, trace)
    placement = _placement_for(npu, dims, batch, S, S)
    traffic = layer_traffic_cached(dims, Phase.PREFILL, batch, S, npu.quant)
    t_layer, e_layer, bneck, bd = _layer_time_and_energy(
        npu, traffic, placement, calibration=calibration)
    n_layers = dims.n_layers + dims.n_encoder_layers
    head = lm_head_traffic_cached(dims, batch, 1, npu.quant)
    t_head, e_head, _, _ = _layer_time_and_energy(
        npu, head, placement, calibration=calibration)
    latency = t_layer * n_layers + t_head
    energy = e_layer * n_layers + e_head
    tokens = float(batch * S)
    power = energy / latency if latency > 0 else 0.0
    return PhaseResult(
        phase=Phase.PREFILL, batch=batch, latency_s=latency, tokens=tokens,
        throughput_tps=tokens / latency if latency else 0.0,
        avg_power_w=power,
        energy_per_token_j=energy / tokens if tokens else 0.0,
        compute_time_s=bd["compute_s"] * n_layers,
        memory_time_s=max(bd["matrix_s"], bd["vector_s"]) * n_layers,
        bottleneck=bneck, mem_breakdown=bd,
    )


def max_decode_batch(npu: NPUConfig, dims: ModelDims, trace: Trace,
                     batch_choices: Optional[list[int]] = None) -> int:
    """Largest batch whose weights+KV+activations fit (Section 4.3)."""
    choices = batch_choices or [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
    context = trace.prompt_tokens + trace.gen_tokens
    w = weight_footprint_gb(dims, npu.quant)
    cap = npu.hierarchy.total_capacity_gb()
    best = 0
    for b in choices:
        need = (w + kv_footprint_gb(dims, b, context, npu.quant)
                + activation_footprint_gb(dims, b, 1, npu.quant))
        if need <= cap:
            best = b
    if best == 0:
        raise InfeasibleConfig(
            f"decode infeasible: weights alone {w:.1f} GB vs capacity "
            f"{cap:.1f} GB ({npu.hierarchy.describe()})")
    return best


def evaluate_decode(npu: NPUConfig, dims: ModelDims, trace: Trace,
                    batch: Optional[int] = None,
                    context_override: Optional[int] = None,
                    calibration=None) -> PhaseResult:
    """Decode-only: max batch under capacity, per-step latency at the
    average context length, sustained TPS and token/J."""
    b = batch if batch is not None else max_decode_batch(npu, dims, trace)
    ctx = (context_override if context_override is not None
           else trace.prompt_tokens + trace.gen_tokens // 2)
    if dims.family is Family.DLLM:
        return _evaluate_dllm_decode(npu, dims, trace, b,
                                     context_override=context_override,
                                     calibration=calibration)
    placement = _placement_for(npu, dims, b,
                               trace.prompt_tokens + trace.gen_tokens, 1)
    traffic = layer_traffic_cached(dims, Phase.DECODE, b, ctx, npu.quant)
    t_layer, e_layer, bneck, bd = _layer_time_and_energy(
        npu, traffic, placement, calibration=calibration)
    n_layers = dims.n_layers
    head = lm_head_traffic_cached(dims, b, 1, npu.quant)
    t_head, e_head, _, _ = _layer_time_and_energy(
        npu, head, placement, calibration=calibration)
    step = t_layer * n_layers + t_head
    energy = e_layer * n_layers + e_head
    tokens = float(b)
    power = energy / step if step else 0.0
    return PhaseResult(
        phase=Phase.DECODE, batch=b, latency_s=step, tokens=tokens,
        throughput_tps=tokens / step if step else 0.0,
        avg_power_w=power,
        energy_per_token_j=energy / tokens if tokens else 0.0,
        compute_time_s=bd["compute_s"] * n_layers,
        memory_time_s=max(bd["matrix_s"], bd["vector_s"]) * n_layers,
        bottleneck=bneck, mem_breakdown=bd,
    )


def _evaluate_dllm_decode(npu: NPUConfig, dims: ModelDims, trace: Trace,
                          batch: int,
                          context_override: Optional[int] = None,
                          calibration=None) -> PhaseResult:
    """Diffusion LM decode (Section 5.4.1): each denoise step processes the
    full sequence; steps per generated token given by the model.

    `context_override` (decode-phase-split roles, Section 5.5) sets the
    sequence length each denoise step reprocesses — the conversation
    early/late in generation — while capacity and placement stay at the
    full context (the device must still hold the whole conversation),
    the same capacity-vs-traffic split `evaluate_decode` applies."""
    S = trace.prompt_tokens + trace.gen_tokens
    seq = context_override if context_override is not None else S
    placement = _placement_for(npu, dims, batch, S, S)
    traffic = layer_traffic_cached(dims, Phase.PREFILL, batch, seq, npu.quant)
    t_layer, e_layer, bneck, bd = _layer_time_and_energy(
        npu, traffic, placement, calibration=calibration)
    steps = max(1.0, trace.gen_tokens * dims.diffusion_steps_per_token)
    t_step = t_layer * dims.n_layers
    e_step = e_layer * dims.n_layers
    total_t = t_step * steps
    total_e = e_step * steps
    tokens = float(batch * trace.gen_tokens)
    return PhaseResult(
        phase=Phase.DECODE, batch=batch, latency_s=total_t, tokens=tokens,
        throughput_tps=tokens / total_t if total_t else 0.0,
        avg_power_w=total_e / total_t if total_t else 0.0,
        energy_per_token_j=total_e / tokens if tokens else 0.0,
        compute_time_s=bd["compute_s"] * dims.n_layers * steps,
        memory_time_s=max(bd["matrix_s"], bd["vector_s"]) * dims.n_layers * steps,
        bottleneck=bneck, mem_breakdown=bd,
    )


def evaluate(npu: NPUConfig, dims: ModelDims, trace: Trace, phase: Phase,
             batch: Optional[int] = None,
             context_override: Optional[int] = None,
             calibration=None) -> PhaseResult:
    if phase is Phase.PREFILL:
        if context_override is not None:
            raise ValueError("context_override applies to DECODE only")
        return evaluate_prefill(npu, dims, trace, batch=batch,
                                calibration=calibration)
    return evaluate_decode(npu, dims, trace, batch=batch,
                           context_override=context_override,
                           calibration=calibration)


def _evaluate_batch_scalar(npus, dims: ModelDims, trace: Trace,
                           phase: Phase,
                           batch: Optional[int] = None,
                           context_override: Optional[int] = None,
                           calibration=None) -> list:
    """Reference oracle: map the scalar `evaluate` over the configs."""
    out = []
    for npu in npus:
        try:
            out.append(evaluate(npu, dims, trace, phase, batch=batch,
                                context_override=context_override,
                                calibration=calibration))
        except (InfeasibleConfig, ValueError):   # infeasible et al.
            out.append(None)
    return out


# ---------------------------------------------------------------------------
# Retry + graceful degradation around the jitted batch path
# ---------------------------------------------------------------------------

# Transient jit failures (XLA OOM burps, compile-cache races) are
# retried immediately — the evaluator is pure in-process compute, so
# backoff buys nothing; `sleep` is injectable for tests regardless.
JIT_RETRY = RetryPolicy(max_retries=2, backoff_s=0.0, sleep=lambda s: None)

#: chunk size of the per-chunk scalar fallback: small enough that one
#: poisoned config cannot take down a 100k-design pool, large enough
#: that the Python loop overhead stays irrelevant.
FALLBACK_CHUNK = 64

#: most recent degradation events (ring buffer), newest last.  Each is a
#: dict with at least {"kind", "n_designs", "reason"}; kinds:
#: "jit_fallback" (persistent jit failure -> scalar oracle),
#: "nan_rescore" (non-finite jit results re-scored via the oracle),
#: "scalar_error" (oracle itself died on a config -> infeasible),
#: "nonfinite_quarantined" (oracle result non-finite -> infeasible).
_DEGRADATION_LOG: deque = deque(maxlen=256)

#: optional callback invoked with each degradation event dict
on_degradation: Optional[callable] = None


def degradation_events() -> list:
    """Snapshot of the recent degradation events (newest last)."""
    return list(_DEGRADATION_LOG)


def clear_degradation_events() -> None:
    _DEGRADATION_LOG.clear()


def _emit_degradation(kind: str, **info) -> None:
    event = {"kind": kind, **info}
    _DEGRADATION_LOG.append(event)
    if on_degradation is not None:
        on_degradation(event)


def _result_finite(r) -> bool:
    return (math.isfinite(r.throughput_tps) and math.isfinite(r.avg_power_w)
            and math.isfinite(r.latency_s)
            and math.isfinite(r.energy_per_token_j))


#: exception classes that are programming errors, not evaluator trouble
#: — a malformed config or a broken call site must fail loudly, never
#: be retried or degraded into "infeasible" (the `best_per_phase`
#: exception-narrowing contract).
_BUG_ERRORS = (AttributeError, TypeError, NameError)


def _scalar_fallback(npus, dims, trace, phase, batch, context_override,
                     reason: str, calibration=None) -> list:
    """Chunked scalar-oracle scoring that cannot die on evaluator
    trouble: unexpected per-chunk exceptions narrow to per-config,
    per-config exceptions and non-finite results become infeasible
    (None) + an event.  Bug-class exceptions (`_BUG_ERRORS`) still
    propagate — a malformed config is a caller error, not a fault."""
    out = []
    for lo in range(0, len(npus), FALLBACK_CHUNK):
        chunk = npus[lo:lo + FALLBACK_CHUNK]
        try:
            results = _evaluate_batch_scalar(chunk, dims, trace, phase,
                                             batch=batch,
                                             context_override=context_override,
                                             calibration=calibration)
        except _BUG_ERRORS:
            raise
        except Exception as exc:       # noqa: BLE001 — degradation path
            results = []
            for npu in chunk:
                try:
                    results.extend(_evaluate_batch_scalar(
                        [npu], dims, trace, phase, batch=batch,
                        context_override=context_override,
                        calibration=calibration))
                except _BUG_ERRORS:
                    raise
                except Exception as exc1:  # noqa: BLE001
                    _emit_degradation("scalar_error", n_designs=1,
                                      reason=repr(exc1),
                                      config=getattr(npu, "name", None))
                    results.append(None)
            _emit_degradation("scalar_chunk_error", n_designs=len(chunk),
                              reason=repr(exc))
        for i, r in enumerate(results):
            if r is not None and not _result_finite(r):
                _emit_degradation(
                    "nonfinite_quarantined", n_designs=1, reason=reason,
                    config=getattr(chunk[i], "name", None))
                results[i] = None
        out.extend(results)
    return out


def _evaluate_batch_jit_guarded(npus, dims, trace, phase, batch,
                                context_override, calibration=None) -> list:
    """The jitted fast path behind JIT_RETRY; degrades to the scalar
    oracle per-chunk when the jit path keeps failing, and re-scores
    non-finite jit results through the oracle.  Bug-class exceptions
    (`_BUG_ERRORS`, e.g. AttributeError from a malformed config during
    table construction) propagate immediately, unretried."""
    from ..runtime.fault import StepFailure
    from . import perfmodel_jit

    def attempt():
        try:
            return perfmodel_jit.evaluate_batch_table(
                perfmodel_jit.NPUTable.from_configs(npus), dims, trace,
                phase, batch=batch, context_override=context_override,
                calibration=calibration)
        except _BUG_ERRORS:
            raise
        except Exception as exc:       # noqa: BLE001 — retried/degraded
            raise StepFailure(f"jit evaluator failed: {exc!r}") from exc

    try:
        results = JIT_RETRY.run(attempt)
    except StepFailure as exc:
        _emit_degradation("jit_fallback", n_designs=len(npus),
                          reason=str(exc))
        return _scalar_fallback(npus, dims, trace, phase, batch,
                                context_override, reason="jit_fallback",
                                calibration=calibration)
    bad = [i for i, r in enumerate(results)
           if r is not None and not _result_finite(r)]
    if bad:
        _emit_degradation("nan_rescore", n_designs=len(bad),
                          reason="non-finite jitted results")
        redo = _scalar_fallback([npus[i] for i in bad], dims, trace, phase,
                                batch, context_override,
                                reason="nan_rescore",
                                calibration=calibration)
        for i, r in zip(bad, redo):
            results[i] = r
    return results


def evaluate_batch(npus, dims: ModelDims, trace: Trace, phase: Phase,
                   batch: Optional[int] = None,
                   context_override: Optional[int] = None,
                   keys: Optional[list] = None,
                   cache: Optional[dict] = None,
                   use_jit: Optional[bool] = None,
                   calibration=None) -> list:
    """Evaluate many NPU configurations on one workload phase.

    Structure-of-arrays fast path for DSE candidate pools and Sobol
    initializations: the configs are packed into a perfmodel_jit
    .NPUTable and scored by one jax.jit call per (model, trace, phase)
    — max-batch capacity search, placement, traffic, transfer and
    energy all vectorized over designs, with infeasibility as a mask.
    Returns one PhaseResult per config, with None for infeasible
    entries instead of raising (batch callers filter rather than
    unwind).

    The scalar path (`evaluate`) remains the reference oracle:
    `use_jit=False` or REPRO_PERFMODEL_SCALAR=1 forces it.  Every
    (family, phase) combination — including diffusion-LM decode, via
    the per-batch-choice denoise-step tables in perfmodel_jit — routes
    through the jitted program; the oracle exists for parity testing
    and explicit opt-out, not as a routing fallback.

    `context_override` (DECODE only) evaluates the per-step traffic at
    an explicit context length instead of the trace's average — the
    decode-phase-split roles of `disagg.SystemTopology` (early vs late
    generation, Section 5.5) score their devices through here.  For
    diffusion-LM decode it sets the sequence length each denoise step
    reprocesses (capacity stays at the full context).

    With `keys` (one hashable per config) and `cache` (a caller-owned
    dict), results memoize across calls: cached keys are returned
    without re-evaluation and misses are written back.  The paired
    disaggregated search threads its per-half caches through here so
    repeated prefill/decode halves cost one evaluation each per sweep.

    `calibration` (a `core.calibration.CalibrationTable`, default None
    = identity) applies measured per-geometry-class GEMM factors on
    BOTH the jitted and scalar paths, preserving the parity convention.
    Caller-owned `cache` dicts must be calibration-consistent: results
    memoize under `keys` alone, so a caller mixing tables must fold the
    table (e.g. `CalibrationTable.digest()`) into its keys or use
    separate caches — the `Objective` wrappers hold one table for the
    life of their private caches, which keeps them coherent.
    """
    if keys is not None and len(keys) != len(npus):
        raise ValueError(f"{len(keys)} keys for {len(npus)} configs")
    if context_override is not None and phase is Phase.PREFILL:
        raise ValueError("context_override applies to DECODE only")
    miss_idx = list(range(len(npus)))
    if cache is not None and keys is not None:
        # a None key means "do not cache this config": always a miss
        miss_idx = [i for i in miss_idx
                    if keys[i] is None or keys[i] not in cache]
    miss = [npus[i] for i in miss_idx]
    if use_jit is None:
        use_jit = os.environ.get("REPRO_PERFMODEL_SCALAR", "") != "1"
    if miss:
        from . import perfmodel_jit
        if use_jit and perfmodel_jit.supports(dims, phase):
            results = _evaluate_batch_jit_guarded(
                miss, dims, trace, phase, batch, context_override,
                calibration=calibration)
        else:
            results = _evaluate_batch_scalar(
                miss, dims, trace, phase, batch=batch,
                context_override=context_override,
                calibration=calibration)
    else:
        results = []
    by_idx = dict(zip(miss_idx, results))
    out = []
    for i in range(len(npus)):
        if i in by_idx:
            r = by_idx[i]
            if cache is not None and keys is not None \
                    and keys[i] is not None:
                cache[keys[i]] = r
        else:
            r = cache[keys[i]]
        out.append(r)
    return out
