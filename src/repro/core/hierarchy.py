"""Hierarchical memory model: physical constraints (Eq. 1) and the
double-buffered transfer model (Eqs. 2-5).

A hierarchy is an ordered list of levels, innermost (on-chip, level 1) to
outermost (level L).  Level 0 is the compute unit itself.  Boundary i is the
link across which data moves from level i+1 territory into level i
(boundary 1 = on-chip <- first off-chip, etc.).

Transfer model (paper Eqs. 2-5)
-------------------------------
  B_i^eff   = B_i^peak - B_{i+1}^eff           (double-buffer pass-through)
  tau_i     = lambda_i + alpha_i * x / B_i^eff
  T_i(x)    = max( lambda_i + x / B_i^eff,     Case 1: boundary-i limited
                   T_{i+1}((1-alpha_i) x) )    Case 2: deeper levels limited

alpha_i is the fraction of the data arriving at boundary i that is already
resident at level i; the remainder must be fetched from deeper levels, which
overlaps with the boundary-i stream thanks to double buffering.  At the
outermost level alpha_L == 1 by construction.

The B^eff recursion can mathematically go negative when a deeper link is
faster than the current one; physically a double-buffered level moves each
datum across its port at most twice (in + out), so pass-through traffic can
never cut the usable inbound bandwidth below half the port peak.  We clamp
accordingly (documented deviation; the paper omits the guard).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .memtech import MemKind, MemoryTechnology

# Physical constants (paper Section 2.1).  The paper quotes a typical
# 2-edge budget (2 x 33 mm) but its own Table 6 configurations (P2: HBM4 x2
# + LPDDR5X x16) exceed it under the Table 1 footprints; we therefore
# default to the full reticle perimeter and expose the strict bound as an
# option (DESIGN.md section 8).
RETICLE_LONG_MM = 33.0           # max exposure field 26 x 33 mm
RETICLE_SHORT_MM = 26.0
L_MEM_TWO_EDGE_MM = 2 * RETICLE_LONG_MM                     # 66 mm (strict)
L_MEM_MAX_MM = 2 * (RETICLE_LONG_MM + RETICLE_SHORT_MM)     # 118 mm perimeter
L_MARGIN_MM = 0.5                # inter-stack routing margin


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """A hierarchy level: one technology replicated `stacks` times."""

    tech: MemoryTechnology
    stacks: int = 1

    def __post_init__(self):
        if self.stacks < 1:
            raise ValueError(f"stacks must be >= 1, got {self.stacks}")

    @property
    def capacity_gb(self) -> float:
        return self.tech.capacity_gb * self.stacks

    @property
    def bandwidth_gbps(self) -> float:
        return self.tech.bandwidth_gbps * self.stacks

    @property
    def latency_s(self) -> float:
        return self.tech.latency_s

    @property
    def shoreline_mm(self) -> float:
        if self.tech.kind is MemKind.ON_CHIP:
            return 0.0
        return (self.tech.shoreline_mm + L_MARGIN_MM) * self.stacks

    def background_power_w(self) -> float:
        return self.tech.background_power_w(self.capacity_gb)

    def describe(self) -> str:
        return f"{self.tech.name}x{self.stacks}"


class ShorelineError(ValueError):
    """Raised when a hierarchy violates the die-shoreline bound (Eq. 1)."""


@dataclasses.dataclass(frozen=True)
class TransferBreakdown:
    """Result of the recursive transfer-time evaluation."""

    total_s: float
    case: str                      # "overlapped" | "bandwidth_limited" | "leaf"
    boundary_times_s: tuple        # lambda_i + x_i / B_i^eff per boundary
    resident_fractions: tuple      # alpha_i actually used


class MemoryHierarchy:
    """Ordered levels, innermost first. Validates Eq. 1 on construction."""

    def __init__(self, levels: Sequence[MemoryLevel],
                 l_mem_mm: float = L_MEM_MAX_MM,
                 validate_shoreline: bool = True):
        if not levels:
            raise ValueError("hierarchy needs at least one level")
        lv = list(levels)
        # on-chip levels must precede off-chip levels
        seen_off = False
        for l in lv:
            if l.tech.kind is MemKind.OFF_CHIP:
                seen_off = True
            elif seen_off:
                raise ValueError("on-chip level found outside off-chip level")
        self.levels: list[MemoryLevel] = lv
        self.l_mem_mm = l_mem_mm
        if validate_shoreline:
            used = self.shoreline_used_mm()
            if used > l_mem_mm + 1e-9:
                raise ShorelineError(
                    f"shoreline {used:.2f} mm exceeds budget {l_mem_mm:.2f} mm "
                    f"for {self.describe()}"
                )

    # ---- static properties -------------------------------------------------

    def describe(self) -> str:
        return " | ".join(l.describe() for l in self.levels)

    def shoreline_used_mm(self) -> float:
        return sum(l.shoreline_mm for l in self.levels)

    def total_capacity_gb(self) -> float:
        return sum(l.capacity_gb for l in self.levels)

    def on_chip_capacity_gb(self) -> float:
        return sum(l.capacity_gb for l in self.levels
                   if l.tech.kind is MemKind.ON_CHIP)

    def off_chip_levels(self) -> list[MemoryLevel]:
        return [l for l in self.levels if l.tech.kind is MemKind.OFF_CHIP]

    def background_power_w(self) -> float:
        return sum(l.background_power_w() for l in self.levels)

    # ---- Eq. 2: effective bandwidths ---------------------------------------

    def effective_bandwidths_gbps(self) -> list[float]:
        """B_i^eff for each boundary i (innermost first), Eq. 2 with clamp."""
        peaks = [l.bandwidth_gbps for l in self.levels]
        effs = [0.0] * len(peaks)
        deeper = 0.0
        for i in reversed(range(len(peaks))):
            eff = peaks[i] - deeper
            eff = max(eff, 0.5 * peaks[i])      # double-buffer pass-through bound
            effs[i] = eff
            deeper = eff
        return effs

    # ---- Eqs. 3-5: recursive double-buffered transfer time ------------------

    def transfer_time_s(
        self,
        x_bytes: float,
        resident_fractions: Optional[Sequence[float]] = None,
        bw_share: float = 1.0,
    ) -> TransferBreakdown:
        """Time to deliver `x_bytes` to the compute unit.

        resident_fractions: alpha_i per level (fraction of the data arriving
        at boundary i that is already resident at level i).  Defaults to all
        zeros except the outermost level (weights streamed from the last
        level).  `bw_share` scales every boundary's effective bandwidth (the
        off-chip bandwidth-priority knob).
        """
        n = len(self.levels)
        if resident_fractions is None:
            alphas = [0.0] * (n - 1) + [1.0]
        else:
            alphas = list(resident_fractions)
            if len(alphas) != n:
                raise ValueError(f"need {n} fractions, got {len(alphas)}")
        alphas[-1] = 1.0  # outermost level holds everything that reaches it
        for a in alphas:
            if not (0.0 <= a <= 1.0):
                raise ValueError(f"fractions must be in [0,1], got {alphas}")

        effs = [b * bw_share for b in self.effective_bandwidths_gbps()]
        lams = [l.latency_s for l in self.levels]

        boundary_times: list[float] = []

        def rec(i: int, x: float) -> tuple[float, str]:
            # time for all of x to cross boundary i
            t_here = lams[i] + (x / (effs[i] * 1e9) if x > 0 else 0.0)
            boundary_times.append(t_here)
            if i == n - 1 or x <= 0:
                return t_here, "leaf"
            x_remain = (1.0 - alphas[i]) * x
            t_deep, _ = rec(i + 1, x_remain)
            if t_here >= t_deep:
                return t_here, "overlapped"        # Case 1
            return t_deep, "bandwidth_limited"     # Case 2

        total, case = rec(0, float(x_bytes))
        return TransferBreakdown(
            total_s=total,
            case=case,
            boundary_times_s=tuple(boundary_times),
            resident_fractions=tuple(alphas),
        )

    # ---- placement ----------------------------------------------------------

    def place_greedy(self, sizes_gb: Sequence[float],
                     priority: Sequence[int]) -> list[list[float]]:
        """Greedily place data classes into levels, innermost first.

        sizes_gb: size of each data class.  priority: evaluation order
        (indices into sizes_gb, highest priority first).  Returns
        placed[level][cls] = GB of class `cls` stored at `level`.
        Raises ValueError if total capacity is insufficient.
        """
        n = len(self.levels)
        placed = [[0.0] * len(sizes_gb) for _ in range(n)]
        free = [l.capacity_gb for l in self.levels]
        for cls in priority:
            remaining = sizes_gb[cls]
            for lvl in range(n):
                take = min(remaining, free[lvl])
                placed[lvl][cls] += take
                free[lvl] -= take
                remaining -= take
                if remaining <= 1e-12:
                    break
            if remaining > 1e-12:
                raise ValueError(
                    f"capacity exhausted placing class {cls}: "
                    f"{remaining:.2f} GB left over in {self.describe()}"
                )
        return placed

    def fits(self, total_gb: float) -> bool:
        return total_gb <= self.total_capacity_gb() + 1e-12

    # ---- structure-of-arrays export (perfmodel_jit) -------------------------

    def level_param_rows(self) -> list[tuple[tuple, bool]]:
        """[(level_params row, is_on_chip)] per level, innermost first.

        Numeric export for the jitted batch evaluator: each level becomes
        one `memtech.LEVEL_PARAM_FIELDS` row computed with the exact same
        float64 expressions as the MemoryLevel properties, so SoA
        hierarchies built from this table evaluate bit-identically to the
        object path."""
        from .memtech import level_params
        return [(level_params(l.tech, l.stacks),
                 l.tech.kind is MemKind.ON_CHIP) for l in self.levels]


def max_stacks(tech: MemoryTechnology, l_mem_mm: float = L_MEM_MAX_MM) -> int:
    """Eq. 1: shoreline bound on the number of attachable stacks."""
    if tech.kind is MemKind.ON_CHIP:
        return 1_000_000  # unbounded by shoreline (thermal-bounded instead)
    return int(l_mem_mm // (tech.shoreline_mm + L_MARGIN_MM))
