"""Unified memory-technology abstraction (paper Table 1).

Every memory technology relevant to NPU co-design is described by a compact
parameter set spanning physical integration (shoreline footprint, stacking)
and performance (latency, capacity, bandwidth, energy).  This is the paper's
central abstraction: heterogeneous technologies become points in a common
(capacity, bandwidth, latency, power) space so the DSE can compose them into
hierarchies.

Units (kept explicit and consistent everywhere):
  latency_s        seconds           I/O access latency
  capacity_gb      GB (1e9 bytes)    per die / stack / package / chip
  bandwidth_gbps   GB/s              peak, per die / stack / package / chip
  shoreline_mm     mm                PHY shoreline footprint per stack
                                     (None for on-chip technologies)
  p_bg_mw_per_gb   mW/GB             static background power
  e_read_pj_per_bit / e_write_pj_per_bit   pJ/bit dynamic access energy
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class MemKind(enum.Enum):
    ON_CHIP = "on_chip"
    OFF_CHIP = "off_chip"


@dataclasses.dataclass(frozen=True)
class MemoryTechnology:
    """One row of the paper's Table 1."""

    name: str
    kind: MemKind
    latency_s: float
    capacity_gb: float
    bandwidth_gbps: float
    shoreline_mm: Optional[float]
    p_bg_mw_per_gb: float
    e_read_pj_per_bit: float
    e_write_pj_per_bit: float
    note: str = ""

    # ---- derived helpers -------------------------------------------------

    def background_power_w(self, capacity_gb: Optional[float] = None) -> float:
        """Static leakage power in W for `capacity_gb` (defaults to one unit)."""
        c = self.capacity_gb if capacity_gb is None else capacity_gb
        return self.p_bg_mw_per_gb * c * 1e-3

    def read_power_w(self, bw_gbps: float) -> float:
        """Dynamic read power in W at a sustained read bandwidth (GB/s)."""
        # GB/s -> bit/s: * 8e9 ; pJ/bit -> J/bit: * 1e-12
        return self.e_read_pj_per_bit * bw_gbps * 8e9 * 1e-12

    def write_power_w(self, bw_gbps: float) -> float:
        return self.e_write_pj_per_bit * bw_gbps * 8e9 * 1e-12

    def bytes_per_joule_read(self) -> float:
        """Capacity-independent read efficiency."""
        return 1.0 / (self.e_read_pj_per_bit * 8e-12 * 1e9)  # bytes per joule / 1e9

    def capacity_per_shoreline(self) -> float:
        """GB per shoreline mm (the HBF headline metric). inf for on-chip."""
        if self.shoreline_mm is None or self.shoreline_mm == 0:
            return float("inf")
        return self.capacity_gb / self.shoreline_mm


# ---------------------------------------------------------------------------
# Table 1 catalog.  Ranged values in the paper ("~50-100") take midpoints;
# each entry carries the paper's note.
# ---------------------------------------------------------------------------

SRAM_2D = MemoryTechnology(
    name="SRAM",
    kind=MemKind.ON_CHIP,
    latency_s=1.5e-9,
    capacity_gb=0.256,          # 256 MB per die
    bandwidth_gbps=4096.0,      # 4 TB/s
    shoreline_mm=None,
    p_bg_mw_per_gb=30_000.0,    # 10k-50k midpoint
    e_read_pj_per_bit=0.1,
    e_write_pj_per_bit=0.1,
    note="conventional 2D on-chip SRAM, one die",
)

SRAM_3D = MemoryTechnology(
    name="3D-SRAM",
    kind=MemKind.ON_CHIP,
    latency_s=5e-9,
    capacity_gb=1.0,            # 1 GB per stacked layer
    bandwidth_gbps=8192.0,      # 8 TB/s per layer
    shoreline_mm=None,
    p_bg_mw_per_gb=30_000.0,
    e_read_pj_per_bit=0.1,
    e_write_pj_per_bit=0.1,
    note="3D-stacked SRAM, per bonded layer (V-Cache style)",
)

HBM3E = MemoryTechnology(
    name="HBM3E",
    kind=MemKind.OFF_CHIP,
    latency_s=100e-9,
    capacity_gb=24.0,
    bandwidth_gbps=1024.0,      # 1 TB/s per stack
    shoreline_mm=11.0,
    p_bg_mw_per_gb=75.0,        # 50-100 midpoint
    e_read_pj_per_bit=3.0,
    e_write_pj_per_bit=3.6,
    note="8-high stack",
)

HBM4 = MemoryTechnology(
    name="HBM4",
    kind=MemKind.OFF_CHIP,
    latency_s=100e-9,
    capacity_gb=36.0,
    bandwidth_gbps=2048.0,      # 2 TB/s per stack
    shoreline_mm=15.0,
    p_bg_mw_per_gb=75.0,
    e_read_pj_per_bit=2.2,      # ~40% better energy than HBM3E
    e_write_pj_per_bit=2.4,
    note="12-high stack; 40% energy efficiency gain over HBM3E",
)

LPDDR5X = MemoryTechnology(
    name="LPDDR5X",
    kind=MemKind.OFF_CHIP,
    latency_s=50e-9,
    capacity_gb=16.0,
    bandwidth_gbps=76.8,
    shoreline_mm=4.1,
    p_bg_mw_per_gb=7.65,
    e_read_pj_per_bit=5.0,
    e_write_pj_per_bit=6.5,
    note="per package",
)

LPDDR6 = MemoryTechnology(
    name="LPDDR6",
    kind=MemKind.OFF_CHIP,
    latency_s=50e-9,
    capacity_gb=16.0,
    bandwidth_gbps=172.8,
    shoreline_mm=4.5,
    p_bg_mw_per_gb=6.12,
    e_read_pj_per_bit=3.75,
    e_write_pj_per_bit=4.87,
    note="20-30% more energy efficient than LPDDR5X",
)

GDDR6 = MemoryTechnology(
    name="GDDR6",
    kind=MemKind.OFF_CHIP,
    latency_s=12e-9,
    capacity_gb=2.0,
    bandwidth_gbps=64.0,
    shoreline_mm=11.0,
    p_bg_mw_per_gb=100.0,
    e_read_pj_per_bit=7.0,
    e_write_pj_per_bit=8.8,
    note="per chip",
)

GDDR7 = MemoryTechnology(
    name="GDDR7",
    kind=MemKind.OFF_CHIP,
    latency_s=12e-9,
    capacity_gb=3.0,
    bandwidth_gbps=128.0,
    shoreline_mm=11.0,
    p_bg_mw_per_gb=120.0,
    e_read_pj_per_bit=5.6,
    e_write_pj_per_bit=7.0,
    note="20% more energy efficient than GDDR6",
)

HBF = MemoryTechnology(
    name="HBF",
    kind=MemKind.OFF_CHIP,
    latency_s=1e-6,
    capacity_gb=384.0,
    bandwidth_gbps=1024.0,      # 1 TB/s per stack
    shoreline_mm=8.25,
    p_bg_mw_per_gb=300.0,       # ~4x HBM3E
    e_read_pj_per_bit=6.0,      # ~2x HBM3E
    e_write_pj_per_bit=10.0,
    note="High Bandwidth Flash: NAND + DRAM buffer + HB PHY",
)

CATALOG: dict[str, MemoryTechnology] = {
    t.name: t
    for t in [SRAM_2D, SRAM_3D, HBM3E, HBM4, LPDDR5X, LPDDR6, GDDR6, GDDR7, HBF]
}

ON_CHIP_TECHS = [t for t in CATALOG.values() if t.kind is MemKind.ON_CHIP]
OFF_CHIP_TECHS = [t for t in CATALOG.values() if t.kind is MemKind.OFF_CHIP]


def get(name: str) -> MemoryTechnology:
    try:
        return CATALOG[name]
    except KeyError:
        raise KeyError(
            f"unknown memory technology {name!r}; known: {sorted(CATALOG)}"
        ) from None


# ---------------------------------------------------------------------------
# Structure-of-arrays export (perfmodel_jit).
#
# The jitted batch evaluator represents a hierarchy level as one numeric
# row instead of a MemoryLevel object.  The arithmetic here mirrors the
# MemoryLevel properties exactly (same expressions, same float64 ops) so
# the SoA path is bit-identical to the object path.
# ---------------------------------------------------------------------------

LEVEL_PARAM_FIELDS = ("capacity_gb", "bandwidth_gbps", "latency_s",
                      "e_read_pj_per_bit", "e_write_pj_per_bit",
                      "background_power_w")


def level_params(tech: MemoryTechnology, stacks: int) -> tuple:
    """One hierarchy level as a `LEVEL_PARAM_FIELDS` numeric row.

    Matches MemoryLevel: capacity/bandwidth scale with `stacks`, access
    energies are per-bit constants, background power is leakage for the
    scaled capacity.  `stacks == 0` yields an all-zero row (absent slot
    in a fixed-slot SoA hierarchy)."""
    if stacks <= 0:
        return (0.0,) * len(LEVEL_PARAM_FIELDS)
    cap = tech.capacity_gb * stacks
    return (cap, tech.bandwidth_gbps * stacks, tech.latency_s,
            tech.e_read_pj_per_bit, tech.e_write_pj_per_bit,
            tech.background_power_w(cap))
