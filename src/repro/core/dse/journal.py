"""Append-only evaluation journal: crash-safe search state + replay.

Long searches (1000-eval fleet sweeps over 100+-gene `SystemSpace`s)
must survive a mid-run kill without discarding every evaluation.  The
journal makes them resumable with a deliberately simple failure model:

* **What is persisted** — every *final* observation the searchers act
  on, one JSON line per design, in evaluation order: the integer design
  key, the objective tuple (or ``null`` when infeasible), the reported
  bottleneck, and a fault tag when the observation was quarantined by
  the guarded evaluation layer (see `runner`).  Nothing else: searcher
  RNG state, GP hyperparameters and population state are *derived*
  state — the seeded searchers recompute them deterministically.
* **What resumes** — on restart the journal replays its records into
  the objective's evaluation cache and the searcher reruns from its
  seed.  Every already-journaled proposal is a cache hit (no model
  evaluation), so the search fast-forwards through the prefix and
  continues live exactly where it died.  Because replayed values are
  byte-exact (JSON round-trips IEEE-754 doubles losslessly) the resumed
  run's proposals, journal lines, and final front are byte-identical to
  the uninterrupted run — `tests/test_journal_resume.py` proves this at
  every iteration boundary against the sha-pinned trajectories.
* **What is refused** — a journal written by a *different* search: the
  header pins the space/objective/seed identity (space type and
  cardinalities, objective type, model/trace/phase, TDP budget,
  objective count, seed) and `begin` raises `JournalMismatch` rather
  than silently mixing trajectories.
* **What survives a crash mid-write** — a torn final line (the process
  died inside `write`).  `begin` truncates the file back to the last
  complete record before replaying; the lost evaluation is simply
  recomputed.

File format (JSONL, canonical separators, sorted keys)::

    {"identity": {...}, "kind": "header", "meta": {...}, "version": 1}
    {"bneck": "...", "f": [t, -p], "i": 0, "kind": "eval", "x": [...]}
    {"f": null, "i": 1, "kind": "eval", "x": [...]}
    {"f": null, "fault": "non_finite", "i": 2, "kind": "eval", ...}

The journal never stores timestamps or host state — identical searches
produce identical bytes, which is what the resume tests pin.
"""

from __future__ import annotations

import json
import os
from typing import Optional


class JournalError(RuntimeError):
    """The journal file cannot be used (corrupt header, bad record)."""


class JournalMismatch(JournalError):
    """The journal belongs to a different space/objective/seed."""


def _canon(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def objective_identity(objective, seed: Optional[int] = None) -> dict:
    """The identity dict pinned by the journal header.

    Everything that changes the meaning of a (design key -> objectives)
    record: the design space encoding, the evaluated workload, the
    feasibility budgets, the objective count — plus the search seed,
    so a journal can never silently resume a differently-seeded run.
    Wrapped objectives (e.g. the fault injector's `FaultyObjective`)
    expose the real objective via ``unwrapped``.
    """
    obj = getattr(objective, "unwrapped", objective)
    space = obj.space
    ident = {
        "objective": type(obj).__name__,
        "space": type(space).__name__,
        "n_dims": int(space.n_dims),
        "cardinalities": [int(c) for c in space.cardinalities],
        "model": getattr(getattr(obj, "dims", None), "name", None),
        "trace": getattr(getattr(obj, "trace", None), "name", None),
        "phase": getattr(getattr(obj, "phase", None), "name", None),
        "tdp_limit_w": float(obj.tdp_limit_w),
        "n_obj": int(getattr(obj, "n_obj", 2)),
    }
    topo = getattr(obj, "topology", None)
    if topo is not None:
        ident["topology"] = getattr(topo, "name", None)
    ttft = getattr(obj, "ttft_cap_s", None)
    if ttft is not None:
        ident["ttft_cap_s"] = float(ttft)
    # serving searches additionally pin the traffic mix (class traces,
    # arrival rates, per-class SLO caps): a journal must never resume
    # against different traffic, which would silently re-interpret
    # every cached (design -> objectives) record
    mix = getattr(obj, "mix", None)
    if mix is not None:
        ident["mix"] = mix.identity()
    # calibrated objectives pin the factor table's content hash: a
    # journal written under one set of measured GEMM factors must not
    # resume under another.  Identity/absent tables add no key, so
    # pre-calibration journals stay valid for default objectives.
    cal = getattr(obj, "calibration", None)
    if cal is not None and not getattr(cal, "is_identity", True):
        ident["calibration"] = cal.digest()
    if seed is not None:
        ident["seed"] = int(seed)
    return ident


class SearchJournal:
    """Append-only JSONL journal of one seeded search's evaluations.

    Usage::

        j = SearchJournal("run.jsonl")
        res = run_mobo(objective, n_total=200, seed=0, journal=j)

    Kill the process at any point and rerun the same two lines: `begin`
    (called by the searcher) replays the journal into the objective's
    cache and the search continues from where it stopped, reproducing
    the uninterrupted trajectory byte-identically.
    """

    def __init__(self, path):
        self.path = os.fspath(path)
        self._fh = None
        self._logged: set = set()
        self._n = 0
        self._begun = False

    # -- lifecycle ---------------------------------------------------------

    def begin(self, objective, seed: int,
              method: Optional[str] = None) -> int:
        """Open the journal for `objective`/`seed`; replay any existing
        records into the objective's evaluation cache.

        Returns the number of replayed evaluations.  Idempotent: the
        searchers, `shared_init` and `system_warm_start` all call it,
        so one journal threads through a warm start plus a search.
        Raises `JournalMismatch` when the on-disk header pins a
        different space/objective/seed.
        """
        identity = objective_identity(objective, seed=seed)
        if self._begun:
            if identity != self._identity:
                raise JournalMismatch(
                    f"{self.path}: journal already begun with a different "
                    f"identity")
            return len(self._logged)
        n_replayed = 0
        if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
            n_replayed = self._replay(objective, identity)
        if self._fh is None:        # fresh file (or torn-header restart)
            header = {"kind": "header", "version": 1, "identity": identity,
                      "meta": {"method": method}}
            self._fh = open(self.path, "a")
            self._fh.write(_canon(header) + "\n")
            self._fh.flush()
        self._identity = identity
        self._begun = True
        return n_replayed

    def _replay(self, objective, identity: dict) -> int:
        # local import: runner imports journal, so the Observation type
        # is fetched lazily to keep the module graph acyclic.
        from .runner import Observation
        with open(self.path, "r+") as f:
            raw = f.read()
            keep = len(raw)
            if raw and not raw.endswith("\n"):
                # torn final line from a crash mid-write: drop it
                keep = raw.rfind("\n") + 1
                f.truncate(keep)
        lines = raw[:keep].splitlines()
        if not lines:
            # the crash tore the header itself: nothing usable survived,
            # restart the journal from scratch
            return 0
        try:
            header = json.loads(lines[0])
        except ValueError as exc:
            raise JournalError(f"{self.path}: unreadable header") from exc
        if header.get("kind") != "header":
            raise JournalError(f"{self.path}: first line is not a header")
        if header.get("identity") != identity:
            raise JournalMismatch(
                f"{self.path}: journal identity does not match this "
                f"search (got {header.get('identity')!r}, "
                f"want {identity!r})")
        cache = getattr(objective, "cache", None)
        n = 0
        for ln, line in enumerate(lines[1:], start=2):
            try:
                rec = json.loads(line)
            except ValueError as exc:
                raise JournalError(
                    f"{self.path}:{ln}: unreadable record") from exc
            if rec.get("kind") != "eval":
                continue
            key = tuple(int(v) for v in rec["x"])
            f_val = rec.get("f")
            obs = Observation(
                x=list(key),
                f=None if f_val is None else tuple(float(v) for v in f_val),
                npu=None, fault=rec.get("fault"))
            if cache is not None and key not in cache:
                cache[key] = obs
            self._logged.add(key)
            n += 1
        self._n = n
        self._fh = open(self.path, "a")
        return n

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- recording ---------------------------------------------------------

    def _line(self, obs) -> Optional[str]:
        """Serialized record line for `obs`, or None if already logged
        (bumps the record counter and the logged-key set)."""
        key = tuple(int(v) for v in obs.x)
        if key in self._logged:
            return None
        rec = {"kind": "eval", "i": self._n, "x": list(key),
               "f": None if obs.f is None else [float(v) for v in obs.f]}
        bneck = getattr(obs.result, "bottleneck", None)
        if bneck is not None:
            rec["bneck"] = str(bneck)
        fault = getattr(obs, "fault", None)
        if fault is not None:
            rec["fault"] = str(fault)
        self._logged.add(key)
        self._n += 1
        return _canon(rec) + "\n"

    def record(self, obs) -> None:
        """Append one observation (no-op for already-journaled keys)."""
        if self._fh is None:
            raise JournalError("journal not begun")
        line = self._line(obs)
        if line is None:
            return
        self._fh.write(line)
        self._fh.flush()

    def record_many(self, observations) -> None:
        """Append a batch of observations as one write + flush (bytes
        identical to per-record appends; a crash mid-batch leaves a
        clean record prefix — plus at most one torn line, which `begin`
        truncates — so a resumed search replays the completed records
        and re-proposes only the missing ones)."""
        if self._fh is None:
            raise JournalError("journal not begun")
        lines = [line for line in map(self._line, observations)
                 if line is not None]
        if lines:
            self._fh.write("".join(lines))
            self._fh.flush()
