"""Exact 2-D Expected Hypervolume Improvement (Eq. 8), vectorized.

For two maximized objectives with independent Gaussian predictive
marginals Y = (Y1, Y2), EHVI has a closed form over the staircase cells
of the incumbent front (box decomposition, Emmerich/Yang style).  With
the front sorted ascending in f1 — points (x_1, v_1) .. (x_m, v_m), v
strictly descending — and sentinels x_0 = r1, x_{m+1} = +inf,
v_{m+1} = r2, the non-dominated region above the reference point r
splits into vertical strips, and

    EHVI = sum_{k=1}^{m+1} [psi1(x_{k-1}) - psi1(x_k)] * psi2(v_k)

where psi_j(t) = E[(Y_j - t)+] = sd_j * phi(z) + (mu_j - t) * Phi(z),
z = (mu_j - t) / sd_j, is the Gaussian partial expectation
(integral of P(Y_j > a) da from t to inf).

Everything is NumPy-vectorized over the candidate pool: one
[n_cand, m+2] matrix of psi1 evaluations and one [n_cand, m+1] of psi2,
so scoring a 256-candidate pool against a 60-point history is a handful
of array ops instead of ~n_cand * n_mc staircase hypervolume rebuilds.

`mc_ehvi` keeps the quasi-Monte-Carlo estimator (the seed
implementation's semantics) as a test oracle for the closed form.
"""

from __future__ import annotations

import math

import numpy as np

from .pareto import _staircase, hypervolume, hypervolume_2d

try:                                    # scipy ships with jax, but keep the
    from scipy.special import ndtr      # dse package importable without it
except ImportError:                     # pragma: no cover - minimal installs
    _erf = np.vectorize(math.erf, otypes=[float])

    def ndtr(z):
        return 0.5 * (1.0 + _erf(np.asarray(z) / math.sqrt(2.0)))

_SQRT_2PI = float(np.sqrt(2.0 * np.pi))


def _psi(t: np.ndarray, mu: np.ndarray, sd: np.ndarray) -> np.ndarray:
    """E[(Y - t)+] for Y ~ N(mu, sd^2), elementwise-broadcast."""
    sd = np.maximum(sd, 1e-300)
    z = (mu - t) / sd
    return sd * np.exp(-0.5 * z * z) / _SQRT_2PI + (mu - t) * ndtr(z)


def ehvi_2d(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
            sd: np.ndarray) -> np.ndarray:
    """Exact EHVI for a batch of candidates (maximization).

    front: [m, 2] incumbent points (any set; reduced to its staircase
    internally).  ref: [2].  mu, sd: [n_cand, 2] independent Gaussian
    predictive marginals.  Returns [n_cand] exact EHVI values.
    """
    mu = np.atleast_2d(np.asarray(mu, dtype=float))
    sd = np.atleast_2d(np.asarray(sd, dtype=float))
    ref = np.asarray(ref, dtype=float)
    front = np.asarray(front, dtype=float).reshape(-1, 2)
    stair = _staircase(front, ref) if len(front) else front
    # thresholds: x_0=r1, x_1..x_m ; v_1..v_m, v_{m+1}=r2
    xs = np.concatenate(([ref[0]], stair[:, 0]))
    vs = np.concatenate((stair[:, 1], [ref[1]]))
    psi1 = _psi(xs[None, :], mu[:, 0:1], sd[:, 0:1])       # [n, m+1]
    psi1 = np.concatenate([psi1, np.zeros((len(mu), 1))], axis=1)
    psi2 = _psi(vs[None, :], mu[:, 1:2], sd[:, 1:2])       # [n, m+1]
    out = np.sum((psi1[:, :-1] - psi1[:, 1:]) * psi2, axis=1)
    return np.maximum(out, 0.0)


def mc_ehvi(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
            sd: np.ndarray, z: np.ndarray) -> np.ndarray:
    """Quasi-MC EHVI estimate: test oracle for `ehvi_2d`, and the MOBO
    acquisition fallback for d > 2 objectives (exact box decomposition
    is 2-D only; see pareto.hypervolume for the nd indicator).

    mu, sd: [n_cand, d]; z: [n_samples, d] standard-normal draws
    (antithetic).  Returns EHVI estimates [n_cand].
    """
    mu = np.atleast_2d(np.asarray(mu, dtype=float))
    ref = np.asarray(ref, dtype=float)
    d = mu.shape[1]
    front = np.asarray(front, dtype=float).reshape(-1, d)
    hv = hypervolume_2d if d == 2 else hypervolume
    base = hv(front, ref) if len(front) else 0.0
    out = np.zeros(len(mu))
    for i in range(len(mu)):
        ys = mu[i] + sd[i] * z            # [s, d]
        hvs = 0.0
        for y in ys:
            if np.any(y <= ref):
                continue
            hvs += max(0.0, hv(
                np.vstack([front, y[None, :]]) if len(front) else y[None, :],
                ref) - base)
        out[i] = hvs / len(ys)
    return out
