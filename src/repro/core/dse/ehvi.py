"""Exact 2-D and 3-D Expected Hypervolume Improvement, vectorized.

For maximized objectives with independent Gaussian predictive marginals,
EHVI has a closed form over a disjoint box decomposition of the
non-dominated region above the reference point (Emmerich/Yang): for a
box (l, u] the contribution is prod_j [psi_j(l_j) - psi_j(u_j)], where

    psi_j(t) = E[(Y_j - t)+] = sd_j * phi(z) + (mu_j - t) * Phi(z),
    z = (mu_j - t) / sd_j,  psi_j(+inf) = 0,

is the Gaussian partial expectation (integral of P(Y_j > a) da from t
to inf).  In 2-D the boxes are the vertical strips of the staircase
front — with the front sorted ascending in f1, points
(x_1, v_1) .. (x_m, v_m), and sentinels x_0 = r1, x_{m+1} = +inf,
v_{m+1} = r2:

    EHVI = sum_{k=1}^{m+1} [psi1(x_{k-1}) - psi1(x_k)] * psi2(v_k)

In 3-D (`ehvi_3d`) the boxes come from a slab sweep descending in f3:
within the slab below each distinct front f3 value, the points whose f3
clears the slab project to a 2-D staircase whose strips, crossed with
the slab's f3 interval, tile the non-dominated region into O(m^2)
disjoint boxes.

Everything is NumPy-vectorized over the candidate pool: one
[n_cand, n_box] contribution matrix per objective, so scoring a
256-candidate pool against a 60-point history is a handful of array ops
instead of ~n_cand * n_mc staircase hypervolume rebuilds.

`mc_ehvi` keeps the quasi-Monte-Carlo estimator (the seed
implementation's semantics) as a test oracle for both closed forms and
the MOBO acquisition fallback for d > 3.
"""

from __future__ import annotations

import math

import numpy as np

from .pareto import _staircase, hypervolume, hypervolume_2d, pareto_mask

try:                                    # scipy ships with jax, but keep the
    from scipy.special import ndtr      # dse package importable without it
except ImportError:                     # pragma: no cover - minimal installs
    _erf = np.vectorize(math.erf, otypes=[float])

    def ndtr(z):
        return 0.5 * (1.0 + _erf(np.asarray(z) / math.sqrt(2.0)))

_SQRT_2PI = float(np.sqrt(2.0 * np.pi))


def _psi(t: np.ndarray, mu: np.ndarray, sd: np.ndarray) -> np.ndarray:
    """E[(Y - t)+] for Y ~ N(mu, sd^2), elementwise-broadcast."""
    sd = np.maximum(sd, 1e-300)
    z = (mu - t) / sd
    return sd * np.exp(-0.5 * z * z) / _SQRT_2PI + (mu - t) * ndtr(z)


def ehvi_2d(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
            sd: np.ndarray) -> np.ndarray:
    """Exact EHVI for a batch of candidates (maximization).

    front: [m, 2] incumbent points (any set; reduced to its staircase
    internally).  ref: [2].  mu, sd: [n_cand, 2] independent Gaussian
    predictive marginals.  Returns [n_cand] exact EHVI values.
    """
    mu = np.atleast_2d(np.asarray(mu, dtype=float))
    sd = np.atleast_2d(np.asarray(sd, dtype=float))
    ref = np.asarray(ref, dtype=float)
    front = np.asarray(front, dtype=float).reshape(-1, 2)
    stair = _staircase(front, ref) if len(front) else front
    # thresholds: x_0=r1, x_1..x_m ; v_1..v_m, v_{m+1}=r2
    xs = np.concatenate(([ref[0]], stair[:, 0]))
    vs = np.concatenate((stair[:, 1], [ref[1]]))
    psi1 = _psi(xs[None, :], mu[:, 0:1], sd[:, 0:1])       # [n, m+1]
    psi1 = np.concatenate([psi1, np.zeros((len(mu), 1))], axis=1)
    psi2 = _psi(vs[None, :], mu[:, 1:2], sd[:, 1:2])       # [n, m+1]
    out = np.sum((psi1[:, :-1] - psi1[:, 1:]) * psi2, axis=1)
    return np.maximum(out, 0.0)


def _boxes_3d(front: np.ndarray,
              ref: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Disjoint box decomposition of the 3-D region above `ref` that is
    not dominated by `front` (maximization).

    Slab sweep descending in f3: slab i spans f3 in (z_i, z_{i-1}] with
    z_0 = +inf and a final slab down to ref[2]; inside it the points
    whose f3 >= z_{i-1} dominate, and their 2-D staircase yields the
    strip boxes of `ehvi_2d`.  Returns (lo, hi) arrays [n_box, 3]; hi
    entries may be +inf (psi(+inf) = 0 kills those factors).
    """
    ref = np.asarray(ref, dtype=float)
    pts = np.asarray(front, dtype=float).reshape(-1, 3)
    pts = pts[np.all(pts > ref, axis=1)]
    if len(pts) == 0:
        return ref[None, :].copy(), np.full((1, 3), np.inf)
    pts = pts[pareto_mask(pts)]
    zs = np.unique(pts[:, 2])[::-1]         # distinct f3, descending
    z_his = np.concatenate(([np.inf], zs))
    z_los = np.concatenate((zs, [ref[2]]))
    los, his = [], []
    for z_hi, z_lo in zip(z_his, z_los):
        if np.isinf(z_hi):                  # topmost slab: nothing above
            stair = pts[:0, :2]
        else:
            stair = _staircase(pts[pts[:, 2] >= z_hi][:, :2], ref[:2])
        lo = np.empty((len(stair) + 1, 3))
        hi = np.empty_like(lo)
        lo[:, 0] = np.concatenate(([ref[0]], stair[:, 0]))
        hi[:, 0] = np.concatenate((stair[:, 0], [np.inf]))
        lo[:, 1] = np.concatenate((stair[:, 1], [ref[1]]))
        hi[:, 1] = np.inf
        lo[:, 2] = z_lo
        hi[:, 2] = z_hi
        los.append(lo)
        his.append(hi)
    return np.concatenate(los), np.concatenate(his)


def ehvi_3d(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
            sd: np.ndarray) -> np.ndarray:
    """Exact EHVI for three maximized objectives (box decomposition),
    vectorized over the candidate pool.

    front: [m, 3] incumbent points (any set; reduced internally).
    ref: [3].  mu, sd: [n_cand, 3] independent Gaussian predictive
    marginals.  Returns [n_cand] exact EHVI values.  O(m^2) boxes, one
    [n_cand, n_box] pass per objective.
    """
    mu = np.atleast_2d(np.asarray(mu, dtype=float))
    sd = np.atleast_2d(np.asarray(sd, dtype=float))
    lo, hi = _boxes_3d(front, ref)
    out = np.ones((len(mu), len(lo)))
    for j in range(3):
        psi_lo = _psi(lo[None, :, j], mu[:, j:j + 1], sd[:, j:j + 1])
        psi_hi = np.zeros_like(psi_lo)
        fin = np.isfinite(hi[:, j])
        if np.any(fin):
            psi_hi[:, fin] = _psi(hi[None, fin, j], mu[:, j:j + 1],
                                  sd[:, j:j + 1])
        out *= psi_lo - psi_hi
    return np.maximum(out.sum(axis=1), 0.0)


def mc_ehvi(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
            sd: np.ndarray, z: np.ndarray) -> np.ndarray:
    """Quasi-MC EHVI estimate: test oracle for `ehvi_2d`/`ehvi_3d`, and
    the MOBO acquisition fallback for d > 3 objectives (see
    pareto.hypervolume for the nd indicator).

    mu, sd: [n_cand, d]; z: [n_samples, d] standard-normal draws
    (antithetic).  Returns EHVI estimates [n_cand].
    """
    mu = np.atleast_2d(np.asarray(mu, dtype=float))
    ref = np.asarray(ref, dtype=float)
    d = mu.shape[1]
    front = np.asarray(front, dtype=float).reshape(-1, d)
    hv = hypervolume_2d if d == 2 else hypervolume
    base = hv(front, ref) if len(front) else 0.0
    out = np.zeros(len(mu))
    for i in range(len(mu)):
        ys = mu[i] + sd[i] * z            # [s, d]
        hvs = 0.0
        for y in ys:
            if np.any(y <= ref):
                continue
            hvs += max(0.0, hv(
                np.vstack([front, y[None, :]]) if len(front) else y[None, :],
                ref) - base)
        out[i] = hvs / len(ys)
    return out
