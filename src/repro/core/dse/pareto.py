"""Pareto utilities: dominance, front extraction, exact 2-D hypervolume.

Objectives are MAXIMIZED throughout the DSE (throughput, -power); the
hypervolume indicator (Eq. 7) is computed against a reference point that
every observed objective vector dominates.

All kernels are sort-based sweeps: `pareto_mask` is O(n log n) for two
objectives (with a vectorized O(n^2) fallback for d != 2),
`hypervolume_2d` is a single staircase sweep over the sorted front,
`hv_contributions_2d` reads every exclusive contribution off the sorted
staircase in one pass, and `hv_history` maintains the front incrementally
(bisect insert + contiguous eviction) instead of recomputing the
hypervolume from scratch after every observation.
"""

from __future__ import annotations

import bisect

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a Pareto-dominates b (maximization): >= everywhere, > somewhere."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    return bool(np.all(a >= b) and np.any(a > b))


def _pareto_mask_2d(ys: np.ndarray) -> np.ndarray:
    """O(n log n) sweep: sort by f1 desc (f2 desc within ties); a point
    survives iff it has the max f2 of its f1-group and beats the best f2
    seen among strictly-larger f1."""
    n = len(ys)
    order = np.lexsort((-ys[:, 1], -ys[:, 0]))
    f1 = ys[order, 0]
    f2 = ys[order, 1]
    new_grp = np.empty(n, dtype=bool)
    new_grp[0] = True
    new_grp[1:] = f1[1:] != f1[:-1]
    grp_start = np.maximum.accumulate(np.where(new_grp, np.arange(n), 0))
    cummax = np.maximum.accumulate(f2)
    best_prev = np.where(grp_start > 0, cummax[np.maximum(grp_start - 1, 0)],
                         -np.inf)
    keep = (f2 == f2[grp_start]) & (f2 > best_prev)
    mask = np.empty(n, dtype=bool)
    mask[order] = keep
    return mask


def _pareto_mask_nd(ys: np.ndarray) -> np.ndarray:
    """Vectorized dominance filter for d != 2 objectives."""
    n = len(ys)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        cand = np.flatnonzero(mask)
        dom = (np.all(ys[cand] >= ys[i], axis=1)
               & np.any(ys[cand] > ys[i], axis=1))
        if np.any(dom):
            mask[i] = False
        else:
            # i survives; anything i dominates cannot be on the front
            sub = (np.all(ys[i] >= ys[cand], axis=1)
                   & np.any(ys[i] > ys[cand], axis=1))
            mask[cand[sub]] = False
    return mask


def pareto_mask(ys: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (maximization)."""
    ys = np.asarray(ys, dtype=float)
    if ys.size == 0:
        return np.zeros(len(ys), dtype=bool)
    if ys.shape[1] == 2:
        return _pareto_mask_2d(ys)
    return _pareto_mask_nd(ys)


def pareto_front(ys: np.ndarray) -> np.ndarray:
    return np.asarray(ys, dtype=float)[pareto_mask(ys)]


def _staircase(ys: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Non-dominated points strictly dominating `ref`, sorted ascending in
    f1 (f2 then strictly descending; duplicates collapsed)."""
    pts = ys[(ys[:, 0] > ref[0]) & (ys[:, 1] > ref[1])]
    if len(pts) == 0:
        return pts
    front = pts[_pareto_mask_2d(pts)]
    order = np.lexsort((front[:, 1], front[:, 0]))
    front = front[order]
    keep = np.empty(len(front), dtype=bool)
    keep[0] = True
    keep[1:] = np.any(front[1:] != front[:-1], axis=1)
    return front[keep]


def hypervolume_2d(ys: np.ndarray, ref: np.ndarray) -> float:
    """Exact dominated hypervolume for 2 maximized objectives (Eq. 7).

    Points not dominating `ref` contribute nothing.  Single staircase
    sweep: with the front sorted ascending in f1 (descending f2), the
    dominated region is a disjoint union of strips
    (x_i - x_{i-1}) * (y_i - ref2).
    """
    ys = np.asarray(ys, dtype=float)
    ref = np.asarray(ref, dtype=float)
    if ys.size == 0:
        return 0.0
    front = _staircase(ys, ref)
    if len(front) == 0:
        return 0.0
    x_prev = np.concatenate(([ref[0]], front[:-1, 0]))
    return float(np.sum((front[:, 0] - x_prev) * (front[:, 1] - ref[1])))


def hypervolume(ys: np.ndarray, ref: np.ndarray) -> float:
    """Exact dominated hypervolume for d maximized objectives.

    d = 2 delegates to the staircase sweep; d > 2 uses dimension-sweep
    slicing: sort the front descending in the last objective, slice the
    dominated region into slabs between consecutive last-objective
    values, and recurse on the (d-1)-dimensional projection of each
    slab's dominating points.  Worst case O(n^{d-1} log n) — fine for
    the <= ~100-point fronts the searchers and the quasi-MC EHVI
    fallback hand it (the exact 3-D box decomposition for the EHVI
    acquisition itself is still a ROADMAP item).
    """
    ys = np.asarray(ys, dtype=float)
    ref = np.asarray(ref, dtype=float)
    if ys.size == 0:
        return 0.0
    ys = ys.reshape(len(ys), -1)
    if ys.shape[1] == 1:
        return float(max(0.0, ys[:, 0].max() - ref[0]))
    if ys.shape[1] == 2:
        return hypervolume_2d(ys, ref)
    pts = ys[np.all(ys > ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[pareto_mask(pts)]
    order = np.argsort(-pts[:, -1], kind="stable")
    pts = pts[order]
    hv = 0.0
    for i in range(len(pts)):
        lo = pts[i + 1, -1] if i + 1 < len(pts) else ref[-1]
        height = pts[i, -1] - lo
        if height <= 0:             # duplicate last-coordinate: empty slab
            continue
        hv += height * hypervolume(pts[:i + 1, :-1], ref[:-1])
    return float(hv)


def hv_contributions_2d(front: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Exclusive hypervolume contribution of each point.

    Dominated points, duplicates, and points not dominating `ref`
    contribute 0; staircase points contribute their private rectangle
    (x_i - x_{i-1}) * (y_i - y_{i+1}), read off the sorted front in one
    vectorized pass.
    """
    front = np.asarray(front, dtype=float)
    ref = np.asarray(ref, dtype=float)
    out = np.zeros(len(front))
    if front.size == 0:
        return out
    dom = (front[:, 0] > ref[0]) & (front[:, 1] > ref[1])
    idx = np.flatnonzero(dom)
    if len(idx) == 0:
        return out
    pts = front[idx]
    on_front = _pareto_mask_2d(pts)
    idx = idx[on_front]
    p = front[idx]
    order = np.lexsort((p[:, 1], p[:, 0]))
    sp = p[order]
    first = np.empty(len(sp), dtype=bool)
    first[0] = True
    first[1:] = np.any(sp[1:] != sp[:-1], axis=1)
    starts = np.flatnonzero(first)
    counts = np.diff(np.append(starts, len(sp)))
    u = sp[first]                       # unique: asc f1, strictly desc f2
    x_prev = np.concatenate(([ref[0]], u[:-1, 0]))
    y_next = np.concatenate((u[1:, 1], [ref[1]]))
    contrib = (u[:, 0] - x_prev) * (u[:, 1] - y_next)
    contrib[counts > 1] = 0.0           # a duplicated point is never exclusive
    grp = np.cumsum(first) - 1
    out[idx[order]] = contrib[grp]
    return out


class IncrementalHV2D:
    """Incremental exact 2-D hypervolume: add points one at a time.

    Maintains the staircase front as parallel sorted lists; each `add` is
    O(log n) search + O(evicted) removal, so a full history over n points
    is O(n log n) total instead of n full recomputations.
    """

    def __init__(self, ref) -> None:
        self.ref = (float(ref[0]), float(ref[1]))
        self._xs: list = []             # ascending f1
        self._ys: list = []             # strictly descending f2
        self.hv = 0.0

    def add(self, point) -> float:
        """Insert one point; returns the updated hypervolume."""
        x, y = float(point[0]), float(point[1])
        r0, r1 = self.ref
        if x <= r0 or y <= r1:
            return self.hv
        xs, ys = self._xs, self._ys
        i = bisect.bisect_right(xs, x)
        # lo: first index whose y <= y (ys descending) among x' <= x
        lo = i
        while lo > 0 and ys[lo - 1] <= y:
            lo -= 1
        # dominated iff some point has x' >= x and y' >= y:
        # the nearest candidate with y' >= y is index lo-1 (x' <= x region)
        # or index i (x' > x, but then y' < ys[lo-1]... check directly).
        if lo > 0 and xs[lo - 1] >= x:
            return self.hv              # duplicate-or-dominated
        if i < len(xs) and ys[i] >= y:
            return self.hv
        x_left = xs[lo - 1] if lo > 0 else r0
        y_right = ys[i] if i < len(xs) else r1
        gained = (x - x_left) * (y - y_right)
        x_prev = x_left
        for k in range(lo, i):          # points newly dominated by (x, y)
            gained -= (xs[k] - x_prev) * (ys[k] - y_right)
            x_prev = xs[k]
        xs[lo:i] = [x]
        ys[lo:i] = [y]
        self.hv += gained
        return self.hv

    def front(self) -> np.ndarray:
        return np.column_stack((self._xs, self._ys)) if self._xs \
            else np.empty((0, 2))


class IncrementalHVND:
    """Incremental exact hypervolume for d >= 3 maximized objectives.

    Dominated, duplicate, and below-reference points are O(|front| * d)
    mask checks and cost nothing; an improving point pays exactly one
    clipped-front hypervolume — its exclusive gain is
    vol(box(ref, y)) - HV(min(front, y), ref), since a point p <= y is
    already covered iff it is covered by the front clipped into y's
    box.  A history over n points therefore pays one nd-hypervolume per
    front *change* instead of a full recompute per prefix (2-D keeps
    the O(log n) staircase in `IncrementalHV2D`).
    """

    def __init__(self, ref) -> None:
        self.ref = np.asarray(ref, dtype=float)
        self._front = np.empty((0, len(self.ref)))
        self.hv = 0.0

    def add(self, point) -> float:
        """Insert one point; returns the updated hypervolume."""
        y = np.asarray(point, dtype=float)
        if not np.all(y > self.ref):
            return self.hv
        f = self._front
        if len(f) and bool(np.any(np.all(f >= y, axis=1))):
            return self.hv              # duplicate-or-dominated: no gain
        box = float(np.prod(y - self.ref))
        covered = hypervolume(np.minimum(f, y), self.ref) if len(f) else 0.0
        self.hv += max(0.0, box - covered)
        keep = ~np.all(y >= f, axis=1)  # evict points y now dominates
        self._front = np.vstack([f[keep], y[None, :]])
        return self.hv

    def front(self) -> np.ndarray:
        return self._front.copy()


def hv_history(ys: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Hypervolume of the first k points, for every k (incremental;
    exact for any d — the 2-D staircase or the nd clipped-front gain)."""
    ys = np.asarray(ys, dtype=float)
    out = np.empty(len(ys))
    if len(ys) == 0:
        return out
    if ys.shape[1] == 2:
        inc = IncrementalHV2D(ref)
    else:
        inc = IncrementalHVND(ref)
    for k, y in enumerate(ys):
        out[k] = inc.add(y)
    return out


def reference_point(ys: np.ndarray, margin: float = 0.05) -> np.ndarray:
    """A reference point slightly below the observed minima."""
    ys = np.asarray(ys, dtype=float)
    lo = ys.min(axis=0)
    span = np.maximum(ys.max(axis=0) - lo, 1e-9)
    return lo - margin * span
