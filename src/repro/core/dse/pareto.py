"""Pareto utilities: dominance, front extraction, exact 2-D hypervolume.

Objectives are MAXIMIZED throughout the DSE (throughput, -power); the
hypervolume indicator (Eq. 7) is computed against a reference point that
every observed objective vector dominates.
"""

from __future__ import annotations

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a Pareto-dominates b (maximization): >= everywhere, > somewhere."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    return bool(np.all(a >= b) and np.any(a > b))


def pareto_mask(ys: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (maximization)."""
    ys = np.asarray(ys, dtype=float)
    n = len(ys)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        for j in range(n):
            if i == j:
                continue
            if dominates(ys[j], ys[i]):
                mask[i] = False
                break
    return mask


def pareto_front(ys: np.ndarray) -> np.ndarray:
    return np.asarray(ys, dtype=float)[pareto_mask(ys)]


def hypervolume_2d(ys: np.ndarray, ref: np.ndarray) -> float:
    """Exact dominated hypervolume for 2 maximized objectives (Eq. 7).

    Points not dominating `ref` contribute nothing.
    """
    ys = np.asarray(ys, dtype=float)
    ref = np.asarray(ref, dtype=float)
    if ys.size == 0:
        return 0.0
    pts = ys[(ys[:, 0] > ref[0]) & (ys[:, 1] > ref[1])]
    if len(pts) == 0:
        return 0.0
    front = pareto_front(pts)
    # sort by f1 ascending; f2 is then descending along the front
    order = np.argsort(front[:, 0])
    front = front[order]
    hv = 0.0
    prev_x = ref[0]
    # iterate right-to-left is equivalent; accumulate strips left-to-right
    # strip i spans [prev_x, x_i] with height (y_i - ref2) where y_i is the
    # max f2 among points with f1 >= x_i -> since front sorted ascending f1
    # and descending f2, point i's own y is the height from its x leftward
    # until a higher-y point.  Simpler: sweep descending f2:
    hv = 0.0
    prev_x = ref[0]
    for i in range(len(front)):
        x, y = front[i]
        width_x = x - prev_x
        if width_x < 0:
            width_x = 0.0
        # height: this point's y (front is descending in y as x grows, so
        # the region right of prev_x up to x is topped by ... ) — use the
        # classic staircase: process points sorted by f1 ascending and sum
        # (x_i - x_{i-1}) * (y_i - ref2) over the *suffix maxima* of y.
        hv += width_x * max(0.0, max(front[i:, 1]) - ref[1])
        prev_x = x
    return float(hv)


def hv_contributions_2d(front: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Exclusive hypervolume contribution of each front point."""
    base = hypervolume_2d(front, ref)
    out = np.zeros(len(front))
    for i in range(len(front)):
        rest = np.delete(front, i, axis=0)
        out[i] = base - hypervolume_2d(rest, ref)
    return out


def reference_point(ys: np.ndarray, margin: float = 0.05) -> np.ndarray:
    """A reference point slightly below the observed minima."""
    ys = np.asarray(ys, dtype=float)
    lo = ys.min(axis=0)
    span = np.maximum(ys.max(axis=0) - lo, 1e-9)
    return lo - margin * span
