"""Gaussian Process surrogate (paper Section 4.4) in JAX.

Independent GPs per objective: RBF kernel with ARD lengthscales, signal
variance and noise optimized by maximum likelihood (Adam on log-params).
Inputs are the normalized design encodings in [0,1]^d; outputs are
standardized internally.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _rbf(x1: jnp.ndarray, x2: jnp.ndarray, log_ls: jnp.ndarray,
         log_sf: jnp.ndarray) -> jnp.ndarray:
    ls = jnp.exp(log_ls)
    d = (x1[:, None, :] - x2[None, :, :]) / ls
    return jnp.exp(2.0 * log_sf) * jnp.exp(-0.5 * jnp.sum(d * d, axis=-1))


def _nll(params, x, y):
    log_ls, log_sf, log_sn = params["ls"], params["sf"], params["sn"]
    n = x.shape[0]
    k = _rbf(x, x, log_ls, log_sf) + jnp.exp(2.0 * log_sn) * jnp.eye(n) \
        + 1e-6 * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol)))
            + 0.5 * n * jnp.log(2.0 * jnp.pi))


@jax.jit
def _fit_adam(x, y, init_ls):
    params = {"ls": init_ls, "sf": jnp.array(0.0), "sn": jnp.array(-2.0)}
    grad_fn = jax.value_and_grad(_nll)
    lr = 0.05
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        params, m, v = carry
        _, g = grad_fn(params, x, y)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        mhat = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vhat = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
            params, mhat, vhat)
        # keep lengthscales in a sane band
        params["ls"] = jnp.clip(params["ls"], -3.0, 3.0)
        params["sn"] = jnp.clip(params["sn"], -5.0, 1.0)
        return (params, m, v), 0.0

    (params, _, _), _ = jax.lax.scan(step, (params, m, v),
                                     jnp.arange(150.0))
    return params


@dataclasses.dataclass
class GP:
    """Fitted GP posterior over one standardized objective."""

    x: np.ndarray
    y_mean: float
    y_std: float
    params: dict
    chol: np.ndarray
    alpha: np.ndarray

    @classmethod
    def fit(cls, x: np.ndarray, y: np.ndarray) -> "GP":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        mu, sd = float(y.mean()), float(y.std() + 1e-9)
        ys = (y - mu) / sd
        init_ls = jnp.zeros(x.shape[1]) - 0.5
        params = _fit_adam(jnp.asarray(x), jnp.asarray(ys), init_ls)
        params = {k: np.asarray(v) for k, v in params.items()}
        k = np.array(_rbf(jnp.asarray(x), jnp.asarray(x),
                          jnp.asarray(params["ls"]),
                          jnp.asarray(params["sf"])))
        k = k + (np.exp(2.0 * params["sn"]) + 1e-6) * np.eye(len(x))
        chol = np.linalg.cholesky(k)
        alpha = np.linalg.solve(chol.T, np.linalg.solve(chol, ys))
        return cls(x=x, y_mean=mu, y_std=sd, params=params, chol=chol,
                   alpha=alpha)

    def predict(self, xq: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Posterior mean and stddev at query points (original scale)."""
        xq = np.asarray(xq, dtype=np.float64)
        ks = np.asarray(_rbf(jnp.asarray(xq), jnp.asarray(self.x),
                             jnp.asarray(self.params["ls"]),
                             jnp.asarray(self.params["sf"])))
        mean = ks @ self.alpha
        v = np.linalg.solve(self.chol, ks.T)
        kss = float(np.exp(2.0 * self.params["sf"]))
        var = np.maximum(kss - np.sum(v * v, axis=0), 1e-12)
        return (mean * self.y_std + self.y_mean,
                np.sqrt(var) * self.y_std)
