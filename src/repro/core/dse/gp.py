"""Gaussian Process surrogate (paper Section 4.4) in JAX.

Independent GPs per objective: RBF kernel with ARD lengthscales, signal
variance and noise optimized by maximum likelihood (Adam on log-params).
Inputs are the normalized design encodings in [0,1]^d; outputs are
standardized internally.

Performance notes (the DSE refits per iteration on a growing dataset):

* The jitted MLE fit pads the data to power-of-two buckets with a
  validity mask folded into the kernel (masked rows/cols become an
  identity block, masked targets are zero), so the whole MOBO run
  compiles O(log n) XLA programs instead of one per dataset size.  The
  masked NLL has identical gradients to the unpadded one, so the fitted
  hyperparameters are unchanged.
* `predict` is pure NumPy: the posterior is a couple of small matmuls
  and a triangular solve, and the per-call NumPy<->JAX round-trip it
  used to pay (dispatch + retrace per query shape) dominated its cost.
* For batched (q-EHVI) acquisition the whole hot path moves onto
  `jax.jit` in float64: `fit(use_jit=True)` factorizes the posterior
  with `_posterior_pad` (same bucket padding, same jitter-escalation /
  eigenvalue-clamp semantics as `_stable_cholesky`, expressed as a
  `lax.while_loop` over the nugget schedule — JAX's Cholesky reports
  failure as NaNs instead of raising), and `predict_batch` runs the
  batched posterior in one compiled call.  The NumPy `fit`/`predict`
  pair stays byte-identical (it is what the sha-pinned B=1
  trajectories ran on) and doubles as the parity oracle: jitted
  fit/predict agree with it to <= 1e-9 including the degenerate-kernel
  hardening cases (tested).

Numerical hardening (degenerate data is routine mid-search: a feasible
set of 4 observations can be constant in an objective, and NSGA-II/TPE
revisit near-duplicate designs constantly):

* `_stable_cholesky` retries `np.linalg.cholesky` with an escalating
  diagonal nugget (1e-10 .. 1e-2 of the mean kernel diagonal) instead
  of raising `LinAlgError`, with an eigenvalue-clamp reconstruction as
  the last resort — a near-singular kernel costs posterior sharpness,
  never the search.
* Non-finite hyperparameters out of the jitted MLE (a diverged Adam
  run on pathological targets) fall back to the initialization values
  (`_sanitize_params`) rather than poisoning the NumPy-side posterior.
* Targets must be finite: the searchers quarantine NaN/Inf
  observations before fitting (see `runner`), and `fit` raises a clear
  `ValueError` if a non-finite target slips through anyway.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

_MIN_BUCKET = 8


def _bucket(n: int) -> int:
    """Smallest power-of-two >= n (>= _MIN_BUCKET): the jit-cache key."""
    b = _MIN_BUCKET
    while b < n:
        b <<= 1
    return b


def _rbf(x1: jnp.ndarray, x2: jnp.ndarray, log_ls: jnp.ndarray,
         log_sf: jnp.ndarray) -> jnp.ndarray:
    ls = jnp.exp(log_ls)
    d = (x1[:, None, :] - x2[None, :, :]) / ls
    return jnp.exp(2.0 * log_sf) * jnp.exp(-0.5 * jnp.sum(d * d, axis=-1))


def _rbf_np(x1: np.ndarray, x2: np.ndarray, log_ls: np.ndarray,
            log_sf: np.ndarray) -> np.ndarray:
    ls = np.exp(log_ls)
    d = (x1[:, None, :] - x2[None, :, :]) / ls
    return np.exp(2.0 * log_sf) * np.exp(-0.5 * np.sum(d * d, axis=-1))


def _nll(params, x, y, mask):
    """Masked negative log marginal likelihood.

    Padded entries (mask == 0) contribute an identity row/col to K and a
    zero target, so their Cholesky pivot is 1 (log-det contribution 0)
    and their alpha is 0: gradients match the unpadded problem exactly.
    """
    log_ls, log_sf, log_sn = params["ls"], params["sf"], params["sn"]
    m2 = mask[:, None] * mask[None, :]
    k = _rbf(x, x, log_ls, log_sf) * m2
    diag = jnp.where(mask > 0, jnp.exp(2.0 * log_sn) + 1e-6, 1.0)
    k = k + jnp.diag(diag)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol)))
            + 0.5 * jnp.sum(mask) * jnp.log(2.0 * jnp.pi))


@jax.jit
def _fit_adam(x, y, mask, init_ls):
    params = {"ls": init_ls, "sf": jnp.array(0.0), "sn": jnp.array(-2.0)}
    grad_fn = jax.value_and_grad(_nll)
    lr = 0.05
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        params, m, v = carry
        _, g = grad_fn(params, x, y, mask)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        mhat = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vhat = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
            params, mhat, vhat)
        # keep lengthscales in a sane band
        params["ls"] = jnp.clip(params["ls"], -3.0, 3.0)
        params["sn"] = jnp.clip(params["sn"], -5.0, 1.0)
        return (params, m, v), 0.0

    (params, _, _), _ = jax.lax.scan(step, (params, m, v),
                                     jnp.arange(150.0))
    return params


#: escalating jitter schedule of `_stable_cholesky`, as fractions of
#: the mean kernel diagonal
_JITTERS = (0.0, 1e-10, 1e-8, 1e-6, 1e-4, 1e-2)


def _stable_cholesky(k: np.ndarray) -> np.ndarray:
    """Cholesky with jitter escalation: retry with an increasing nugget
    on the diagonal instead of raising `LinAlgError` on degenerate
    kernels (duplicate rows, constant targets pushing the noise floor
    down).  Falls back to an eigenvalue clamp if even the largest
    nugget fails — always returns a finite factor."""
    n = len(k)
    scale = float(np.mean(np.diag(k))) or 1.0
    for jit in _JITTERS:
        try:
            chol = np.linalg.cholesky(k if jit == 0.0
                                      else k + (jit * scale) * np.eye(n))
        except np.linalg.LinAlgError:
            continue
        if np.all(np.isfinite(chol)):
            return chol
    # last resort: clamp the spectrum and refactor (cannot fail: the
    # clamped matrix is symmetric positive definite by construction)
    w, v = np.linalg.eigh((k + k.T) / 2.0)
    w = np.maximum(w, 1e-10 * scale)
    return np.linalg.cholesky((v * w) @ v.T)


@jax.jit
def _posterior_pad(xp, yp, mask, log_ls, log_sf, log_sn):
    """Jitted masked posterior factorization (call under `enable_x64`).

    Mirrors the NumPy path of `GP.fit` on the bucket-padded problem:
    the masked kernel gives the padded rows an identity block, so the
    leading valid block of the factor equals the unpadded Cholesky and
    the padded alpha entries are zero.  Jitter escalation follows
    `_stable_cholesky` exactly — retry over the `_JITTERS` nugget
    schedule (JAX's Cholesky returns NaNs where LAPACK would raise),
    then the eigenvalue-clamp last resort.
    """
    b = xp.shape[0]
    m2 = mask[:, None] * mask[None, :]
    k = _rbf(xp, xp, log_ls, log_sf) * m2
    k = k + jnp.diag(jnp.where(mask > 0,
                               jnp.exp(2.0 * log_sn) + 1e-6, 1.0))
    # mean diagonal of the valid block (the RBF diagonal is constant,
    # so this equals NumPy's mean over the unpadded diagonal)
    n_valid = jnp.maximum(jnp.sum(mask), 1.0)
    scale = jnp.sum(jnp.diag(k) * mask) / n_valid
    scale = jnp.where(scale == 0.0, 1.0, scale)
    jitters = jnp.asarray(_JITTERS)
    eye = jnp.eye(b, dtype=k.dtype)

    def cond(state):
        i, chol = state
        return (i < len(_JITTERS)) & ~jnp.all(jnp.isfinite(chol))

    def body(state):
        i, _ = state
        return i + 1, jnp.linalg.cholesky(k + (jitters[i] * scale) * eye)

    _, chol = jax.lax.while_loop(
        cond, body, (0, jnp.full_like(k, jnp.nan)))

    def _clamp(_):
        w, v = jnp.linalg.eigh((k + k.T) / 2.0)
        w = jnp.maximum(w, 1e-10 * scale)
        return jnp.linalg.cholesky((v * w) @ v.T)

    chol = jax.lax.cond(jnp.all(jnp.isfinite(chol)),
                        lambda _: chol, _clamp, None)
    alpha = jax.scipy.linalg.cho_solve((chol, True), yp)
    return chol, alpha


@jax.jit
def _predict_pad(xqp, xp, mask, cholp, alphap, log_ls, log_sf):
    """Jitted batched posterior on bucket-padded blocks (under
    `enable_x64`).  Masked cross-covariance columns zero out the padded
    training rows; padded query rows are sliced off by the caller."""
    ks = _rbf(xqp, xp, log_ls, log_sf) * mask[None, :]
    mean = ks @ alphap
    v = jax.scipy.linalg.solve_triangular(cholp, ks.T, lower=True)
    kss = jnp.exp(2.0 * log_sf)
    var = jnp.maximum(kss - jnp.sum(v * v, axis=0), 1e-12)
    return mean, var


def _sanitize_params(params: dict, d: int) -> dict:
    """Replace non-finite fitted hyperparameters (diverged MLE on
    degenerate data) with the optimizer's initialization values."""
    defaults = {"ls": np.full(d, -0.5), "sf": np.array(0.0),
                "sn": np.array(-2.0)}
    return {key: (val if np.all(np.isfinite(val)) else defaults[key])
            for key, val in params.items()}


@dataclasses.dataclass
class GP:
    """Fitted GP posterior over one standardized objective."""

    x: np.ndarray
    y_mean: float
    y_std: float
    params: dict
    chol: np.ndarray
    alpha: np.ndarray

    @classmethod
    def fit_design(cls, space, designs, y: np.ndarray,
                   use_jit: bool = False) -> "GP":
        """Fit on integer design vectors, normalized via their
        `DesignSpace` (each gene mapped to bin centers in [0,1]).

        The searcher never normalizes by hand, so the GP works for any
        space dimensionality — 17 genes for the single-device space, 34
        for the paired prefill/decode space (the jit bucket cache keys
        on (padded n, d), so each space compiles its own small set of
        programs).  Query points still go through
        `space.normalize_batch` before `predict`.
        """
        return cls.fit(space.normalize_batch(designs), y, use_jit=use_jit)

    @classmethod
    def fit(cls, x: np.ndarray, y: np.ndarray,
            use_jit: bool = False) -> "GP":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if not np.all(np.isfinite(y)):
            raise ValueError("GP.fit: non-finite targets — quarantine "
                             "NaN/Inf observations before fitting")
        mu, sd = float(y.mean()), float(y.std() + 1e-9)
        ys = (y - mu) / sd
        n, d = x.shape
        b = _bucket(n)
        xp = np.zeros((b, d))
        xp[:n] = x
        yp = np.zeros(b)
        yp[:n] = ys
        mask = np.zeros(b)
        mask[:n] = 1.0
        init_ls = jnp.zeros(d) - 0.5
        params = _fit_adam(jnp.asarray(xp), jnp.asarray(yp),
                           jnp.asarray(mask), init_ls)
        params = {k: np.asarray(v, dtype=np.float64)
                  for k, v in params.items()}
        params = _sanitize_params(params, d)
        if use_jit:
            with enable_x64():
                cp, ap = _posterior_pad(
                    jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mask),
                    jnp.asarray(params["ls"]), jnp.asarray(params["sf"]),
                    jnp.asarray(params["sn"]))
                chol = np.asarray(cp)[:n, :n]
                alpha = np.asarray(ap)[:n]
        else:
            k = _rbf_np(x, x, params["ls"], params["sf"])
            k = k + (np.exp(2.0 * params["sn"]) + 1e-6) * np.eye(n)
            chol = _stable_cholesky(k)
            alpha = np.linalg.solve(chol.T, np.linalg.solve(chol, ys))
        return cls(x=x, y_mean=mu, y_std=sd, params=params, chol=chol,
                   alpha=alpha)

    def predict(self, xq: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Posterior mean and stddev at query points (original scale)."""
        xq = np.asarray(xq, dtype=np.float64)
        ks = _rbf_np(xq, self.x, self.params["ls"], self.params["sf"])
        mean = ks @ self.alpha
        v = np.linalg.solve(self.chol, ks.T)
        kss = float(np.exp(2.0 * self.params["sf"]))
        var = np.maximum(kss - np.sum(v * v, axis=0), 1e-12)
        return (mean * self.y_std + self.y_mean,
                np.sqrt(var) * self.y_std)

    def predict_batch(self, xq: np.ndarray) -> tuple[np.ndarray,
                                                     np.ndarray]:
        """Jitted batched posterior mean/stddev (original scale).

        Bucket-pads both the query block and the training factor so
        compiles stay O(log q * log n); `predict` is the NumPy parity
        oracle (agreement <= 1e-9, tested).
        """
        xq = np.asarray(xq, dtype=np.float64)
        q, d = xq.shape
        n = len(self.x)
        bq, bn = _bucket(q), _bucket(n)
        xqp = np.zeros((bq, d))
        xqp[:q] = xq
        xp = np.zeros((bn, d))
        xp[:n] = self.x
        mask = np.zeros(bn)
        mask[:n] = 1.0
        cholp = np.eye(bn)
        cholp[:n, :n] = self.chol
        alphap = np.zeros(bn)
        alphap[:n] = self.alpha
        with enable_x64():
            mean, var = _predict_pad(
                jnp.asarray(xqp), jnp.asarray(xp), jnp.asarray(mask),
                jnp.asarray(cholp), jnp.asarray(alphap),
                jnp.asarray(self.params["ls"]),
                jnp.asarray(self.params["sf"]))
            mean = np.asarray(mean)[:q]
            var = np.asarray(var)[:q]
        return (mean * self.y_std + self.y_mean,
                np.sqrt(var) * self.y_std)
