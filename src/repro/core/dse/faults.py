"""Deterministic fault injection for the DSE evaluation path.

The searchers' robustness claims ("every searcher completes and, for
transient faults, converges to the failure-free result") are only worth
anything if they are exercised — this module is the seeded chaos layer
that exercises them.  `FaultyObjective` wraps any objective (it sits
exactly where `Objective.evaluate_batch` / `evaluate_system_batch`
deliver results to the searchers) and injects three failure modes the
fleet-scale searches actually see:

* **transient evaluator exceptions** — a whole `evaluate_batch` call
  raises `TransientEvalError` (a `runtime.fault.StepFailure`) before
  any work happens, simulating a jit compile/dispatch crash.  The
  guarded evaluation layer in `runner` retries; since the fault budget
  per distinct batch is finite, retries converge to the clean result.
* **NaN/Inf objective storms** — selected designs deliver non-finite
  objective tuples for their first `fault_attempts` deliveries,
  simulating numerical blowups in the evaluator.  The clean value is
  computed (and cached) underneath; only the *delivered copy* is
  corrupted, so a retry after the budget is spent observes the true
  objectives and trajectories converge to the failure-free run.
* **infeasibility floods** — selected designs are reported infeasible
  (``f=None``).  These are *sticky* (an infeasible verdict is
  indistinguishable from a real one, so nothing retries it): they test
  that searchers complete and keep flooded points out of the front,
  not that they converge.

All decisions are drawn from RNGs seeded by (injector seed, design key)
— independent of call order — so a run with injection is itself
deterministic and reproducible.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional

import numpy as np

from ...runtime.fault import StepFailure


class TransientEvalError(StepFailure):
    """Injected (or simulated) transient evaluator failure."""


@dataclasses.dataclass
class FaultSpec:
    """Probabilities and budgets of the injected failure modes.

    `p_transient` applies per distinct batch (the set of keys passed to
    one `evaluate_batch` call); `p_nan` / `p_infeasible` apply per
    distinct design key.  `fault_attempts` is how many deliveries of a
    faulted key (or batch) fail before the clean result flows.

    Convergence bound: faults *compose* within one guarded evaluation —
    a transient-faulted batch containing a NaN-faulted key must survive
    ``fault_attempts`` raised calls plus ``fault_attempts`` corrupted
    deliveries before a clean delivery, i.e. worst case
    ``2 * fault_attempts + 1`` attempts against the runner's
    ``EVAL_RETRIES + 1`` budget.  For convergence tests keep the summed
    per-mode budgets at or below ``EVAL_RETRIES`` (e.g.
    ``fault_attempts=1`` with both modes on, or ``EVAL_RETRIES`` with a
    single mode); push past the budget to exercise quarantine instead.
    """

    p_transient: float = 0.0
    p_nan: float = 0.0
    p_infeasible: float = 0.0
    fault_attempts: int = 1
    nan_value: float = math.nan     # swap for math.inf to storm with Infs
    seed: int = 0


class FaultInjector:
    """Seeded, key-addressed fault decisions + an event log."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.events: list = []
        self._key_plan: dict = {}       # key -> [kind, remaining]
        self._batch_plan: dict = {}     # batch signature -> remaining

    def _rng_for(self, token) -> np.random.Generator:
        h = zlib.crc32(repr(token).encode())
        return np.random.default_rng((int(self.spec.seed) << 32) ^ h)

    def batch_should_fail(self, keys) -> bool:
        """Transient-exception decision for one evaluate_batch call."""
        sig = tuple(keys)
        if sig not in self._batch_plan:
            fails = (self._rng_for(("batch", sig)).random()
                     < self.spec.p_transient)
            self._batch_plan[sig] = self.spec.fault_attempts if fails else 0
        if self._batch_plan[sig] > 0:
            self._batch_plan[sig] -= 1
            self.events.append(("transient", len(sig)))
            return True
        return False

    def plan_for(self, key) -> Optional[str]:
        """The per-key fault to apply to this delivery, if any."""
        if key not in self._key_plan:
            u = self._rng_for(("key", key)).random()
            if u < self.spec.p_nan:
                self._key_plan[key] = ["nan", self.spec.fault_attempts]
            elif u < self.spec.p_nan + self.spec.p_infeasible:
                # sticky: infeasible verdicts are never retried
                self._key_plan[key] = ["infeasible", -1]
            else:
                self._key_plan[key] = [None, 0]
        kind, remaining = self._key_plan[key]
        if kind is None:
            return None
        if remaining == 0:
            return None
        if remaining > 0:
            self._key_plan[key][1] -= 1
        self.events.append((kind, key))
        return kind


class FaultyObjective:
    """Wrap an objective, corrupting deliveries per a `FaultInjector`.

    Delegates every attribute (``space``, ``tdp_limit_w``, ``cache``,
    ...) to the wrapped objective, so searchers, journals and warm
    starts treat it as the objective itself.  Corruption happens on the
    *returned copies* only — the wrapped objective's cache always holds
    the clean evaluations, which is what makes transient-fault runs
    converge to the failure-free trajectory once retries drain the
    fault budgets.
    """

    def __init__(self, objective, injector: FaultInjector):
        self._inner = objective
        self.injector = injector

    @property
    def unwrapped(self):
        return getattr(self._inner, "unwrapped", self._inner)

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def _deliver(self, obs):
        key = tuple(int(v) for v in obs.x)
        kind = self.injector.plan_for(key)
        if kind is None:
            return obs
        if kind == "infeasible":
            return dataclasses.replace(obs, f=None, result=None)
        # NaN/Inf storm: corrupt one objective component per delivery
        if obs.f is None:
            return obs                  # nothing to corrupt
        bad = list(obs.f)
        bad[len(bad) // 2] = self.injector.spec.nan_value
        return dataclasses.replace(obs, f=tuple(bad))

    def __call__(self, x):
        key = (tuple(int(v) for v in x),)
        if self.injector.batch_should_fail(key):
            raise TransientEvalError("injected transient evaluator failure")
        return self._deliver(self._inner(x))

    def evaluate_batch(self, xs):
        keys = tuple(tuple(int(v) for v in x) for x in xs)
        if self.injector.batch_should_fail(keys):
            raise TransientEvalError("injected transient evaluator failure")
        return [self._deliver(o) for o in self._inner.evaluate_batch(xs)]
