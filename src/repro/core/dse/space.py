"""The co-design space (paper Table 2) and its integer encoding.

A design point is a 17-dimensional integer vector indexing categorical
choices; `decode` builds the NPUConfig (compute + hierarchy + quant +
software strategy).  The off-chip hierarchy order is canonical by
technology bandwidth class: HBM -> HBF -> GDDR -> LPDDR (matching the
paper's Table 6 configurations).

The encoded space (~7 x 10^8 raw combinations; ~10^6 after validity
filtering) is searched by the optimizers in runner.py, which are generic
over a `DesignSpace`:

  SingleDeviceSpace   the 17-gene Table 2 space (wraps this module's
                      functions; the paper's Fig. 6 experiment)
  SystemSpace         K concatenated 17-gene halves — one device per
                      `disagg.SystemTopology` role, co-searched as one
                      K*17-gene point (paper Section 5.5 extreme
                      heterogeneity), with declarative `GeneTie`
                      cross-half constraints
  PairedSpace         the K=2 SystemSpace with the KV-cache-quant tie
                      (a prefill and a decode device, paper Sections
                      5.3/5.5, Fig. 8; transferred KV must decode on
                      the other device)

The module-level functions remain the single-device fast path; the
classes delegate to them so existing seeded trajectories are unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..compute import ComputeConfig, Dataflow
from ..dataflow import BandwidthPriority, SoftwareStrategy, StoragePriority
from ..hierarchy import MemoryHierarchy, MemoryLevel, ShorelineError
from ..memtech import get as get_tech
from ..npu import NPUConfig
from ..quant.formats import QuantConfig

PE_CHOICES = [(128, 128), (64, 256), (32, 512), (16, 1024),
              (2048, 64), (2048, 128), (2048, 256), (1024, 64), (1024, 512)]
VLEN_CHOICES = [128, 256, 512, 1024, 2048]
SRAM3D_CHOICES = [0, 1, 2, 3, 4]
SRAM2D_CHOICES = [0, 1]
HBM_TYPES = ["HBM3E", "HBM4"]
GDDR_TYPES = ["GDDR6", "GDDR7"]
LPDDR_TYPES = ["LPDDR5X", "LPDDR6"]
STACK_CHOICES = [0, 1, 2, 4, 8]
LPDDR_STACK_CHOICES = [0, 1, 2, 4, 8, 16]
ACT_FMTS = ["MXFP8", "MXFP16", "MXINT8", "MXINT16"]
KV_FMTS = ["MXFP4", "MXFP8", "MXINT4", "MXINT8"]
W_FMTS = ["MXFP4", "MXFP8", "MXINT4", "MXINT8"]
STORAGE_CHOICES = [StoragePriority.ACTIVATION, StoragePriority.KV_CACHE,
                   StoragePriority.WEIGHT, StoragePriority.EQUAL]
DATAFLOW_CHOICES = [Dataflow.WEIGHT_STATIONARY, Dataflow.OUTPUT_STATIONARY,
                    Dataflow.INPUT_STATIONARY]
BW_CHOICES = [BandwidthPriority.MATRIX, BandwidthPriority.VECTOR,
              BandwidthPriority.EQUAL]

CARDINALITIES = [
    len(PE_CHOICES), len(VLEN_CHOICES), len(SRAM3D_CHOICES),
    len(SRAM2D_CHOICES), len(HBM_TYPES), len(STACK_CHOICES),
    len(GDDR_TYPES), len(STACK_CHOICES), len(LPDDR_TYPES),
    len(LPDDR_STACK_CHOICES), len(STACK_CHOICES),
    len(ACT_FMTS), len(KV_FMTS), len(W_FMTS),
    len(STORAGE_CHOICES), len(DATAFLOW_CHOICES), len(BW_CHOICES),
]
N_DIMS = len(CARDINALITIES)


class InvalidDesign(ValueError):
    pass


def decode(x) -> NPUConfig:
    """Integer vector -> NPUConfig. Raises InvalidDesign for impossible
    combinations (no on-chip memory, no memory at all, shoreline)."""
    x = [int(v) for v in x]
    if len(x) != N_DIMS:
        raise InvalidDesign(f"need {N_DIMS} genes, got {len(x)}")
    for v, c in zip(x, CARDINALITIES):
        if not (0 <= v < c):
            raise InvalidDesign(f"gene out of range: {x}")
    pe_r, pe_c = PE_CHOICES[x[0]]
    compute = ComputeConfig(pe_rows=pe_r, pe_cols=pe_c,
                            vlen=VLEN_CHOICES[x[1]])
    levels: list[MemoryLevel] = []
    n3d = SRAM3D_CHOICES[x[2]]
    if n3d > 0:
        levels.append(MemoryLevel(get_tech("3D-SRAM"), n3d))
    if SRAM2D_CHOICES[x[3]]:
        levels.append(MemoryLevel(get_tech("SRAM"), 1))
    if not levels:
        raise InvalidDesign("no on-chip memory")
    # canonical off-chip order: HBM -> HBF -> GDDR -> LPDDR
    if STACK_CHOICES[x[5]] > 0:
        levels.append(MemoryLevel(get_tech(HBM_TYPES[x[4]]),
                                  STACK_CHOICES[x[5]]))
    if STACK_CHOICES[x[10]] > 0:
        levels.append(MemoryLevel(get_tech("HBF"), STACK_CHOICES[x[10]]))
    if STACK_CHOICES[x[7]] > 0:
        levels.append(MemoryLevel(get_tech(GDDR_TYPES[x[6]]),
                                  STACK_CHOICES[x[7]]))
    if LPDDR_STACK_CHOICES[x[9]] > 0:
        levels.append(MemoryLevel(get_tech(LPDDR_TYPES[x[8]]),
                                  LPDDR_STACK_CHOICES[x[9]]))
    try:
        hierarchy = MemoryHierarchy(levels)
    except ShorelineError as e:
        raise InvalidDesign(str(e)) from None
    strategy = SoftwareStrategy(
        dataflow=DATAFLOW_CHOICES[x[15]],
        storage_priority=STORAGE_CHOICES[x[14]],
        bw_priority=BW_CHOICES[x[16]],
    )
    quant = QuantConfig(weight=W_FMTS[x[13]], activation=ACT_FMTS[x[11]],
                        kv_cache=KV_FMTS[x[12]])
    name = f"dse-{''.join(f'{v:x}' for v in x)}"
    return NPUConfig(name=name, compute=compute, hierarchy=hierarchy,
                     strategy=strategy, quant=quant)


def normalize(x) -> np.ndarray:
    """Integer vector -> [0,1]^d (GP input)."""
    return np.array([(v + 0.5) / c for v, c in zip(x, CARDINALITIES)],
                    dtype=np.float64)


def normalize_batch(xs) -> np.ndarray:
    """Vectorized `normalize` for an [n, N_DIMS] design batch."""
    return ((np.asarray(xs, dtype=np.float64) + 0.5)
            / np.asarray(CARDINALITIES, dtype=np.float64))


def from_unit(u) -> list[int]:
    """[0,1)^d -> integer vector (Sobol mapping)."""
    return [min(int(v * c), c - 1) for v, c in zip(u, CARDINALITIES)]


def random_design(rng: np.random.Generator) -> list[int]:
    return [int(rng.integers(c)) for c in CARDINALITIES]


def random_designs(rng: np.random.Generator, n: int) -> np.ndarray:
    """`n` random designs in one vectorized draw ([n, N_DIMS] int array)."""
    return rng.integers(0, np.asarray(CARDINALITIES), size=(n, N_DIMS))


def space_cardinality() -> int:
    out = 1
    for c in CARDINALITIES:
        out *= c
    return out


# ---------------------------------------------------------------------------
# Vectorized validity / TDP / capacity over encoded design batches.
#
# `decode` + `NPUConfig.tdp_w()` cost ~50 us per design, which dominates
# candidate-pool filtering in the MOBO inner loop.  Both validity and TDP
# decompose over the genes (each hierarchy level contributes independently
# to shoreline / background+dynamic peak power / capacity), so we
# precompute small per-gene lookup tables FROM the same constructors
# `decode` uses and reduce a whole [n, N_DIMS] batch with NumPy gathers.
# ---------------------------------------------------------------------------

_GENE_TABLES: Optional[dict] = None


def _level_stats(tech_name: str, stacks: int) -> tuple[float, float, float]:
    """(tdp_w, shoreline_mm, capacity_gb) contribution of one level."""
    if stacks <= 0:
        return 0.0, 0.0, 0.0
    level = MemoryLevel(get_tech(tech_name), stacks)
    e = max(level.tech.e_read_pj_per_bit, level.tech.e_write_pj_per_bit)
    tdp = level.background_power_w() + e * level.bandwidth_gbps * 8e9 * 1e-12
    return tdp, level.shoreline_mm, level.capacity_gb


def _gene_tables() -> dict:
    """Per-gene (tdp, shoreline, capacity) lookup tables, built lazily."""
    global _GENE_TABLES
    if _GENE_TABLES is not None:
        return _GENE_TABLES
    from ..power import compute_tdp_w

    def table(fn, *dims):
        out = np.zeros(dims + (3,))
        for idx in np.ndindex(*dims):
            out[idx] = fn(*idx)
        return out

    t = {
        "compute": table(
            lambda p, v: (compute_tdp_w(ComputeConfig(
                pe_rows=PE_CHOICES[p][0], pe_cols=PE_CHOICES[p][1],
                vlen=VLEN_CHOICES[v])), 0.0, 0.0),
            len(PE_CHOICES), len(VLEN_CHOICES)),
        "sram3d": table(lambda i: _level_stats("3D-SRAM", SRAM3D_CHOICES[i]),
                        len(SRAM3D_CHOICES)),
        "sram2d": table(lambda i: _level_stats("SRAM", SRAM2D_CHOICES[i]),
                        len(SRAM2D_CHOICES)),
        "hbm": table(lambda ty, s: _level_stats(HBM_TYPES[ty],
                                                STACK_CHOICES[s]),
                     len(HBM_TYPES), len(STACK_CHOICES)),
        "gddr": table(lambda ty, s: _level_stats(GDDR_TYPES[ty],
                                                 STACK_CHOICES[s]),
                      len(GDDR_TYPES), len(STACK_CHOICES)),
        "lpddr": table(lambda ty, s: _level_stats(LPDDR_TYPES[ty],
                                                  LPDDR_STACK_CHOICES[s]),
                       len(LPDDR_TYPES), len(LPDDR_STACK_CHOICES)),
        "hbf": table(lambda s: _level_stats("HBF", STACK_CHOICES[s]),
                     len(STACK_CHOICES)),
    }
    _GENE_TABLES = t
    return t


def _batch_stats(xs: np.ndarray) -> np.ndarray:
    """[n, 3] (tdp_w, shoreline_mm, capacity_gb) per encoded design."""
    t = _gene_tables()
    xs = np.asarray(xs, dtype=np.int64)
    return (t["compute"][xs[:, 0], xs[:, 1]]
            + t["sram3d"][xs[:, 2]] + t["sram2d"][xs[:, 3]]
            + t["hbm"][xs[:, 4], xs[:, 5]]
            + t["gddr"][xs[:, 6], xs[:, 7]]
            + t["lpddr"][xs[:, 8], xs[:, 9]]
            + t["hbf"][xs[:, 10]])


def valid_mask(xs: np.ndarray) -> np.ndarray:
    """Vectorized `decode`-validity: in-range genes, some on-chip memory,
    and the Eq. 1 shoreline bound (same tolerance as MemoryHierarchy)."""
    from ..hierarchy import L_MEM_MAX_MM
    xs = np.asarray(xs, dtype=np.int64)
    in_range = np.all((xs >= 0) & (xs < np.asarray(CARDINALITIES)), axis=1)
    safe = np.where(in_range[:, None], xs, 0)
    has_onchip = (np.asarray(SRAM3D_CHOICES)[safe[:, 2]] > 0) \
        | (np.asarray(SRAM2D_CHOICES)[safe[:, 3]] > 0)
    shoreline = _batch_stats(safe)[:, 1]
    return in_range & has_onchip & (shoreline <= L_MEM_MAX_MM + 1e-9)


def tdp_w_batch(xs: np.ndarray) -> np.ndarray:
    """Vectorized `NPUConfig.tdp_w()` for encoded designs (valid genes)."""
    return _batch_stats(xs)[:, 0]


def capacity_gb_batch(xs: np.ndarray) -> np.ndarray:
    """Vectorized `hierarchy.total_capacity_gb()` for encoded designs."""
    return _batch_stats(xs)[:, 2]


# ---------------------------------------------------------------------------
# Structure-of-arrays decoding: gene batch -> perfmodel_jit.NPUTable.
#
# The jitted batch evaluator wants parallel parameter arrays, not
# NPUConfig objects (`decode` costs ~50 us per design, which at 100k
# candidates would dwarf the evaluation itself).  Like the TDP/validity
# tables above, the slot tables are built FROM the same MemoryLevel /
# QuantConfig constructors `decode` uses (via memtech.level_params), so
# the SoA parameters are bit-identical to the object path's.
# ---------------------------------------------------------------------------

# Canonical hierarchy slots of a decoded design, innermost first
# (matches the level order `decode` constructs).
_SLOT_NAMES = ("3D-SRAM", "SRAM", "HBM", "HBF", "GDDR", "LPDDR")
_N_SLOTS = len(_SLOT_NAMES)

_SOA_TABLES: Optional[dict] = None


def _soa_tables() -> dict:
    """Per-gene numeric lookup tables for `decode_batch`, built lazily."""
    global _SOA_TABLES
    if _SOA_TABLES is not None:
        return _SOA_TABLES
    from ..memtech import level_params

    def lv_table(names, stack_choices):
        out = np.zeros((len(names), len(stack_choices), 6))
        for ti, name in enumerate(names):
            for si, s in enumerate(stack_choices):
                out[ti, si] = level_params(get_tech(name), s)
        return out

    bw_rows = np.array([SoftwareStrategy(bw_priority=ch).bw_split()
                        for ch in BW_CHOICES])
    t = {
        "pe_rows": np.array([p[0] for p in PE_CHOICES], dtype=np.float64),
        "pe_cols": np.array([p[1] for p in PE_CHOICES], dtype=np.float64),
        "vlen": np.array(VLEN_CHOICES, dtype=np.float64),
        "sram3d": lv_table(["3D-SRAM"], SRAM3D_CHOICES)[0],
        "sram2d": lv_table(["SRAM"], SRAM2D_CHOICES)[0],
        "hbm": lv_table(HBM_TYPES, STACK_CHOICES),
        "hbf": lv_table(["HBF"], STACK_CHOICES)[0],
        "gddr": lv_table(GDDR_TYPES, STACK_CHOICES),
        "lpddr": lv_table(LPDDR_TYPES, LPDDR_STACK_CHOICES),
        # DATAFLOW_CHOICES gene order -> canonical WS/IS/OS code
        "df_code": np.array([{Dataflow.WEIGHT_STATIONARY: 0,
                              Dataflow.INPUT_STATIONARY: 1,
                              Dataflow.OUTPUT_STATIONARY: 2}[df]
                             for df in DATAFLOW_CHOICES], dtype=np.int32),
        "bw_mx": bw_rows[:, 0], "bw_vec": bw_rows[:, 1],
    }
    _SOA_TABLES = t
    return t


def decode_batch(xs: np.ndarray):
    """Vectorized `decode`: [n, N_DIMS] int batch -> perfmodel_jit
    .NPUTable (structure-of-arrays NPU parameters, no NPUConfig
    construction).  Rows must be decode-valid (`valid_mask`); invalid
    rows yield undefined table entries, not exceptions."""
    from ..perfmodel_jit import NPUTable
    t = _soa_tables()
    xs = np.asarray(xs, dtype=np.int64)
    n = xs.shape[0]
    lvl_rows = np.zeros((n, _N_SLOTS, 6))
    lvl_rows[:, 0] = t["sram3d"][xs[:, 2]]
    lvl_rows[:, 1] = t["sram2d"][xs[:, 3]]
    lvl_rows[:, 2] = t["hbm"][xs[:, 4], xs[:, 5]]
    lvl_rows[:, 3] = t["hbf"][xs[:, 10]]
    lvl_rows[:, 4] = t["gddr"][xs[:, 6], xs[:, 7]]
    lvl_rows[:, 5] = t["lpddr"][xs[:, 8], xs[:, 9]]
    onchip = np.zeros((n, _N_SLOTS), dtype=bool)
    onchip[:, :2] = True
    # distinct QuantConfigs present in the batch (usually few dozen max)
    fmt_genes = xs[:, [13, 11, 12]]          # (weight, act, kv) gene cols
    uniq, quant_idx = np.unique(fmt_genes, axis=0, return_inverse=True)
    quants = tuple(QuantConfig(weight=W_FMTS[w], activation=ACT_FMTS[a],
                               kv_cache=KV_FMTS[k]) for w, a, k in uniq)
    return NPUTable.from_parts(
        pe_rows=t["pe_rows"][xs[:, 0]], pe_cols=t["pe_cols"][xs[:, 0]],
        vlen=t["vlen"][xs[:, 1]], clock_ghz=np.ones(n),
        lvl_rows=lvl_rows, lvl_onchip=onchip,
        quants=quants, quant_idx=quant_idx,
        df_idx=t["df_code"][xs[:, 15]], storage_idx=xs[:, 14],
        bw_mx=t["bw_mx"][xs[:, 16]], bw_vec=t["bw_vec"][xs[:, 16]])


# ---------------------------------------------------------------------------
# DesignSpace protocol: what the searchers in runner.py require of a space.
# ---------------------------------------------------------------------------

class DesignSpace:
    """Integer-encoded design space searched by the runner.py optimizers.

    A concrete space provides `cardinalities` (one categorical range per
    gene) plus vectorized validity / TDP tables; everything the four
    searchers touch (sampling, Sobol mapping, GP normalization, repair)
    has a generic default implemented on top of `cardinalities`, so the
    optimizers never hard-code a particular encoding.

    `repair` projects an arbitrary in-range gene vector onto the space's
    constraint manifold (identity by default); searchers call it on every
    proposal so crossover/mutation cannot silently leave the feasible
    encoding set.  It must not consume RNG state (seeded trajectories
    depend on the draw sequence alone).
    """

    name: str = "design-space"
    cardinalities: list
    # When True, shared_init keeps only valid_mask-passing Sobol points
    # (topping up with random_design); spaces whose raw-uniform validity
    # is low opt in so the init budget is spent on decodable designs.
    init_filter_valid: bool = False
    # When True, random_designs returns only valid_mask-passing rows
    # (rejection sampling), so callers may skip re-filtering its output.
    samples_valid: bool = False

    @property
    def n_dims(self) -> int:
        return len(self.cardinalities)

    def decode(self, x):
        """Integer vector -> evaluatable design (space-specific type).
        Raises InvalidDesign for impossible combinations."""
        raise NotImplementedError

    def repair(self, x) -> list:
        """Project an in-range gene vector onto the constraint manifold."""
        return list(x)

    def random_design(self, rng: np.random.Generator) -> list:
        return self.repair([int(rng.integers(c))
                            for c in self.cardinalities])

    def random_designs(self, rng: np.random.Generator, n: int) -> np.ndarray:
        xs = rng.integers(0, np.asarray(self.cardinalities),
                          size=(n, self.n_dims))
        return self.repair_batch(xs)

    def repair_batch(self, xs: np.ndarray) -> np.ndarray:
        return xs

    def from_unit(self, u) -> list:
        """[0,1)^d -> integer vector (Sobol mapping)."""
        return self.repair([min(int(v * c), c - 1)
                            for v, c in zip(u, self.cardinalities)])

    def normalize(self, x) -> np.ndarray:
        """Integer vector -> [0,1]^d (GP input)."""
        return np.array([(v + 0.5) / c
                         for v, c in zip(x, self.cardinalities)],
                        dtype=np.float64)

    def normalize_batch(self, xs) -> np.ndarray:
        return ((np.asarray(xs, dtype=np.float64) + 0.5)
                / np.asarray(self.cardinalities, dtype=np.float64))

    def valid_mask(self, xs: np.ndarray) -> np.ndarray:
        """Vectorized decode-validity over an [n, n_dims] batch."""
        raise NotImplementedError

    def tdp_w_batch(self, xs: np.ndarray) -> np.ndarray:
        """Vectorized peak-power (W) over an [n, n_dims] batch."""
        raise NotImplementedError

    def decode_batch(self, xs: np.ndarray):
        """Vectorized `decode` into structure-of-arrays NPU parameters
        for the jitted batch perfmodel (no per-design object
        construction).  Spaces without an SoA decoding raise."""
        raise NotImplementedError

    def space_cardinality(self) -> int:
        out = 1
        for c in self.cardinalities:
            out *= c
        return out


class SingleDeviceSpace(DesignSpace):
    """The 17-gene Table 2 single-device space (module functions wrapped).

    Sampling, normalization and Sobol mapping inherit the generic
    `DesignSpace` implementations, which are line-for-line the module
    functions above — the RNG call sequence (one `rng.integers` per gene
    for `random_design`, one vectorized draw for `random_designs`) is
    identical, keeping pre-refactor seeded trajectories byte-identical.
    """

    name = "single-device"

    def __init__(self):
        self.cardinalities = list(CARDINALITIES)

    def decode(self, x) -> "NPUConfig":
        return decode(x)

    def valid_mask(self, xs: np.ndarray) -> np.ndarray:
        return valid_mask(xs)

    def tdp_w_batch(self, xs: np.ndarray) -> np.ndarray:
        return tdp_w_batch(xs)

    def capacity_gb_batch(self, xs: np.ndarray) -> np.ndarray:
        return capacity_gb_batch(xs)

    def decode_batch(self, xs: np.ndarray):
        return decode_batch(xs)


# Gene index of the KV-cache quantization format within one 17-gene half.
KV_GENE = 12


def check_sobol_capacity(space: DesignSpace) -> None:
    """Fail construction loudly when a space outgrows the Sobol
    direction-number table.

    Without this, the first symptom is a deep `ValueError` out of
    `sobol.sobol` inside `shared_init` — long after the space was
    built, with no hint of the fix.  Serving genes (replicas + routing)
    push large-topology spaces toward the table edge, so the check runs
    at construction time and names the remedy."""
    from .sobol import max_dims
    if space.n_dims > max_dims():
        raise ValueError(
            f"space {space.name!r} has {space.n_dims} genes but the Sobol "
            f"direction-number table covers only {max_dims()} dimensions, "
            f"so Sobol initialization (dse.runner.shared_init) cannot map "
            f"it.  Fix: regenerate a larger table with "
            f"scripts/gen_sobol_directions.py and update the _JOE_KUO "
            f"rows in src/repro/core/dse/sobol.py, or search a smaller "
            f"space (fewer roles/request classes).")


@dataclasses.dataclass(frozen=True)
class GeneTie:
    """Declarative cross-half equality constraint of a `SystemSpace`.

    Gene `gene` (an index within one 17-gene half) must take the same
    value in every half listed in `halves` (None = all halves).  The
    first listed half is authoritative: `repair` copies its value onto
    the others.  `value_names` (optional) labels values in violation
    messages.
    """

    gene: int
    halves: Optional[tuple] = None
    label: str = "tied gene"
    value_names: tuple = ()

    def resolve(self, k: int) -> tuple:
        return tuple(range(k)) if self.halves is None else self.halves

    def violation(self, x, k: int) -> Optional[str]:
        """A human-readable violation description, or None if satisfied."""
        hs = self.resolve(k)
        src = hs[0]
        for h in hs[1:]:
            a, b = x[src * N_DIMS + self.gene], x[h * N_DIMS + self.gene]
            if a != b:
                name = (self.value_names[v] if self.value_names else str(v)
                        for v in (a, b))
                return (f"{self.label} mismatch between halves {src} "
                        f"and {h}: {' vs '.join(name)}")
        return None


def kv_quant_tie(halves: Optional[tuple] = None) -> GeneTie:
    """The KV-cache quantization compatibility rule as one `GeneTie`:
    every device on the KV hand-off path must consume the format the
    prefill device writes (a mismatch would need a re-quantization pass
    the system model does not provide)."""
    return GeneTie(KV_GENE, halves, label="KV-cache quant",
                   value_names=tuple(KV_FMTS))


class SystemSpace(DesignSpace):
    """K concatenated single-device halves searched as one point
    (paper Sections 5.3/5.5).

    A design is K 17-gene Table 2 encodings back to back — one device
    per `disagg.SystemTopology` role — plus a declarative list of
    `GeneTie` cross-half constraints (the KV-quant compatibility rule
    is the canonical instance).  `PairedSpace` is the K=2
    specialization; an extreme-heterogeneity system (prefill-attn /
    prefill-ffn / decode-early / decode-late) is K=4 with the same tie.

    `repair` (and therefore every sampling primitive) enforces each tie
    by copying the authoritative half's gene onto the others;
    `valid_mask`/`decode` reject vectors that still violate one
    (e.g. raw crossover output that bypassed repair).
    """

    init_filter_valid = True
    samples_valid = True

    # Bound on validity rejection-sampling rounds (raw validity of a
    # random K-tuple is exp. small in K — ~10-20% at K=2 — so a handful
    # of rounds nearly always suffices; the bound keeps sampling total
    # even if tables change).
    _MAX_RESAMPLE = 64

    def __init__(self, k: int, ties: tuple = (),
                 name: Optional[str] = None):
        if k < 1:
            raise ValueError("SystemSpace needs at least one half")
        self.k = k
        self.ties = tuple(ties)
        self.cardinalities = list(CARDINALITIES) * k
        if name is not None:
            self.name = name
        else:
            self.name = f"system-{k}dev"
        for tie in self.ties:
            for h in tie.resolve(k):
                if not (0 <= h < k):
                    raise ValueError(f"tie half {h} out of range for K={k}")
        check_sobol_capacity(self)

    @classmethod
    def for_topology(cls, topology) -> "SystemSpace":
        """One half per `disagg.SystemTopology` role, KV formats tied
        across all halves (the KV cache crosses every hand-off link)."""
        return cls(topology.k, ties=(kv_quant_tie(),),
                   name=f"system-{topology.name}")

    def random_design(self, rng: np.random.Generator) -> list:
        """One random *valid* K-tuple (rejection sampling over
        valid_mask).

        Every half of a raw uniform draw must independently pass the
        single-device validity tables, which compounds the rejection
        rate — uniform sampling would waste most of the search budget
        on undecodable tuples, so the system space samples the
        validity-filtered set directly (the single-device space keeps
        raw draws for seeded-trajectory compatibility)."""
        x = super().random_design(rng)
        for _ in range(self._MAX_RESAMPLE):
            if bool(self.valid_mask(np.asarray([x], dtype=np.int64))[0]):
                break
            x = super().random_design(rng)
        return x

    def random_designs(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """`n` random K-tuples, validity-rejection-sampled like
        `random_design` (vectorized: oversample, filter, top up)."""
        out = np.empty((0, self.n_dims), dtype=np.int64)
        for _ in range(self._MAX_RESAMPLE):
            if len(out) >= n:
                break
            draw = super().random_designs(rng, max(n, 2 * (n - len(out))))
            out = np.concatenate([out, draw[self.valid_mask(draw)]])
        if len(out) < n:            # fall back to raw draws (tables degenerate)
            out = np.concatenate([out, super().random_designs(
                rng, n - len(out))])
        return out[:n]

    def split(self, x) -> tuple:
        """K*17-gene vector -> K 17-gene halves."""
        x = list(x)
        return tuple(x[i * N_DIMS:(i + 1) * N_DIMS] for i in range(self.k))

    def repair(self, x) -> list:
        x = list(x)
        for tie in self.ties:
            hs = tie.resolve(self.k)
            v = x[hs[0] * N_DIMS + tie.gene]
            for h in hs[1:]:
                x[h * N_DIMS + tie.gene] = v
        return x

    def repair_batch(self, xs: np.ndarray) -> np.ndarray:
        xs = np.array(xs)           # copy: never mutate the caller's batch
        for tie in self.ties:
            hs = tie.resolve(self.k)
            for h in hs[1:]:
                xs[:, h * N_DIMS + tie.gene] = xs[:, hs[0] * N_DIMS
                                                  + tie.gene]
        return xs

    def decode(self, x) -> tuple:
        """K*17-gene vector -> one NPUConfig per half."""
        x = [int(v) for v in x]
        if len(x) != self.k * N_DIMS:
            raise InvalidDesign(
                f"need {self.k * N_DIMS} genes, got {len(x)}")
        for tie in self.ties:
            msg = tie.violation(x, self.k)
            if msg is not None:
                raise InvalidDesign(msg)
        return tuple(decode(h) for h in self.split(x))

    def valid_mask(self, xs: np.ndarray) -> np.ndarray:
        xs = np.asarray(xs, dtype=np.int64)
        m = np.ones(len(xs), dtype=bool)
        for i in range(self.k):
            m &= valid_mask(xs[:, i * N_DIMS:(i + 1) * N_DIMS])
        for tie in self.ties:
            hs = tie.resolve(self.k)
            for h in hs[1:]:
                m &= (xs[:, hs[0] * N_DIMS + tie.gene]
                      == xs[:, h * N_DIMS + tie.gene])
        return m

    def tdp_w_batch(self, xs: np.ndarray) -> np.ndarray:
        """Combined system TDP: all K devices draw from one power budget."""
        xs = np.asarray(xs, dtype=np.int64)
        out = tdp_w_batch(xs[:, :N_DIMS])
        for i in range(1, self.k):
            out = out + tdp_w_batch(xs[:, i * N_DIMS:(i + 1) * N_DIMS])
        return out

    def decode_batch(self, xs: np.ndarray) -> tuple:
        """One perfmodel_jit.NPUTable per half — SoA decoding."""
        xs = np.asarray(xs, dtype=np.int64)
        return tuple(decode_batch(xs[:, i * N_DIMS:(i + 1) * N_DIMS])
                     for i in range(self.k))


class PairedSpace(SystemSpace):
    """Prefill/decode disaggregated pair space: the K=2 `SystemSpace`
    with the KV-quant tie (paper Sections 5.3/5.5).

    Genes [0, 17) encode the prefill-optimized device, genes [17, 34)
    the decode-optimized one; the KV cache produced during prefill is
    shipped over the interconnect and consumed verbatim by the decode
    device, so both halves must share the KV-cache quantization format
    (`kv_quant_tie`).  All sampling/repair/validity behavior is the
    generic SystemSpace machinery — seeded paired trajectories are
    byte-identical to the pre-refactor pair-specific implementation.
    """

    def __init__(self):
        super().__init__(2, ties=(kv_quant_tie(),),
                         name="paired-prefill-decode")

    def split(self, x) -> tuple:
        """34-gene pair -> (prefill 17-gene half, decode 17-gene half)."""
        x = list(x)
        return x[:N_DIMS], x[N_DIMS:]


# ---------------------------------------------------------------------------
# Serving extension: replica counts + traffic routing as appended genes.
# ---------------------------------------------------------------------------

# Per-role replica-count vocabulary (datacenter provisioning ladder).
REPLICA_CHOICES = (1, 2, 3, 4, 6, 8, 12, 16)

# Routing weight vocabulary: a class's decode routing fractions are its
# normalized weights, so every decode role keeps a strictly positive
# share and the simplex is searched through ordinary categorical genes.
ROUTE_WEIGHT_CHOICES = (1, 2, 3, 4, 5, 6, 7, 8)


def routing_fractions(route_genes: np.ndarray) -> np.ndarray:
    """Routing genes [..., D] -> decode routing fractions (simplex rows).

    Genes index `ROUTE_WEIGHT_CHOICES`; fractions are the weights
    normalized per row.  Equal genes reproduce the uniform splits of
    every shipped topology exactly (1/1, 1/2, 1/4 are binary
    fractions), so topology-default routing is representable without
    rounding error — the serving parity tests depend on that."""
    w = np.asarray(ROUTE_WEIGHT_CHOICES, dtype=np.float64)[
        np.asarray(route_genes, dtype=np.int64)]
    return w / w.sum(axis=-1, keepdims=True)


@dataclasses.dataclass(frozen=True)
class ServingDesign:
    """Decoded `ServingSpace` point: K devices, per-role replica counts,
    and per-class decode routing fractions."""

    npus: tuple                 # one NPUConfig per topology role
    replicas: tuple             # int per role
    phi: tuple                  # [n_classes][n_decode_roles] fractions


class ServingSpace(SystemSpace):
    """`SystemSpace` plus fleet-serving genes: per-role replica counts
    and per-class decode routing fractions (the ROADMAP's "replication
    counts per role and traffic routing fractions as genes").

    Gene layout (all categorical, so the generic `DesignSpace`
    Sobol/GP machinery applies unchanged)::

        [K x 17 device genes][K replica genes][C x D routing genes]

    with K topology roles, C request classes and D decode roles.
    Replica genes index `REPLICA_CHOICES`; routing genes index
    `ROUTE_WEIGHT_CHOICES` and decode per class to normalized simplex
    fractions (`routing_fractions`).  Device-gene semantics, `GeneTie`
    constraints, and the rejection samplers are inherited verbatim —
    serving genes are purely additive, so existing `SystemSpace`
    searches and their sha-pinned trajectories are untouched."""

    def __init__(self, topology, n_classes: int, ties: Optional[tuple] = None,
                 name: Optional[str] = None):
        if n_classes < 1:
            raise ValueError("ServingSpace needs at least one request class")
        self.topology = topology
        self.n_classes = int(n_classes)
        self.n_decode = len(topology.decode_indices())
        if ties is None:
            ties = (kv_quant_tie(),)
        super().__init__(topology.k, ties=ties,
                         name=(name if name is not None
                               else f"serving-{topology.name}-"
                                    f"{n_classes}cls"))
        self.dev_genes = self.k * N_DIMS
        self.cardinalities = (
            list(CARDINALITIES) * self.k
            + [len(REPLICA_CHOICES)] * self.k
            + [len(ROUTE_WEIGHT_CHOICES)] * (self.n_classes * self.n_decode))
        check_sobol_capacity(self)

    @classmethod
    def for_topology(cls, topology) -> "SystemSpace":
        raise TypeError(
            "ServingSpace needs a class count: use "
            "ServingSpace(topology, n_classes) or ServingSpace.for_mix()")

    @classmethod
    def for_mix(cls, topology, mix) -> "ServingSpace":
        """One space per (topology, `serving.TrafficMix`) pair."""
        return cls(topology, len(mix.classes))

    # -- serving-gene views -------------------------------------------------

    def replica_counts(self, xs: np.ndarray) -> np.ndarray:
        """[n, K] replica counts (decoded, not gene indices)."""
        xs = np.asarray(xs, dtype=np.int64)
        return np.asarray(REPLICA_CHOICES, dtype=np.int64)[
            xs[..., self.dev_genes:self.dev_genes + self.k]]

    def routing(self, xs: np.ndarray) -> np.ndarray:
        """[n, C, D] decode routing fractions."""
        xs = np.asarray(xs, dtype=np.int64)
        genes = xs[..., self.dev_genes + self.k:]
        shape = genes.shape[:-1] + (self.n_classes, self.n_decode)
        return routing_fractions(genes.reshape(shape))

    # -- DesignSpace protocol ----------------------------------------------

    def decode(self, x) -> ServingDesign:
        x = [int(v) for v in x]
        if len(x) != self.n_dims:
            raise InvalidDesign(f"need {self.n_dims} genes, got {len(x)}")
        for v, c in zip(x[self.dev_genes:],
                        self.cardinalities[self.dev_genes:]):
            if not (0 <= v < c):
                raise InvalidDesign(f"serving gene out of range: {x}")
        npus = super().decode(x[:self.dev_genes])
        arr = np.asarray([x], dtype=np.int64)
        return ServingDesign(
            npus=npus,
            replicas=tuple(int(v) for v in self.replica_counts(arr)[0]),
            phi=tuple(tuple(float(v) for v in row)
                      for row in self.routing(arr)[0]))

    def valid_mask(self, xs: np.ndarray) -> np.ndarray:
        xs = np.asarray(xs, dtype=np.int64)
        m = super().valid_mask(xs)      # device halves + ties
        extra = xs[:, self.dev_genes:]
        cards = np.asarray(self.cardinalities[self.dev_genes:],
                           dtype=np.int64)
        return m & np.all((extra >= 0) & (extra < cards), axis=1)

    def tdp_w_batch(self, xs: np.ndarray) -> np.ndarray:
        """Provisioned fleet peak power: every replica of a role draws
        from the datacenter budget, busy or not."""
        xs = np.asarray(xs, dtype=np.int64)
        rep = self.replica_counts(xs).astype(np.float64)
        out = np.zeros(len(xs))
        for i in range(self.k):
            out += rep[:, i] * tdp_w_batch(
                xs[:, i * N_DIMS:(i + 1) * N_DIMS])
        return out

    def decode_batch(self, xs: np.ndarray) -> tuple:
        """(per-half NPUTable tuple, [n, K] replicas, [n, C, D] routing)."""
        xs = np.asarray(xs, dtype=np.int64)
        return (super().decode_batch(xs[:, :self.dev_genes]),
                self.replica_counts(xs), self.routing(xs))
