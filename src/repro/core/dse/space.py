"""The co-design space (paper Table 2) and its integer encoding.

A design point is a 17-dimensional integer vector indexing categorical
choices; `decode` builds the NPUConfig (compute + hierarchy + quant +
software strategy).  The off-chip hierarchy order is canonical by
technology bandwidth class: HBM -> HBF -> GDDR -> LPDDR (matching the
paper's Table 6 configurations).

The encoded space (~7 x 10^8 raw combinations; ~10^6 after validity
filtering) is searched by the optimizers in mobo.py / nsga2.py /
motpe.py / random_search.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..compute import ComputeConfig, Dataflow
from ..dataflow import BandwidthPriority, SoftwareStrategy, StoragePriority
from ..hierarchy import MemoryHierarchy, MemoryLevel, ShorelineError
from ..memtech import get as get_tech
from ..npu import NPUConfig
from ..quant.formats import QuantConfig

PE_CHOICES = [(128, 128), (64, 256), (32, 512), (16, 1024),
              (2048, 64), (2048, 128), (2048, 256), (1024, 64), (1024, 512)]
VLEN_CHOICES = [128, 256, 512, 1024, 2048]
SRAM3D_CHOICES = [0, 1, 2, 3, 4]
SRAM2D_CHOICES = [0, 1]
HBM_TYPES = ["HBM3E", "HBM4"]
GDDR_TYPES = ["GDDR6", "GDDR7"]
LPDDR_TYPES = ["LPDDR5X", "LPDDR6"]
STACK_CHOICES = [0, 1, 2, 4, 8]
LPDDR_STACK_CHOICES = [0, 1, 2, 4, 8, 16]
ACT_FMTS = ["MXFP8", "MXFP16", "MXINT8", "MXINT16"]
KV_FMTS = ["MXFP4", "MXFP8", "MXINT4", "MXINT8"]
W_FMTS = ["MXFP4", "MXFP8", "MXINT4", "MXINT8"]
STORAGE_CHOICES = [StoragePriority.ACTIVATION, StoragePriority.KV_CACHE,
                   StoragePriority.WEIGHT, StoragePriority.EQUAL]
DATAFLOW_CHOICES = [Dataflow.WEIGHT_STATIONARY, Dataflow.OUTPUT_STATIONARY,
                    Dataflow.INPUT_STATIONARY]
BW_CHOICES = [BandwidthPriority.MATRIX, BandwidthPriority.VECTOR,
              BandwidthPriority.EQUAL]

CARDINALITIES = [
    len(PE_CHOICES), len(VLEN_CHOICES), len(SRAM3D_CHOICES),
    len(SRAM2D_CHOICES), len(HBM_TYPES), len(STACK_CHOICES),
    len(GDDR_TYPES), len(STACK_CHOICES), len(LPDDR_TYPES),
    len(LPDDR_STACK_CHOICES), len(STACK_CHOICES),
    len(ACT_FMTS), len(KV_FMTS), len(W_FMTS),
    len(STORAGE_CHOICES), len(DATAFLOW_CHOICES), len(BW_CHOICES),
]
N_DIMS = len(CARDINALITIES)


class InvalidDesign(ValueError):
    pass


def decode(x) -> NPUConfig:
    """Integer vector -> NPUConfig. Raises InvalidDesign for impossible
    combinations (no on-chip memory, no memory at all, shoreline)."""
    x = [int(v) for v in x]
    if len(x) != N_DIMS:
        raise InvalidDesign(f"need {N_DIMS} genes, got {len(x)}")
    for v, c in zip(x, CARDINALITIES):
        if not (0 <= v < c):
            raise InvalidDesign(f"gene out of range: {x}")
    pe_r, pe_c = PE_CHOICES[x[0]]
    compute = ComputeConfig(pe_rows=pe_r, pe_cols=pe_c,
                            vlen=VLEN_CHOICES[x[1]])
    levels: list[MemoryLevel] = []
    n3d = SRAM3D_CHOICES[x[2]]
    if n3d > 0:
        levels.append(MemoryLevel(get_tech("3D-SRAM"), n3d))
    if SRAM2D_CHOICES[x[3]]:
        levels.append(MemoryLevel(get_tech("SRAM"), 1))
    if not levels:
        raise InvalidDesign("no on-chip memory")
    # canonical off-chip order: HBM -> HBF -> GDDR -> LPDDR
    if STACK_CHOICES[x[5]] > 0:
        levels.append(MemoryLevel(get_tech(HBM_TYPES[x[4]]),
                                  STACK_CHOICES[x[5]]))
    if STACK_CHOICES[x[10]] > 0:
        levels.append(MemoryLevel(get_tech("HBF"), STACK_CHOICES[x[10]]))
    if STACK_CHOICES[x[7]] > 0:
        levels.append(MemoryLevel(get_tech(GDDR_TYPES[x[6]]),
                                  STACK_CHOICES[x[7]]))
    if LPDDR_STACK_CHOICES[x[9]] > 0:
        levels.append(MemoryLevel(get_tech(LPDDR_TYPES[x[8]]),
                                  LPDDR_STACK_CHOICES[x[9]]))
    try:
        hierarchy = MemoryHierarchy(levels)
    except ShorelineError as e:
        raise InvalidDesign(str(e)) from None
    strategy = SoftwareStrategy(
        dataflow=DATAFLOW_CHOICES[x[15]],
        storage_priority=STORAGE_CHOICES[x[14]],
        bw_priority=BW_CHOICES[x[16]],
    )
    quant = QuantConfig(weight=W_FMTS[x[13]], activation=ACT_FMTS[x[11]],
                        kv_cache=KV_FMTS[x[12]])
    name = f"dse-{''.join(f'{v:x}' for v in x)}"
    return NPUConfig(name=name, compute=compute, hierarchy=hierarchy,
                     strategy=strategy, quant=quant)


def normalize(x) -> np.ndarray:
    """Integer vector -> [0,1]^d (GP input)."""
    return np.array([(v + 0.5) / c for v, c in zip(x, CARDINALITIES)],
                    dtype=np.float64)


def from_unit(u) -> list[int]:
    """[0,1)^d -> integer vector (Sobol mapping)."""
    return [min(int(v * c), c - 1) for v, c in zip(u, CARDINALITIES)]


def random_design(rng: np.random.Generator) -> list[int]:
    return [int(rng.integers(c)) for c in CARDINALITIES]


def space_cardinality() -> int:
    out = 1
    for c in CARDINALITIES:
        out *= c
    return out
