"""DSE orchestration: objective wrappers + the four search methods
(GP+EHVI MOBO, NSGA-II, MO-TPE, Random), paper Section 4.4 / Figure 6,
generic over a `space.DesignSpace`.

The objective wrappers share one informal protocol (`.space`,
`.tdp_limit_w`, `.n_obj`, `__call__`, `.evaluate_batch`):

* `Objective` — single-device search on `SingleDeviceSpace`:
  f(x) = (throughput_tps, -avg_power_w) under a device TDP cap
  (the paper's Fig. 6 experiment).
* `SystemObjective` — K-role system search on `SystemSpace` over any
  `disagg.SystemTopology`: f(x) = (aggregate tokens/joule, -total
  system power) under a combined system TDP budget and a TTFT
  feasibility cap that includes the inter-device hand-offs (Sections
  5.3/5.5).  With `ttft_objective=True`, TTFT becomes a third
  maximized objective (-TTFT) instead of a hard gate.
* `DisaggObjective` — the K=2 prefill/decode specialization on
  `PairedSpace` (the paper's Fig. 8 co-design, Section 5.3);
  byte-identical to the pre-SystemObjective pair implementation.
* `ServingObjective` — datacenter fleet search on `ServingSpace`
  (devices + per-role replica counts + per-class routing) against a
  `serving.TrafficMix`: f(x) = (fleet tokens/joule, -fleet power)
  under a provisioned-peak power budget and per-class p99 TTFT/TPOT
  SLOs from the jitted queueing model (docs/serving.md);
  `serving_warm_start` is its champion-composition seeder.

All methods maximize f (2 objectives by default; d = 3 routes MOBO's
acquisition to the exact 3-D box decomposition, d > 3 to the quasi-MC
EHVI fallback), share the same
Sobol/random initialization, and report their evaluation history so
hypervolume-convergence curves can be drawn against a common reference
point.  The searchers read every space-specific operation (sampling,
Sobol mapping, GP normalization, validity/TDP prefilters, constraint
repair) off `objective.space`, so they run unchanged on any
`DesignSpace`.  `system_warm_start` seeds a system search from the
best per-role single devices of a scored random pool (the
`disagg.best_per_phase` enumeration idea, batched).

Hot-path structure (vectorized engine):

* Candidate selection stays sequential per method (so seeded RNG
  trajectories are reproducible), but objective evaluation is batched:
  `evaluate_batch` routes whole design lists through the vectorized
  `space.valid_mask` / `space.tdp_w_batch` prefilters and the perfmodel
  batch fast path (`perfmodel.evaluate_batch` for single devices,
  `disagg.evaluate_disagg_batch` with per-half memoization for pairs).
  Since PR 3 that fast path is the jitted structure-of-arrays program
  in `perfmodel_jit` — every surviving candidate of a batch is scored
  by one `jax.jit` call (scalar `perfmodel.evaluate` remains the
  reference oracle); 100k-design pools score in ~1 s
  (`benchmarks/bench_dse.py --pool 100000`).
* MOBO scores its candidate pool with the exact closed-form EHVI
  (`ehvi.ehvi_2d` strips / `ehvi.ehvi_3d` boxes) instead of a quasi-MC
  estimate, and filters the pool with the per-gene TDP/validity tables
  instead of decoding every draw.  With `batch_size=B > 1` it proposes
  B points per GP fit (kriging-believer q-EHVI) so every GP iteration
  amortizes over one jitted B-design evaluation, and the GP fit/predict
  hot path itself runs on `jax.jit` (`gp.GP.fit(use_jit=True)` /
  `predict_batch`).
* Hypervolume convergence curves come from the incremental staircase
  (`pareto.IncrementalHV2D`) or the nd clipped-front gain
  (`pareto.IncrementalHVND`), not a from-scratch recompute per step.

Failure model (the crash-safe search runtime):

* **Retried** — transient evaluator failures surfacing as
  `runtime.fault.StepFailure` (the jitted perfmodel path wraps its own
  exceptions this way; `faults.FaultyObjective` injects them in tests)
  and non-finite objective tuples, both up to `EVAL_RETRIES` immediate
  retries per call.  Retries are immediate, with no backoff: the
  evaluator is pure in-process compute, so there is no external
  resource to wait out.  Before a non-finite retry the poisoned key is
  evicted from the objective cache so the evaluator actually reruns.
* **Quarantined** — observations still failing after the retry budget:
  they are recorded as infeasible (``f=None``) with a ``fault`` tag and
  are never propagated into GP fits, EHVI scoring, NSGA-II/MO-TPE
  sorting, `hv_history`, or the Pareto front (`_finite_f` guards every
  aggregation, so a non-finite ``f`` smuggled in via a caller-built
  init cannot poison the surrogates either).  Genuinely infeasible
  verdicts are *not* retried — they are indistinguishable from real
  infeasibility and the evaluators are deterministic.
* **Resumed** — every searcher takes an optional ``journal``
  (`journal.SearchJournal`): final observations append to a JSONL
  evaluation journal and, on restart, replay into the objective cache
  so the seeded search fast-forwards through the already-evaluated
  prefix and continues byte-identically (see the journal module
  docstring for the format and `docs/search_runtime.md` for the
  operational story).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from ...runtime.fault import StepFailure
from ..disagg import PD_PAIR, evaluate_disagg_batch, evaluate_system_batch
from ..perfmodel import InfeasibleConfig, evaluate, evaluate_batch
from ..workload import ModelDims, Phase, Trace
from . import space as sp
from .ehvi import ehvi_2d, ehvi_3d, mc_ehvi
from .journal import SearchJournal
from .pareto import (IncrementalHV2D, IncrementalHVND, pareto_front,
                     pareto_mask)
from .sobol import sobol

# Quasi-MC sample count for the d > 3 EHVI acquisition fallback
# (antithetic pairs, drawn from the method RNG so seeded trajectories
# stay deterministic; 2- and 3-objective searches never draw these —
# d = 3 routes through the exact box decomposition `ehvi.ehvi_3d`).
MC_EHVI_SAMPLES = 64

# Immediate-retry budget of the guarded evaluation layer (transient
# evaluator exceptions and non-finite objective tuples); failures that
# outlive it are quarantined as infeasible, never raised.
EVAL_RETRIES = 3


@dataclasses.dataclass
class Observation:
    x: list
    f: Optional[tuple]          # objective tuple or None if infeasible
    npu: Optional[object]       # NPUConfig, or (prefill, decode) pair
    result: Optional[object] = None   # full evaluation record (DisaggResult)
    fault: Optional[str] = None       # quarantine tag ("non_finite", ...)


def _finite_f(f: Optional[tuple]) -> bool:
    """Feasible AND numerically sane: the gate every aggregation
    (GP fit, EHVI, sorting, HV, fronts) applies to observations."""
    return f is not None and all(math.isfinite(v) for v in f)


@dataclasses.dataclass
class DSEResult:
    method: str
    observations: list          # in evaluation order

    def feasible_f(self) -> np.ndarray:
        return np.array([o.f for o in self.observations if _finite_f(o.f)],
                        dtype=float)

    def hv_history(self, ref: np.ndarray) -> np.ndarray:
        """HV of the feasible front after each evaluation (incremental
        for any d: the 2-D staircase, or the nd clipped-front gain —
        dominated points are mask checks, only front *changes* pay an
        exact nd hypervolume).  Quarantined/non-finite observations
        contribute nothing."""
        ref = np.asarray(ref, dtype=float)
        inc = IncrementalHV2D(ref) if len(ref) == 2 \
            else IncrementalHVND(ref)
        out = np.empty(len(self.observations))
        hv = 0.0
        for i, o in enumerate(self.observations):
            if _finite_f(o.f):
                hv = inc.add(o.f)
            out[i] = hv
        return out

    def pareto(self) -> list:
        obs = [o for o in self.observations if _finite_f(o.f)]
        if not obs:
            return []
        mask = pareto_mask(np.array([o.f for o in obs]))
        return [o for o, m in zip(obs, mask) if m]


def _dedup_pending(cache: dict, keys: list) -> list:
    """Keys not yet cached, first occurrence wins (shared by both
    objective wrappers' batch paths so their dedup cannot diverge)."""
    todo = []
    pending = set()
    for k in keys:
        if k not in cache and k not in pending:
            pending.add(k)
            todo.append(k)
    return todo


# ---------------------------------------------------------------------------
# Guarded evaluation: retry transients, quarantine NaN/Inf, journal
# ---------------------------------------------------------------------------

def _quarantine(obs: Observation, tag: str) -> Observation:
    """An infeasible copy of `obs` carrying the quarantine tag (the
    original — possibly cached — observation is left untouched)."""
    return dataclasses.replace(obs, f=None, fault=tag)


def _evict(objective, xs) -> None:
    """Drop poisoned keys from the objective cache so a retry actually
    re-runs the evaluator instead of re-serving the cached value."""
    cache = getattr(objective, "cache", None)
    if cache is None:
        return
    for x in xs:
        cache.pop(tuple(int(v) for v in x), None)


def _eval_many(objective, xs, journal: Optional[SearchJournal]) -> list:
    """`objective.evaluate_batch` behind the failure model of the module
    docstring: `EVAL_RETRIES` immediate retries for transient
    `StepFailure`s and non-finite objective tuples, quarantine-as-
    infeasible beyond the budget, and journal append of the final
    observations.  On the healthy path this is exactly
    `objective.evaluate_batch(xs)` — seeded trajectories are unchanged.
    """
    obs: list = []
    for attempt in range(EVAL_RETRIES + 1):
        try:
            obs = objective.evaluate_batch(xs)
        except StepFailure:
            if attempt == EVAL_RETRIES:
                obs = [Observation(x=[int(v) for v in x], f=None, npu=None,
                                   fault="evaluator_error") for x in xs]
                break
            continue
        bad = {i for i, o in enumerate(obs)
               if o.f is not None and not _finite_f(o.f)}
        if not bad:
            break
        if attempt == EVAL_RETRIES:
            obs = [_quarantine(o, "non_finite") if i in bad else o
                   for i, o in enumerate(obs)]
            break
        _evict(objective, [xs[i] for i in bad])
    if journal is not None:
        journal.record_many(obs)
    return obs


def _eval_one(objective, x, journal: Optional[SearchJournal]) -> Observation:
    """`objective(x)` behind the same failure model as `_eval_many`
    (kept separate because `Objective.__call__` routes through the
    scalar oracle while `evaluate_batch` routes through the jitted
    path — the sha-pinned trajectories depend on that distinction)."""
    obs = None
    for attempt in range(EVAL_RETRIES + 1):
        try:
            obs = objective(x)
        except StepFailure:
            if attempt == EVAL_RETRIES:
                obs = Observation(x=[int(v) for v in x], f=None, npu=None,
                                  fault="evaluator_error")
                break
            continue
        if obs.f is None or _finite_f(obs.f):
            break
        if attempt == EVAL_RETRIES:
            obs = _quarantine(obs, "non_finite")
            break
        _evict(objective, [x])
    if journal is not None:
        journal.record(obs)
    return obs


def _begin_journal(journal: Optional[SearchJournal], objective, seed: int,
                   method: str, init: Optional[list]) -> list:
    """Open/replay the journal at searcher entry and return the starting
    observation list.  Caller-provided init observations are journaled
    too (idempotently — a `shared_init`/`system_warm_start` that ran
    with the same journal already logged them), so the journal is a
    self-contained record of the whole search."""
    if journal is not None:
        journal.begin(objective, seed, method=method)
        if init:
            journal.record_many(init)
    return list(init) if init else []


class Objective:
    """Evaluate designs on one (model, trace, phase) under a TDP cap.

    `calibration` (a `core.calibration.CalibrationTable`, default None
    = identity) threads measured per-geometry-class GEMM factors into
    both the scalar and jitted evaluation paths.  The table is fixed
    for the objective's lifetime (the evaluation cache memoizes by
    design key alone) and non-identity tables are pinned into journal
    headers by content hash, so a calibrated search can never silently
    resume an uncalibrated journal or vice versa.
    """

    n_obj = 2

    def __init__(self, dims: ModelDims, trace: Trace, phase: Phase,
                 tdp_limit_w: float = 700.0, batch: Optional[int] = None,
                 space: Optional[sp.DesignSpace] = None,
                 calibration=None):
        self.space = space if space is not None else sp.SingleDeviceSpace()
        self.dims, self.trace, self.phase = dims, trace, phase
        self.tdp_limit_w = tdp_limit_w
        self.batch = batch
        self.calibration = calibration
        self.cache: dict = {}
        self.n_evals = 0

    def __call__(self, x) -> Observation:
        key = tuple(int(v) for v in x)
        if key in self.cache:
            return self.cache[key]
        self.n_evals += 1
        obs = Observation(x=list(key), f=None, npu=None)
        try:
            npu = self.space.decode(key)
            obs.npu = npu
            if npu.tdp_w() <= self.tdp_limit_w:
                r = evaluate(npu, self.dims, self.trace, self.phase,
                             batch=self.batch,
                             calibration=self.calibration)
                obs.result = r
                obs.f = (r.throughput_tps, -r.avg_power_w)
        except (sp.InvalidDesign, InfeasibleConfig, ValueError):
            pass
        self.cache[key] = obs
        return obs

    def evaluate_batch(self, xs) -> list:
        """Evaluate a list of designs in bulk (same results as mapping
        `self(x)`, same cache), using the vectorized validity prefilter
        and the perfmodel batch fast path."""
        keys = [tuple(int(v) for v in x) for x in xs]
        todo = _dedup_pending(self.cache, keys)
        if todo:
            valid = self.space.valid_mask(np.asarray(todo, dtype=np.int64))
            run_keys, run_npus = [], []
            for k, ok in zip(todo, valid):
                self.n_evals += 1
                obs = Observation(x=list(k), f=None, npu=None)
                self.cache[k] = obs
                if not ok:
                    continue
                try:
                    obs.npu = self.space.decode(k)
                except sp.InvalidDesign:   # defensive: mask mirrors decode
                    continue
                if obs.npu.tdp_w() <= self.tdp_limit_w:
                    run_keys.append(k)
                    run_npus.append(obs.npu)
            results = evaluate_batch(run_npus, self.dims, self.trace,
                                     self.phase, batch=self.batch,
                                     calibration=self.calibration)
            for k, r in zip(run_keys, results):
                if r is not None:
                    self.cache[k].result = r
                    self.cache[k].f = (r.throughput_tps, -r.avg_power_w)
        return [self.cache[k] for k in keys]


class SystemObjective:
    """Evaluate K-role systems end-to-end for the system DSE on
    `SystemSpace` (paper Sections 5.3/5.5).

    f(x) = (aggregate tokens/joule across all devices incl. hand-off
    energy, -total system power), subject to

      * a combined system TDP cap (`tdp_limit_w`, default one 700 W
        socket per role), enforced pre-evaluation via
        `space.tdp_w_batch`, and
      * a TTFT feasibility cap (`ttft_cap_s`): per-request TTFT =
        prefill-chain latency + the KV/activation hand-offs over the
        NVLink-class interconnect; systems whose hand-offs push TTFT
        past the cap are infeasible regardless of their steady-state
        efficiency.  The 90 s default is an agentic-trace SLO roughly
        4x the hand-designed Table 6 pairs' TTFT on OSWorld — loose
        enough that the searchers see a feasible gradient early, tight
        enough to reject the capacity-starved region (TTFT in the
        175-1000 s range).

    With `ttft_objective=True` the cap is dropped and -TTFT becomes a
    third maximized objective; MOBO's acquisition then routes through
    the exact 3-D box decomposition (`ehvi.ehvi_3d`).

    Batched evaluation dedups the K 17-gene halves across systems and
    memoizes their per-(role, phase) results across generations
    (NSGA-II children and TPE proposals reuse halves constantly), so
    the hot path stays `perfmodel.evaluate_batch` on each role's
    unique-half miss set.
    """

    def __init__(self, dims: ModelDims, trace: Trace,
                 topology=PD_PAIR,
                 tdp_limit_w: Optional[float] = None,
                 ttft_cap_s: Optional[float] = 90.0,
                 ttft_objective: bool = False,
                 space: Optional[sp.SystemSpace] = None,
                 calibration=None):
        self.topology = topology
        self.space = (space if space is not None
                      else sp.SystemSpace.for_topology(topology))
        self.dims, self.trace = dims, trace
        self.tdp_limit_w = (tdp_limit_w if tdp_limit_w is not None
                            else 700.0 * topology.k)
        self.ttft_objective = ttft_objective
        self.ttft_cap_s = None if ttft_objective else ttft_cap_s
        self.n_obj = 3 if ttft_objective else 2
        # measured GEMM-factor table (core.calibration); fixed for the
        # objective's lifetime so the role caches stay coherent, and
        # pinned by hash into journal headers when non-identity
        self.calibration = calibration
        self.cache: dict = {}
        self.n_evals = 0
        # one half-name -> PhaseResult|None memo per topology role
        self._role_caches = [dict() for _ in topology.roles]

    def _score_systems(self, systems: list) -> list:
        return evaluate_system_batch(systems, self.topology, self.dims,
                                     self.trace, caches=self._role_caches,
                                     calibration=self.calibration)

    def _objective_tuple(self, r) -> tuple:
        base = (r.tokens_per_joule, -r.total_power_w)
        return base + (-r.ttft_s,) if self.ttft_objective else base

    def __call__(self, x) -> Observation:
        key = tuple(int(v) for v in x)
        if key in self.cache:
            return self.cache[key]
        return self.evaluate_batch([key])[0]

    def evaluate_batch(self, xs) -> list:
        keys = [tuple(int(v) for v in x) for x in xs]
        todo = _dedup_pending(self.cache, keys)
        if todo:
            valid = self.space.valid_mask(np.asarray(todo, dtype=np.int64))
            run_keys, run_systems = [], []
            for k, ok in zip(todo, valid):
                self.n_evals += 1
                obs = Observation(x=list(k), f=None, npu=None)
                self.cache[k] = obs
                if not ok:
                    continue
                try:
                    system = self.space.decode(k)
                except sp.InvalidDesign:   # defensive: mask mirrors decode
                    continue
                obs.npu = system
                if sum(n.tdp_w() for n in system) <= self.tdp_limit_w:
                    run_keys.append(k)
                    run_systems.append(system)
            results = self._score_systems(run_systems)
            for k, r in zip(run_keys, results):
                if r is None:
                    continue
                obs = self.cache[k]
                obs.result = r
                if self.ttft_cap_s is None or r.ttft_s <= self.ttft_cap_s:
                    obs.f = self._objective_tuple(r)
        return [self.cache[k] for k in keys]


class DisaggObjective(SystemObjective):
    """Evaluate prefill/decode pairs end-to-end (paper Fig. 8) for the
    paired DSE on `PairedSpace` — the K=2 `SystemObjective` on the
    `disagg.PD_PAIR` topology, scoring through `evaluate_disagg_batch`
    so results are the original `DisaggResult` records (and numbers are
    byte-identical to the pre-SystemObjective pair implementation)."""

    def __init__(self, dims: ModelDims, trace: Trace,
                 tdp_limit_w: float = 1400.0,
                 ttft_cap_s: Optional[float] = 90.0,
                 space: Optional[sp.PairedSpace] = None,
                 calibration=None):
        super().__init__(
            dims, trace, topology=PD_PAIR, tdp_limit_w=tdp_limit_w,
            ttft_cap_s=ttft_cap_s,
            space=space if space is not None else sp.PairedSpace(),
            calibration=calibration)

    def _score_systems(self, systems: list) -> list:
        return evaluate_disagg_batch(
            systems, self.dims, self.trace,
            pre_cache=self._role_caches[0],
            dec_cache=self._role_caches[1],
            calibration=self.calibration)

    @property
    def _pre_results(self) -> dict:    # prefill-half name -> PhaseResult|None
        return self._role_caches[0]

    @property
    def _dec_results(self) -> dict:    # decode-half name -> PhaseResult|None
        return self._role_caches[1]


class ServingObjective:
    """Fleet-serving search on `space.ServingSpace` (devices + replica
    counts + routing co-searched against a `serving.TrafficMix`).

    f(x) = (fleet tokens/joule, -utilization-aware fleet power),
    subject to

      * a datacenter power budget (`tdp_limit_w`, default four 700 W
        sockets per role): *provisioned peak* power — every replica of
        a role draws from the budget whether busy or not
        (`ServingSpace.tdp_w_batch`), enforced pre-evaluation;
      * queueing stability (rho < 1 on every role) and the mix's
        per-class p99 TTFT/TPOT SLOs under the serving queueing model
        (`serving.FleetEvaluator`; see docs/serving.md).

    The hot path never decodes candidates into objects: valid gene
    rows go straight through the fleet evaluator's cached per-role
    metric rows and one jitted queueing fold, so scoring cost tracks
    *distinct device halves*, not candidates — replica/routing sweeps
    are pure cache hits.  The journal identity pins the mix
    (`TrafficMix.identity` via `journal.objective_identity`), so a
    serving journal can never resume against different traffic.
    """

    def __init__(self, dims: ModelDims, mix, topology=PD_PAIR,
                 power_budget_w: Optional[float] = None,
                 space: Optional[sp.ServingSpace] = None):
        from ..serving import FleetEvaluator
        self.topology = topology
        self.dims = dims
        self.mix = mix
        self.space = (space if space is not None
                      else sp.ServingSpace.for_mix(topology, mix))
        self.tdp_limit_w = (power_budget_w if power_budget_w is not None
                            else 2800.0 * topology.k)
        self.n_obj = 2
        self.cache: dict = {}
        self.n_evals = 0
        self.fleet = FleetEvaluator(topology, dims, mix)

    def _result(self, key: tuple, out: dict, i: int):
        from ..serving import ServingResult
        arr = np.asarray([key], dtype=np.int64)
        return ServingResult(
            feasible=bool(out["feasible"][i]),
            slo_ok=bool(out["slo_ok"][i]),
            tokens_per_joule=float(out["tokens_per_joule"][i]),
            fleet_power_w=float(out["fleet_power_w"][i]),
            busy_power_w=float(out["busy_power_w"][i]),
            token_rate_tps=float(self.mix.token_rate_tps),
            ttft_p99_s=tuple(float(v) for v in out["ttft_p99_s"][i]),
            tpot_p99_s=tuple(float(v) for v in out["tpot_p99_s"][i]),
            ttft0_s=tuple(float(v) for v in out["ttft0_s"][i]),
            tpot0_s=tuple(float(v) for v in out["tpot0_s"][i]),
            rho=tuple(float(v) for v in out["rho"][i]),
            wq_s=tuple(float(v) for v in out["wq_s"][i]),
            replicas=tuple(int(v)
                           for v in self.space.replica_counts(arr)[0]),
            phi=tuple(tuple(float(v) for v in row)
                      for row in self.space.routing(arr)[0]),
            topology=self.topology, mix=self.mix)

    def design(self, x) -> sp.ServingDesign:
        """Decode one candidate for reporting (off the hot path)."""
        return self.space.decode(x)

    def __call__(self, x) -> Observation:
        key = tuple(int(v) for v in x)
        if key in self.cache:
            return self.cache[key]
        return self.evaluate_batch([key])[0]

    def evaluate_batch(self, xs) -> list:
        keys = [tuple(int(v) for v in x) for x in xs]
        todo = _dedup_pending(self.cache, keys)
        if todo:
            arr = np.asarray(todo, dtype=np.int64)
            valid = self.space.valid_mask(arr)
            tdp = self.space.tdp_w_batch(arr)
            run_keys = []
            for k, ok, p in zip(todo, valid, tdp):
                self.n_evals += 1
                self.cache[k] = Observation(x=list(k), f=None, npu=None)
                if ok and p <= self.tdp_limit_w:
                    run_keys.append(k)
            if run_keys:
                out = self.fleet.evaluate_genes(
                    np.asarray(run_keys, dtype=np.int64))
                for i, k in enumerate(run_keys):
                    if not out["feasible"][i]:
                        continue
                    obs = self.cache[k]
                    obs.result = self._result(k, out, i)
                    if out["slo_ok"][i]:
                        obs.f = (float(out["tokens_per_joule"][i]),
                                 -float(out["fleet_power_w"][i]))
        return [self.cache[k] for k in keys]


def shared_init(objective, n_init: int, seed: int,
                journal: Optional[SearchJournal] = None) -> list:
    """Sobol initialization (paper: N_init = 20), skipping duplicates.

    Spaces with `init_filter_valid` (the paired space, whose raw-uniform
    validity is ~10-20%) additionally drop Sobol points that fail
    `valid_mask`, so the init budget is spent on decodable designs; the
    shortfall is topped up by the space's (rejection-) sampler.

    With a `journal`, the init evaluations are journaled (and replayed
    on resume) like any other — `begin` here is idempotent with the
    searcher's own `begin`, so one journal threads through both."""
    if journal is not None:
        journal.begin(objective, seed, method="init")
    space = objective.space
    xs: list = []
    seen = set()
    u = sobol(4 * n_init, space.n_dims, skip=seed * 101)
    cand = [tuple(space.from_unit(ui)) for ui in u]
    if space.init_filter_valid and cand:
        keep = space.valid_mask(np.asarray(cand, dtype=np.int64))
        cand = [x for x, k in zip(cand, keep) if k]
    i = 0
    while len(xs) < n_init and i < len(cand):
        x = cand[i]
        i += 1
        if x in seen:
            continue
        seen.add(x)
        xs.append(x)
    rng = np.random.default_rng(seed)
    while len(xs) < n_init:
        x = tuple(space.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        xs.append(x)
    return _eval_many(objective, xs, journal)


# ---------------------------------------------------------------------------
# Random search baseline
# ---------------------------------------------------------------------------

def run_random(objective, n_total: int = 100, seed: int = 0,
               init: Optional[list] = None,
               journal: Optional[SearchJournal] = None) -> DSEResult:
    space = objective.space
    rng = np.random.default_rng(seed + 7)
    obs = _begin_journal(journal, objective, seed, "Random", init)
    seen = {tuple(o.x) for o in obs}
    xs = []
    while len(obs) + len(xs) < n_total:
        x = tuple(space.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        xs.append(x)
    obs.extend(_eval_many(objective, xs, journal))
    return DSEResult(method="Random", observations=obs)


# ---------------------------------------------------------------------------
# GP + EHVI (ours)
# ---------------------------------------------------------------------------

def _ehvi_scores(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
                 sd: np.ndarray, n_obj: int, rng) -> np.ndarray:
    """Acquisition scores for a candidate pool: exact box decomposition
    for 2 and 3 objectives, antithetic quasi-MC beyond (drawn from the
    method RNG, so seeded exact-path trajectories never change)."""
    if n_obj == 2:
        return ehvi_2d(front, ref, mu, sd)
    if n_obj == 3:
        return ehvi_3d(front, ref, mu, sd)
    half = rng.standard_normal((MC_EHVI_SAMPLES // 2, n_obj))
    return mc_ehvi(front, ref, mu, sd, np.concatenate([half, -half]))


def run_mobo(objective, n_total: int = 100, seed: int = 0,
             init: Optional[list] = None, n_init: int = 20,
             pool_size: int = 256, batch_size: int = 1,
             gp_jit: Optional[bool] = None,
             journal: Optional[SearchJournal] = None) -> DSEResult:
    """Multi-Objective Bayesian Optimization with GP surrogates + exact
    closed-form EHVI (2-D strips / 3-D box decomposition) over a
    table-filtered candidate pool.

    `batch_size=B > 1` turns on batched q-EHVI acquisition: each GP fit
    proposes B points by kriging-believer (constant-liar) — pick the
    EHVI argmax, hallucinate its outcome as the GP posterior mean,
    augment the incumbent front with that lie, re-score the remaining
    pool, repeat — then evaluates all B designs through the jitted
    `objective.evaluate_batch` in one call and journals them as one
    batch.  The GP hot path itself moves onto `jax.jit`
    (`gp_jit=None` means "jit iff B > 1"): padded-bucket fit
    factorization + batched posterior predict.  B=1 keeps the original
    sequential loop byte-identical (scalar-oracle evaluation, NumPy
    GP), so the sha-pinned trajectories are unchanged.
    """
    from .gp import GP
    space = objective.space
    rng = np.random.default_rng(seed + 13)
    if gp_jit is None:
        gp_jit = batch_size > 1
    obs = _begin_journal(journal, objective, seed, "GP+EHVI", init)
    if not obs:
        obs = shared_init(objective, n_init, seed, journal=journal)
    seen = {tuple(o.x) for o in obs}
    while len(obs) < n_total:
        feas = [o for o in obs if _finite_f(o.f)]
        if len(feas) < 4:
            x = tuple(space.random_design(rng))
            if x in seen:
                continue
            seen.add(x)
            obs.append(_eval_one(objective, x, journal))
            continue
        fs = np.array([o.f for o in feas], dtype=float)
        n_obj = fs.shape[1]
        gps = [GP.fit_design(space, [o.x for o in feas], fs[:, m],
                             use_jit=gp_jit)
               for m in range(n_obj)]
        front = pareto_front(fs)
        ref = fs.min(axis=0) - 0.05 * (fs.max(axis=0) - fs.min(axis=0) + 1e-9)
        # candidate pool: one vectorized draw, validity/TDP filtered via
        # the per-gene tables (no NPUConfig construction per draw)
        cand = space.random_designs(rng, pool_size * 10)
        ok = space.tdp_w_batch(cand) <= objective.tdp_limit_w
        if not space.samples_valid:     # rejection samplers pre-validate
            ok &= space.valid_mask(cand)
        pool = []
        pool_seen = set()
        for x in map(tuple, cand[ok].tolist()):
            if x in seen or x in pool_seen:
                continue
            pool_seen.add(x)
            pool.append(x)
            if len(pool) >= pool_size:
                break
        if not pool:
            break
        xq = space.normalize_batch(pool)
        mus, sds = zip(*((g.predict_batch(xq) if gp_jit else g.predict(xq))
                         for g in gps))
        mu = np.stack(mus, axis=1)
        sd = np.stack(sds, axis=1)
        scores = _ehvi_scores(front, ref, mu, sd, n_obj, rng)
        if batch_size <= 1:
            x_best = pool[int(np.argmax(scores))]
            seen.add(x_best)
            obs.append(_eval_one(objective, x_best, journal))
            continue
        # q-EHVI via kriging believer: greedily build the batch,
        # treating each pick's posterior mean as its observed outcome
        b_max = min(batch_size, n_total - len(obs), len(pool))
        avail = np.ones(len(pool), dtype=bool)
        liar_front = front
        picked = []
        for b in range(b_max):
            idx = int(np.argmax(np.where(avail, scores, -np.inf)))
            avail[idx] = False
            picked.append(pool[idx])
            seen.add(pool[idx])
            if b + 1 < b_max:
                liar_front = np.vstack([liar_front, mu[idx][None, :]])
                scores = _ehvi_scores(liar_front, ref, mu, sd, n_obj, rng)
        obs.extend(_eval_many(objective, picked, journal))
    return DSEResult(method="GP+EHVI", observations=obs)


# ---------------------------------------------------------------------------
# NSGA-II baseline
# ---------------------------------------------------------------------------

def _fast_nondominated_sort(fs: np.ndarray) -> list:
    n = len(fs)
    S = [[] for _ in range(n)]
    nd = np.zeros(n, dtype=int)
    fronts = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if (np.all(fs[p] >= fs[q]) and np.any(fs[p] > fs[q])):
                S[p].append(q)
            elif (np.all(fs[q] >= fs[p]) and np.any(fs[q] > fs[p])):
                nd[p] += 1
        if nd[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt = []
        for p in fronts[i]:
            for q in S[p]:
                nd[q] -= 1
                if nd[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return [f for f in fronts if f]


def _crowding(fs: np.ndarray, front: list) -> dict:
    d = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: np.inf for i in front}
    for m in range(fs.shape[1]):
        order = sorted(front, key=lambda i: fs[i, m])
        d[order[0]] = d[order[-1]] = np.inf
        span = fs[order[-1], m] - fs[order[0], m] + 1e-12
        for j in range(1, len(order) - 1):
            d[order[j]] += (fs[order[j + 1], m] - fs[order[j - 1], m]) / span
    return d


def run_nsga2(objective, n_total: int = 100, seed: int = 0,
              init: Optional[list] = None, pop_size: int = 20,
              p_cross: float = 0.9,
              journal: Optional[SearchJournal] = None) -> DSEResult:
    space = objective.space
    rng = np.random.default_rng(seed + 29)
    obs = _begin_journal(journal, objective, seed, "NSGA-II", init)
    seen = {tuple(o.x) for o in obs}

    n_obj = getattr(objective, "n_obj", 2)

    def penal(o: Observation) -> np.ndarray:
        # constraint-domination: infeasible AND quarantined/non-finite
        # points sit far below (a NaN here would poison the sort)
        return (np.array(o.f) if _finite_f(o.f)
                else np.full(n_obj, -1e18))

    pop = list(obs[-pop_size:])
    while len(pop) < pop_size and len(obs) < n_total:
        x = tuple(space.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        o = _eval_one(objective, x, journal)
        obs.append(o)
        pop.append(o)

    while len(obs) < n_total:
        fs = np.array([penal(o) for o in pop])
        fronts = _fast_nondominated_sort(fs)
        rank = {}
        for r, fr in enumerate(fronts):
            for i in fr:
                rank[i] = r
        crowd = {}
        for fr in fronts:
            crowd.update(_crowding(fs, fr))

        def tournament() -> list:
            a, b = rng.integers(len(pop)), rng.integers(len(pop))
            if (rank[a], -crowd[a]) < (rank[b], -crowd[b]):
                return list(pop[a].x)
            return list(pop[b].x)

        children = []
        tries = 0
        while len(children) < pop_size and len(obs) + len(children) < n_total:
            tries += 1
            if tries > 64 * pop_size:
                break               # near-saturation: stop breeding
            p1, p2 = tournament(), tournament()
            child = list(p1)
            if rng.random() < p_cross:
                for d in range(space.n_dims):
                    if rng.random() < 0.5:
                        child[d] = p2[d]
            for d in range(space.n_dims):  # mutation
                if rng.random() < 1.0 / space.n_dims:
                    child[d] = int(rng.integers(space.cardinalities[d]))
            t = tuple(space.repair(child))
            if t in seen:
                continue
            seen.add(t)
            children.append(t)
        if not children:
            # saturated: bounded random-restart fallback (mirrors
            # run_motpe; the seed implementation's `continue` could spin
            # forever once every restart draw was already in `seen`).
            x = None
            for _ in range(64 * pop_size):
                c = tuple(space.random_design(rng))
                if c not in seen:
                    x = c
                    break
            if x is None:
                break               # retry budget exhausted: stop early
            seen.add(x)
            obs.append(_eval_one(objective, x, journal))
            continue
        child_obs = _eval_many(objective, children, journal)
        obs.extend(child_obs)
        # environmental selection on parents + children
        union = pop + child_obs
        fs = np.array([penal(o) for o in union])
        fronts = _fast_nondominated_sort(fs)
        new_pop = []
        for fr in fronts:
            if len(new_pop) + len(fr) <= pop_size:
                new_pop.extend(fr)
            else:
                crowd = _crowding(fs, fr)
                rest = sorted(fr, key=lambda i: -crowd[i])
                new_pop.extend(rest[:pop_size - len(new_pop)])
                break
        pop = [union[i] for i in new_pop]
    return DSEResult(method="NSGA-II", observations=obs[:n_total])


# ---------------------------------------------------------------------------
# MO-TPE baseline
# ---------------------------------------------------------------------------

def run_motpe(objective, n_total: int = 100, seed: int = 0,
              init: Optional[list] = None, gamma: float = 0.3,
              n_candidates: int = 24,
              journal: Optional[SearchJournal] = None) -> DSEResult:
    """Multi-objective TPE: split observations into good (near-Pareto) /
    bad by hypervolume-contribution ranking; per-dimension categorical
    densities l(x), g(x); propose argmax l/g."""
    space = objective.space
    rng = np.random.default_rng(seed + 43)
    obs = _begin_journal(journal, objective, seed, "MO-TPE", init)
    seen = {tuple(o.x) for o in obs}
    while len(obs) < n_total:
        feas = [o for o in obs if _finite_f(o.f)]
        if len(feas) < 6:
            x = tuple(space.random_design(rng))
            if x in seen:
                continue
            seen.add(x)
            obs.append(_eval_one(objective, x, journal))
            continue
        fs = np.array([o.f for o in feas], dtype=float)
        # rank: non-dominated first, then by scalarized distance
        mask = pareto_mask(fs)
        scal = (fs - fs.min(0)) / (np.ptp(fs, axis=0) + 1e-12)
        score = scal.sum(axis=1) + mask * 10.0
        order = np.argsort(-score)
        n_good = max(2, int(gamma * len(feas)))
        good = [feas[i] for i in order[:n_good]]
        bad = [feas[i] for i in order[n_good:]] or good

        def density(group: list) -> list:
            ps = []
            for d in range(space.n_dims):
                card = space.cardinalities[d]
                cnt = np.ones(card)
                for o in group:
                    cnt[o.x[d]] += 1.0
                ps.append(cnt / cnt.sum())
            return ps

        l_ps, g_ps = density(good), density(bad)
        best_x, best_ratio = None, -np.inf
        for _ in range(n_candidates):
            x = tuple(space.repair(
                [int(rng.choice(space.cardinalities[d], p=l_ps[d]))
                 for d in range(space.n_dims)]))
            if x in seen:
                continue
            ratio = sum(np.log(l_ps[d][x[d]]) - np.log(g_ps[d][x[d]])
                        for d in range(space.n_dims))
            if ratio > best_ratio:
                best_ratio, best_x = ratio, x
        if best_x is None:
            # near-saturation: every sampled candidate was already seen.
            # Bounded fallback to a random unseen design (the seed
            # implementation's `continue` could spin forever here).
            for _ in range(max(1, n_candidates) * 8):
                x = tuple(space.random_design(rng))
                if x not in seen:
                    best_x = x
                    break
            if best_x is None:
                break                   # retry budget exhausted: stop early
        seen.add(best_x)
        obs.append(_eval_one(objective, best_x, journal))
    return DSEResult(method="MO-TPE", observations=obs)


# ---------------------------------------------------------------------------
# System-search warm start (the disagg.best_per_phase idea, batched)
# ---------------------------------------------------------------------------

def system_warm_start(objective: SystemObjective, n_init: int, seed: int,
                      pool: int = 256,
                      journal: Optional[SearchJournal] = None) -> list:
    """Seed a `SystemSpace` search from per-role champions of a scored
    single-device pool.

    Draws a pool of valid single-device genes (TDP-prefiltered to one
    role's share of the system budget), scores every decoded config
    against each topology role's restricted workload through the
    batched/jitted `perfmodel.evaluate_batch`, ranks the pool per role
    by tokens/joule, and composes the i-th best half of every role into
    the i-th warm-start system (repaired, so cross-half ties hold).
    Shortfall — infeasible compositions or a thin pool — is topped up
    by the space's rejection sampler, and everything is evaluated
    through `objective.evaluate_batch` so warm starts land in the same
    caches the searchers use.

    With a `journal`, the warm-start evaluations are journaled and
    replayed on resume just like searcher evaluations (`begin` is
    idempotent with the searcher's, so pass the same journal to both).
    """
    if journal is not None:
        journal.begin(objective, seed, method="warm-start")
    topo = objective.topology
    space = objective.space
    rng = np.random.default_rng(seed + 97)
    xs = np.empty((0, sp.N_DIMS), dtype=np.int64)
    for _ in range(8):
        if len(xs) >= pool:
            break
        draw = sp.random_designs(rng, pool)
        draw = draw[sp.valid_mask(draw)]
        draw = draw[sp.tdp_w_batch(draw)
                    <= objective.tdp_limit_w / topo.k]
        xs = np.concatenate([xs, draw])
    xs = xs[:pool]
    configs = [sp.decode(x) for x in xs]
    per_role_order = []
    for role in topo.roles:
        results = evaluate_batch(configs, role.dims_for(objective.dims),
                                 objective.trace, role.phase,
                                 context_override=role.context_for(
                                     objective.trace))
        scores = np.array([-np.inf if r is None else r.tokens_per_joule
                           for r in results])
        per_role_order.append(np.argsort(-scores, kind="stable"))
    seen = set()
    starts = []
    for i in range(min(n_init, len(xs))):
        genes = []
        for order in per_role_order:
            genes.extend(int(v) for v in xs[order[i]])
        x = tuple(space.repair(genes))
        if x not in seen:
            seen.add(x)
            starts.append(x)
    while len(starts) < n_init:
        x = tuple(space.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        starts.append(x)
    return _eval_many(objective, starts, journal)


def serving_warm_start(objective: ServingObjective, n_init: int, seed: int,
                       pool: int = 256,
                       journal: Optional[SearchJournal] = None) -> list:
    """Seed a `ServingSpace` search from per-role single-device
    champions at maximal uniform replication.

    Device halves follow the `system_warm_start` recipe — a valid
    single-device pool, TDP-prefiltered to one *unreplicated* role's
    share of the budget, scored per (role, class) through the batched
    evaluator — but ranked by the mix's token-rate-weighted
    tokens/joule (a half infeasible on any class is out).  Each start
    composes the i-th best half per role with topology-default routing
    genes and the LARGEST uniform replica level whose provisioned peak
    power fits the budget: tokens/joule is replica-invariant while
    queueing feasibility only improves with replicas, so maximal
    replication is the right warm-start prior for SLO-capped mixes.
    """
    if journal is not None:
        journal.begin(objective, seed, method="warm-start")
    topo = objective.topology
    space = objective.space
    mix = objective.mix
    rng = np.random.default_rng(seed + 97)
    xs = np.empty((0, sp.N_DIMS), dtype=np.int64)
    for _ in range(8):
        if len(xs) >= pool:
            break
        draw = sp.random_designs(rng, pool)
        draw = draw[sp.valid_mask(draw)]
        draw = draw[sp.tdp_w_batch(draw)
                    <= objective.tdp_limit_w / topo.k]
        xs = np.concatenate([xs, draw])
    xs = xs[:pool]
    configs = [sp.decode(x) for x in xs]
    weights = [rc.rate_rps * rc.trace.gen_tokens for rc in mix.classes]
    per_role_order = []
    for role in topo.roles:
        score = np.zeros(len(xs))
        for wc, rc in zip(weights, mix.classes):
            results = evaluate_batch(
                configs, role.dims_for(objective.dims), rc.trace,
                role.phase, context_override=role.context_for(rc.trace))
            tokj = np.array([-np.inf if r is None else r.tokens_per_joule
                             for r in results])
            score = score + wc * tokj
        per_role_order.append(np.argsort(-score, kind="stable"))
    n_route = space.n_classes * space.n_decode
    seen = set()
    starts = []
    for i in range(min(n_init, len(xs))):
        genes = []
        for order in per_role_order:
            genes.extend(int(v) for v in xs[order[i]])
        genes = space.repair(genes + [0] * space.k + [0] * n_route)
        for rep_idx in reversed(range(len(sp.REPLICA_CHOICES))):
            for r in range(space.k):
                genes[space.dev_genes + r] = rep_idx
            if space.tdp_w_batch(np.asarray([genes], dtype=np.int64))[0] \
                    <= objective.tdp_limit_w:
                break
        x = tuple(genes)
        if x not in seen:
            seen.add(x)
            starts.append(x)
    while len(starts) < n_init:
        x = tuple(space.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        starts.append(x)
    return _eval_many(objective, starts, journal)


METHODS: dict[str, Callable] = {
    "GP+EHVI": run_mobo,
    "NSGA-II": run_nsga2,
    "MO-TPE": run_motpe,
    "Random": run_random,
}
