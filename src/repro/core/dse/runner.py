"""DSE orchestration: shared objective wrapper + the four search methods
(GP+EHVI MOBO, NSGA-II, MO-TPE, Random), paper Section 4.4 / Figure 6.

All methods maximize f(x) = (throughput_tps, -avg_power_w) subject to a
TDP constraint, share the same Sobol/random initialization, and report
their evaluation history so hypervolume-convergence curves can be drawn
against a common reference point.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..npu import NPUConfig
from ..perfmodel import InfeasibleConfig, evaluate
from ..workload import ModelDims, Phase, Trace
from . import space as sp
from .pareto import hypervolume_2d, pareto_front, pareto_mask
from .sobol import sobol


@dataclasses.dataclass
class Observation:
    x: list
    f: Optional[tuple]          # (tps, -power) or None if infeasible
    npu: Optional[NPUConfig]


@dataclasses.dataclass
class DSEResult:
    method: str
    observations: list          # in evaluation order

    def feasible_f(self) -> np.ndarray:
        return np.array([o.f for o in self.observations if o.f is not None],
                        dtype=float)

    def hv_history(self, ref: np.ndarray) -> np.ndarray:
        """HV of the feasible front after each evaluation."""
        out = []
        fs = []
        for o in self.observations:
            if o.f is not None:
                fs.append(o.f)
            out.append(hypervolume_2d(np.array(fs, dtype=float), ref)
                       if fs else 0.0)
        return np.array(out)

    def pareto(self) -> list:
        obs = [o for o in self.observations if o.f is not None]
        if not obs:
            return []
        mask = pareto_mask(np.array([o.f for o in obs]))
        return [o for o, m in zip(obs, mask) if m]


class Objective:
    """Evaluate one design on one (model, trace, phase) under a TDP cap."""

    def __init__(self, dims: ModelDims, trace: Trace, phase: Phase,
                 tdp_limit_w: float = 700.0, batch: Optional[int] = None):
        self.dims, self.trace, self.phase = dims, trace, phase
        self.tdp_limit_w = tdp_limit_w
        self.batch = batch
        self.cache: dict = {}
        self.n_evals = 0

    def __call__(self, x) -> Observation:
        key = tuple(int(v) for v in x)
        if key in self.cache:
            return self.cache[key]
        self.n_evals += 1
        obs = Observation(x=list(key), f=None, npu=None)
        try:
            npu = sp.decode(key)
            obs.npu = npu
            if npu.tdp_w() <= self.tdp_limit_w:
                r = evaluate(npu, self.dims, self.trace, self.phase,
                             batch=self.batch)
                obs.f = (r.throughput_tps, -r.avg_power_w)
        except (sp.InvalidDesign, InfeasibleConfig, ValueError):
            pass
        self.cache[key] = obs
        return obs


def shared_init(objective: Objective, n_init: int, seed: int) -> list:
    """Sobol initialization (paper: N_init = 20), skipping duplicates."""
    obs = []
    seen = set()
    u = sobol(4 * n_init, sp.N_DIMS, skip=seed * 101)
    i = 0
    while len(obs) < n_init and i < len(u):
        x = tuple(sp.from_unit(u[i]))
        i += 1
        if x in seen:
            continue
        seen.add(x)
        obs.append(objective(x))
    rng = np.random.default_rng(seed)
    while len(obs) < n_init:
        x = tuple(sp.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        obs.append(objective(x))
    return obs


# ---------------------------------------------------------------------------
# Random search baseline
# ---------------------------------------------------------------------------

def run_random(objective: Objective, n_total: int = 100, seed: int = 0,
               init: Optional[list] = None) -> DSEResult:
    rng = np.random.default_rng(seed + 7)
    obs = list(init) if init else []
    seen = {tuple(o.x) for o in obs}
    while len(obs) < n_total:
        x = tuple(sp.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        obs.append(objective(x))
    return DSEResult(method="Random", observations=obs)


# ---------------------------------------------------------------------------
# GP + EHVI (ours)
# ---------------------------------------------------------------------------

def _mc_ehvi(front: np.ndarray, ref: np.ndarray, mu: np.ndarray,
             sd: np.ndarray, z: np.ndarray) -> np.ndarray:
    """Quasi-MC Expected Hypervolume Improvement for a candidate batch.

    mu, sd: [n_cand, 2]; z: [n_samples, 2] standard-normal draws
    (antithetic).  Returns EHVI estimates [n_cand].
    """
    base = hypervolume_2d(front, ref)
    out = np.zeros(len(mu))
    for i in range(len(mu)):
        ys = mu[i] + sd[i] * z            # [s, 2]
        hvs = 0.0
        for y in ys:
            if y[0] <= ref[0] or y[1] <= ref[1]:
                continue
            hvs += max(0.0, hypervolume_2d(
                np.vstack([front, y[None, :]]) if len(front) else y[None, :],
                ref) - base)
        out[i] = hvs / len(ys)
    return out


def run_mobo(objective: Objective, n_total: int = 100, seed: int = 0,
             init: Optional[list] = None, n_init: int = 20,
             pool_size: int = 256, n_mc: int = 32) -> DSEResult:
    """Multi-Objective Bayesian Optimization with GP surrogates + EHVI."""
    from .gp import GP
    rng = np.random.default_rng(seed + 13)
    obs = list(init) if init else shared_init(objective, n_init, seed)
    seen = {tuple(o.x) for o in obs}
    half = rng.standard_normal((1, 2))  # placeholder; re-drawn per iter
    while len(obs) < n_total:
        feas = [o for o in obs if o.f is not None]
        if len(feas) < 4:
            x = tuple(sp.random_design(rng))
            if x in seen:
                continue
            seen.add(x)
            obs.append(objective(x))
            continue
        xs = np.array([sp.normalize(o.x) for o in feas])
        fs = np.array([o.f for o in feas], dtype=float)
        gps = [GP.fit(xs, fs[:, m]) for m in range(2)]
        front = pareto_front(fs)
        ref = fs.min(axis=0) - 0.05 * (fs.max(axis=0) - fs.min(axis=0) + 1e-9)
        # candidate pool: random unevaluated designs, cheap-filtered
        pool = []
        tries = 0
        while len(pool) < pool_size and tries < pool_size * 10:
            tries += 1
            x = tuple(sp.random_design(rng))
            if x in seen:
                continue
            try:
                npu = sp.decode(x)
                if npu.tdp_w() > objective.tdp_limit_w:
                    continue
            except sp.InvalidDesign:
                continue
            pool.append(x)
        if not pool:
            break
        xq = np.array([sp.normalize(x) for x in pool])
        mus, sds = zip(*(g.predict(xq) for g in gps))
        mu = np.stack(mus, axis=1)
        sd = np.stack(sds, axis=1)
        h = rng.standard_normal((n_mc // 2, 2))
        z = np.vstack([h, -h])
        scores = _mc_ehvi(front, ref, mu, sd, z)
        x_best = pool[int(np.argmax(scores))]
        seen.add(x_best)
        obs.append(objective(x_best))
    return DSEResult(method="GP+EHVI", observations=obs)


# ---------------------------------------------------------------------------
# NSGA-II baseline
# ---------------------------------------------------------------------------

def _fast_nondominated_sort(fs: np.ndarray) -> list:
    n = len(fs)
    S = [[] for _ in range(n)]
    nd = np.zeros(n, dtype=int)
    fronts = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if (np.all(fs[p] >= fs[q]) and np.any(fs[p] > fs[q])):
                S[p].append(q)
            elif (np.all(fs[q] >= fs[p]) and np.any(fs[q] > fs[p])):
                nd[p] += 1
        if nd[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt = []
        for p in fronts[i]:
            for q in S[p]:
                nd[q] -= 1
                if nd[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return [f for f in fronts if f]


def _crowding(fs: np.ndarray, front: list) -> dict:
    d = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: np.inf for i in front}
    for m in range(fs.shape[1]):
        order = sorted(front, key=lambda i: fs[i, m])
        d[order[0]] = d[order[-1]] = np.inf
        span = fs[order[-1], m] - fs[order[0], m] + 1e-12
        for j in range(1, len(order) - 1):
            d[order[j]] += (fs[order[j + 1], m] - fs[order[j - 1], m]) / span
    return d


def run_nsga2(objective: Objective, n_total: int = 100, seed: int = 0,
              init: Optional[list] = None, pop_size: int = 20,
              p_cross: float = 0.9) -> DSEResult:
    rng = np.random.default_rng(seed + 29)
    obs = list(init) if init else []
    seen = {tuple(o.x) for o in obs}

    def penal(o: Observation) -> np.ndarray:
        # constraint-domination: infeasible points sit far below
        return (np.array(o.f) if o.f is not None
                else np.array([-1e18, -1e18]))

    pop = list(obs[-pop_size:])
    while len(pop) < pop_size and len(obs) < n_total:
        x = tuple(sp.random_design(rng))
        if x in seen:
            continue
        seen.add(x)
        o = objective(x)
        obs.append(o)
        pop.append(o)

    while len(obs) < n_total:
        fs = np.array([penal(o) for o in pop])
        fronts = _fast_nondominated_sort(fs)
        rank = {}
        for r, fr in enumerate(fronts):
            for i in fr:
                rank[i] = r
        crowd = {}
        for fr in fronts:
            crowd.update(_crowding(fs, fr))

        def tournament() -> list:
            a, b = rng.integers(len(pop)), rng.integers(len(pop))
            if (rank[a], -crowd[a]) < (rank[b], -crowd[b]):
                return list(pop[a].x)
            return list(pop[b].x)

        children = []
        while len(children) < pop_size and len(obs) + len(children) < n_total:
            p1, p2 = tournament(), tournament()
            child = list(p1)
            if rng.random() < p_cross:
                for d in range(sp.N_DIMS):
                    if rng.random() < 0.5:
                        child[d] = p2[d]
            for d in range(sp.N_DIMS):  # mutation
                if rng.random() < 1.0 / sp.N_DIMS:
                    child[d] = int(rng.integers(sp.CARDINALITIES[d]))
            t = tuple(child)
            if t in seen:
                continue
            seen.add(t)
            children.append(t)
        if not children:
            # saturated: random restarts
            x = tuple(sp.random_design(rng))
            if x in seen:
                continue
            seen.add(x)
            obs.append(objective(x))
            continue
        child_obs = [objective(c) for c in children]
        obs.extend(child_obs)
        # environmental selection on parents + children
        union = pop + child_obs
        fs = np.array([penal(o) for o in union])
        fronts = _fast_nondominated_sort(fs)
        new_pop = []
        for fr in fronts:
            if len(new_pop) + len(fr) <= pop_size:
                new_pop.extend(fr)
            else:
                crowd = _crowding(fs, fr)
                rest = sorted(fr, key=lambda i: -crowd[i])
                new_pop.extend(rest[:pop_size - len(new_pop)])
                break
        pop = [union[i] for i in new_pop]
    return DSEResult(method="NSGA-II", observations=obs[:n_total])


# ---------------------------------------------------------------------------
# MO-TPE baseline
# ---------------------------------------------------------------------------

def run_motpe(objective: Objective, n_total: int = 100, seed: int = 0,
              init: Optional[list] = None, gamma: float = 0.3,
              n_candidates: int = 24) -> DSEResult:
    """Multi-objective TPE: split observations into good (near-Pareto) /
    bad by hypervolume-contribution ranking; per-dimension categorical
    densities l(x), g(x); propose argmax l/g."""
    rng = np.random.default_rng(seed + 43)
    obs = list(init) if init else []
    seen = {tuple(o.x) for o in obs}
    while len(obs) < n_total:
        feas = [o for o in obs if o.f is not None]
        if len(feas) < 6:
            x = tuple(sp.random_design(rng))
            if x in seen:
                continue
            seen.add(x)
            obs.append(objective(x))
            continue
        fs = np.array([o.f for o in feas], dtype=float)
        # rank: non-dominated first, then by scalarized distance
        mask = pareto_mask(fs)
        scal = (fs - fs.min(0)) / (np.ptp(fs, axis=0) + 1e-12)
        score = scal.sum(axis=1) + mask * 10.0
        order = np.argsort(-score)
        n_good = max(2, int(gamma * len(feas)))
        good = [feas[i] for i in order[:n_good]]
        bad = [feas[i] for i in order[n_good:]] or good

        def density(group: list) -> list:
            ps = []
            for d in range(sp.N_DIMS):
                card = sp.CARDINALITIES[d]
                cnt = np.ones(card)
                for o in group:
                    cnt[o.x[d]] += 1.0
                ps.append(cnt / cnt.sum())
            return ps

        l_ps, g_ps = density(good), density(bad)
        best_x, best_ratio = None, -np.inf
        for _ in range(n_candidates):
            x = tuple(int(rng.choice(sp.CARDINALITIES[d], p=l_ps[d]))
                      for d in range(sp.N_DIMS))
            if x in seen:
                continue
            ratio = sum(np.log(l_ps[d][x[d]]) - np.log(g_ps[d][x[d]])
                        for d in range(sp.N_DIMS))
            if ratio > best_ratio:
                best_ratio, best_x = ratio, x
        if best_x is None:
            best_x = tuple(sp.random_design(rng))
            if best_x in seen:
                continue
        seen.add(best_x)
        obs.append(objective(best_x))
    return DSEResult(method="MO-TPE", observations=obs)


METHODS: dict[str, Callable] = {
    "GP+EHVI": run_mobo,
    "NSGA-II": run_nsga2,
    "MO-TPE": run_motpe,
    "Random": run_random,
}
