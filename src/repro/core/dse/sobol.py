"""Sobol quasi-random sequence (paper: N_init = 20 Sobol points).

Self-contained gray-code Sobol generator with Joe-Kuo style direction
numbers for the first dimensions.  Direction-number rows beyond the
well-known low dimensions remain *valid* Sobol initializers (odd m_i <
2^i with primitive polynomials), which is sufficient for DSE
initialization diversity (documented in DESIGN.md 8.5).
"""

from __future__ import annotations

import numpy as np

# (s, a, [m_1..m_s]) per dimension >= 2; dimension 1 is van der Corput.
_JOE_KUO = [
    (1, 0, [1]),
    (2, 1, [1, 3]),
    (3, 1, [1, 3, 1]),
    (3, 2, [1, 1, 1]),
    (4, 1, [1, 1, 3, 3]),
    (4, 4, [1, 3, 5, 13]),
    (5, 2, [1, 1, 5, 5, 17]),
    (5, 4, [1, 1, 5, 5, 5]),
    (5, 7, [1, 1, 7, 11, 19]),
    (5, 11, [1, 1, 5, 1, 1]),
    (5, 13, [1, 1, 1, 3, 11]),
    (5, 14, [1, 3, 5, 5, 31]),
    (6, 1, [1, 3, 3, 9, 7, 49]),
    (6, 13, [1, 1, 1, 15, 21, 21]),
    (6, 16, [1, 3, 1, 13, 27, 49]),
    (6, 19, [1, 1, 1, 15, 7, 5]),
    (6, 22, [1, 3, 1, 3, 25, 31]),
    (6, 25, [1, 1, 5, 5, 19, 61]),
    (7, 1, [1, 3, 7, 11, 41, 79, 113]),
    (7, 4, [1, 3, 7, 5, 11, 27, 43]),
    (7, 7, [1, 1, 5, 11, 27, 77, 3]),
    (7, 8, [1, 3, 7, 3, 15, 63, 81]),
    (7, 14, [1, 1, 7, 5, 47, 11, 55]),
    (7, 19, [1, 3, 5, 5, 41, 43, 69]),
    # Rows 25-34 (distinct degree-7 primitive polynomials, odd m_i < 2^i)
    # so the 34-dim paired prefill/decode space gets 34 *distinct*
    # dimensions — recycling rows would make decode-half init coordinates
    # exact copies of prefill-half ones.
    (7, 21, [1, 3, 1, 7, 21, 51, 67]),
    (7, 22, [1, 1, 3, 9, 29, 21, 113]),
    (7, 25, [1, 3, 5, 15, 17, 41, 89]),
    (7, 26, [1, 1, 7, 13, 3, 59, 25]),
    (7, 28, [1, 3, 3, 5, 23, 37, 103]),
    (7, 31, [1, 1, 1, 11, 19, 61, 47]),
    (7, 32, [1, 3, 7, 9, 31, 29, 99]),
    (7, 37, [1, 1, 5, 3, 9, 49, 61]),
    (7, 41, [1, 3, 3, 13, 11, 17, 119]),
    (7, 42, [1, 1, 7, 7, 13, 55, 21]),
]

_BITS = 30


def _direction_numbers(dim_index: int) -> np.ndarray:
    """V_j (scaled direction integers) for one dimension."""
    v = np.zeros(_BITS, dtype=np.int64)
    if dim_index == 0:
        for i in range(_BITS):
            v[i] = 1 << (_BITS - 1 - i)
        return v
    s, a, m = _JOE_KUO[(dim_index - 1) % len(_JOE_KUO)]
    m = list(m)
    for i in range(s):
        v[i] = m[i] << (_BITS - 1 - i)
    for i in range(s, _BITS):
        vi = v[i - s] ^ (v[i - s] >> s)
        for k in range(1, s):
            if (a >> (s - 1 - k)) & 1:
                vi ^= v[i - k]
        v[i] = vi
    return v


def sobol(n: int, dims: int, skip: int = 0) -> np.ndarray:
    """First `n` points (after `skip`) of a `dims`-dimensional Sobol
    sequence in [0,1)^dims, gray-code order."""
    vs = np.stack([_direction_numbers(d) for d in range(dims)])  # [dims, BITS]
    total = n + skip
    x = np.zeros(dims, dtype=np.int64)
    out = np.empty((total, dims), dtype=np.float64)
    for i in range(total):
        if i > 0:
            # gray code: flip the bit of the lowest zero bit of (i-1)
            c = 0
            value = i - 1
            while value & 1:
                value >>= 1
                c += 1
            x ^= vs[:, c]
        out[i] = x / float(1 << _BITS)
    return out[skip:]
