"""Design-space exploration (paper Section 4.4).

space   Table 2 encoding <-> NPUConfig (+ vectorized validity/TDP tables)
        and the DesignSpace protocol: SingleDeviceSpace (17 genes),
        SystemSpace (K concatenated halves + GeneTie cross-half
        constraints), PairedSpace (its K=2 prefill/decode
        specialization with the KV-quant tie) and ServingSpace
        (SystemSpace + per-role replica genes + per-class decode
        routing genes for the fleet-serving search)
sobol   quasi-random initialization (N_init = 20)
gp      GP surrogates (JAX, MLE-fit RBF-ARD, bucketed jit cache)
pareto  dominance / front / exact 2-D hypervolume (Eq. 7), sweep-based,
        + nd slicing hypervolume and incremental nd HV histories
        (IncrementalHV2D staircase, IncrementalHVND clipped-front gain)
ehvi    exact closed-form EHVI: 2-D strips (Eq. 8) + 3-D box
        decomposition, vectorized over the candidate pool; quasi-MC
        estimator (test oracle, and the d > 3 acquisition fallback)
runner  GP+EHVI MOBO + NSGA-II / MO-TPE / Random baselines (batched),
        generic over any DesignSpace; Objective (single device),
        SystemObjective (K-role systems over a disagg.SystemTopology)
        and DisaggObjective (disaggregated pairs, Sections 5.3/5.5),
        plus system_warm_start (per-role champion seeding); guarded
        evaluation (retry transients, quarantine NaN/Inf)
journal append-only JSONL evaluation journal: crash-safe searches with
        deterministic (byte-identical) resume
faults  seeded fault injection (transient exceptions, NaN storms,
        infeasibility floods) wrapping any objective
"""

from . import space
from .ehvi import ehvi_2d, ehvi_3d, mc_ehvi
from .faults import FaultInjector, FaultSpec, FaultyObjective, \
    TransientEvalError
from .journal import (JournalError, JournalMismatch, SearchJournal,
                      objective_identity)
from .pareto import (IncrementalHV2D, IncrementalHVND, dominates,
                     hv_contributions_2d, hv_history, hypervolume,
                     hypervolume_2d, pareto_front, pareto_mask,
                     reference_point)
from .runner import (METHODS, DisaggObjective, DSEResult, Objective,
                     Observation, ServingObjective, SystemObjective,
                     run_mobo, run_motpe, run_nsga2, run_random,
                     serving_warm_start, shared_init, system_warm_start)
from .sobol import max_dims, sobol
from .space import (DesignSpace, GeneTie, PairedSpace, ServingDesign,
                    ServingSpace, SingleDeviceSpace, SystemSpace,
                    kv_quant_tie)
