"""Design-space exploration (paper Section 4.4).

space   Table 2 encoding <-> NPUConfig (+ vectorized validity/TDP tables)
        and the DesignSpace protocol: SingleDeviceSpace (17 genes) and
        PairedSpace (prefill/decode pair, 34 genes, KV-quant constraint)
sobol   quasi-random initialization (N_init = 20)
gp      GP surrogates (JAX, MLE-fit RBF-ARD, bucketed jit cache)
pareto  dominance / front / exact 2-D hypervolume (Eq. 7), sweep-based
ehvi    exact closed-form 2-D EHVI (Eq. 8) + quasi-MC oracle
runner  GP+EHVI MOBO + NSGA-II / MO-TPE / Random baselines (batched),
        generic over any DesignSpace; Objective (single device) and
        DisaggObjective (disaggregated pairs, Sections 5.3/5.5)
"""

from . import space
from .ehvi import ehvi_2d, mc_ehvi
from .pareto import (IncrementalHV2D, dominates, hv_contributions_2d,
                     hv_history, hypervolume_2d, pareto_front, pareto_mask,
                     reference_point)
from .runner import (METHODS, DisaggObjective, DSEResult, Objective,
                     Observation, run_mobo, run_motpe, run_nsga2, run_random,
                     shared_init)
from .sobol import sobol
from .space import DesignSpace, PairedSpace, SingleDeviceSpace
