"""Design-space exploration (paper Section 4.4).

space   Table 2 encoding <-> NPUConfig (+ vectorized validity/TDP tables)
sobol   quasi-random initialization (N_init = 20)
gp      GP surrogates (JAX, MLE-fit RBF-ARD, bucketed jit cache)
pareto  dominance / front / exact 2-D hypervolume (Eq. 7), sweep-based
ehvi    exact closed-form 2-D EHVI (Eq. 8) + quasi-MC oracle
runner  GP+EHVI MOBO + NSGA-II / MO-TPE / Random baselines (batched)
"""

from . import space
from .ehvi import ehvi_2d, mc_ehvi
from .pareto import (IncrementalHV2D, dominates, hv_contributions_2d,
                     hv_history, hypervolume_2d, pareto_front, pareto_mask,
                     reference_point)
from .runner import (METHODS, DSEResult, Objective, Observation,
                     run_mobo, run_motpe, run_nsga2, run_random, shared_init)
from .sobol import sobol
