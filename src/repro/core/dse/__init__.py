"""Design-space exploration (paper Section 4.4).

space   Table 2 encoding <-> NPUConfig
sobol   quasi-random initialization (N_init = 20)
gp      GP surrogates (JAX, MLE-fit RBF-ARD)
pareto  dominance / front / exact 2-D hypervolume (Eq. 7)
runner  GP+EHVI MOBO (Eq. 8) + NSGA-II / MO-TPE / Random baselines
"""

from . import space
from .pareto import (dominates, hv_contributions_2d, hypervolume_2d,
                     pareto_front, pareto_mask, reference_point)
from .runner import (METHODS, DSEResult, Objective, Observation,
                     run_mobo, run_motpe, run_nsga2, run_random, shared_init)
from .sobol import sobol
