"""Data Movement Model (paper Section 4.2).

Three software-controlled strategies, jointly searched by the DSE:

* Dataflow strategy   — GEMM execution order (WS / IS / OS), which operand
                        stays resident in the PE array.
* On-chip storage priority — which data class (weights / activations /
                        KV cache / equal) claims on-chip capacity first.
* Off-chip bandwidth priority — split of off-chip bandwidth between the
                        matrix and vector streams (75/25 fixed policy).
"""

from __future__ import annotations

import dataclasses
import enum

from .compute import Dataflow
from .hierarchy import MemoryHierarchy


class StoragePriority(enum.Enum):
    ACTIVATION = "Act"
    KV_CACHE = "KV"
    WEIGHT = "Weight"
    EQUAL = "Equal"


class BandwidthPriority(enum.Enum):
    MATRIX = "Matrix"
    VECTOR = "Vector"
    EQUAL = "Equal"


# Fixed allocation policy (Section 4.2): priority stream gets 75%.
_BW_SPLIT = {
    BandwidthPriority.MATRIX: (0.75, 0.25),
    BandwidthPriority.VECTOR: (0.25, 0.75),
    BandwidthPriority.EQUAL: (0.5, 0.5),
}

# Data classes, fixed order: [weights, activations, kv]
WEIGHTS, ACTS, KV = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class SoftwareStrategy:
    dataflow: Dataflow = Dataflow.WEIGHT_STATIONARY
    storage_priority: StoragePriority = StoragePriority.EQUAL
    bw_priority: BandwidthPriority = BandwidthPriority.EQUAL

    def bw_split(self) -> tuple[float, float]:
        """(matrix_share, vector_share) of off-chip bandwidth."""
        return _BW_SPLIT[self.bw_priority]

    def placement_order(self) -> list[int]:
        """Class placement order, highest priority first."""
        if self.storage_priority is StoragePriority.ACTIVATION:
            return [ACTS, KV, WEIGHTS]
        if self.storage_priority is StoragePriority.KV_CACHE:
            return [KV, ACTS, WEIGHTS]
        if self.storage_priority is StoragePriority.WEIGHT:
            return [WEIGHTS, ACTS, KV]
        return [ACTS, WEIGHTS, KV]   # Equal: round-robin-ish default order

    def describe(self) -> str:
        return (f"{self.dataflow.value}/{self.storage_priority.value}"
                f"/{self.bw_priority.value}")


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where each data class lives: fractions per hierarchy level."""

    # fractions[level][cls] of that class's total bytes resident at level
    fractions: tuple
    sizes_gb: tuple = (0.0, 0.0, 0.0)    # total per class [weights, acts, kv]

    def on_chip_bytes(self, cls: int, hierarchy: MemoryHierarchy) -> float:
        """Absolute bytes of class `cls` staged in on-chip levels."""
        from .memtech import MemKind
        tot = 0.0
        for lv, level in zip(self.fractions, hierarchy.levels):
            if level.tech.kind is MemKind.ON_CHIP:
                tot += lv[cls] * self.sizes_gb[cls] * 1e9
        return tot

    def resident_fraction_chain(self, cls: int) -> list[float]:
        """alpha_i chain for hierarchy.transfer_time_s: fraction of data
        arriving at boundary i that is resident at level i."""
        fr = [lv[cls] for lv in self.fractions]
        alphas = []
        remaining = 1.0
        for f in fr:
            if remaining <= 1e-12:
                alphas.append(1.0)
                continue
            alphas.append(min(1.0, f / remaining))
            remaining -= f
        if alphas:
            alphas[-1] = 1.0
        return alphas

    def on_chip_fraction(self, cls: int, hierarchy: MemoryHierarchy) -> float:
        from .memtech import MemKind
        tot = 0.0
        for lv, level in zip(self.fractions, hierarchy.levels):
            if level.tech.kind is MemKind.ON_CHIP:
                tot += lv[cls]
        return tot


def place_data(hierarchy: MemoryHierarchy, strategy: SoftwareStrategy,
               sizes_gb: list[float]) -> Placement:
    """Greedy placement of [weights, acts, kv] by the storage priority.

    With EQUAL priority, each class gets a proportional share of every
    level (no class monopolizes on-chip capacity).
    Raises ValueError if the hierarchy lacks capacity (caller treats the
    config as infeasible).
    """
    n = len(hierarchy.levels)
    total = sum(sizes_gb)
    if total > hierarchy.total_capacity_gb() + 1e-9:
        raise ValueError(
            f"workload needs {total:.1f} GB > capacity "
            f"{hierarchy.total_capacity_gb():.1f} GB ({hierarchy.describe()})"
        )
    if strategy.storage_priority is StoragePriority.EQUAL and total > 0:
        fractions = []
        remaining = list(sizes_gb)
        for level in hierarchy.levels:
            cap = level.capacity_gb
            rem_total = sum(remaining)
            row = [0.0, 0.0, 0.0]
            if rem_total > 1e-12:
                share = min(1.0, cap / rem_total)
                for c in range(3):
                    take = remaining[c] * share
                    row[c] = take / sizes_gb[c] if sizes_gb[c] > 0 else 0.0
                    remaining[c] -= take
            fractions.append(tuple(row))
        return Placement(fractions=tuple(fractions), sizes_gb=tuple(sizes_gb))

    placed = hierarchy.place_greedy(sizes_gb, strategy.placement_order())
    fractions = tuple(
        tuple((placed[lvl][c] / sizes_gb[c]) if sizes_gb[c] > 0 else 0.0
              for c in range(3))
        for lvl in range(n)
    )
    return Placement(fractions=fractions, sizes_gb=tuple(sizes_gb))


