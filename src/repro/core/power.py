"""Power models (paper Eq. 6 + parametric compute power).

Memory power (exact Eq. 6):
    P(C, BW_r, BW_w) = p_bg * C + e_read * BW_r + e_write * BW_w

Compute power: the paper fits parametric models to Synopsys DC / 7nm
OpenROAD synthesis samples of PLENA components.  Synthesis is unavailable
here, so we keep the same parametric *form* — static leakage linear in PE
count, dynamic energy linear in executed MACs / vector ops, plus a fixed
SoC base — with coefficients calibrated so the paper's reported operating
points hold (Base config ~= 300 W TDP / ~246 W average, Table 6).  This is
a documented deviation (DESIGN.md section 8.1); all paper claims we
reproduce are *relative* so the calibration preserves them.
"""

from __future__ import annotations

import dataclasses

from .compute import ComputeConfig
from .hierarchy import MemoryHierarchy

# ---------------------------------------------------------------------------
# Calibrated compute-power coefficients (7 nm class).
# e_mac: energy per INT8/FP8-class MAC including local register movement.
# ---------------------------------------------------------------------------
E_MAC_PJ = 0.35            # pJ per MAC (datapath + local SRAM traffic)
P_PE_STATIC_MW = 0.10      # mW leakage per PE
E_VECTOR_OP_PJ = 1.20      # pJ per vector lane-op
P_VECTOR_STATIC_MW = 0.30  # mW leakage per vector lane
P_BASE_W = 25.0            # NoC + controllers + PHY logic base


@dataclasses.dataclass(frozen=True)
class PowerBreakdown:
    compute_w: float
    memory_background_w: float
    memory_dynamic_w: float

    @property
    def total_w(self) -> float:
        return self.compute_w + self.memory_background_w + self.memory_dynamic_w


def memory_power_w(hierarchy: MemoryHierarchy,
                   read_gbps_per_level: list[float],
                   write_gbps_per_level: list[float]) -> tuple[float, float]:
    """Eq. 6 summed over levels -> (background_w, dynamic_w)."""
    bg = hierarchy.background_power_w()
    dyn = 0.0
    for level, br, bw in zip(hierarchy.levels, read_gbps_per_level,
                             write_gbps_per_level):
        dyn += level.tech.read_power_w(br) + level.tech.write_power_w(bw)
    return bg, dyn


def compute_power_w(cfg: ComputeConfig, mac_rate_per_s: float,
                    vector_rate_per_s: float = 0.0) -> float:
    """Parametric compute power at a sustained MAC/vector-op rate."""
    static = (P_PE_STATIC_MW * cfg.n_pe
              + P_VECTOR_STATIC_MW * cfg.vlen) * 1e-3
    dynamic = (E_MAC_PJ * mac_rate_per_s
               + E_VECTOR_OP_PJ * vector_rate_per_s) * 1e-12
    return P_BASE_W + static + dynamic


def compute_tdp_w(cfg: ComputeConfig) -> float:
    """Peak compute power (100% activity)."""
    return compute_power_w(cfg, cfg.peak_macs_per_s, cfg.peak_vector_ops_per_s)


def system_tdp_w(cfg: ComputeConfig, hierarchy: MemoryHierarchy) -> float:
    """Thermal design power: all units at peak simultaneously."""
    bg = hierarchy.background_power_w()
    dyn = 0.0
    for level in hierarchy.levels:
        # peak: full-bandwidth reads (reads dominate inference traffic; use
        # the more conservative of read/write energy)
        e = max(level.tech.e_read_pj_per_bit, level.tech.e_write_pj_per_bit)
        dyn += e * level.bandwidth_gbps * 8e9 * 1e-12
    return compute_tdp_w(cfg) + bg + dyn
