"""Structure-of-arrays, jit-compiled batch evaluator for the analytical
performance model (the DSE hot path).

`perfmodel.evaluate` walks one NPUConfig through placement, traffic,
transfer and energy arithmetic in pure Python — ~1 ms per design.  The
DSE scores 1e4-1e5 candidates per phase, so PR 1/2's vectorized search
engine is now bottlenecked on evaluation.  This module re-expresses the
whole model as parallel jnp arrays:

  * `NPUTable` — n designs as a structure of arrays: compute dims, a
    fixed-slot memory hierarchy (per-level capacity / bandwidth /
    latency / access energy, with `present` masks for absent slots),
    quantization byte widths and software-strategy codes.  Built either
    from gene batches (`dse.space.SingleDeviceSpace.decode_batch`, no
    NPUConfig construction) or from NPUConfig lists (`from_configs`).
  * `_phase_tables` — the workload side: per-batch-choice GEMM geometry
    (`LayerTraffic.gemm_geometry`), footprint/capacity-need tables per
    distinct QuantConfig, vector-op counts, lm-head traffic.  Computed
    once per (model, trace, phase) with the exact scalar footprint
    functions so the jitted feasibility masks match `InfeasibleConfig`
    raises bit-for-bit.
  * `evaluate_batch_arrays` — one `jax.jit` call scoring every design:
    max-batch capacity search, greedy/proportional placement,
    dataflow-aware traffic inflation, the recursive double-buffered
    transfer model, and the energy model, all vmapped over designs.
    Infeasibility is a mask, not an exception.

Fidelity contract: the scalar path (`perfmodel.evaluate`) is the
reference oracle.  The jitted program replicates its float64 arithmetic
op-for-op (same association order, same `ceil`/`floor` boundaries, same
1e-9/1e-12 tolerances), runs under `jax.experimental.enable_x64`, and is
property-tested against the oracle at rtol 1e-5 with identical
feasibility masks (tests/test_perfmodel_jit.py).  Absent hierarchy slots
are transparent: zero capacity/energy, pass-through bandwidth, zero
resident fraction.

Known oracle deviations (documented, sub-1e-12 relative):
  * residues below the scalar's 1e-12 placement cutoffs may route
    through an absent slot's forced alpha instead of the next level;
  * jnp may fuse/reassociate a handful of scalar adds.
Neither affects feasibility (capacity comparisons use inputs computed
by the scalar footprint functions themselves).

Diffusion-LM decode (the denoise-step table): DLLM decode has no
autoregressive step — every denoise step reprocesses the whole
sequence with PREFILL GEMM geometry, and a request's generation costs
``steps = max(1, gen_tokens * diffusion_steps_per_token)`` such
passes.  `_phase_tables` encodes this as a per-batch-choice table
whose capacity-need column keeps the `max_decode_batch` selection rule
(activations at q_len = 1) while the placement-size and `need_place`
columns hold the full-sequence state the `place_data` gate actually
checks (activations and KV at prompt + gen tokens), and whose traffic
geometry is the full-sequence PREFILL pass at the (optionally
`context_override`-shortened) denoised sequence length.  The jitted
program then scales the layer pass by the dynamic `steps` scalar and
drops the lm-head term (`head_mult = 0`), reproducing the scalar
`_evaluate_dllm_decode` op-for-op — so `supports()` is True for every
(family, phase) pair and no scalar routing fallback remains.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from .dataflow import StoragePriority
from .hierarchy import MemoryHierarchy
from .npu import NPUConfig
from .power import (E_MAC_PJ, E_VECTOR_OP_PJ, P_BASE_W, P_PE_STATIC_MW,
                    P_VECTOR_STATIC_MW)
from .quant.formats import QuantConfig
from .workload import (Family, ModelDims, Phase, Trace,
                       activation_footprint_gb, kv_footprint_gb,
                       layer_traffic_cached, lm_head_traffic_cached,
                       weight_footprint_gb)

# Default batch choice ladders (max_prefill_batch / max_decode_batch).
PREFILL_BATCH_CHOICES = (1, 2, 4, 8, 16, 32, 64, 128)
DECODE_BATCH_CHOICES = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)

# Greedy placement order per StoragePriority, as class indices into the
# (weights, acts, kv) sizes vector — mirrors SoftwareStrategy
# .placement_order().  Row order matches dse.space.STORAGE_CHOICES.
_STORAGE_LIST = (StoragePriority.ACTIVATION, StoragePriority.KV_CACHE,
                 StoragePriority.WEIGHT, StoragePriority.EQUAL)
_PLACEMENT_ORDERS = np.array([[1, 2, 0],    # ACTIVATION: acts, kv, weights
                              [2, 1, 0],    # KV_CACHE:   kv, acts, weights
                              [0, 1, 2],    # WEIGHT:     weights, acts, kv
                              [1, 0, 2]],   # EQUAL:      (greedy unused)
                             dtype=np.int32)
_EQUAL_IDX = 3

# Canonical dataflow codes (order of perfmodel._ALL_DATAFLOWS, which
# sets the tie-break of the attention-GEMM argmin): WS=0, IS=1, OS=2.
WS, IS, OS = 0, 1, 2

_BNECK_NAMES = ("compute", "matrix_mem", "vector_mem")


@dataclasses.dataclass(frozen=True)
class NPUTable:
    """n NPU configurations as a structure of numpy float64 arrays.

    The hierarchy is a fixed grid of `L` slots per design, innermost
    first; absent slots have `present=False` and all-zero parameters.
    Derived per-design quantities that the scalar model computes with
    plain Python floats (total capacity, background power, effective
    bandwidths, on-chip bandwidth) are precomputed here with the same
    sequential association order, so comparisons against scalar-derived
    thresholds are exact.
    """

    n: int
    # compute
    pe_rows: np.ndarray           # [n]
    pe_cols: np.ndarray
    vlen: np.ndarray
    clock_ghz: np.ndarray
    # hierarchy slots [n, L]
    lvl_cap_gb: np.ndarray
    lvl_bw_gbps: np.ndarray
    lvl_lat_s: np.ndarray
    lvl_er_pj: np.ndarray
    lvl_ew_pj: np.ndarray
    lvl_present: np.ndarray       # bool
    lvl_onchip: np.ndarray        # bool
    # derived (exact sequential order)
    total_cap_gb: np.ndarray      # [n]
    eff_bw_gbps: np.ndarray       # [n, L] clamped Eq. 2, inf at absent slots
    onchip_bw: np.ndarray         # [n] bytes/s denominator for scratch
    static_w: np.ndarray          # [n] background + idle compute power
    last_present: np.ndarray      # [n] index of outermost present slot
    er0_pj: np.ndarray            # [n] innermost PRESENT level's access
    ew0_pj: np.ndarray            # [n]   energies (scratch is charged here)
    # quantization
    w_bytes: np.ndarray
    a_bytes: np.ndarray
    kv_bytes: np.ndarray
    mx_rate: np.ndarray
    vec_rate: np.ndarray
    quant_idx: np.ndarray         # [n] index into `quants`
    quants: tuple                 # distinct QuantConfig objects
    # software strategy
    df_idx: np.ndarray            # [n] canonical WS/IS/OS code
    order: np.ndarray             # [n, 3] greedy placement class order
    is_equal: np.ndarray          # [n] bool, proportional placement
    bw_mx: np.ndarray             # [n] matrix-stream bandwidth share
    bw_vec: np.ndarray

    @property
    def n_slots(self) -> int:
        return self.lvl_cap_gb.shape[1]

    @classmethod
    def from_parts(cls, pe_rows, pe_cols, vlen, clock_ghz, lvl_rows,
                   lvl_onchip, quants, quant_idx, df_idx, storage_idx,
                   bw_mx, bw_vec) -> "NPUTable":
        """Assemble a table from raw per-design pieces.

        lvl_rows: [n, L, 6] `memtech.LEVEL_PARAM_FIELDS` rows (absent
        slots all-zero); lvl_onchip: [n, L] bool; quant_idx: index into
        `quants`; storage_idx: index into the STORAGE_CHOICES order.
        """
        lvl_rows = np.asarray(lvl_rows, dtype=np.float64)
        n, L = lvl_rows.shape[0], lvl_rows.shape[1]
        cap, bw, lat, er, ew, pbg = (lvl_rows[:, :, j] for j in range(6))
        present = bw > 0.0
        onchip = np.asarray(lvl_onchip, dtype=bool) & present
        # exact sequential sums, matching Python's left-to-right `sum`
        total_cap = np.zeros(n)
        bg = np.zeros(n)
        onchip_sum = np.zeros(n)
        for j in range(L):
            total_cap = total_cap + cap[:, j]
            bg = bg + pbg[:, j]
            onchip_sum = onchip_sum + np.where(onchip[:, j], bw[:, j], 0.0)
        # Eq. 2 effective bandwidths with the double-buffer clamp;
        # absent slots are transparent (inf time-wise, pass-through).
        eff = np.full((n, L), np.inf)
        deeper = np.zeros(n)
        for j in reversed(range(L)):
            raw = np.maximum(bw[:, j] - deeper, 0.5 * bw[:, j])
            eff[:, j] = np.where(present[:, j], raw, np.inf)
            deeper = np.where(present[:, j], raw, deeper)
        pe_rows = np.asarray(pe_rows, dtype=np.float64)
        pe_cols = np.asarray(pe_cols, dtype=np.float64)
        vlen = np.asarray(vlen, dtype=np.float64)
        n_pe = pe_rows * pe_cols
        static_w = bg + (P_BASE_W
                         + (P_PE_STATIC_MW * n_pe
                            + P_VECTOR_STATIC_MW * vlen) * 1e-3 + 0.0)
        idxs = np.arange(L)
        last_present = np.where(present, idxs, -1).max(axis=1)
        first_present = np.argmax(present, axis=1)
        rows_n = np.arange(n)
        w_b = np.array([q.weight_bytes for q in quants])
        a_b = np.array([q.activation_bytes for q in quants])
        kv_b = np.array([q.kv_bytes for q in quants])
        mxr = np.array([q.matrix_rate_scale for q in quants])
        vcr = np.array([q.vector_rate_scale for q in quants])
        quant_idx = np.asarray(quant_idx, dtype=np.int32)
        storage_idx = np.asarray(storage_idx, dtype=np.int64)
        return cls(
            n=n, pe_rows=pe_rows, pe_cols=pe_cols, vlen=vlen,
            clock_ghz=np.asarray(clock_ghz, dtype=np.float64),
            lvl_cap_gb=cap, lvl_bw_gbps=bw, lvl_lat_s=lat,
            lvl_er_pj=er, lvl_ew_pj=ew,
            lvl_present=present, lvl_onchip=onchip,
            total_cap_gb=total_cap, eff_bw_gbps=eff,
            onchip_bw=np.maximum(onchip_sum * 1e9, bw[:, 0] * 1e9),
            static_w=static_w,
            last_present=last_present.astype(np.int32),
            er0_pj=er[rows_n, first_present],
            ew0_pj=ew[rows_n, first_present],
            w_bytes=w_b[quant_idx], a_bytes=a_b[quant_idx],
            kv_bytes=kv_b[quant_idx], mx_rate=mxr[quant_idx],
            vec_rate=vcr[quant_idx],
            quant_idx=quant_idx, quants=tuple(quants),
            df_idx=np.asarray(df_idx, dtype=np.int32),
            order=_PLACEMENT_ORDERS[storage_idx],
            is_equal=(storage_idx == _EQUAL_IDX),
            bw_mx=np.asarray(bw_mx, dtype=np.float64),
            bw_vec=np.asarray(bw_vec, dtype=np.float64),
        )

    @classmethod
    def from_configs(cls, npus: Sequence[NPUConfig]) -> "NPUTable":
        """SoA view of arbitrary NPUConfig objects (hand-built designs,
        Table 6 configurations, decoded DSE points).

        The slot count is padded to the canonical 6 (absent slots are
        transparent) so every typical batch shares one jitted program
        shape — taller hand-built hierarchies widen it."""
        from .compute import Dataflow
        n = len(npus)
        L = max([6] + [len(c.hierarchy.levels) for c in npus])
        lvl_rows = np.zeros((n, L, 6))
        onchip = np.zeros((n, L), dtype=bool)
        quants: list = []
        qkey: dict = {}
        quant_idx = np.zeros(n, dtype=np.int32)
        df_map = {Dataflow.WEIGHT_STATIONARY: WS,
                  Dataflow.INPUT_STATIONARY: IS,
                  Dataflow.OUTPUT_STATIONARY: OS}
        df_idx = np.zeros(n, dtype=np.int32)
        st_idx = np.zeros(n, dtype=np.int64)
        bw_mx = np.zeros(n)
        bw_vec = np.zeros(n)
        pe_r = np.zeros(n)
        pe_c = np.zeros(n)
        vlen = np.zeros(n)
        clock = np.zeros(n)
        for i, c in enumerate(npus):
            for j, (row, is_on) in enumerate(
                    c.hierarchy.level_param_rows()):
                lvl_rows[i, j] = row
                onchip[i, j] = is_on
            q = c.quant
            k = (q.weight, q.activation, q.kv_cache)
            if k not in qkey:
                qkey[k] = len(quants)
                quants.append(q)
            quant_idx[i] = qkey[k]
            df_idx[i] = df_map[c.strategy.dataflow]
            st_idx[i] = _STORAGE_LIST.index(c.strategy.storage_priority)
            bw_mx[i], bw_vec[i] = c.strategy.bw_split()
            pe_r[i], pe_c[i] = c.compute.pe_rows, c.compute.pe_cols
            vlen[i] = c.compute.vlen
            clock[i] = c.compute.clock_ghz
        return cls.from_parts(pe_r, pe_c, vlen, clock, lvl_rows, onchip,
                              quants, quant_idx, df_idx, st_idx,
                              bw_mx, bw_vec)


# ---------------------------------------------------------------------------
# Workload tables: per-(model, trace, phase) constants shared by all
# designs, expanded over the batch-choice ladder and the distinct
# QuantConfigs present in the batch.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _phase_tables(dims: ModelDims, trace: Trace, phase: Phase,
                  batch: Optional[int], quants: tuple,
                  context_override: Optional[int] = None) -> dict:
    """Numpy tables: capacity-need / placement-size per (quant, batch
    choice), GEMM geometry per batch choice, byte terms per quant.

    All footprint entries come from the scalar model's own lru-cached
    functions, so the jitted feasibility comparison `need <= capacity`
    reproduces `max_*_batch` / `place_data` decisions exactly.

    `context_override` (DECODE only) moves the per-step traffic context
    off the trace average, mirroring the scalar
    `evaluate_decode(context_override=...)`: capacity stays at the full
    context (the device must still hold the whole conversation's KV),
    only the streamed KV length changes.  For diffusion-LM decode it
    shortens the sequence each denoise step reprocesses instead.

    Diffusion-LM decode is the one (family, phase) pair where the
    batch-selection need and the placement state diverge: the scalar
    `max_decode_batch` sizes activations at q_len = 1, but
    `_evaluate_dllm_decode` then places (and `place_data` gates) the
    full-sequence activations/KV.  The tables therefore carry a
    separate `need_place` column (the `place_data` sum, + 1e-9 slack in
    the program) alongside the selection `need`, PREFILL-geometry
    traffic at the denoised sequence length, the denoise-step count
    `steps`, and `head_mult` = 0 (no lm-head pass per denoise step).
    """
    dllm_decode = dims.family is Family.DLLM and phase is Phase.DECODE
    if phase is Phase.PREFILL:
        choices = (batch,) if batch is not None else PREFILL_BATCH_CHOICES
        ctx_cap = trace.prompt_tokens          # capacity at prompt KV
        q_cap = trace.prompt_tokens            # activations at full prompt
        q_sel = q_cap                          # selection == placement size
        ctx_traffic = trace.prompt_tokens
        traffic_phase = Phase.PREFILL
        n_layers_mult = dims.n_layers + dims.n_encoder_layers
    elif dllm_decode:
        choices = (batch,) if batch is not None else DECODE_BATCH_CHOICES
        S = trace.prompt_tokens + trace.gen_tokens
        ctx_cap = S                # capacity/placement at the full context
        q_cap = S                  # ... incl. full-sequence activations
        q_sel = 1                  # but max_decode_batch selects at q=1
        ctx_traffic = (context_override if context_override is not None
                       else S)     # sequence reprocessed per denoise step
        traffic_phase = Phase.PREFILL      # full-sequence denoise pass
        n_layers_mult = dims.n_layers
    else:
        choices = (batch,) if batch is not None else DECODE_BATCH_CHOICES
        ctx_cap = trace.prompt_tokens + trace.gen_tokens   # full-context KV
        q_cap = 1
        q_sel = 1
        ctx_traffic = (context_override if context_override is not None
                       else trace.prompt_tokens + trace.gen_tokens // 2)
        traffic_phase = Phase.DECODE
        n_layers_mult = dims.n_layers
    U, NB = len(quants), len(choices)
    need = np.zeros((U, NB))
    need_place = np.zeros((U, NB))
    sizes = np.zeros((U, NB, 3))
    kvw = np.zeros((U, NB))
    actx = np.zeros((U, NB))
    actx_h = np.zeros((U, NB))
    gm_num = gm_cls = vec_el = None
    hd_num = hd_cls = vec_h = None
    for ui, q in enumerate(quants):
        w = weight_footprint_gb(dims, q)
        for bi, b in enumerate(choices):
            kv = kv_footprint_gb(dims, b, ctx_cap, q)
            act = activation_footprint_gb(dims, b, q_cap, q)
            act_sel = (act if q_sel == q_cap
                       else activation_footprint_gb(dims, b, q_sel, q))
            if batch is None:
                need[ui, bi] = w + kv + act_sel    # max_*_batch order
            else:
                # explicit batch: only place_data's sum([w, act, kv])
                # + 1e-9 slack gate applies
                need[ui, bi] = (0.0 + w + act) + kv
            # the place_data gate on the chosen batch's placement state
            # (sum([w, act, kv]) association, 1e-9 slack in the program)
            need_place[ui, bi] = (0.0 + w + act) + kv
            sizes[ui, bi] = (w, act, kv)
            tr = layer_traffic_cached(dims, traffic_phase, b, ctx_traffic,
                                      q)
            kvw[ui, bi] = tr.kv_write_bytes
            actx[ui, bi] = tr.act_extra_bytes
            hd = lm_head_traffic_cached(dims, b, 1, q)
            actx_h[ui, bi] = hd.act_extra_bytes
            if ui == 0:
                num, cls_ = tr.gemm_geometry()
                hnum, hcls = hd.gemm_geometry()
                if bi == 0:
                    G, GH = num.shape[0], hnum.shape[0]
                    gm_num = np.zeros((NB, G, 5))
                    hd_num = np.zeros((NB, GH, 5))
                    gm_cls, hd_cls = cls_, hcls
                    vec_el = np.zeros(NB)
                    vec_h = np.zeros(NB)
                gm_num[bi], hd_num[bi] = num, hnum
                vec_el[bi] = tr.vector_elems
                vec_h[bi] = hd.vector_elems
            else:                   # geometry must be quant-independent
                num, cls_ = tr.gemm_geometry()
                assert np.array_equal(num, gm_num[bi]) \
                    and np.array_equal(cls_, gm_cls), \
                    "GEMM geometry unexpectedly depends on quantization"
    return {
        "choices": np.asarray(choices, dtype=np.float64),
        "need": need, "need_place": need_place,
        "sizes": sizes, "kvw": kvw, "actx": actx,
        "gm_num": gm_num, "gm_cls": gm_cls, "vec_el": vec_el,
        "hd_num": hd_num, "hd_cls": hd_cls, "vec_h": vec_h,
        "actx_h": actx_h,
        "n_layers_mult": float(n_layers_mult),
        "token_mult": (float(trace.prompt_tokens)
                       if phase is Phase.PREFILL
                       else float(trace.gen_tokens) if dllm_decode
                       else 1.0),
        # denoise passes per request; the whole layer term scales by it
        "steps": (max(1.0, trace.gen_tokens
                      * dims.diffusion_steps_per_token)
                  if dllm_decode else 1.0),
        # DLLM decode has NO lm-head term at all (the scalar
        # _evaluate_dllm_decode never computes one): zero it out
        "head_mult": 0.0 if dllm_decode else 1.0,
        "tol": 1e-9 if batch is not None else 0.0,
    }


# ---------------------------------------------------------------------------
# The jitted program.  Built once per array-shape signature
# (slots, batch choices, gemm counts) and cached; model/trace constants
# enter as dynamic scalars so switching workloads does not recompile.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _build_program(L: int, NB: int, G: int, GH: int):

    def one(d, t, tol, token_mult, n_mult, steps, head_mult):
        # quant-dependent workload rows arrive pre-gathered per design
        # (numpy-side), so the distinct-quant count never enters the
        # traced shapes — one program per (L, NB, G, GH) signature.
        cap_total = d["total_cap"]
        ok = d["need"] <= cap_total + tol                  # [NB]
        b_idx = jnp.maximum(jnp.max(jnp.where(ok, jnp.arange(NB), -1)), 0)
        # selection picks the batch, place_data gates its placement
        # state (diverges from the selection need only for DLLM decode,
        # whose batch rule sizes activations at q=1 but places them at
        # the full sequence — mirrors the scalar InfeasibleConfig path)
        feasible = jnp.any(ok) \
            & (d["need_place"][b_idx] <= cap_total + 1e-9)
        sizes3 = d["sizes"][b_idx]                         # (w, act, kv) GB
        cap = d["cap"]                                     # [L]

        # ---- placement (dataflow.place_data) ----------------------------
        def greedy():
            placed = jnp.zeros((L, 3))
            free = cap
            for j in range(3):
                cls_oh = jax.nn.one_hot(d["order"][j], 3,
                                        dtype=jnp.float64)
                rem = jnp.sum(sizes3 * cls_oh)
                active = jnp.asarray(True)
                for lv in range(L):
                    take = jnp.where(active,
                                     jnp.minimum(rem, free[lv]), 0.0)
                    placed = placed.at[lv].add(cls_oh * take)
                    free = free.at[lv].add(-take)
                    rem = rem - take
                    active = active & (rem > 1e-12)
            return placed

        def equal():
            placed = jnp.zeros((L, 3))
            remaining = sizes3
            for lv in range(L):
                rem_total = (remaining[0] + remaining[1]) + remaining[2]
                go = rem_total > 1e-12
                share = jnp.minimum(
                    1.0, cap[lv] / jnp.where(go, rem_total, 1.0))
                take = jnp.where(go, remaining * share, 0.0)
                placed = placed.at[lv].set(take)
                remaining = remaining - take
            return placed

        placed = jnp.where(d["is_equal"], equal(), greedy())
        pos = sizes3 > 0
        frac = jnp.where(pos[None, :],
                         placed / jnp.where(pos, sizes3, 1.0)[None, :], 0.0)

        # ---- on-chip staging bytes (Placement.on_chip_bytes) ------------
        stage3 = jnp.zeros(3)
        for lv in range(L):
            stage3 = stage3 + jnp.where(
                d["onchip"][lv], frac[lv] * sizes3 * 1e9, 0.0)
        n_pe = d["pe_r"] * d["pe_c"]
        min_stage = n_pe * d["a_bytes"]
        # class order: WEIGHT, ACT, KV, SCRATCH
        stage4 = jnp.stack([stage3[0], stage3[1], stage3[2],
                            jnp.maximum(stage3[1], min_stage)])
        bytes4 = jnp.stack([d["w_bytes"], d["a_bytes"], d["kv_bytes"],
                            d["a_bytes"]])

        # ---- resident-fraction chains (alpha_i per class) ---------------
        def alpha_chain(fr):
            alphas = []
            remaining = 1.0
            for lv in range(L):
                a = jnp.where(
                    remaining <= 1e-12, 1.0,
                    jnp.minimum(1.0, fr[lv] / jnp.where(
                        remaining <= 1e-12, 1.0, remaining)))
                a = jnp.where(d["present"][lv], a, 0.0)
                alphas.append(a)
                remaining = remaining - fr[lv]
            arr = jnp.stack(alphas)
            return jnp.where(jnp.arange(L) == d["last_present"], 1.0, arr)

        alphas3 = [alpha_chain(frac[:, c]) for c in range(3)]

        # ---- recursive double-buffered transfer (hierarchy Eqs. 3-5) ----
        def transfer(nbytes, alphas, share):
            xs = []
            x = nbytes
            for lv in range(L):
                xs.append(x)
                x = (1.0 - alphas[lv]) * x
            T = jnp.float64(-jnp.inf)
            for lv in reversed(range(L)):
                eff = d["eff"][lv] * share
                t_here = d["lat"][lv] + jnp.where(
                    xs[lv] > 0, xs[lv] / (eff * 1e9), 0.0)
                Ti = jnp.where(xs[lv] <= 0, d["lat"][lv],
                               jnp.maximum(t_here, T))
                T = jnp.where(d["present"][lv], Ti, T)
            return T

        # ---- one layer pass (perfmodel._layer_time_and_energy) ----------
        fill = d["pe_r"] + d["pe_c"]
        r, c = d["pe_r"], d["pe_c"]

        def gemm_terms(m, k, n_, count):
            """Per-dataflow (cycles, a_mult, b_mult) triples, stacked
            WS/IS/OS (the perfmodel._ALL_DATAFLOWS order)."""
            zero = (jnp.minimum(jnp.minimum(m, k), n_) <= 0) | (count <= 0)
            cycles = []
            for dfk in (WS, IS, OS):
                rows = k if dfk == WS else m
                pack = jnp.maximum(1.0, jnp.minimum(
                    jnp.floor(count),
                    jnp.floor(r / jnp.maximum(1.0, rows))))
                rows_used = rows * pack
                eff_count = jnp.ceil(count / pack)
                if dfk == WS:
                    tiles = jnp.ceil(rows_used / r) * jnp.ceil(n_ / c)
                    stream = m
                elif dfk == IS:
                    tiles = jnp.ceil(rows_used / r) * jnp.ceil(k / c)
                    stream = n_
                else:
                    tiles = jnp.ceil(rows_used / r) * jnp.ceil(n_ / c)
                    stream = k
                cyc = (tiles * stream + fill) * eff_count
                cycles.append(jnp.where(zero, 0.0, cyc))
            return jnp.stack(cycles), zero

        def gemm_mults(dfk, m, k, n_, a_b, b_b, o_b, st_a, st_b, st_o):
            a_cap = jnp.ceil(n_ / c)
            b_cap = jnp.ceil(m / r)
            if dfk == WS:
                stage = jnp.maximum(st_b, r * c * b_b)
                a_m = jnp.minimum(a_cap, jnp.ceil(k * n_ * b_b / stage))
                return jnp.maximum(1.0, a_m), jnp.float64(1.0)
            if dfk == IS:
                stage = jnp.maximum(st_a, r * c * a_b)
                b_m = jnp.minimum(b_cap, jnp.ceil(m * k * a_b / stage))
                return jnp.float64(1.0), jnp.maximum(1.0, b_m)
            stage = jnp.maximum(st_o, r * c * o_b)
            tt = jnp.sqrt(stage / jnp.maximum(o_b, 1e-9))
            a_m = jnp.minimum(a_cap, jnp.ceil(n_ / jnp.maximum(tt, c)))
            b_m = jnp.minimum(b_cap, jnp.ceil(m / jnp.maximum(tt, r)))
            return jnp.maximum(1.0, a_m), jnp.maximum(1.0, b_m)

        def layer_pass(gm_num, gm_cls, n_gemms, vec_elems, act_extra,
                       kv_write, cal_eff, cal_set):
            out4 = jnp.zeros(4)
            t_gemm = 0.0
            macs = 0.0
            for g in range(n_gemms):
                m, k, n_, count, chunks = (gm_num[b_idx, g, j]
                                           for j in range(5))
                acls, bcls, ocls = (gm_cls[g, j] for j in range(3))
                cyc3, zero = gemm_terms(m, k, n_, count)
                # dataflow: strategy for weight-bearing GEMMs, best-of-3
                # for attention-internal ones (argmin = first minimum,
                # matching min() over _ALL_DATAFLOWS).  The argmin runs
                # on UNCALIBRATED cycles, like the scalar oracle's
                # `_gemm_dataflow`: per-class factors scale every
                # candidate dataflow equally.
                df_g = jnp.where(bcls == 0, d["df_idx"],
                                 jnp.argmin(cyc3).astype(jnp.int32))
                # calibration (cycles * eff + setup); the zero gate
                # mirrors the scalar early return — a degenerate GEMM
                # costs nothing, per-pass setup included
                cyc = jnp.where(zero, 0.0,
                                cyc3[df_g] * cal_eff[b_idx, g]
                                + cal_set[b_idx, g])
                sec = cyc / (d["clock"] * 1e9)
                t_gemm = t_gemm + sec
                macs = macs + m * k * n_ * count
                a_b = bytes4[acls]
                b_b = bytes4[bcls]
                o_b = bytes4[ocls]
                mults = [gemm_mults(dfk, m, k, n_, a_b, b_b, o_b,
                                    stage4[acls], stage4[bcls],
                                    stage4[ocls]) for dfk in (WS, IS, OS)]
                am3 = jnp.stack([mm[0] for mm in mults])
                bm3 = jnp.stack([mm[1] for mm in mults])
                a_mult = jnp.where(zero, 1.0, am3[df_g])
                b_mult = jnp.where(zero, 1.0, bm3[df_g])
                a_once = m * k * count * a_b
                b_once = k * n_ * count * b_b
                a_panel = m * k * a_b / jnp.maximum(1.0, chunks)
                b_panel = k * n_ * b_b

                def add(out, cls_i, first, reread, panel):
                    oh = jax.nn.one_hot(cls_i, 4, dtype=jnp.float64)
                    out = out + oh * first
                    to_scr = (cls_i == 3) | (
                        (cls_i == 1) & (panel <= stage4[1] + 1e-9))
                    oh_r = jnp.where(to_scr,
                                     jax.nn.one_hot(3, 4,
                                                    dtype=jnp.float64), oh)
                    return out + jnp.where(reread > 0, oh_r * reread, 0.0)

                out4 = add(out4, acls, a_once, a_once * (a_mult - 1.0),
                           a_panel)
                out4 = add(out4, bcls, b_once, b_once * (b_mult - 1.0),
                           b_panel)
                out4 = out4 + jax.nn.one_hot(ocls, 4, dtype=jnp.float64) \
                    * (m * n_ * count * o_b)
            out4 = out4 + jnp.array([0.0, 1.0, 0.0, 0.0]) * act_extra
            out4 = out4 + jnp.array([0.0, 0.0, 1.0, 0.0]) * kv_write

            # compute time: matrix & vector engines in parallel
            t_gemm = t_gemm / d["mx_rate"]
            t_vec = jnp.where(
                vec_elems > 0, jnp.ceil(vec_elems / d["vlen"]), 0.0) \
                / (d["clock"] * 1e9) / d["vec_rate"]
            t_compute = jnp.maximum(t_gemm, t_vec)

            # per-stream transfer time
            t_w = jnp.where(out4[0] > 0,
                            transfer(out4[0], alphas3[0], d["bw_mx"]), 0.0)
            t_kv = jnp.where(out4[2] > 0,
                             transfer(out4[2], alphas3[2], d["bw_mx"]), 0.0)
            t_a = jnp.where(out4[1] > 0,
                            transfer(out4[1], alphas3[1], d["bw_vec"]), 0.0)
            t_scr = jnp.where(out4[3] > 0, out4[3] / d["onchip_bw"], 0.0)
            t_matrix = t_w + t_kv
            t_vecmem = t_a + t_scr
            t_layer = jnp.maximum(jnp.maximum(t_compute, t_matrix),
                                  t_vecmem)
            bneck = jnp.where(
                t_layer == t_compute, 0,
                jnp.where(t_layer == t_matrix, 1, 2)).astype(jnp.int32)

            # energy
            e_comp = (E_MAC_PJ * macs + E_VECTOR_OP_PJ * vec_elems) * 1e-12
            e_mem = 0.0
            wr3 = jnp.stack([
                jnp.float64(0.0), jnp.float64(0.5),
                jnp.where(out4[2] > 0,
                          jnp.minimum(1.0, kv_write / jnp.where(
                              out4[2] > 0, out4[2], 1.0)), 0.0)])
            for cls_i in range(3):
                nb = out4[cls_i]
                wr = wr3[cls_i]
                for lv in range(L):
                    bits = nb * frac[lv, cls_i] * 8.0
                    e_mem = e_mem + jnp.where(
                        nb > 0,
                        d["er"][lv] * bits * (1 - wr) * 1e-12, 0.0)
                    e_mem = e_mem + jnp.where(
                        nb > 0, d["ew"][lv] * bits * wr * 1e-12, 0.0)
            e_mem = e_mem + jnp.where(
                out4[3] > 0,
                (d["er0"] + d["ew0"]) / 2.0 * out4[3] * 8.0 * 1e-12,
                0.0)
            e_static = d["static_w"] * t_layer
            e_layer = e_comp + e_mem + e_static
            bd = (t_compute, t_matrix, t_vecmem, t_scr,
                  out4[0], out4[1], out4[2], out4[3])
            return t_layer, e_layer, bneck, bd

        t_layer, e_layer, bneck, bd = layer_pass(
            t["gm_num"], t["gm_cls"], G, t["vec_el"][b_idx],
            d["actx"][b_idx], d["kvw"][b_idx],
            t["cal_gm_eff"], t["cal_gm_set"])
        t_head, e_head, _, _ = layer_pass(
            t["hd_num"], t["hd_cls"], GH, t["vec_h"][b_idx],
            d["actx_h"][b_idx], 0.0,
            t["cal_hd_eff"], t["cal_hd_set"])

        # `steps` (denoise passes per request) multiplies the layer term
        # AFTER the n_mult product — the scalar's (t_layer * n_layers)
        # * steps association — and the head term is gated by head_mult
        # (0 for DLLM decode: no lm-head pass per denoise step).
        latency = t_layer * n_mult * steps + t_head * head_mult
        energy = e_layer * n_mult * steps + e_head * head_mult
        batch_val = t["choices"][b_idx]
        tokens = batch_val * token_mult
        tps = jnp.where(latency > 0, tokens / latency, 0.0)
        power = jnp.where(latency > 0, energy / latency, 0.0)
        ept = jnp.where(tokens > 0, energy / tokens, 0.0)
        return {
            "feasible": feasible,
            "batch": batch_val,
            "latency_s": latency,
            "tokens": tokens,
            "throughput_tps": tps,
            "avg_power_w": power,
            "energy_per_token_j": ept,
            "compute_time_s": bd[0] * n_mult * steps,
            "memory_time_s": jnp.maximum(bd[1], bd[2]) * n_mult * steps,
            "bottleneck": bneck,
            "compute_s": bd[0], "matrix_s": bd[1], "vector_s": bd[2],
            "scratch_s": bd[3], "bytes_weights": bd[4],
            "bytes_acts": bd[5], "bytes_kv": bd[6], "bytes_scratch": bd[7],
        }

    def run(d, t, tol, token_mult, n_mult, steps, head_mult):
        return jax.vmap(lambda di: one(di, t, tol, token_mult, n_mult,
                                       steps, head_mult))(d)

    return jax.jit(run)


def _design_pytree(table: NPUTable) -> dict:
    return {
        "pe_r": table.pe_rows, "pe_c": table.pe_cols,
        "vlen": table.vlen, "clock": table.clock_ghz,
        "cap": table.lvl_cap_gb, "lat": table.lvl_lat_s,
        "er": table.lvl_er_pj, "ew": table.lvl_ew_pj,
        "present": table.lvl_present, "onchip": table.lvl_onchip,
        "eff": table.eff_bw_gbps, "total_cap": table.total_cap_gb,
        "onchip_bw": table.onchip_bw, "static_w": table.static_w,
        "last_present": table.last_present,
        "er0": table.er0_pj, "ew0": table.ew0_pj,
        "w_bytes": table.w_bytes, "a_bytes": table.a_bytes,
        "kv_bytes": table.kv_bytes, "mx_rate": table.mx_rate,
        "vec_rate": table.vec_rate,
        "df_idx": table.df_idx, "order": table.order,
        "is_equal": table.is_equal,
        "bw_mx": table.bw_mx, "bw_vec": table.bw_vec,
    }


def evaluate_batch_arrays(table: NPUTable, dims: ModelDims, trace: Trace,
                          phase: Phase,
                          batch: Optional[int] = None,
                          context_override: Optional[int] = None,
                          calibration=None) -> dict:
    """Score every design in `table` on (dims, trace, phase) in one
    jitted call.  Returns numpy arrays keyed like PhaseResult fields
    plus `feasible` (bool mask) and the mem-breakdown terms.

    Runs in float64 under `jax.experimental.enable_x64` regardless of
    the session default, so results track the scalar oracle.

    `calibration` (core.calibration.CalibrationTable, default None =
    identity) enters as per-batch-choice, per-GEMM (efficiency, setup)
    arrays gathered numpy-side and indexed by the dynamic batch choice
    inside the program — the table's values are runtime data, so
    switching tables never recompiles, and the identity arrays
    reproduce the uncalibrated arithmetic bit-for-bit.
    """
    from .calibration import calibration_arrays
    t = _phase_tables(dims, trace, phase, batch, table.quants,
                      context_override)
    prog = _build_program(table.n_slots, len(t["choices"]),
                          t["gm_num"].shape[1], t["hd_num"].shape[1])
    tables = {k: t[k] for k in ("choices", "gm_num", "gm_cls", "vec_el",
                                "hd_num", "hd_cls", "vec_h")}
    (tables["cal_gm_eff"],
     tables["cal_gm_set"]) = calibration_arrays(calibration, t["gm_num"],
                                                t["gm_cls"])
    (tables["cal_hd_eff"],
     tables["cal_hd_set"]) = calibration_arrays(calibration, t["hd_num"],
                                                t["hd_cls"])
    d = _design_pytree(table)
    uq = table.quant_idx
    d["need"] = t["need"][uq]           # [n, NB]
    d["need_place"] = t["need_place"][uq]
    d["sizes"] = t["sizes"][uq]         # [n, NB, 3]
    d["kvw"] = t["kvw"][uq]
    d["actx"] = t["actx"][uq]
    d["actx_h"] = t["actx_h"][uq]
    # bucket-pad the design axis to a power of two (replicating row 0)
    # so varying DSE batch sizes reuse one compiled program per bucket;
    # the 64 floor folds every small searcher batch (inits, NSGA-II
    # child generations, TPE proposals) into a single compilation
    n = table.n
    bucket = 64
    while bucket < n:
        bucket *= 2
    if bucket != n:
        pad_idx = np.concatenate([np.arange(n),
                                  np.zeros(bucket - n, dtype=np.int64)])
        d = {k: np.asarray(v)[pad_idx] for k, v in d.items()}
    with enable_x64():
        out = prog(d, tables, t["tol"], t["token_mult"],
                   t["n_layers_mult"], t["steps"], t["head_mult"])
        out = {k: np.asarray(v)[:n] for k, v in out.items()}
    return out


def results_from_arrays(arrays: dict, phase: Phase) -> list:
    """Materialize per-design PhaseResult objects (None when the
    feasibility mask rejected the design) from `evaluate_batch_arrays`
    output — the object-API compatibility layer over the SoA core."""
    from .perfmodel import PhaseResult
    out = []
    feas = arrays["feasible"]
    for i in range(len(feas)):
        if not feas[i]:
            out.append(None)
            continue
        bd = {"compute_s": float(arrays["compute_s"][i]),
              "matrix_s": float(arrays["matrix_s"][i]),
              "vector_s": float(arrays["vector_s"][i]),
              "scratch_s": float(arrays["scratch_s"][i]),
              "bytes_weights": float(arrays["bytes_weights"][i]),
              "bytes_acts": float(arrays["bytes_acts"][i]),
              "bytes_kv": float(arrays["bytes_kv"][i]),
              "bytes_scratch": float(arrays["bytes_scratch"][i])}
        out.append(PhaseResult(
            phase=phase,
            batch=int(arrays["batch"][i]),
            latency_s=float(arrays["latency_s"][i]),
            tokens=float(arrays["tokens"][i]),
            throughput_tps=float(arrays["throughput_tps"][i]),
            avg_power_w=float(arrays["avg_power_w"][i]),
            energy_per_token_j=float(arrays["energy_per_token_j"][i]),
            compute_time_s=float(arrays["compute_time_s"][i]),
            memory_time_s=float(arrays["memory_time_s"][i]),
            bottleneck=_BNECK_NAMES[int(arrays["bottleneck"][i])],
            mem_breakdown=bd,
        ))
    return out


def supports(dims: ModelDims, phase: Phase) -> bool:
    """Whether the jitted path covers this (family, phase).

    Always True: the denoise-step tables folded the last holdout
    (diffusion-LM decode) into the jitted program.  Kept as the
    routing hook so a future family with genuinely table-free
    aggregation has a place to opt out — and so callers can assert
    full coverage."""
    del dims, phase
    return True


def evaluate_batch_table(table: NPUTable, dims: ModelDims, trace: Trace,
                         phase: Phase,
                         batch: Optional[int] = None,
                         context_override: Optional[int] = None,
                         calibration=None) -> list:
    """`evaluate_batch_arrays` + PhaseResult materialization."""
    if table.n == 0:
        return []
    return results_from_arrays(
        evaluate_batch_arrays(table, dims, trace, phase, batch=batch,
                              context_override=context_override,
                              calibration=calibration),
        phase)
