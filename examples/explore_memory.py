"""Full MemExplorer exploration: the four DSE methods on one workload
with a shared Sobol init — the paper's Fig. 6 experiment, interactive.
(For the disaggregated prefill/decode *pair* search on `PairedSpace`,
see examples/explore_disagg.py.)

    PYTHONPATH=src python examples/explore_memory.py [--evals 60]
"""

import argparse

import numpy as np

from repro.configs.paper_models import QWEN3_32B
from repro.core.dse import METHODS, Objective, shared_init
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=60)
    ap.add_argument("--phase", choices=["prefill", "decode"],
                    default="decode")
    args = ap.parse_args()

    phase = Phase.PREFILL if args.phase == "prefill" else Phase.DECODE
    obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, phase,
                    tdp_limit_w=700.0)
    init = shared_init(obj, 20, seed=0)
    print(f"== {args.phase} DSE on Qwen3-32B/OSWorld, {args.evals} evals, "
          f"700 W TDP, shared 20-point Sobol init ==")

    results = {}
    for name, runner in METHODS.items():
        res = runner(obj, n_total=args.evals, seed=0, init=list(init))
        results[name] = res
    all_f = np.vstack([r.feasible_f() for r in results.values()
                       if len(r.feasible_f())])
    ref = all_f.min(axis=0) - 1.0
    print(f"\n{'method':10s} {'final HV':>12s} {'pareto':>7s} "
          f"{'best TPS':>10s}")
    for name, res in results.items():
        hv = res.hv_history(ref)[-1]
        pareto = res.pareto()
        best_tps = max((o.f[0] for o in pareto), default=0.0)
        print(f"{name:10s} {hv:12.4e} {len(pareto):7d} {best_tps:10.1f}")
    winner = max(results, key=lambda n: results[n].hv_history(ref)[-1])
    print(f"\nwinner: {winner} (paper Fig. 6: GP+EHVI)")
    print("\nbest designs on the winner's frontier:")
    for o in sorted(results[winner].pareto(), key=lambda o: -o.f[0])[:4]:
        print(f"  TPS={o.f[0]:9.1f} P={-o.f[1]:6.1f}W  {o.npu.describe()}")


if __name__ == "__main__":
    main()
