"""Train a ~small LM for a few hundred steps with the full runtime:
AdamW + remat + deterministic step-indexed data + periodic checkpoints +
fault-tolerant supervisor (one injected failure) + elastic restore.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import DataConfig, batch_for_step
from repro.runtime.fault import (RetryPolicy, StepFailure, StragglerDetector,
                                 TrainSupervisor)
from repro.runtime.optim import AdamWConfig, init_opt_state
from repro.runtime.steps import make_train_step, model_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced(n_layers=4, d_model=128, vocab=1024)
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"== training reduced {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps ==")

    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3,
                                                       warmup_steps=20)))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16, seed=0)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    state = {"params": params, "opt": opt}
    fail_at = {"step": args.steps // 2, "armed": True}

    def save(step):
        path = ckpt.save(ckpt_dir, step, state)
        print(f"  [ckpt] step {step} -> {path}")

    sup = TrainSupervisor(
        retry=RetryPolicy(max_retries=2, backoff_s=0.01),
        straggler=StragglerDetector(window=32),
        checkpoint_every=50, checkpoint_fn=save)

    losses = []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, i).items()}

        def one_step(b):
            if fail_at["armed"] and i == fail_at["step"]:
                fail_at["armed"] = False
                raise StepFailure("injected transient failure")
            loss, p2, o2, m = step_fn(state["params"], state["opt"], b)
            state["params"], state["opt"] = p2, o2
            return float(loss)

        loss = sup.run_step(i, one_step, batch)
        losses.append(loss)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss={loss:.4f}  "
                  f"median_step={sup.straggler.median()*1e3:.0f}ms")

    print(f"\nloss: {np.mean(losses[:10]):.3f} (first 10) -> "
          f"{np.mean(losses[-10:]):.3f} (last 10)")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "no learning?"

    # elastic-style restore check: latest checkpoint round-trips
    last = ckpt.latest_step(ckpt_dir)
    template = jax.eval_shape(lambda: state)
    restored, s = ckpt.restore(ckpt_dir, last, template)
    print(f"restored checkpoint @ step {s}: "
          f"{len(jax.tree.leaves(restored))} arrays OK "
          f"(survived 1 injected failure)")


if __name__ == "__main__":
    main()
