"""End-to-end disaggregated serving driver (the paper's system, small).

Runs REAL JAX prefill + batched decode with a reduced qwen3-4b-family
model on CPU: a "prefill device" processes prompt batches and hands the
KV cache to a "decode device" loop (kv-cache int8 quantization on), with
per-phase timing + the analytical model's view of the same split.

    PYTHONPATH=src python examples/serve_disaggregated.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import d1_npu, p1_npu
from repro.core.disagg import evaluate_disaggregated
from repro.core.workload import OSWORLD_LIBREOFFICE
from repro.configs.paper_models import LLAMA33_70B
from repro.runtime.data import DataConfig, batch_for_step
from repro.runtime.steps import make_decode_step, make_prefill_step, model_fns


def main():
    cfg = get_arch("qwen3-4b").reduced(n_layers=4, d_model=128, vocab=512)
    cfg = dataclasses.replace(cfg, kv_quant=True)
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(0))

    batch_size, prompt_len, gen_len = 4, 48, 24
    dc = DataConfig(vocab=cfg.vocab, seq_len=prompt_len,
                    global_batch=batch_size, seed=0)
    s_max = prompt_len + gen_len

    prefill = jax.jit(make_prefill_step(cfg, s_max=s_max))
    decode = jax.jit(make_decode_step(cfg))

    print(f"== serving reduced {cfg.name}: batch={batch_size} "
          f"prompt={prompt_len} gen={gen_len} (int8 KV cache) ==")
    batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, 0).items()}

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    ttft = time.perf_counter() - t0
    print(f"prefill device: TTFT={ttft*1e3:.1f}ms "
          f"(logits {logits.shape})")

    # hand the cache to the "decode device" (same host here)
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for step in range(gen_len - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + step))
        tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    toks = np.stack(generated, axis=1)
    print(f"decode device: {gen_len-1} steps in {dt*1e3:.1f}ms "
          f"({(gen_len-1)*batch_size/dt:.0f} tok/s aggregate)")
    print(f"sample continuation (request 0): {toks[0][:12].tolist()}")

    print("\n== the analytical model's view of the production split "
          "(P1 + D1, LLaMA-3.3-70B, OSWorld) ==")
    r = evaluate_disaggregated(p1_npu(), d1_npu(), LLAMA33_70B,
                               OSWORLD_LIBREOFFICE)
    print(f"TTFT={r.ttft_s:.1f}s  KV transfer={r.kv_transfer_s*1e3:.0f}ms  "
          f"decode TPS(agg)={r.decode_tps_aggregate:.1f}  "
          f"power={r.total_power_w:.0f}W  token/J={r.tokens_per_joule:.3f}")


if __name__ == "__main__":
    main()
