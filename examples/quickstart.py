"""Quickstart: explore the heterogeneous memory design space.

Evaluates the paper's Table 6 configurations on the OSWorld agentic
trace, then runs a small GP+EHVI design-space exploration under a 700 W
TDP budget and prints the Pareto frontier.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs.paper_models import LLAMA33_70B
from repro.core import baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu
from repro.core.dse import Objective, run_mobo
from repro.core.perfmodel import evaluate_decode, evaluate_prefill
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase


def main():
    trace = OSWORLD_LIBREOFFICE
    print(f"== workload: {trace.name} ({trace.prompt_tokens} prompt / "
          f"{trace.gen_tokens} generated tokens), LLaMA-3.3-70B ==\n")

    print("-- paper Table 6 configurations --")
    for mk in (baseline_npu, p1_npu, p2_npu):
        npu = mk()
        r = evaluate_prefill(npu, LLAMA33_70B, trace)
        print(f"prefill {npu.name:4s}: batch={r.batch:3d} "
              f"TPS={r.throughput_tps:8.1f} power={r.avg_power_w:6.1f}W "
              f"token/J={r.tokens_per_joule:6.2f} [{r.bottleneck}]")
    for mk in (baseline_npu, d1_npu, d2_npu):
        npu = mk()
        r = evaluate_decode(npu, LLAMA33_70B, trace)
        print(f"decode  {npu.name:4s}: batch={r.batch:3d} "
              f"TPS={r.throughput_tps:8.1f} power={r.avg_power_w:6.1f}W "
              f"token/J={r.tokens_per_joule:6.2f} [{r.bottleneck}]")

    print("\n-- GP+EHVI design-space exploration (decode, 40 evals, "
          "700 W TDP) --")
    obj = Objective(LLAMA33_70B, trace, Phase.DECODE, tdp_limit_w=700.0)
    res = run_mobo(obj, n_total=40, seed=0)
    pareto = res.pareto()
    print(f"feasible: {sum(o.f is not None for o in res.observations)}/40, "
          f"pareto points: {len(pareto)}")
    for o in sorted(pareto, key=lambda o: -o.f[0])[:5]:
        print(f"  TPS={o.f[0]:8.1f} P={-o.f[1]:6.1f}W  {o.npu.describe()}")


if __name__ == "__main__":
    main()
