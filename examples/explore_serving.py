"""SLO-constrained fleet-serving DSE: co-search devices, replica
counts and traffic routing for a datacenter serving a real traffic
mix (docs/serving.md).

Three request classes share one `EXTREME_4ROLE` fleet: an interactive
chat stream with a tight p99 TTFT SLO plus two long-context agentic
streams (OSWorld, BFCL web-search) with loose ones.  The searched
genes are the 4 x 17 device genes, one replica-count gene per role and
one routing-weight gene per (class, decode role) — 78 genes total —
and the objectives are aggregate tokens/joule and fleet power, under
the datacenter power budget with per-class p99 SLOs as feasibility.

The naive alternative printed first is what you get WITHOUT the
serving genes: clone the best hand-designed single system uniformly
until every queue drains (`serving.naive_replication`).  The seeded
warm-started GP+EHVI sweep then searches heterogeneous replication
and routing directly.

    PYTHONPATH=src python examples/explore_serving.py [--evals 96]
"""

import argparse

from repro.configs.paper_models import LLAMA33_70B
from repro.core import d1_npu, p1_npu
from repro.core.disagg import EXTREME_4ROLE
from repro.core.dse import ServingObjective, run_mobo, serving_warm_start
from repro.core.serving import RequestClass, TrafficMix, naive_replication
from repro.core.workload import (BFCL_WEB_SEARCH, CHATBOT,
                                 OSWORLD_LIBREOFFICE)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=96)
    ap.add_argument("--budget", type=float, default=12000.0,
                    help="datacenter power budget (provisioned peak W)")
    ap.add_argument("--chat-rps", type=float, default=4.0,
                    help="chatbot arrival rate (requests/s)")
    args = ap.parse_args()

    mix = TrafficMix("agentic-3class", (
        RequestClass(CHATBOT, rate_rps=args.chat_rps, ttft_p99_slo_s=6.0),
        RequestClass(OSWORLD_LIBREOFFICE, rate_rps=0.02,
                     ttft_p99_slo_s=90.0),
        RequestClass(BFCL_WEB_SEARCH, rate_rps=0.01, ttft_p99_slo_s=120.0),
    ))
    print(f"== serving {mix.name} on {EXTREME_4ROLE.name}, "
          f"{args.budget:.0f} W budget ==")
    for c in mix.classes:
        print(f"  {c.trace.name:22s} {c.rate_rps:6.2f} req/s "
              f"({c.trace.prompt_tokens}/{c.trace.gen_tokens} tokens, "
              f"p99 TTFT <= {c.ttft_p99_slo_s:.0f}s)")

    naive = naive_replication([p1_npu(), p1_npu(), d1_npu(), d1_npu()],
                              EXTREME_4ROLE, LLAMA33_70B, mix, args.budget)
    if naive is None:
        print("naive replication of the hand system is infeasible at "
              "this budget — raise --budget or lower --chat-rps")
    else:
        print(f"\nnaive replication (hand P1/P1/D1/D1 x uniform): "
              f"tokJ={naive.tokens_per_joule:.4f} reps={naive.replicas} "
              f"P={naive.fleet_power_w:.0f}W "
              f"ttft99={'/'.join(f'{t:.1f}' for t in naive.ttft_p99_s)}s")

    obj = ServingObjective(LLAMA33_70B, mix, topology=EXTREME_4ROLE,
                           power_budget_w=args.budget)
    print(f"\nseeded GP+EHVI sweep: {obj.space.n_dims} genes, "
          f"{args.evals} evals, B=16, warm-started")
    init = serving_warm_start(obj, 24, seed=0)
    res = run_mobo(obj, n_total=args.evals, seed=0, init=list(init),
                   batch_size=16)
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    if best is None:
        print("no SLO-feasible fleet found — loosen the SLOs or budget")
        return
    r = best.result
    design = obj.design(best.x)
    ratio = ("" if naive is None else
             f" ({r.tokens_per_joule / naive.tokens_per_joule:.2f}x naive)")
    print(f"\nbest searched fleet: tokJ={r.tokens_per_joule:.4f}{ratio} "
          f"P={r.fleet_power_w:.0f}W "
          f"ttft99={'/'.join(f'{t:.1f}' for t in r.ttft_p99_s)}s")
    for i, (role, cfg) in enumerate(zip(EXTREME_4ROLE.roles, design.npus)):
        print(f"  {role.name:13s} x{r.replicas[i]:<2d} "
              f"rho={r.rho[i]:.2f}  {cfg.describe()}")
    dec = [EXTREME_4ROLE.roles[j].name
           for j in EXTREME_4ROLE.decode_indices()]
    print("decode routing (class -> " + ", ".join(dec) + "):")
    for c, row_phi in zip(mix.classes, r.phi):
        print(f"  {c.trace.name:22s} "
              + "  ".join(f"{p:.2f}" for p in row_phi))


if __name__ == "__main__":
    main()
