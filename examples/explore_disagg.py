"""Paired and N-device disaggregated DSE: co-design every device of a
disaggregated serving system in one sweep (paper Sections 5.3/5.5).

The four searchers run unchanged on the 34-gene `PairedSpace` (two
concatenated Table 2 encodings with the KV-quant compatibility
constraint); `DisaggObjective` scores each pair end-to-end — aggregate
tokens/joule and total system power, under a combined TDP budget and a
TTFT cap that includes the NVLink KV-cache hand-off.

The extreme-heterogeneity section then co-searches a *4-role* system
(prefill-attn / prefill-ffn / decode-early / decode-late, the Section
5.5 layer-group + decode-phase splits) on the 68-gene `SystemSpace`
with a seeded GP+EHVI sweep warm-started from per-role champions.

Finally, the diffusion-LM fleet section co-searches the `dllm-3role`
topology (prompt prefill + early/late denoise split) on LLaDA-8B over
the agentic-length `OSWORLD_DLLM` trace — DLLM decode is a first-class
jitted scenario, so the same machinery searches it unchanged.

    PYTHONPATH=src python examples/explore_disagg.py [--evals 60]
"""

import argparse

import numpy as np

from repro.configs.paper_models import LLADA_8B, LLAMA33_70B
from repro.core import d1_npu, p1_npu
from repro.core.disagg import (DLLM_3ROLE, EXTREME_4ROLE,
                               evaluate_disaggregated)
from repro.core.dse import (METHODS, DisaggObjective, SystemObjective,
                            run_mobo, shared_init, system_warm_start)
from repro.core.workload import OSWORLD_DLLM, OSWORLD_LIBREOFFICE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=60)
    ap.add_argument("--tdp", type=float, default=1400.0,
                    help="combined pair TDP budget (W)")
    ap.add_argument("--ttft-cap", type=float, default=90.0,
                    help="TTFT feasibility cap (s), incl. KV transfer")
    args = ap.parse_args()

    trace = OSWORLD_LIBREOFFICE
    hand = evaluate_disaggregated(p1_npu(), d1_npu(), LLAMA33_70B, trace)
    print(f"== paired prefill/decode DSE on LLaMA-3.3-70B/OSWorld, "
          f"{args.evals} evals, {args.tdp:.0f} W pair TDP, "
          f"TTFT cap {args.ttft_cap:.0f} s ==")
    print(f"hand-designed P1+D1 reference: tokJ={hand.tokens_per_joule:.3f} "
          f"TTFT={hand.ttft_s:.1f}s P={hand.total_power_w:.0f}W")

    obj = DisaggObjective(LLAMA33_70B, trace, tdp_limit_w=args.tdp,
                          ttft_cap_s=args.ttft_cap)
    init = shared_init(obj, 20, seed=0)
    results = {}
    for name, runner in METHODS.items():
        res = runner(obj, n_total=args.evals, seed=0, init=list(init))
        results[name] = res
    fronts = [r.feasible_f() for r in results.values()
              if len(r.feasible_f())]
    if not fronts:
        print("no feasible pair found — loosen --ttft-cap / --tdp")
        return
    ref = np.vstack(fronts).min(axis=0) - np.array([0.01, 1.0])
    print(f"\n{'method':10s} {'final HV':>12s} {'pareto':>7s} "
          f"{'best tokJ':>10s}")
    for name, res in results.items():
        hv = res.hv_history(ref)[-1]
        pareto = res.pareto()
        best = max((o.f[0] for o in pareto), default=0.0)
        print(f"{name:10s} {hv:12.4e} {len(pareto):7d} {best:10.3f}")
    winner = max(results, key=lambda n: results[n].hv_history(ref)[-1])
    print(f"\nwinner: {winner}")
    print("best pairs on the winner's frontier:")
    best_pair_tokj = hand.tokens_per_joule
    for o in sorted(results[winner].pareto(), key=lambda o: -o.f[0])[:3]:
        p, d = o.npu
        r = o.result
        best_pair_tokj = max(best_pair_tokj, o.f[0])
        print(f"  tokJ={o.f[0]:6.3f} P={-o.f[1]:6.1f}W TTFT={r.ttft_s:5.1f}s "
              f"(vs P1+D1 {o.f[0]/hand.tokens_per_joule:.2f}x)")
        print(f"    prefill: {p.describe()}")
        print(f"    decode:  {d.describe()}")

    # --- extreme heterogeneity: searched 4-role system (Section 5.5) ---
    print(f"\n== extreme heterogeneity: {EXTREME_4ROLE.name} "
          f"({', '.join(r.name for r in EXTREME_4ROLE.roles)}), "
          f"GP+EHVI {args.evals} evals, {2 * args.tdp:.0f} W system TDP ==")
    sys_obj = SystemObjective(LLAMA33_70B, trace, topology=EXTREME_4ROLE,
                              tdp_limit_w=2 * args.tdp,
                              ttft_cap_s=args.ttft_cap)
    sys_init = system_warm_start(sys_obj, 20, seed=0)
    sys_res = run_mobo(sys_obj, n_total=args.evals, seed=0,
                       init=list(sys_init))
    feas = [o for o in sys_res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    if best is None:
        print("no feasible 4-role system found — loosen the caps")
        return
    r = best.result
    print(f"best system: tokJ={r.tokens_per_joule:.3f} "
          f"P={r.total_power_w:.0f}W TTFT={r.ttft_s:.1f}s "
          f"(vs searched pair {r.tokens_per_joule/best_pair_tokj:.2f}x, "
          f"vs P1+D1 {r.tokens_per_joule/hand.tokens_per_joule:.2f}x)")
    for role, cfg in zip(EXTREME_4ROLE.roles, best.npu):
        print(f"  {role.name:13s} {cfg.describe()}")

    # --- diffusion-LM fleet: DLLM decode as a searched scenario ---
    print(f"\n== diffusion-LM fleet: {DLLM_3ROLE.name} "
          f"({', '.join(r.name for r in DLLM_3ROLE.roles)}) on "
          f"LLaDA-8B/{OSWORLD_DLLM.name}, GP+EHVI {args.evals} evals, "
          f"2100 W fleet TDP ==")
    dllm_obj = SystemObjective(LLADA_8B, OSWORLD_DLLM,
                               topology=DLLM_3ROLE, tdp_limit_w=2100.0,
                               ttft_cap_s=args.ttft_cap)
    dllm_init = system_warm_start(dllm_obj, 20, seed=0)
    dllm_res = run_mobo(dllm_obj, n_total=args.evals, seed=0,
                        init=list(dllm_init))
    feas = [o for o in dllm_res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    if best is None:
        print("no feasible DLLM fleet found — loosen the caps")
        return
    r = best.result
    print(f"best fleet: tokJ={r.tokens_per_joule:.4f} "
          f"P={r.total_power_w:.0f}W TTFT={r.ttft_s:.1f}s "
          f"TPSagg={r.decode_tps_aggregate:.2f}")
    for role, cfg in zip(DLLM_3ROLE.roles, best.npu):
        print(f"  {role.name:13s} {cfg.describe()}")


if __name__ == "__main__":
    main()
