"""Table 7 + the searched diffusion-LM fleet.

Table 7 (LLaDA-8B, GSM8K trace): full-sequence iterative denoising
favors on-chip activation capacity for BOTH phases.  Paper: prefill-opt
1.65x, decode-opt 1.33x token/J over baseline.

Searched fleet: DLLM decode is now a first-class jitted scenario, so
the same seeded GP+EHVI machinery that co-designs the extreme-
heterogeneity system searches a 3-role diffusion serving fleet
(`disagg.DLLM_3ROLE`: prompt prefill + early/late denoise split) on the
agentic-length `OSWORLD_DLLM` trace.  The result is merged into
``BENCH_dse.json`` (key ``dllm_system``) so ``benchmarks/run.py
--check`` gates both its timing and its achieved tokens/joule against
the hand-designed reference floor.
"""

import dataclasses

from repro.configs.paper_models import LLADA_8B
from repro.core import Dataflow, make_hierarchy, p1_npu
from repro.core.dataflow import (BandwidthPriority, SoftwareStrategy,
                                 StoragePriority)
from repro.core.disagg import DLLM_3ROLE, evaluate_system
from repro.core.dse import SystemObjective, run_mobo, system_warm_start
from repro.core.npu import NPUConfig, baseline_npu
from repro.core.perfmodel import InfeasibleConfig, evaluate_decode
from repro.core.workload import GSM8K_DLLM, OSWORLD_DLLM

from .common import merge_bench_json, row, timed

CONFIGS = {
    "baseline": [("SRAM", 1), ("HBM3E", 4)],
    "prefill_opt": [("3D-SRAM", 2), ("HBM3E", 2)],
    "decode_opt": [("3D-SRAM", 3), ("HBM3E", 2)],
}
PAPER = {"baseline": 1.00, "prefill_opt": 1.65, "decode_opt": 1.33}

SEARCH_N_TOTAL = 60          # acceptance setting: seeded sweep budget
SEARCH_N_INIT = 20
SEARCH_SEED = 0
SMOKE_N_TOTAL = 40
TDP_LIMIT_W = 2100.0         # three 700 W sockets, one fleet budget
TTFT_CAP_S = 90.0


def _hand_reference():
    """Hand-designed fleet: P1 in every role.  D1/D2 lose (or are
    outright infeasible) on the agentic DLLM trace — each denoise step
    is a full-sequence pass, so the prefill-optimized on-chip-heavy
    device wins the denoise roles too (the Table 7 observation at
    system scale)."""
    names = [f"P1-{r.name}" for r in DLLM_3ROLE.roles]
    npus = [dataclasses.replace(p1_npu(), name=n) for n in names]
    try:
        return evaluate_system(npus, DLLM_3ROLE, LLADA_8B, OSWORLD_DLLM)
    except (InfeasibleConfig, ValueError):
        return None


def _searched_system(trace, n_total: int):
    """Seeded 3-role GP+EHVI sweep; returns (best Observation, objective)."""
    obj = SystemObjective(LLADA_8B, trace, topology=DLLM_3ROLE,
                          tdp_limit_w=TDP_LIMIT_W, ttft_cap_s=TTFT_CAP_S)
    init = system_warm_start(obj, SEARCH_N_INIT, seed=SEARCH_SEED)
    res = run_mobo(obj, n_total=n_total, seed=SEARCH_SEED, init=list(init))
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    return best, obj


def run(smoke: bool = False) -> list:
    base = baseline_npu()
    strat = SoftwareStrategy(Dataflow.WEIGHT_STATIONARY,
                             StoragePriority.ACTIVATION,
                             BandwidthPriority.MATRIX)
    out = []
    results = {}
    for name, spec in CONFIGS.items():
        npu = NPUConfig(name=name, compute=base.compute,
                        hierarchy=make_hierarchy(spec),
                        strategy=strat if name != "baseline"
                        else base.strategy, quant=base.quant)
        r, us = timed(evaluate_decode, npu, LLADA_8B, GSM8K_DLLM)
        results[name] = (r, us)
    base_tj = results["baseline"][0].tokens_per_joule
    for name, (r, us) in results.items():
        out.append(row(
            f"t7_{name}", us,
            f"power={r.avg_power_w:.0f}W batch={r.batch} "
            f"tokJ_rel={r.tokens_per_joule/base_tj:.2f}x "
            f"paper={PAPER[name]:.2f}x"))

    # searched 3-role diffusion fleet: seeded GP+EHVI over SystemSpace
    hand = _hand_reference()
    if hand is not None:
        out.append(row(
            "t7_hand_fleet_p1x3", 0.0,
            f"tokJ={hand.tokens_per_joule:.4f} TTFT={hand.ttft_s:.1f}s "
            f"P={hand.total_power_w:.0f}W"))
    n_total = SMOKE_N_TOTAL if smoke else SEARCH_N_TOTAL
    (best, obj), us = timed(_searched_system, OSWORLD_DLLM, n_total)
    if best is None:
        out.append(row("t7_searched_fleet", us,
                       f"no feasible fleet in {n_total} evals"))
        merge_bench_json("dllm_system", {
            "n_total": n_total, "seed": SEARCH_SEED,
            "smoke": smoke, "us_per_run": us,
            "tokens_per_joule": None})
        return out
    r = best.result
    rel = (r.tokens_per_joule / hand.tokens_per_joule
           if hand is not None else float("nan"))
    out.append(row(
        "t7_searched_fleet", us,
        f"TTFT={r.ttft_s:.1f}s TPSagg={r.decode_tps_aggregate:.2f} "
        f"P={r.total_power_w:.0f}W tokJ={r.tokens_per_joule:.4f} "
        f"({rel:.2f}x hand P1-fleet; seed={SEARCH_SEED}, N={n_total}, "
        f"{obj.n_evals} system evals)"))
    out.append(row(
        "t7_searched_fleet_devices", 0.0,
        " || ".join(f"{role.name}:{cfg.hierarchy.describe()}"
                    for role, cfg in zip(DLLM_3ROLE.roles, best.npu))))
    merge_bench_json("dllm_system", {
        "n_total": n_total, "seed": SEARCH_SEED, "smoke": smoke,
        "us_per_run": us,
        "tokens_per_joule": r.tokens_per_joule,
        "ttft_s": r.ttft_s,
        "total_power_w": r.total_power_w,
        "n_evals": obj.n_evals,
        "topology": DLLM_3ROLE.name,
        "tdp_limit_w": TDP_LIMIT_W,
    })
    return out
