"""Table 7: diffusion LM (LLaDA-8B, GSM8K trace) — full-sequence
iterative denoising favors on-chip activation capacity for BOTH phases.
Paper: prefill-opt 1.65x, decode-opt 1.33x token/J over baseline."""

import dataclasses

from repro.configs.paper_models import LLADA_8B
from repro.core import Dataflow, make_hierarchy
from repro.core.dataflow import (BandwidthPriority, SoftwareStrategy,
                                 StoragePriority)
from repro.core.npu import NPUConfig, baseline_npu
from repro.core.perfmodel import evaluate_decode
from repro.core.workload import GSM8K_DLLM

from .common import row, timed

CONFIGS = {
    "baseline": [("SRAM", 1), ("HBM3E", 4)],
    "prefill_opt": [("3D-SRAM", 2), ("HBM3E", 2)],
    "decode_opt": [("3D-SRAM", 3), ("HBM3E", 2)],
}
PAPER = {"baseline": 1.00, "prefill_opt": 1.65, "decode_opt": 1.33}


def run() -> list:
    base = baseline_npu()
    strat = SoftwareStrategy(Dataflow.WEIGHT_STATIONARY,
                             StoragePriority.ACTIVATION,
                             BandwidthPriority.MATRIX)
    out = []
    results = {}
    for name, spec in CONFIGS.items():
        npu = NPUConfig(name=name, compute=base.compute,
                        hierarchy=make_hierarchy(spec),
                        strategy=strat if name != "baseline"
                        else base.strategy, quant=base.quant)
        r, us = timed(evaluate_decode, npu, LLADA_8B, GSM8K_DLLM)
        results[name] = (r, us)
    base_tj = results["baseline"][0].tokens_per_joule
    for name, (r, us) in results.items():
        out.append(row(
            f"t7_{name}", us,
            f"power={r.avg_power_w:.0f}W batch={r.batch} "
            f"tokJ_rel={r.tokens_per_joule/base_tj:.2f}x "
            f"paper={PAPER[name]:.2f}x"))
    return out
