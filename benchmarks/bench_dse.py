"""Fig 6: hypervolume convergence of GP+EHVI vs NSGA-II vs MO-TPE vs
Random (shared 20-point Sobol init, multiple seeds), plus the jitted
candidate-pool scoring row (``pool100000``): a 100k-design pool scored
end-to-end (gene batch -> `space.decode_batch` -> one jitted
`perfmodel_jit.evaluate_batch_arrays` call) against the scalar oracle
loop's per-design cost.

Also emits machine-readable per-method timings to ``BENCH_dse.json`` so
future optimization PRs have a perf trajectory to regress against — the
``jit_pool`` entry carries the jitted-vs-scalar speedup that
``benchmarks/run.py --check`` gates on.  In ``--smoke`` mode (see
benchmarks/run.py) the budget shrinks to one seed / 30 evaluations and
a 10k pool for a fast end-to-end sanity pass.

Standalone pool sizing::

  PYTHONPATH=src python -m benchmarks.bench_dse --pool 100000
"""

import json
import os
import time

import numpy as np

from repro.configs.paper_models import QWEN3_32B
from repro.core.dse import (METHODS, Objective, shared_init)
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import atomic_write_json, row, timed

N_TOTAL = 60
N_INIT = 20
SEEDS = (0, 1, 2)
POOL_SIZE = 100_000
SCALAR_SAMPLE = 300          # scalar-oracle subsample, extrapolated

SMOKE_N_TOTAL = 30
SMOKE_SEEDS = (0,)
SMOKE_POOL_SIZE = 10_000

DEFAULT_JSON_PATH = "BENCH_dse.json"


def pool_rows(pool_size: int = POOL_SIZE) -> tuple:
    """([csv rows], jit_pool payload): score a `pool_size` candidate
    pool with the jitted SoA path and compare against the scalar
    oracle's per-design cost (measured on a subsample, extrapolated).

    The jitted timing is steady-state (one warm-up call pays the
    per-shape XLA compile, reported separately) and includes
    `decode_batch` — the full genes-to-scores path the searchers use.
    """
    from repro.core import perfmodel_jit as pj
    from repro.core.dse import space as sp
    from repro.core.perfmodel import evaluate_batch

    rng = np.random.default_rng(0)
    xs = sp.random_designs(rng, 2 * pool_size)
    xs = xs[sp.valid_mask(xs)]
    while len(xs) < pool_size:          # top up (raw validity ~60-70%)
        draw = sp.random_designs(rng, pool_size)
        xs = np.concatenate([xs, draw[sp.valid_mask(draw)]])
    xs = xs[:pool_size]

    t0 = time.perf_counter()
    table = sp.decode_batch(xs)
    t_decode = time.perf_counter() - t0

    t0 = time.perf_counter()
    arrs = pj.evaluate_batch_arrays(table, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                    Phase.PREFILL)
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    arrs = pj.evaluate_batch_arrays(table, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                    Phase.PREFILL)
    t_jit = time.perf_counter() - t0

    sample = [sp.decode(x) for x in xs[:SCALAR_SAMPLE]]
    t0 = time.perf_counter()
    ref = evaluate_batch(sample, QWEN3_32B, OSWORLD_LIBREOFFICE,
                         Phase.PREFILL, use_jit=False)
    scalar_per_design = (time.perf_counter() - t0) / len(sample)

    # sanity: identical feasibility + matching throughput on the sample
    n_bad = sum(
        (r is None) != (not arrs["feasible"][i])
        or (r is not None
            and abs(r.throughput_tps - arrs["throughput_tps"][i])
            > 1e-5 * abs(r.throughput_tps))
        for i, r in enumerate(ref))
    jit_total = t_decode + t_jit
    scalar_total = scalar_per_design * pool_size
    speedup = scalar_total / jit_total
    payload = {
        "pool_size": pool_size,
        "feasible": int(arrs["feasible"].sum()),
        "decode_batch_s": t_decode,
        "jit_eval_s": t_jit,
        "jit_compile_s": t_compile,
        "scalar_us_per_design": scalar_per_design * 1e6,
        "scalar_extrapolated_s": scalar_total,
        "speedup": speedup,
        "parity_mismatches": int(n_bad),
    }
    rows = [row(f"pool{pool_size}_jit", jit_total * 1e6,
                f"feasible={payload['feasible']} "
                f"compile={t_compile:.1f}s "
                f"scalar~{scalar_total:.1f}s speedup={speedup:.0f}x "
                f"parity_bad={n_bad}")]
    return rows, payload


def run(smoke: bool = False) -> list:
    # resolved at run time (not import time) so the perf-regression
    # check in benchmarks/run.py can redirect the fresh timings
    json_path = os.environ.get("BENCH_DSE_JSON", DEFAULT_JSON_PATH)
    n_total = SMOKE_N_TOTAL if smoke else N_TOTAL
    seeds = SMOKE_SEEDS if smoke else SEEDS
    us_total = {m: 0.0 for m in METHODS}
    all_f = []
    runs = {m: [] for m in METHODS}
    for seed in seeds:
        obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.PREFILL,
                        tdp_limit_w=700.0)
        init = shared_init(obj, N_INIT, seed=seed)
        for name, runner in METHODS.items():
            res, us = timed(runner, obj, n_total=n_total, seed=seed,
                            init=list(init))
            us_total[name] += us
            runs[name].append(res)
            f = res.feasible_f()
            if len(f):
                all_f.append(f)
    ref = (np.vstack(all_f).min(axis=0) - 1.0) if all_f else np.zeros(2)
    out = []
    finals = {}
    timings = {}
    for name in METHODS:
        hvs = np.stack([r.hv_history(ref) for r in runs[name]])
        finals[name] = hvs[:, -1].mean()
        mid = hvs[:, N_INIT + (n_total - N_INIT) // 2].mean()
        timings[name] = {
            "us_per_run": us_total[name] / len(seeds),
            "hv_final": float(finals[name]),
            "hv_mid": float(mid),
        }
        out.append(row(
            f"fig6_{name.lower().replace('+','').replace('-','')}",
            us_total[name] / len(seeds),
            f"HV@{n_total}={finals[name]:.3e} "
            f"HV@mid={mid:.3e} seeds={len(seeds)}"))
    best = max(finals, key=finals.get)
    out.append(row("fig6_winner", 0.0,
                   f"{best} (paper: GP+EHVI converges highest)"))
    pool_out, jit_pool = pool_rows(SMOKE_POOL_SIZE if smoke else POOL_SIZE)
    out.extend(pool_out)
    payload = {
        "bench": "dse_convergence",
        "settings": {"n_total": n_total, "n_init": N_INIT,
                     "seeds": list(seeds), "smoke": smoke},
        "methods": timings,
        "jit_pool": jit_pool,
        "winner": best,
        "total_us": sum(us_total.values()),
    }
    # bench_dse rewrites the whole file fresh (the searched-system
    # benches then merge their keys in); atomic_write_json stages to a
    # temp file + os.replace and warns loudly on failure, so a killed
    # or read-only run can't leave a truncated baseline behind
    atomic_write_json(json_path, payload)
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pool", type=int, default=POOL_SIZE,
                    help="candidate-pool size for the jitted scoring row")
    ap.add_argument("--full", action="store_true",
                    help="also run the full fig6 convergence sweep")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.full:
        for line in run():
            print(line)
    else:
        rows, payload = pool_rows(args.pool)
        for line in rows:
            print(line)
        print(json.dumps(payload, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
