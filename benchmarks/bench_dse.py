"""Fig 6: hypervolume convergence of GP+EHVI vs NSGA-II vs MO-TPE vs
Random (shared 20-point Sobol init, multiple seeds).

Also emits machine-readable per-method timings to ``BENCH_dse.json`` so
future optimization PRs have a perf trajectory to regress against.  In
``--smoke`` mode (see benchmarks/run.py) the budget shrinks to one seed
and 30 evaluations for a fast end-to-end sanity pass.
"""

import json
import os

import numpy as np

from repro.configs.paper_models import QWEN3_32B
from repro.core.dse import (METHODS, Objective, shared_init)
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import row, timed

N_TOTAL = 60
N_INIT = 20
SEEDS = (0, 1, 2)

SMOKE_N_TOTAL = 30
SMOKE_SEEDS = (0,)

DEFAULT_JSON_PATH = "BENCH_dse.json"


def run(smoke: bool = False) -> list:
    # resolved at run time (not import time) so the perf-regression
    # check in benchmarks/run.py can redirect the fresh timings
    json_path = os.environ.get("BENCH_DSE_JSON", DEFAULT_JSON_PATH)
    n_total = SMOKE_N_TOTAL if smoke else N_TOTAL
    seeds = SMOKE_SEEDS if smoke else SEEDS
    us_total = {m: 0.0 for m in METHODS}
    all_f = []
    runs = {m: [] for m in METHODS}
    for seed in seeds:
        obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.PREFILL,
                        tdp_limit_w=700.0)
        init = shared_init(obj, N_INIT, seed=seed)
        for name, runner in METHODS.items():
            res, us = timed(runner, obj, n_total=n_total, seed=seed,
                            init=list(init))
            us_total[name] += us
            runs[name].append(res)
            f = res.feasible_f()
            if len(f):
                all_f.append(f)
    ref = (np.vstack(all_f).min(axis=0) - 1.0) if all_f else np.zeros(2)
    out = []
    finals = {}
    timings = {}
    for name in METHODS:
        hvs = np.stack([r.hv_history(ref) for r in runs[name]])
        finals[name] = hvs[:, -1].mean()
        mid = hvs[:, N_INIT + (n_total - N_INIT) // 2].mean()
        timings[name] = {
            "us_per_run": us_total[name] / len(seeds),
            "hv_final": float(finals[name]),
            "hv_mid": float(mid),
        }
        out.append(row(
            f"fig6_{name.lower().replace('+','').replace('-','')}",
            us_total[name] / len(seeds),
            f"HV@{n_total}={finals[name]:.3e} "
            f"HV@mid={mid:.3e} seeds={len(seeds)}"))
    best = max(finals, key=finals.get)
    out.append(row("fig6_winner", 0.0,
                   f"{best} (paper: GP+EHVI converges highest)"))
    payload = {
        "bench": "dse_convergence",
        "settings": {"n_total": n_total, "n_init": N_INIT,
                     "seeds": list(seeds), "smoke": smoke},
        "methods": timings,
        "winner": best,
        "total_us": sum(us_total.values()),
    }
    try:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
    except OSError:
        pass                        # read-only working dir: CSV rows suffice
    return out
