"""Fig 6: hypervolume convergence of GP+EHVI vs NSGA-II vs MO-TPE vs
Random (shared 20-point Sobol init, multiple seeds)."""

import numpy as np

from repro.configs.paper_models import QWEN3_32B
from repro.core.dse import (METHODS, Objective, shared_init)
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import row, timed

N_TOTAL = 60
N_INIT = 20
SEEDS = (0, 1, 2)


def run() -> list:
    curves = {m: [] for m in METHODS}
    us_total = {m: 0.0 for m in METHODS}
    all_f = []
    runs = {m: [] for m in METHODS}
    for seed in SEEDS:
        obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.PREFILL,
                        tdp_limit_w=700.0)
        init = shared_init(obj, N_INIT, seed=seed)
        for name, runner in METHODS.items():
            res, us = timed(runner, obj, n_total=N_TOTAL, seed=seed,
                            init=list(init))
            us_total[name] += us
            runs[name].append(res)
            f = res.feasible_f()
            if len(f):
                all_f.append(f)
    ref = (np.vstack(all_f).min(axis=0) - 1.0) if all_f else np.zeros(2)
    out = []
    finals = {}
    for name in METHODS:
        hvs = np.stack([r.hv_history(ref) for r in runs[name]])
        finals[name] = hvs[:, -1].mean()
        mid = hvs[:, N_INIT + (N_TOTAL - N_INIT) // 2].mean()
        out.append(row(
            f"fig6_{name.lower().replace('+','').replace('-','')}",
            us_total[name] / len(SEEDS),
            f"HV@{N_TOTAL}={finals[name]:.3e} "
            f"HV@mid={mid:.3e} seeds={len(SEEDS)}"))
    best = max(finals, key=finals.get)
    out.append(row("fig6_winner", 0.0,
                   f"{best} (paper: GP+EHVI converges highest)"))
    return out
