"""Kernel-calibrated perfmodel: measured Pallas factors end-to-end.

Runs the calibration harness (`repro.core.calibration`): the repo's
Pallas kernels (flash/decode attention, MX quant) plus the XLA matmul
proxy are timed across the geometry ladders, per-geometry-class
efficiency/setup factors are fitted, and the fitted `CalibrationTable`
is pushed through the full stack:

* **fit quality** — the per-class normalized residual's max
  (``fit_err``) is the number ``benchmarks/run.py --check`` gates
  against `CAL_FIT_ERR_CEILING`;
* **coverage** — measured classes vs the classes the bundled
  QWEN3-32B/OSWorld trace actually emits;
* **shift** — max relative latency change, identity table vs fitted
  table, across P1/D1/baseline x prefill/decode: the fitted factors
  must *measurably* move predicted cycles on a bundled trace
  (shift > 0 is gated);
* **searched system** — a seeded GP+EHVI sweep through a calibrated
  ``Objective`` proves the table rides through the jitted batch path,
  the evaluation cache and the searchers unchanged.

On CPU the kernels run through the Pallas interpreter, so the fitted
efficiencies are orders of magnitude above 1 — the row validates the
harness and the threading, not silicon (docs/calibration.md).
"""

from repro.configs.paper_models import QWEN3_32B
from repro.core import baseline_npu, d1_npu, evaluate, p1_npu
from repro.core.calibration import (fit_table, measure_all,
                                    trace_geometry_classes)
from repro.core.dse import Objective, run_mobo, shared_init
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import merge_bench_json, row, timed

SEARCH_N_TOTAL = 24          # tiny sweep: the threading, not convergence
SEARCH_N_INIT = 10
SEARCH_SEED = 0
SMOKE_N_TOTAL = 16
TDP_LIMIT_W = 700.0


def _measure_and_fit(smoke: bool):
    samples = measure_all(smoke=smoke, seed=0)
    table, report = fit_table(samples, source="bench")
    return samples, table, report


def _latency_shift(table) -> tuple:
    """Max relative latency change (fitted vs identity) over bundled
    NPUs x phases on QWEN3-32B/OSWorld — the acceptance number: a
    non-identity table must move predicted cycles on a real trace."""
    shift = 0.0
    where = ""
    for npu in (p1_npu(), d1_npu(), baseline_npu()):
        for phase in (Phase.PREFILL, Phase.DECODE):
            base = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase)
            cal = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase,
                           calibration=table)
            rel = abs(cal.latency_s - base.latency_s) / base.latency_s
            if rel > shift:
                shift = rel
                where = f"{npu.name}/{phase.name.lower()}"
    return shift, where


def _searched_calibrated(table, n_total: int):
    """Seeded GP+EHVI sweep with the fitted table on the objective;
    returns (best feasible Observation, objective)."""
    obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.PREFILL,
                    tdp_limit_w=TDP_LIMIT_W, calibration=table)
    init = shared_init(obj, SEARCH_N_INIT, seed=SEARCH_SEED)
    res = run_mobo(obj, n_total=n_total, seed=SEARCH_SEED, init=list(init))
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    return best, obj


def run(smoke: bool = False) -> list:
    out = []
    (samples, table, report), fit_us = timed(_measure_and_fit, smoke)
    classes = report["classes"]
    out.append(row(
        "calibration_fit", fit_us,
        f"fit_err={report['fit_err']:.3f} classes={len(classes)} "
        f"samples={report['n_samples']} digest={table.digest()}"))
    for name in sorted(classes):
        c = classes[name]
        out.append(row(
            f"calibration_class_{name.replace('/', '_')}", 0.0,
            f"eff={c['efficiency']:.1f} setup={c['setup_cycles']:.0f}cyc "
            f"rel_rms={c['rel_rms']:.3f} n={c['n_samples']}"))
    # coverage: measured classes vs what the bundled trace emits
    emitted = trace_geometry_classes(QWEN3_32B, OSWORLD_LIBREOFFICE,
                                     p1_npu().quant)
    measured = {name for name, _, _ in table.entries}
    missing = sorted(set(emitted) - measured)
    out.append(row(
        "calibration_coverage", 0.0,
        f"emitted={len(emitted)} measured={len(set(emitted) & measured)} "
        f"identity={','.join(missing) if missing else 'none'}"))
    # shift: the fitted table must move a bundled-trace prediction
    (shift, where), shift_us = timed(_latency_shift, table)
    out.append(row(
        "calibration_shift", shift_us,
        f"max_rel_latency_shift={shift:.3f} at {where}"))
    # searched system: the table threads through the jitted batch
    # path + cache + searcher end-to-end
    n_total = SMOKE_N_TOTAL if smoke else SEARCH_N_TOTAL
    (best, obj), search_us = timed(_searched_calibrated, table, n_total)
    tokj = None if best is None else best.f[0]
    out.append(row(
        "calibration_searched", search_us,
        (f"no feasible design in {n_total} evals" if best is None else
         f"tokJ={tokj:.3f} (seed={SEARCH_SEED}, N={n_total}, "
         f"{obj.n_evals} evals, calibrated)")))
    merge_bench_json("calibration", {
        "smoke": smoke,
        "us_per_run": fit_us,
        "fit_err": report["fit_err"],
        "n_samples": report["n_samples"],
        "digest": table.digest(),
        "classes": {name: {"efficiency": c["efficiency"],
                           "setup_cycles": c["setup_cycles"],
                           "rel_rms": c["rel_rms"]}
                    for name, c in sorted(classes.items())},
        "shift": shift,
        "shift_at": where,
        "n_total": n_total,
        "seed": SEARCH_SEED,
        "search_us": search_us,
        "tokens_per_joule": tokj,
    })
    return out
