"""Roofline summary from the dry-run sweep (results/dryrun_scan.jsonl):
per-(arch x shape x mesh) terms on TPU v5e constants."""

import json
import os

from .common import row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_scan.jsonl")


def load_rows(path: str = RESULTS) -> list:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            out.append(json.loads(line))
    return out


def run() -> list:
    rows = load_rows()
    if not rows:
        return [row("roofline_missing", 0.0,
                    "run: python -m repro.launch.dryrun --all "
                    "--both-meshes --scan --out results/dryrun_scan.jsonl")]
    ok = [r for r in rows if r.get("status") == "ok"]
    out = [row("roofline_cells", 0.0,
               f"ok={len(ok)} skip={sum(r['status'] == 'skip' for r in rows)}"
               f" err={sum(r['status'] == 'error' for r in rows)}")]
    # aggregate stats per shape
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        cells = [r for r in ok if r["shape"] == shape
                 and r["mesh"] == "16x16"]
        if not cells:
            continue
        worst = min(cells, key=lambda r: r["roofline_fraction"])
        best = max(cells, key=lambda r: r["roofline_fraction"])
        bnecks = {}
        for r in cells:
            bnecks[r["bottleneck"]] = bnecks.get(r["bottleneck"], 0) + 1
        out.append(row(
            f"roofline_{shape}", 0.0,
            f"n={len(cells)} best={best['arch']}:"
            f"{best['roofline_fraction']:.3f} "
            f"worst={worst['arch']}:{worst['roofline_fraction']:.4f} "
            f"bottlenecks={bnecks}"))
    # most collective-bound cell
    coll = max(ok, key=lambda r: r.get("collective_s", 0.0))
    out.append(row(
        "roofline_most_collective", 0.0,
        f"{coll['arch']}x{coll['shape']}@{coll['mesh']} "
        f"coll_s={coll['collective_s']:.3e}"))
    return out
