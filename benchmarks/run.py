"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

Running the benchmarks
----------------------
From the repo root::

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only t4,t5    # filter by name
  PYTHONPATH=src python -m benchmarks.run --smoke         # fast sanity pass
  PYTHONPATH=src python -m benchmarks.run --check         # perf regression

``--smoke`` asks each module that supports it (currently the DSE
convergence and disaggregation benches) to shrink its budget — fewer
seeds / evaluations — so the whole suite finishes quickly in CI.
Modules that take a ``smoke`` keyword receive it; the rest run at full
settings.

The DSE bench additionally writes machine-readable timings to
``BENCH_dse.json`` (override the path with the ``BENCH_DSE_JSON`` env
var) so perf changes can be tracked across PRs.

``--check`` is the perf-regression gate: it reruns the DSE bench in
smoke mode and compares the fresh per-method timings against the
committed baseline (``benchmarks/BENCH_dse.json``), failing (exit 1)
when any method is slower than ``--tolerance`` times its baseline — so
future PRs can't silently re-quadratize the DSE hot path.  It also
gates the jitted perfmodel: the fresh ``jit_pool`` entry
(jitted-vs-scalar candidate-pool speedup, see bench_dse.pool_rows)
must stay above both the hard 10x floor and ``1/tolerance`` of the
baseline speedup, and must report zero jit/scalar parity mismatches —
a silent regression of the jitted path fails loudly here.  Finally it
reruns the seeded searched-system sweeps: the 4-role extreme-
heterogeneity search (bench_extreme) must keep its ``extreme_system``
tokens/joule at or above both the hard 0.276 floor (the PR 2 searched
pair) and the committed baseline, and the 3-role diffusion-LM fleet
search (bench_dllm) must keep its ``dllm_system`` tokens/joule at or
above both the hard `DLLM_TOKJ_FLOOR` (the hand-designed all-P1
fleet) and the committed baseline — each within the timing tolerance.
The batched-acquisition headline (bench_fleet) is gated too: the
seeded 1000-evaluation B=16 q-EHVI search over the 102-gene 6-role
fleet space must keep its ``fleet1000`` hypervolume at the committed
baseline and finish under both the timing tolerance and the hard
`FLEET1000_US_CEILING` (the single-digit-minutes claim).  The
``serving`` row (bench_serving) gates the SLO-constrained fleet
search: the seeded searched fleet's tokens/joule must beat BOTH the
committed baseline and a fresh naive replication of the hand-designed
system at the same power budget/rates/SLOs, and the jitted
fleet-pool scoring must stay under `SERVING_POOL_S_CEILING` seconds
and `SERVING_OVERHEAD_MAX` x the bare system path.  The
``calibration`` row (bench_calibration) gates the kernel-measured
perfmodel factors: the fitted per-geometry-class efficiency/setup
table must keep its max normalized residual under
`CAL_FIT_ERR_CEILING`, still shift at least one bundled-trace
prediction (shift > 0 — a no-op table means the calibration threading
broke), and finish within the timing tolerance.
Refresh the baselines after an intentional perf change with::

  BENCH_DSE_JSON=benchmarks/BENCH_dse.json \\
      PYTHONPATH=src python -m benchmarks.run \\
      --only "fig6,fig9,table7,fleet1000,serving,calibration" --smoke
"""

import argparse
import inspect
import json
import os
import sys
import tempfile
import traceback

MODULES = [
    ("table9_validation", "benchmarks.bench_validation"),
    ("table3_quant", "benchmarks.bench_quant"),
    ("table4_software", "benchmarks.bench_software"),
    ("table5_hierarchy", "benchmarks.bench_hierarchy"),
    ("table6_pareto", "benchmarks.bench_pareto"),
    ("fig6_dse_convergence", "benchmarks.bench_dse"),
    ("fig8_disaggregation", "benchmarks.bench_disagg"),
    ("table7_dllm", "benchmarks.bench_dllm"),
    ("table8_moe", "benchmarks.bench_moe"),
    ("fig9_extreme_heterogeneity", "benchmarks.bench_extreme"),
    ("fleet1000_batched_search", "benchmarks.bench_fleet"),
    ("serving_fleet_search", "benchmarks.bench_serving"),
    ("roofline", "benchmarks.bench_roofline"),
    ("calibration", "benchmarks.bench_calibration"),
]

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_dse.json")

# Acceptance floor for the jitted perfmodel: scoring a candidate pool
# through decode_batch + the jitted evaluator must beat the scalar
# oracle loop by at least this factor, regardless of the baseline.
JIT_SPEEDUP_FLOOR = 10.0

# Acceptance floor for the searched 4-role extreme-heterogeneity system
# (bench_extreme): its seeded tokens/joule must at least match the PR 2
# searched prefill/decode *pair* on the same workload, regardless of
# the committed baseline.
EXTREME_TOKJ_FLOOR = 0.276

# Acceptance floor for the searched 3-role diffusion-LM fleet
# (bench_dllm): its seeded tokens/joule must at least match the
# hand-designed all-P1 fleet on LLaDA-8B/OSWORLD_DLLM (each denoise
# step is a full-sequence pass, so the on-chip-heavy prefill device is
# the strongest hand-designed choice for every role).
DLLM_TOKJ_FLOOR = 0.0034

# Hard wall-clock ceiling for the fleet1000 headline search
# (bench_fleet): the seeded 1000-evaluation batched q-EHVI sweep over
# the 102-gene SystemSpace(6) must finish in single-digit minutes on
# CI hardware, regardless of the committed baseline timing.
FLEET1000_US_CEILING = 540e6

# Hard ceilings for the serving-fleet bench (bench_serving): scoring
# its 16384-design serving pool through the jitted FleetEvaluator
# (fresh caches, post-compile) must finish inside the wall-clock
# ceiling AND cost at most SERVING_OVERHEAD_MAX x the bare
# evaluate_system_batch path on the same device halves — the queueing
# layer may not re-quadratize pool scoring.
SERVING_POOL_S_CEILING = 2.0
SERVING_OVERHEAD_MAX = 1.2

# Fit-quality ceiling for the kernel calibration row (bench_calibration):
# the max per-geometry-class normalized residual ||pred - y|| / ||y|| of
# the fitted efficiency/setup factors.  Observed ~0.44 (smoke) / ~0.58
# (full) under the Pallas interpreter on CI hardware; a fit above this
# means the measured kernel timings no longer look affine in the
# analytical cycle counts — a kernel or harness regression.
CAL_FIT_ERR_CEILING = 0.85


def compare_timings(base: dict, fresh: dict, tolerance: float) -> list:
    """Per-method regression verdicts: (method, fresh_us, limit_us, ok).

    A method regresses when its fresh ``us_per_run`` exceeds
    ``tolerance x`` its baseline; methods missing from the fresh run
    count as regressed (limit < 0 marks them)."""
    out = []
    for method, b in base.get("methods", {}).items():
        g = fresh.get("methods", {}).get(method)
        limit = b["us_per_run"] * tolerance
        if g is None:
            out.append((method, float("nan"), -1.0, False))
        else:
            out.append((method, g["us_per_run"], limit,
                        g["us_per_run"] <= limit))
    return out


def compare_jit_pool(base: dict, fresh: dict, tolerance: float):
    """Jitted-perfmodel regression verdict, or None when the baseline
    predates the jit_pool entry.

    Returns (fresh_speedup, floor, parity_mismatches, ok): the fresh
    jitted-vs-scalar pool-scoring speedup must reach both the hard
    `JIT_SPEEDUP_FLOOR` and `1/tolerance` of the baseline speedup, with
    zero parity mismatches against the scalar oracle.  A missing fresh
    entry counts as a regression (floor < 0 marks it)."""
    b = base.get("jit_pool")
    if not b or not isinstance(b.get("speedup"), (int, float)):
        return None
    g = fresh.get("jit_pool")
    if not g or not isinstance(g.get("speedup"), (int, float)):
        return (float("nan"), -1.0, 0, False)
    floor = max(JIT_SPEEDUP_FLOOR, b["speedup"] / tolerance)
    bad = int(g.get("parity_mismatches", 0))
    return (g["speedup"], floor, bad, g["speedup"] >= floor and bad == 0)


def _compare_searched_system(base: dict, fresh: dict, key: str,
                             hard_floor: float, tolerance: float):
    """Seeded searched-system regression verdict for one BENCH_dse.json
    entry (`extreme_system`, `dllm_system`), or None when the baseline
    predates it.

    Returns (fresh_tokj, tokj_floor, fresh_us, limit_us, ok): the
    seeded searched-system tokens/joule must reach both the hard
    `hard_floor` and ~the committed baseline (the search is seeded, so
    a drop means a modeling or search regression), and its runtime
    must stay within ``tolerance x`` of the baseline.  A missing fresh
    entry counts as a regression (limit < 0 marks it), and a baseline
    captured at a different search budget than the fresh smoke run is
    flagged (floor = -2: refresh the baseline with ``--smoke``) rather
    than compared apples-to-oranges."""
    b = base.get(key)
    if not b or not isinstance(b.get("tokens_per_joule"), (int, float)):
        return None
    g = fresh.get(key)
    if not g or not isinstance(g.get("tokens_per_joule"), (int, float)):
        return (float("nan"), hard_floor, float("nan"), -1.0, False)
    if b.get("n_total") != g.get("n_total"):
        return (g["tokens_per_joule"], -2.0, g["us_per_run"], -2.0, False)
    floor = max(hard_floor, b["tokens_per_joule"] * (1 - 1e-3))
    limit = b["us_per_run"] * tolerance
    ok = g["tokens_per_joule"] >= floor and g["us_per_run"] <= limit
    return (g["tokens_per_joule"], floor, g["us_per_run"], limit, ok)


def compare_extreme(base: dict, fresh: dict, tolerance: float):
    """`extreme_system` verdict: hard floor = the PR 2 searched pair."""
    return _compare_searched_system(base, fresh, "extreme_system",
                                    EXTREME_TOKJ_FLOOR, tolerance)


def compare_dllm(base: dict, fresh: dict, tolerance: float):
    """`dllm_system` verdict: hard floor = the hand-designed P1 fleet."""
    return _compare_searched_system(base, fresh, "dllm_system",
                                    DLLM_TOKJ_FLOOR, tolerance)


def compare_fleet1000(base: dict, fresh: dict, tolerance: float):
    """`fleet1000` verdict (the batched-acquisition headline search), or
    None when the baseline predates it.

    Returns (fresh_hv, hv_floor, fresh_us, limit_us, ok): the seeded
    1000-evaluation q-EHVI search must keep its achieved hypervolume at
    ~the committed baseline (seeded search: a drop means an
    acquisition, GP, or modeling regression), and its runtime must stay
    within both ``tolerance x`` the baseline and the hard
    `FLEET1000_US_CEILING` (the single-digit-minutes headline).
    Mirrors `_compare_searched_system`'s missing-entry (limit = -1) and
    budget-mismatch (floor = -2, also raised when the batch size
    differs) conventions."""
    b = base.get("fleet1000")
    if not b or not isinstance(b.get("hv"), (int, float)):
        return None
    g = fresh.get("fleet1000")
    if not g or not isinstance(g.get("hv"), (int, float)):
        return (float("nan"), float("nan"), float("nan"), -1.0, False)
    if (b.get("n_total") != g.get("n_total")
            or b.get("batch_size") != g.get("batch_size")):
        return (g["hv"], -2.0, g["us_per_run"], -2.0, False)
    floor = b["hv"] * (1 - 1e-3)
    limit = min(b["us_per_run"] * tolerance, FLEET1000_US_CEILING)
    ok = g["hv"] >= floor and g["us_per_run"] <= limit
    return (g["hv"], floor, g["us_per_run"], limit, ok)


def compare_serving(base: dict, fresh: dict, tolerance: float):
    """`serving` verdict (the SLO-constrained fleet search +
    fleet-pool microbench), or None when the baseline predates it.

    Returns (fresh_tokj, tokj_floor, pool_s, overhead, fresh_us,
    limit_us, ok).  The seeded searched fleet's aggregate tokens/joule
    must reach both the committed baseline (seeded search: a drop
    means a queueing-model or search regression) and the FRESH naive-
    replication tokens/joule — searched must beat cloning the best
    hand system at the same power budget, rates and SLO caps, every
    run.  The pool microbench must stay under `SERVING_POOL_S_CEILING`
    seconds and `SERVING_OVERHEAD_MAX` x the bare system path, and the
    search runtime within ``tolerance x`` baseline.  Mirrors
    `_compare_searched_system`'s missing-entry (limit = -1) and
    budget-mismatch (floor = -2) conventions."""
    b = base.get("serving")
    if not b or not isinstance(b.get("tokens_per_joule"), (int, float)):
        return None
    g = fresh.get("serving")
    if not g or not isinstance(g.get("tokens_per_joule"), (int, float)):
        return (float("nan"), float("nan"), float("nan"), float("nan"),
                float("nan"), -1.0, False)
    if (b.get("n_total") != g.get("n_total")
            or b.get("batch_size") != g.get("batch_size")):
        return (g["tokens_per_joule"], -2.0, float("nan"), float("nan"),
                g["us_per_run"], -2.0, False)
    floor = b["tokens_per_joule"] * (1 - 1e-3)
    naive = g.get("naive_tokens_per_joule")
    if isinstance(naive, (int, float)):
        floor = max(floor, naive)
    pool_s = g.get("pool_s")
    overhead = g.get("overhead_ratio")
    limit = b["us_per_run"] * tolerance
    ok = (g["tokens_per_joule"] >= floor
          and isinstance(pool_s, (int, float))
          and isinstance(overhead, (int, float))
          and pool_s <= SERVING_POOL_S_CEILING
          and overhead <= SERVING_OVERHEAD_MAX
          and g["us_per_run"] <= limit)
    return (g["tokens_per_joule"], floor,
            float("nan") if pool_s is None else pool_s,
            float("nan") if overhead is None else overhead,
            g["us_per_run"], limit, ok)


def compare_calibration(base: dict, fresh: dict, tolerance: float):
    """`calibration` verdict (the kernel-measured perfmodel factors),
    or None when the baseline predates it.

    Returns (fresh_fit_err, err_ceiling, fresh_shift, fresh_us,
    limit_us, ok): the fresh fit's max per-class normalized residual
    must stay under the hard `CAL_FIT_ERR_CEILING` (an affine fit of
    measured kernel cycles against the analytical model — blowing past
    the ceiling means a kernel or harness regression, not noise), the
    fitted table must still *shift* a bundled-trace prediction
    (shift > 0: a table that moves nothing is a threading regression),
    and the measure+fit runtime must stay within ``tolerance x`` of the
    baseline.  Mirrors `_compare_searched_system`'s missing-entry
    (limit = -1) convention; no budget key to mismatch — the shape
    ladders are fixed."""
    b = base.get("calibration")
    if not b or not isinstance(b.get("fit_err"), (int, float)):
        return None
    g = fresh.get("calibration")
    if not g or not isinstance(g.get("fit_err"), (int, float)):
        return (float("nan"), CAL_FIT_ERR_CEILING, float("nan"),
                float("nan"), -1.0, False)
    shift = g.get("shift")
    shift = float(shift) if isinstance(shift, (int, float)) else 0.0
    limit = b["us_per_run"] * tolerance
    ok = (g["fit_err"] <= CAL_FIT_ERR_CEILING
          and shift > 0.0
          and g["us_per_run"] <= limit)
    return (g["fit_err"], CAL_FIT_ERR_CEILING, shift,
            g["us_per_run"], limit, ok)


def check_perf(baseline_path: str, tolerance: float) -> int:
    """Fresh --smoke DSE timings vs the committed baseline.

    Returns the process exit code: 0 when every method is within
    ``tolerance x`` of its baseline ``us_per_run``, 1 on regression,
    2 when the baseline is missing/unreadable.
    """
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except (OSError, ValueError) as e:
        print(f"cannot read baseline {baseline_path}: {e}", file=sys.stderr)
        return 2
    methods = base.get("methods")
    if not methods or any(not isinstance(b.get("us_per_run"), (int, float))
                          for b in methods.values()):
        # schema-drifted / truncated baselines must not pass vacuously
        # (and must fail before the expensive fresh bench run)
        print(f"baseline {baseline_path} has no usable 'methods' timings",
              file=sys.stderr)
        return 2
    fd, fresh_path = tempfile.mkstemp(suffix="_bench_dse.json")
    os.close(fd)
    prev_json_path = os.environ.get("BENCH_DSE_JSON")
    os.environ["BENCH_DSE_JSON"] = fresh_path
    try:
        from benchmarks import (bench_calibration, bench_dllm, bench_dse,
                                bench_extreme, bench_fleet, bench_serving)
        for line in bench_dse.run(smoke=True):
            print(line)
        if base.get("extreme_system"):   # gate the system search too
            for line in bench_extreme.run(smoke=True):
                print(line)
        if base.get("dllm_system"):      # ... and the diffusion fleet
            for line in bench_dllm.run(smoke=True):
                print(line)
        if base.get("fleet1000"):        # ... and the batched headline
            for line in bench_fleet.run(smoke=True):
                print(line)
        if base.get("serving"):          # ... and the serving fleet
            for line in bench_serving.run(smoke=True):
                print(line)
        if base.get("calibration"):      # ... and the kernel factors
            for line in bench_calibration.run(smoke=True):
                print(line)
        with open(fresh_path) as f:
            fresh = json.load(f)
    finally:
        if prev_json_path is None:
            os.environ.pop("BENCH_DSE_JSON", None)
        else:
            os.environ["BENCH_DSE_JSON"] = prev_json_path
        try:
            os.unlink(fresh_path)
        except OSError:
            pass
    failures = []
    for method, got_us, limit_us, ok in compare_timings(base, fresh,
                                                        tolerance):
        if limit_us < 0:
            failures.append(f"{method}: missing from fresh run")
            continue
        print(f"check_{method},{got_us:.1f},"
              f"limit={limit_us:.1f} {'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{method}: {got_us/1e6:.2f}s/run > {tolerance:g}x "
                f"baseline {limit_us/tolerance/1e6:.2f}s/run")
    jit = compare_jit_pool(base, fresh, tolerance)
    if jit is not None:
        speedup, floor, bad, ok = jit
        if floor < 0:
            failures.append("jit_pool: missing from fresh run")
        else:
            print(f"check_jit_pool,{speedup:.1f},"
                  f"floor={floor:.1f}x parity_bad={bad} "
                  f"{'ok' if ok else 'FAIL'}")
            if bad:
                failures.append(
                    f"jit_pool: {bad} jit-vs-scalar parity mismatches "
                    f"(speedup {speedup:.1f}x)")
            if speedup < floor:
                failures.append(
                    f"jit_pool: jitted-vs-scalar speedup {speedup:.1f}x "
                    f"below floor {floor:.1f}x")
    ext = compare_extreme(base, fresh, tolerance)
    dll = compare_dllm(base, fresh, tolerance)
    # the refresh recipe reruns ALL baseline-writing modules: bench_dse
    # rewrites BENCH_dse.json from scratch, so refreshing one searched-
    # system key alone would clobber the others and silently disable
    # their gates on the next --check
    refresh_only = "fig6,fig9,table7,fleet1000,serving,calibration"
    for key, verdict in (("extreme_system", ext), ("dllm_system", dll)):
        if verdict is None:
            continue
        tokj, floor_tokj, got_us, limit_us, ok = verdict
        if floor_tokj == -2.0:
            failures.append(
                f"{key}: baseline search budget differs from the "
                "fresh --smoke run; refresh the baseline with "
                "BENCH_DSE_JSON=benchmarks/BENCH_dse.json "
                f"python -m benchmarks.run --only {refresh_only} --smoke")
        elif limit_us < 0:
            failures.append(f"{key}: missing from fresh run")
        else:
            print(f"check_{key},{got_us:.1f},"
                  f"tokJ={tokj:.4f} floor={floor_tokj:.4f} "
                  f"limit_us={limit_us:.1f} {'ok' if ok else 'FAIL'}")
            if tokj < floor_tokj:
                failures.append(
                    f"{key}: searched tokens/joule {tokj:.4f} "
                    f"below floor {floor_tokj:.4f}")
            if got_us > limit_us:
                failures.append(
                    f"{key}: {got_us/1e6:.2f}s/run > "
                    f"{tolerance:g}x baseline "
                    f"{limit_us/tolerance/1e6:.2f}s/run")
    flt = compare_fleet1000(base, fresh, tolerance)
    if flt is not None:
        hv, floor_hv, got_us, limit_us, ok = flt
        if floor_hv == -2.0:
            failures.append(
                "fleet1000: baseline search budget/batch size differs "
                "from the fresh --smoke run; refresh the baseline with "
                "BENCH_DSE_JSON=benchmarks/BENCH_dse.json "
                f"python -m benchmarks.run --only {refresh_only} --smoke")
        elif limit_us < 0:
            failures.append("fleet1000: missing from fresh run")
        else:
            print(f"check_fleet1000,{got_us:.1f},"
                  f"hv={hv:.2f} floor={floor_hv:.2f} "
                  f"limit_us={limit_us:.1f} {'ok' if ok else 'FAIL'}")
            if hv < floor_hv:
                failures.append(
                    f"fleet1000: searched hypervolume {hv:.2f} "
                    f"below floor {floor_hv:.2f}")
            if got_us > limit_us:
                failures.append(
                    f"fleet1000: {got_us/1e6:.2f}s/run > ceiling "
                    f"{limit_us/1e6:.2f}s/run (single-digit-minutes "
                    f"headline / {tolerance:g}x baseline)")
    srv = compare_serving(base, fresh, tolerance)
    if srv is not None:
        tokj, floor_tokj, pool_s, overhead, got_us, limit_us, ok = srv
        if floor_tokj == -2.0:
            failures.append(
                "serving: baseline search budget/batch size differs "
                "from the fresh --smoke run; refresh the baseline with "
                "BENCH_DSE_JSON=benchmarks/BENCH_dse.json "
                f"python -m benchmarks.run --only {refresh_only} --smoke")
        elif limit_us < 0:
            failures.append("serving: missing from fresh run")
        else:
            print(f"check_serving,{got_us:.1f},"
                  f"tokJ={tokj:.4f} floor={floor_tokj:.4f} "
                  f"pool_s={pool_s:.2f} overhead={overhead:.2f} "
                  f"limit_us={limit_us:.1f} {'ok' if ok else 'FAIL'}")
            if tokj < floor_tokj:
                failures.append(
                    f"serving: searched tokens/joule {tokj:.4f} below "
                    f"floor {floor_tokj:.4f} (max of naive replication "
                    f"and the committed baseline)")
            if not (pool_s <= SERVING_POOL_S_CEILING):
                failures.append(
                    f"serving: 16k-pool scoring {pool_s:.2f}s over the "
                    f"{SERVING_POOL_S_CEILING:g}s ceiling")
            if not (overhead <= SERVING_OVERHEAD_MAX):
                failures.append(
                    f"serving: queueing-layer overhead {overhead:.2f}x "
                    f"over the {SERVING_OVERHEAD_MAX:g}x bare-path cap")
            if got_us > limit_us:
                failures.append(
                    f"serving: {got_us/1e6:.2f}s/run > {tolerance:g}x "
                    f"baseline {limit_us/tolerance/1e6:.2f}s/run")
    cal = compare_calibration(base, fresh, tolerance)
    if cal is not None:
        fit_err, ceiling, shift, got_us, limit_us, ok = cal
        if limit_us < 0:
            failures.append("calibration: missing from fresh run")
        else:
            print(f"check_calibration,{got_us:.1f},"
                  f"fit_err={fit_err:.3f} ceiling={ceiling:g} "
                  f"shift={shift:.3f} limit_us={limit_us:.1f} "
                  f"{'ok' if ok else 'FAIL'}")
            if fit_err > ceiling:
                failures.append(
                    f"calibration: fit_err {fit_err:.3f} over the "
                    f"{ceiling:g} ceiling (measured kernel cycles no "
                    f"longer affine in the analytical model)")
            if not (shift > 0.0):
                failures.append(
                    "calibration: fitted table shifts no bundled-trace "
                    "prediction (calibration threading regression)")
            if got_us > limit_us:
                failures.append(
                    f"calibration: {got_us/1e6:.2f}s/run > {tolerance:g}x "
                    f"baseline {limit_us/tolerance/1e6:.2f}s/run")
    if failures:
        print("PERF REGRESSION:\n  " + "\n  ".join(failures), file=sys.stderr)
        return 1
    print(f"perf check passed ({len(base.get('methods', {}))} methods "
          f"within {tolerance:g}x of baseline"
          + (", jit_pool above floor" if jit is not None else "")
          + (", extreme_system above floor" if ext is not None else "")
          + (", dllm_system above floor" if dll is not None else "")
          + (", fleet1000 above floor" if flt is not None else "")
          + (", serving above floor" if srv is not None else "")
          + (", calibration within ceiling" if cal is not None else "")
          + ")")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced budgets for a fast end-to-end pass")
    ap.add_argument("--check", action="store_true",
                    help="compare fresh --smoke DSE timings against the "
                         "committed baseline; exit 1 on regression")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON for --check "
                         "(default: benchmarks/BENCH_dse.json)")
    ap.add_argument("--tolerance", type=float, default=5.0,
                    help="--check failure threshold, as a factor over the "
                         "baseline us_per_run (default 5.0: catches "
                         "order-of-magnitude regressions, tolerates "
                         "machine noise)")
    args = ap.parse_args()
    if args.check:
        print("name,us_per_call,derived")
        raise SystemExit(check_perf(args.baseline, args.tolerance))
    filters = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for title, modname in MODULES:
        if filters and not any(f in title for f in filters):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            kwargs = {}
            if args.smoke and "smoke" in inspect.signature(
                    mod.run).parameters:
                kwargs["smoke"] = True
            for line in mod.run(**kwargs):
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{title},0.0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
