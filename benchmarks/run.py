"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

Running the benchmarks
----------------------
From the repo root::

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only t4,t5    # filter by name
  PYTHONPATH=src python -m benchmarks.run --smoke         # fast sanity pass

``--smoke`` asks each module that supports it (currently the DSE
convergence bench) to shrink its budget — fewer seeds / evaluations — so
the whole suite finishes quickly in CI.  Modules that take a ``smoke``
keyword receive it; the rest run at full settings.

The DSE bench additionally writes machine-readable timings to
``BENCH_dse.json`` (override the path with the ``BENCH_DSE_JSON`` env
var) so perf changes can be tracked across PRs.
"""

import argparse
import inspect
import sys
import traceback

MODULES = [
    ("table9_validation", "benchmarks.bench_validation"),
    ("table3_quant", "benchmarks.bench_quant"),
    ("table4_software", "benchmarks.bench_software"),
    ("table5_hierarchy", "benchmarks.bench_hierarchy"),
    ("table6_pareto", "benchmarks.bench_pareto"),
    ("fig6_dse_convergence", "benchmarks.bench_dse"),
    ("fig8_disaggregation", "benchmarks.bench_disagg"),
    ("table7_dllm", "benchmarks.bench_dllm"),
    ("table8_moe", "benchmarks.bench_moe"),
    ("fig9_extreme_heterogeneity", "benchmarks.bench_extreme"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced budgets for a fast end-to-end pass")
    args = ap.parse_args()
    filters = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for title, modname in MODULES:
        if filters and not any(f in title for f in filters):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            kwargs = {}
            if args.smoke and "smoke" in inspect.signature(
                    mod.run).parameters:
                kwargs["smoke"] = True
            for line in mod.run(**kwargs):
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{title},0.0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
