"""Table 6 / Fig 7: Pareto-frontier search under the 700 W TDP budget,
separate prefill and decode DSE on the OSWorld trace (LLaMA-3.3-70B),
8/8/8 quantization fixed per Table 3."""

import numpy as np

from repro.configs.paper_models import LLAMA33_70B
from repro.core.dse import Objective, run_mobo
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import row, timed

N_TOTAL = 60


def run() -> list:
    out = []
    for phase in (Phase.PREFILL, Phase.DECODE):
        obj = Objective(LLAMA33_70B, OSWORLD_LIBREOFFICE, phase,
                        tdp_limit_w=700.0)
        res, us = timed(run_mobo, obj, n_total=N_TOTAL, seed=0)
        pareto = res.pareto()
        # Fig 7 selection rule: max token/J on the frontier under 700 W
        best = None
        for o in pareto:
            tps, negp = o.f
            tj = tps / max(1.0, -negp)
            if best is None or tj > best[0]:
                best = (tj, o)
        n_feas = sum(o.f is not None for o in res.observations)
        if best is None:
            out.append(row(f"t6_{phase.value}", us, "no feasible design"))
            continue
        _, o = best
        out.append(row(
            f"t6_{phase.value}_best", us / N_TOTAL,
            f"evals={N_TOTAL} feasible={n_feas} pareto={len(pareto)} "
            f"TPS={o.f[0]:.1f} P={-o.f[1]:.0f}W "
            f"cfg=[{o.npu.describe().replace(',', ';')}]"))
    return out
