"""Shared helpers for the per-table benchmarks."""

from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 1, **kwargs):
    """(result, microseconds per call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def fmt(x: float, nd: int = 2) -> str:
    return f"{x:.{nd}f}"
