"""Shared helpers for the per-table benchmarks."""

from __future__ import annotations

import json
import os
import time

DEFAULT_BENCH_JSON = "BENCH_dse.json"


def merge_bench_json(key: str, payload: dict) -> None:
    """Merge one top-level entry into the (possibly existing) machine-
    readable benchmark JSON (``BENCH_DSE_JSON`` env var, default
    ``BENCH_dse.json``) — bench_dse writes the file fresh earlier in
    the suite; the searched-system benches add their keys through here
    without clobbering the rest (or each other)."""
    json_path = os.environ.get("BENCH_DSE_JSON", DEFAULT_BENCH_JSON)
    data = {}
    try:
        with open(json_path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        pass                        # no/unreadable file: start fresh
    data[key] = payload
    try:
        with open(json_path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
    except OSError:
        pass                        # read-only working dir: CSV rows suffice


def timed(fn, *args, repeat: int = 1, **kwargs):
    """(result, microseconds per call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def fmt(x: float, nd: int = 2) -> str:
    return f"{x:.{nd}f}"
