"""Shared helpers for the per-table benchmarks."""

from __future__ import annotations

import gc
import json
import os
import sys
import tempfile
import time

DEFAULT_BENCH_JSON = "BENCH_dse.json"


def atomic_write_json(json_path: str, data: dict) -> None:
    """Write ``data`` to ``json_path`` atomically (temp file in the
    same directory + ``os.replace``), warning loudly on failure instead
    of swallowing it.  Every writer of a shared ``BENCH_*.json``
    artifact must go through here (or :func:`merge_bench_json`) so a
    killed bench run can never leave a truncated baseline behind — the
    ``nonatomic-artifact-write`` lint rule enforces this."""
    tmp_name = None
    try:
        fd, tmp_name = tempfile.mkstemp(
            dir=os.path.dirname(json_path) or ".",
            prefix=os.path.basename(json_path) + ".", suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp_name, json_path)
        tmp_name = None
    except OSError as exc:
        print(f"WARNING: could not update {json_path} ({exc}); the "
              f"committed baseline is UNCHANGED — --check will gate "
              f"against stale numbers", file=sys.stderr)
    finally:
        if tmp_name is not None:
            try:
                os.unlink(tmp_name)
            except OSError:
                pass


def merge_bench_json(key: str, payload: dict) -> None:
    """Merge one top-level entry into the (possibly existing) machine-
    readable benchmark JSON (``BENCH_DSE_JSON`` env var, default
    ``BENCH_dse.json``) — bench_dse writes the file fresh earlier in
    the suite; the searched-system benches add their keys through here
    without clobbering the rest (or each other).

    Crash-safe: the merged document is written to a temp file in the
    same directory and atomically renamed over the target, so a bench
    run killed mid-write can never leave a truncated baseline behind
    to poison the ``--check`` gates.  Write failures (read-only working
    dir, full disk) are survivable — the CSV rows on stdout still carry
    the numbers — but they are *warned about*, never swallowed: a
    ``--check`` user must know the baseline was not updated."""
    json_path = os.environ.get("BENCH_DSE_JSON", DEFAULT_BENCH_JSON)
    data = {}
    try:
        with open(json_path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        pass                        # no/unreadable file: start fresh
    data[key] = payload
    atomic_write_json(json_path, data)


def timed(fn, *args, repeat: int = 1, **kwargs):
    """(result, microseconds per call).

    Cyclic GC is drained before the clock starts and suspended inside
    the measured region (re-enabled after, pyperf-style).  Without
    this, whether a full gen-2 collection of the process's accumulated
    heap (jit caches, evaluation caches) lands inside a short timed
    region depends on the *allocation phase* — e.g. how many objects
    parsing an unrelated JSON happened to create earlier — which made
    the cheap method timings under ``--check`` fail nondeterministically
    at 15-30x their true cost."""
    was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        out = None
        for _ in range(repeat):
            out = fn(*args, **kwargs)
        dt = (time.perf_counter() - t0) / repeat
    finally:
        if was_enabled:
            gc.enable()
    return out, dt * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def fmt(x: float, nd: int = 2) -> str:
    return f"{x:.{nd}f}"
