"""Table 8: very large sparse MoE (Qwen3.5-397B-A17B, ~370 GB weights).
HBF is the load-bearing capacity tier; 3D-SRAM reduces expert-activation
traffic.  Paper: prefill-opt 3.52x, decode-opt 1.13x token/J vs the
PLENA + HBF x2 baseline."""

from repro.configs.paper_models import QWEN35_397B_A17B
from repro.core import Dataflow, make_hierarchy
from repro.core.dataflow import (BandwidthPriority, SoftwareStrategy,
                                 StoragePriority)
from repro.core.npu import NPUConfig, baseline_npu
from repro.core.perfmodel import evaluate_decode, evaluate_prefill
from repro.core.workload import OSWORLD_LIBREOFFICE

from .common import row, timed

CONFIGS = {
    "baseline": ([("SRAM", 1), ("HBF", 2)], "decode"),
    "prefill_opt": ([("3D-SRAM", 4), ("HBF", 2)], "prefill"),
    "decode_opt": ([("SRAM", 1), ("HBF", 1), ("LPDDR5X", 16)], "decode"),
}
PAPER = {"baseline": 1.00, "prefill_opt": 3.52, "decode_opt": 1.13}


def run() -> list:
    base = baseline_npu()
    strat = SoftwareStrategy(Dataflow.WEIGHT_STATIONARY,
                             StoragePriority.ACTIVATION,
                             BandwidthPriority.MATRIX)
    out = []
    npus = {name: NPUConfig(name=name, compute=base.compute,
                            hierarchy=make_hierarchy(spec), strategy=strat,
                            quant=base.quant)
            for name, (spec, _) in CONFIGS.items()}
    # phase-matched normalization: each optimized config compares against
    # the baseline hierarchy evaluated on the SAME phase
    base_prefill = evaluate_prefill(npus["baseline"], QWEN35_397B_A17B,
                                    OSWORLD_LIBREOFFICE)
    base_decode = evaluate_decode(npus["baseline"], QWEN35_397B_A17B,
                                  OSWORLD_LIBREOFFICE)
    for name, (spec, phase) in CONFIGS.items():
        fn = evaluate_prefill if phase == "prefill" else evaluate_decode
        r, us = timed(fn, npus[name], QWEN35_397B_A17B,
                      OSWORLD_LIBREOFFICE)
        ref = base_prefill if phase == "prefill" else base_decode
        out.append(row(
            f"t8_{name}_{phase}", us,
            f"power={r.avg_power_w:.0f}W batch={r.batch} "
            f"tokJ_rel={r.tokens_per_joule/ref.tokens_per_joule:.2f}x "
            f"paper={PAPER[name]:.2f}x"))
    return out
