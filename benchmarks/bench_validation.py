"""Table 9: analytic model vs transaction-level emulator cross-validation
(LLaMA-3.3-70B transformer block, prefill, seq 4096)."""

from repro.configs.paper_models import LLAMA33_70B
from repro.core import baseline_npu
from repro.core.emulator import analytic_layer_seconds, emulate_layer
from repro.core.workload import Phase

from .common import row, timed


def run() -> list:
    npu = baseline_npu()
    t_analytic, us_a = timed(
        analytic_layer_seconds, npu, LLAMA33_70B, Phase.PREFILL, 1, 4096,
        repeat=5)
    emu, us_e = timed(
        emulate_layer, npu, LLAMA33_70B, Phase.PREFILL, 1, 4096, 16,
        repeat=3)
    gap = abs(t_analytic - emu.total_s) / emu.total_s * 100
    # paper: emulator 814 ms sim / 4.15 min wall; analytic 3-24 ms wall,
    # 10-19% gap.  We report our own sim times + gap + wall costs.
    return [
        row("t9_emulator_block_ms", us_e,
            f"simulated={emu.total_s*1e3:.2f}ms"),
        row("t9_analytic_block_ms", us_a,
            f"simulated={t_analytic*1e3:.2f}ms"),
        row("t9_analytic_vs_emulator_gap", us_a + us_e,
            f"gap={gap:.1f}% (paper: 10.2%)"),
    ]
