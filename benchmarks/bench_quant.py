"""Table 3: bit-width ablation (Qwen3-32B class workload, BFCL trace).

Storage and peak-bandwidth columns are exact; the BFCL success rate is
proxied by logit-KL / top-1 agreement of a reduced real model (DESIGN.md
8.2).  Expected reproduction: 8/8/8 matches fp16-class quality at half
the storage/BW; 4/4/4 collapses."""

from repro.configs import get_arch
from repro.configs.paper_models import QWEN3_32B
from repro.core import QuantConfig, baseline_npu
from repro.core.perfmodel import class_traffic_bytes
from repro.core.quant.accuracy import quantization_quality_proxy
from repro.core.workload import BFCL_WEB_SEARCH, Phase, layer_traffic
from repro.core.workload import kv_footprint_gb, weight_footprint_gb

from .common import row, timed

CONFIGS = {
    "base_16": QuantConfig("MXINT16", "MXINT16", "MXINT16"),
    "q1_8": QuantConfig("MXINT8", "MXINT8", "MXINT8"),
    "q2_4": QuantConfig("MXINT4", "MXINT4", "MXINT4"),
}


def run() -> list:
    out = []
    proxy_cfg = get_arch("qwen3-4b").reduced(n_layers=2, d_model=128,
                                             vocab=512)
    trace = BFCL_WEB_SEARCH
    for name, q in CONFIGS.items():
        storage = (weight_footprint_gb(QWEN3_32B, q)
                   + kv_footprint_gb(QWEN3_32B, 1,
                                     trace.prompt_tokens, q))
        # peak BW requirement: decode-step raw traffic (weights + KV once)
        # / target step time (50 ms) — placement-free, like the paper's
        # Peak-BW column
        kv_step = (QWEN3_32B.kv_bytes_per_token(q) * trace.prompt_tokens)
        step_bytes = weight_footprint_gb(QWEN3_32B, q) * 1e9 + kv_step
        peak_bw_tbps = step_bytes / 0.05 / 1e12
        (metrics, us) = timed(quantization_quality_proxy, proxy_cfg, q)
        out.append(row(
            f"t3_{name}", us,
            f"storage={storage:.1f}GB peakBW={peak_bw_tbps:.1f}TB/s "
            f"top1={metrics['top1_agreement']:.3f} "
            f"kl={metrics['logit_kl']:.4f}"))
    return out
