"""Fig 8: PD-disaggregated serving — P1+D1 / P2+D2 / Base+Base NPU pairs
vs 4x A100 / 4x H100 (GPUs modeled analytically; DESIGN.md 8.3) on the
OSWorld trace, plus a *searched* pair: a seeded GP+EHVI sweep over the
34-gene `PairedSpace` (prefill and decode devices co-designed in one
run, Section 5.3) that must beat the hand-designed P1+D1 on
tokens/joule."""

from repro.configs.paper_models import LLAMA33_70B
from repro.core import baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu
from repro.core.disagg import evaluate_disaggregated
from repro.core.dse import DisaggObjective, run_mobo, shared_init
from repro.core.gpu import A100, H100, evaluate_gpu
from repro.core.quant.formats import FP16_CONFIG, QuantConfig
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import row, timed

SEARCH_N_TOTAL = 60          # acceptance setting: seeded sweep budget
SEARCH_N_INIT = 20
SEARCH_SEED = 0
SMOKE_N_TOTAL = 30


def _searched_pair(trace, n_total: int):
    """Seeded paired GP+EHVI sweep; returns the best feasible Observation."""
    obj = DisaggObjective(LLAMA33_70B, trace)
    init = shared_init(obj, SEARCH_N_INIT, seed=SEARCH_SEED)
    res = run_mobo(obj, n_total=n_total, seed=SEARCH_SEED, init=list(init))
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    return best, obj


def run(smoke: bool = False) -> list:
    out = []
    trace = OSWORLD_LIBREOFFICE
    pairs = {
        "base+base": (baseline_npu(), baseline_npu()),
        "p1+d1": (p1_npu(), d1_npu()),
        "p2+d2": (p2_npu(), d2_npu()),
    }
    results = {}
    for name, (p, d) in pairs.items():
        r, us = timed(evaluate_disaggregated, p, d, LLAMA33_70B, trace)
        results[name] = r
        out.append(row(
            f"fig8_{name}", us,
            f"TTFT={r.ttft_s:.1f}s TPSagg={r.decode_tps_aggregate:.1f} "
            f"TPSreq={r.decode_tps_per_request:.2f} "
            f"P={r.total_power_w:.0f}W tokJ={r.tokens_per_joule:.3f}"))
    for spec in (A100, H100):
        pre, us1 = timed(evaluate_gpu, spec, LLAMA33_70B, trace,
                         Phase.PREFILL, FP16_CONFIG, 4)
        dec, us2 = timed(evaluate_gpu, spec, LLAMA33_70B, trace,
                         Phase.DECODE, FP16_CONFIG, 4)
        e_tok = (pre.avg_power_w * pre.latency_s / pre.batch
                 / trace.gen_tokens + dec.energy_per_token_j)
        out.append(row(
            f"fig8_4x{spec.name.split('-')[0].lower()}", us1 + us2,
            f"TTFT={pre.latency_s/pre.batch:.1f}s "
            f"TPSagg={dec.throughput_tps:.1f} "
            f"P={pre.avg_power_w + dec.avg_power_w:.0f}W "
            f"tokJ={1.0/e_tok:.3f}"))
    # headline claims: energy-efficiency ratios vs Base and vs H100
    p1d1 = results["p1+d1"]
    base = results["base+base"]
    out.append(row(
        "fig8_claims", 0.0,
        f"p1d1_vs_base_tokJ={p1d1.tokens_per_joule/base.tokens_per_joule:.2f}x"
        f" (paper prefill 2.3x / decode 1.93x class)"))
    # searched pair: seeded GP+EHVI co-design over PairedSpace
    n_total = SMOKE_N_TOTAL if smoke else SEARCH_N_TOTAL
    (best, obj), us = timed(_searched_pair, trace, n_total)
    if best is None:
        out.append(row("fig8_searched_pair", us,
                       f"no feasible pair in {n_total} evals"))
    else:
        r = best.result
        p, d = best.npu
        out.append(row(
            "fig8_searched_pair", us,
            f"TTFT={r.ttft_s:.1f}s TPSagg={r.decode_tps_aggregate:.1f} "
            f"P={r.total_power_w:.0f}W tokJ={r.tokens_per_joule:.3f} "
            f"[{p.hierarchy.describe()} || {d.hierarchy.describe()}]"))
        out.append(row(
            "fig8_searched_vs_p1d1", 0.0,
            f"searched_tokJ={r.tokens_per_joule:.3f} vs "
            f"p1d1_tokJ={p1d1.tokens_per_joule:.3f} -> "
            f"{r.tokens_per_joule/p1d1.tokens_per_joule:.2f}x "
            f"(seed={SEARCH_SEED}, N={n_total}, "
            f"{obj.n_evals} pair evals)"))
    return out
