"""Fig 8: PD-disaggregated serving — P1+D1 / P2+D2 / Base+Base NPU pairs
vs 4x A100 / 4x H100 (GPUs modeled analytically; DESIGN.md 8.3) on the
OSWorld trace."""

from repro.configs.paper_models import LLAMA33_70B
from repro.core import baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu
from repro.core.disagg import evaluate_disaggregated
from repro.core.gpu import A100, H100, evaluate_gpu
from repro.core.quant.formats import FP16_CONFIG, QuantConfig
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

from .common import row, timed


def run() -> list:
    out = []
    trace = OSWORLD_LIBREOFFICE
    pairs = {
        "base+base": (baseline_npu(), baseline_npu()),
        "p1+d1": (p1_npu(), d1_npu()),
        "p2+d2": (p2_npu(), d2_npu()),
    }
    results = {}
    for name, (p, d) in pairs.items():
        r, us = timed(evaluate_disaggregated, p, d, LLAMA33_70B, trace)
        results[name] = r
        out.append(row(
            f"fig8_{name}", us,
            f"TTFT={r.ttft_s:.1f}s TPSagg={r.decode_tps_aggregate:.1f} "
            f"TPSreq={r.decode_tps_per_request:.2f} "
            f"P={r.total_power_w:.0f}W tokJ={r.tokens_per_joule:.3f}"))
    for spec in (A100, H100):
        pre, us1 = timed(evaluate_gpu, spec, LLAMA33_70B, trace,
                         Phase.PREFILL, FP16_CONFIG, 4)
        dec, us2 = timed(evaluate_gpu, spec, LLAMA33_70B, trace,
                         Phase.DECODE, FP16_CONFIG, 4)
        e_tok = (pre.avg_power_w * pre.latency_s / pre.batch
                 / trace.gen_tokens + dec.energy_per_token_j)
        out.append(row(
            f"fig8_4x{spec.name.split('-')[0].lower()}", us1 + us2,
            f"TTFT={pre.latency_s/pre.batch:.1f}s "
            f"TPSagg={dec.throughput_tps:.1f} "
            f"P={pre.avg_power_w + dec.avg_power_w:.0f}W "
            f"tokJ={1.0/e_tok:.3f}"))
    # headline claims: energy-efficiency ratios vs Base and vs H100
    p1d1 = results["p1+d1"]
    base = results["base+base"]
    out.append(row(
        "fig8_claims", 0.0,
        f"p1d1_vs_base_tokJ={p1d1.tokens_per_joule/base.tokens_per_joule:.2f}x"
        f" (paper prefill 2.3x / decode 1.93x class)"))
    return out
