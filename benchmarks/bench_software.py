"""Table 4: software-strategy ablation on the P1 hardware, batch 1
(OSWorld trace).  Paper: WS + Act storage + weight-favoring BW = 2.31x
token/J over the OS/Equal/Equal baseline; IS + Act-favoring BW = 0.59x."""

import dataclasses

from repro.configs.paper_models import LLAMA33_70B
from repro.core import Dataflow, p1_npu
from repro.core.dataflow import (BandwidthPriority, SoftwareStrategy,
                                 StoragePriority)
from repro.core.perfmodel import evaluate_prefill
from repro.core.workload import OSWORLD_LIBREOFFICE

from .common import row, timed

STRATEGIES = {
    "base": SoftwareStrategy(Dataflow.OUTPUT_STATIONARY,
                             StoragePriority.EQUAL, BandwidthPriority.EQUAL),
    "s1": SoftwareStrategy(Dataflow.OUTPUT_STATIONARY,
                           StoragePriority.EQUAL, BandwidthPriority.MATRIX),
    "s2": SoftwareStrategy(Dataflow.OUTPUT_STATIONARY,
                           StoragePriority.ACTIVATION,
                           BandwidthPriority.MATRIX),
    "s3": SoftwareStrategy(Dataflow.WEIGHT_STATIONARY,
                           StoragePriority.ACTIVATION,
                           BandwidthPriority.MATRIX),
    "s4": SoftwareStrategy(Dataflow.INPUT_STATIONARY,
                           StoragePriority.WEIGHT,
                           BandwidthPriority.VECTOR),
}

PAPER = {"base": 1.00, "s1": 1.32, "s2": 1.41, "s3": 2.31, "s4": 0.59}


def run() -> list:
    out = []
    results = {}
    for name, strat in STRATEGIES.items():
        npu = dataclasses.replace(p1_npu(), name=name, strategy=strat)
        r, us = timed(evaluate_prefill, npu, LLAMA33_70B,
                      OSWORLD_LIBREOFFICE, batch=1)
        results[name] = (r, us)
    base_tj = results["base"][0].tokens_per_joule
    for name, (r, us) in results.items():
        out.append(row(
            f"t4_{name}_{STRATEGIES[name].describe().replace('/', '-')}",
            us,
            f"tokJ={r.tokens_per_joule:.2f} rel={r.tokens_per_joule/base_tj:.2f}x "
            f"paper={PAPER[name]:.2f}x bneck={r.bottleneck}"))
    return out
