"""serving: SLO-constrained fleet search on a real traffic mix.

Two claims, one bench (docs/serving.md):

1. **Searched beats naive replication.**  A seeded warm-started
   GP+EHVI search over `ServingSpace(EXTREME_4ROLE, 3)` — device genes
   + per-role replica counts + per-class decode routing, 78 genes —
   on a 3-class agentic mix (chatbot + OSWorld + BFCL web-search
   traces, each with its own p99 TTFT SLO) must find a fleet with
   strictly better aggregate tokens/joule than `serving.naive_
   replication` of the hand-designed P1/P1/D1/D1 system at the same
   datacenter power budget, rates and SLO caps.  Naive replication is
   what you get without replica/routing co-search: clone the best
   single system uniformly until the queues drain.
2. **The jitted fleet evaluator is effectively free.**  Scoring a
   16384-design serving pool through `FleetEvaluator` (per-role metric
   cache + one jitted queueing fold, fresh caches, post-compile) must
   finish inside `SERVING_POOL_S_CEILING` seconds and cost at most
   `SERVING_OVERHEAD_MAX` x the bare `evaluate_system_batch` path on
   the same device halves — the queueing layer may not re-quadratize
   pool scoring.

Both are merged into ``BENCH_dse.json`` (key ``serving``) and gated by
``benchmarks/run.py --check`` (`compare_serving`).  The search budget
is NOT reduced in smoke mode — the row IS the claim and the whole
bench fits in about a minute.
"""

import time

import numpy as np

from repro.configs.paper_models import LLAMA33_70B
from repro.core.disagg import EXTREME_4ROLE, evaluate_system_batch
from repro.core.dse import ServingObjective, run_mobo, serving_warm_start
from repro.core.dse import space as sp
from repro.core.npu import d1_npu, p1_npu
from repro.core.serving import (FleetEvaluator, RequestClass, TrafficMix,
                                naive_replication)
from repro.core.workload import (BFCL_WEB_SEARCH, CHATBOT,
                                 OSWORLD_LIBREOFFICE)

from .common import merge_bench_json, row, timed

# The served traffic: a chat stream with a tight TTFT SLO plus two
# long-context agentic streams with loose ones (rates in requests/s,
# calibrated so uniform replication of the hand system is feasible at
# the budget but leaves headroom a heterogeneous fleet can convert).
RATES_RPS = (4.0, 0.02, 0.01)
TTFT_SLOS_S = (6.0, 90.0, 120.0)
POWER_BUDGET_W = 12000.0     # provisioned datacenter budget (peak W)

N_TOTAL = 96                 # search budget (same in smoke mode)
BATCH_SIZE = 16              # q-EHVI proposals per GP fit
SEARCH_N_INIT = 24
SEARCH_SEED = 0
WARM_POOL = 256

POOL_N = 16384               # fleet-pool microbench size


def _traffic_mix() -> TrafficMix:
    traces = (CHATBOT, OSWORLD_LIBREOFFICE, BFCL_WEB_SEARCH)
    return TrafficMix("agentic-3class", tuple(
        RequestClass(t, rate_rps=r, ttft_p99_slo_s=s)
        for t, r, s in zip(traces, RATES_RPS, TTFT_SLOS_S)))


def _searched_fleet(mix: TrafficMix):
    """Seeded warm-started GP+EHVI serving sweep; returns (best obs,
    objective)."""
    obj = ServingObjective(LLAMA33_70B, mix, topology=EXTREME_4ROLE,
                           power_budget_w=POWER_BUDGET_W)
    init = serving_warm_start(obj, SEARCH_N_INIT, seed=SEARCH_SEED,
                              pool=WARM_POOL)
    res = run_mobo(obj, n_total=N_TOTAL, seed=SEARCH_SEED,
                   init=list(init), batch_size=BATCH_SIZE)
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    return best, obj


def _pool_bench(out: list) -> tuple:
    """(pool_s, bare_s): fresh-cache post-compile fleet-pool scoring
    vs the bare system path on the same device halves."""
    mix = TrafficMix("pool", (RequestClass(OSWORLD_LIBREOFFICE,
                                           rate_rps=0.02),))
    space = sp.ServingSpace.for_mix(EXTREME_4ROLE, mix)
    rng = np.random.default_rng(SEARCH_SEED)
    xs = space.random_designs(rng, POOL_N)
    base = sp.SystemSpace.for_topology(EXTREME_4ROLE)
    halves = xs[:, :space.dev_genes]

    # warm both jit paths at this pool bucket (one-time XLA compiles)
    FleetEvaluator(EXTREME_4ROLE, LLAMA33_70B, mix).evaluate_genes(xs)
    systems = [base.decode(x) for x in halves]
    evaluate_system_batch(systems, EXTREME_4ROLE, LLAMA33_70B,
                          OSWORLD_LIBREOFFICE)

    fleet = FleetEvaluator(EXTREME_4ROLE, LLAMA33_70B, mix)
    t0 = time.perf_counter()
    fleet_out = fleet.evaluate_genes(xs)
    pool_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    systems = [base.decode(x) for x in halves]
    bare = evaluate_system_batch(systems, EXTREME_4ROLE, LLAMA33_70B,
                                 OSWORLD_LIBREOFFICE)
    bare_s = time.perf_counter() - t0
    n_fleet = int(fleet_out["feasible"].sum())
    n_bare = sum(1 for r in bare if r is not None)
    out.append(row(
        "serving_pool16k", pool_s * 1e6,
        f"{POOL_N}-design fleet pool in {pool_s:.3f}s "
        f"({n_fleet} stable of {n_bare} phase-feasible) vs bare "
        f"system path {bare_s:.3f}s => overhead {pool_s / bare_s:.2f}x"))
    return pool_s, bare_s


def run(smoke: bool = False) -> list:
    out = []
    mix = _traffic_mix()

    naive, naive_us = timed(
        naive_replication, [p1_npu(), p1_npu(), d1_npu(), d1_npu()],
        EXTREME_4ROLE, LLAMA33_70B, mix, POWER_BUDGET_W)
    if naive is None:
        out.append(row("serving_naive", naive_us,
                       f"naive replication infeasible at "
                       f"{POWER_BUDGET_W:.0f}W"))
        naive_tokj = None
    else:
        naive_tokj = naive.tokens_per_joule
        out.append(row(
            "serving_naive", naive_us,
            f"tokJ={naive_tokj:.4f} reps={naive.replicas} "
            f"P={naive.fleet_power_w:.0f}W "
            f"ttft99={'/'.join(f'{t:.1f}' for t in naive.ttft_p99_s)}s"))

    (best, obj), us = timed(_searched_fleet, mix)
    if best is None:
        out.append(row("serving_search", us,
                       f"no SLO-feasible fleet in {N_TOTAL} evals"))
        merge_bench_json("serving", {
            "n_total": N_TOTAL, "batch_size": BATCH_SIZE,
            "seed": SEARCH_SEED, "smoke": smoke, "us_per_run": us,
            "tokens_per_joule": None,
            "naive_tokens_per_joule": naive_tokj})
        return out
    r = best.result
    out.append(row(
        "serving_search", us,
        f"tokJ={r.tokens_per_joule:.4f} "
        f"(naive {naive_tokj if naive_tokj is None else round(naive_tokj, 4)}"
        f", {r.tokens_per_joule / naive_tokj:.2f}x) "
        f"P={r.fleet_power_w:.0f}W reps={r.replicas} "
        f"ttft99={'/'.join(f'{t:.1f}' for t in r.ttft_p99_s)}s "
        f"(seed={SEARCH_SEED}, N={N_TOTAL}, B={BATCH_SIZE}, "
        f"{obj.space.n_dims} genes)"))

    pool_s, bare_s = _pool_bench(out)
    merge_bench_json("serving", {
        "n_total": N_TOTAL, "batch_size": BATCH_SIZE,
        "seed": SEARCH_SEED, "smoke": smoke, "us_per_run": us,
        "tokens_per_joule": r.tokens_per_joule,
        "naive_tokens_per_joule": naive_tokj,
        "fleet_power_w": r.fleet_power_w,
        "replicas": list(r.replicas),
        "pool_s": pool_s,
        "pool_n": POOL_N,
        "overhead_ratio": pool_s / bare_s,
        "n_genes": obj.space.n_dims,
        "topology": EXTREME_4ROLE.name,
        "mix": mix.identity(),
        "power_budget_w": POWER_BUDGET_W,
    })
    return out
