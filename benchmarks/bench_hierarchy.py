"""Table 5: memory-hierarchy ablation with software fixed to the P1
strategy (decode on OSWorld).  Paper: 3D-SRAM x3 lifts token/J 2.62x;
adding LPDDR capacity (H2) reaches 3.06x (batch 8); HBF capacity (H3)
trades power for batch 32 at 1.55x."""

import dataclasses

from repro.configs.paper_models import LLAMA33_70B
from repro.core import Dataflow, make_hierarchy
from repro.core.dataflow import (BandwidthPriority, SoftwareStrategy,
                                 StoragePriority)
from repro.core.npu import NPUConfig, baseline_npu
from repro.core.perfmodel import evaluate_decode
from repro.core.workload import OSWORLD_LIBREOFFICE

from .common import row, timed

HIERARCHIES = {
    "base": [("SRAM", 1), ("HBM3E", 4)],
    "h1": [("3D-SRAM", 3), ("HBM3E", 4)],
    "h2": [("3D-SRAM", 3), ("HBM3E", 4), ("LPDDR5X", 8)],
    "h3": [("3D-SRAM", 3), ("HBM3E", 4), ("HBF", 2), ("LPDDR5X", 8)],
}
PAPER = {"base": (300.09, 1, 1.00), "h1": (364.74, 1, 2.62),
         "h2": (386.12, 8, 3.06), "h3": (718.96, 32, 1.55)}


def run() -> list:
    strat = SoftwareStrategy(Dataflow.WEIGHT_STATIONARY,
                             StoragePriority.ACTIVATION,
                             BandwidthPriority.MATRIX)
    base_cfg = baseline_npu()
    out = []
    results = {}
    for name, spec in HIERARCHIES.items():
        npu = NPUConfig(name=name, compute=base_cfg.compute,
                        hierarchy=make_hierarchy(spec), strategy=strat,
                        quant=base_cfg.quant)
        r, us = timed(evaluate_decode, npu, LLAMA33_70B,
                      OSWORLD_LIBREOFFICE)
        results[name] = (npu, r, us)
    base_tj = results["base"][1].tokens_per_joule
    for name, (npu, r, us) in results.items():
        pw, pb, ptj = PAPER[name]
        out.append(row(
            f"t5_{name}_{npu.hierarchy.describe().replace(' | ', '+')}",
            us,
            f"power={r.avg_power_w:.0f}W batch={r.batch} "
            f"tokJ_rel={r.tokens_per_joule/base_tj:.2f}x "
            f"paper=({pw:.0f}W b{pb} {ptj:.2f}x)"))
    return out
