"""fleet1000: the batched-acquisition headline search.

A seeded 1000-evaluation GP+EHVI search over the 102-gene
`SystemSpace(6)` of the `disagg.FLEET_6ROLE` topology (prefill
attention/FFN split + a 4-way pipelined decode fleet) on the agentic
LLaMA-3.3-70B / OSWorld-LibreOffice trace — the scale the batched
acquisition stack exists for.  One search exercises the hot path
end to end:

* `run_mobo(batch_size=16)` — kriging-believer q-EHVI, 16 proposals
  per GP fit, evaluated through one jitted `evaluate_batch` call;
* `gp.GP.fit(use_jit=True)` / `predict_batch` — the GP hot path on
  `jax.jit` (implied by `batch_size > 1`).

The search keeps the standard 2-objective formulation (tokens/joule,
-power, TTFT as a 90 s feasibility cap): dropping the cap via
`ttft_objective=True` makes nearly every valid system feasible, so
the GP training set grows toward the full 1000 points and the O(n^3)
fits — not the acquisition — dominate the wall clock (~10x slower;
the exact 3-D EHVI that such searches route through has its own
microbench bound in tests/test_acquisition_bench.py).

The result is merged into ``BENCH_dse.json`` (key ``fleet1000``) so
``benchmarks/run.py --check`` gates both the wall clock (the
single-digit-minutes headline) and the achieved hypervolume against
the committed baseline.  The budget is deliberately NOT reduced in
smoke mode: the row IS the 1000-evaluation claim, a smaller budget
would gate a different search, and the whole run fits in ~2 minutes.
"""

from repro.configs.paper_models import LLAMA33_70B
from repro.core.disagg import FLEET_6ROLE
from repro.core.dse import (SystemObjective, reference_point, run_mobo,
                            system_warm_start)
from repro.core.workload import OSWORLD_LIBREOFFICE

from .common import merge_bench_json, row, timed

N_TOTAL = 1000               # the headline budget (same in smoke mode)
BATCH_SIZE = 16              # q-EHVI proposals per GP fit
SEARCH_N_INIT = 20
SEARCH_SEED = 0
WARM_POOL = 256
TDP_LIMIT_W = 4200.0         # six 700 W sockets, one fleet budget
TTFT_CAP_S = 90.0


def _searched_fleet(n_total: int):
    """Seeded 6-role batched GP+EHVI sweep; returns (DSEResult, objective)."""
    obj = SystemObjective(LLAMA33_70B, OSWORLD_LIBREOFFICE,
                          topology=FLEET_6ROLE, tdp_limit_w=TDP_LIMIT_W,
                          ttft_cap_s=TTFT_CAP_S)
    init = system_warm_start(obj, SEARCH_N_INIT, seed=SEARCH_SEED,
                             pool=WARM_POOL)
    res = run_mobo(obj, n_total=n_total, seed=SEARCH_SEED,
                   init=list(init), batch_size=BATCH_SIZE)
    return res, obj


def run(smoke: bool = False) -> list:
    out = []
    (res, obj), us = timed(_searched_fleet, N_TOTAL)
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    if best is None:
        out.append(row("fleet1000_search", us,
                       f"no feasible fleet in {N_TOTAL} evals"))
        merge_bench_json("fleet1000", {
            "n_total": N_TOTAL, "batch_size": BATCH_SIZE,
            "seed": SEARCH_SEED, "smoke": smoke, "us_per_run": us,
            "hv": None, "tokens_per_joule": None})
        return out
    fs = res.feasible_f()
    hv = float(res.hv_history(reference_point(fs))[-1])
    r = best.result
    out.append(row(
        "fleet1000_search", us,
        f"hv={hv:.2f} tokJ={r.tokens_per_joule:.4f} TTFT={r.ttft_s:.1f}s "
        f"P={r.total_power_w:.0f}W n_feas={len(feas)} "
        f"(seed={SEARCH_SEED}, N={N_TOTAL}, B={BATCH_SIZE}, "
        f"{obj.space.n_dims} genes, {obj.n_evals} system evals)"))
    out.append(row(
        "fleet1000_devices", 0.0,
        " || ".join(f"{role.name}:{cfg.hierarchy.describe()}"
                    for role, cfg in zip(FLEET_6ROLE.roles, best.npu))))
    merge_bench_json("fleet1000", {
        "n_total": N_TOTAL, "batch_size": BATCH_SIZE,
        "seed": SEARCH_SEED, "smoke": smoke, "us_per_run": us,
        "hv": hv,
        "tokens_per_joule": r.tokens_per_joule,
        "ttft_s": r.ttft_s,
        "total_power_w": r.total_power_w,
        "n_evals": obj.n_evals,
        "n_genes": obj.space.n_dims,
        "topology": FLEET_6ROLE.name,
        "tdp_limit_w": TDP_LIMIT_W,
    })
    return out
