"""Fig 9: extreme heterogeneity — per-layer-group (Attention vs FFN)
prefill profiles and early/late decode-phase splits for the P1 and D1
devices."""

from repro.configs.paper_models import LLAMA33_70B
from repro.core import d1_npu, p1_npu
from repro.core.disagg import decode_phase_profile, prefill_layer_group_profile
from repro.core.workload import OSWORLD_LIBREOFFICE

from .common import row, timed


def run() -> list:
    out = []
    for npu in (p1_npu(), d1_npu()):
        prof, us = timed(prefill_layer_group_profile, npu, LLAMA33_70B,
                         OSWORLD_LIBREOFFICE)
        out.append(row(
            f"fig9_prefill_groups_{npu.name.lower()}", us,
            f"attn={prof.attn_seconds*1e3:.1f}ms({prof.attn_bottleneck}) "
            f"ffn={prof.ffn_seconds*1e3:.1f}ms({prof.ffn_bottleneck})"))
    for npu in (p1_npu(), d1_npu()):
        prof, us = timed(decode_phase_profile, npu, LLAMA33_70B,
                         OSWORLD_LIBREOFFICE, 8)
        out.append(row(
            f"fig9_decode_phases_{npu.name.lower()}", us,
            f"early={prof.early_step_s*1e3:.1f}ms "
            f"late={prof.late_step_s*1e3:.1f}ms "
            f"({prof.early_bottleneck}->{prof.late_bottleneck})"))
    return out
