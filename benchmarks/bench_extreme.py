"""Fig 9 / Section 5.5: extreme heterogeneity.

Profiles: per-layer-group (Attention vs FFN) prefill splits and
early/late decode-phase splits for the P1 and D1 devices.

Search: a *searched* 4-role system — prefill-attn / prefill-ffn /
decode-early / decode-late co-designed in one seeded GP+EHVI sweep over
the 68-gene `SystemSpace` (warm-started from per-role champions of a
scored single-device pool), which must beat the PR 2 searched *pair* on
tokens/joule.  The result is merged into ``BENCH_dse.json`` (key
``extreme_system``) so ``benchmarks/run.py --check`` can gate both its
timing and its achieved tokens/joule.
"""

from repro.configs.paper_models import LLAMA33_70B
from repro.core import d1_npu, p1_npu
from repro.core.disagg import (EXTREME_4ROLE, decode_phase_profile,
                               prefill_layer_group_profile)
from repro.core.dse import SystemObjective, run_mobo, system_warm_start
from repro.core.workload import OSWORLD_LIBREOFFICE

from .common import merge_bench_json, row, timed

SEARCH_N_TOTAL = 60          # acceptance setting: seeded sweep budget
SEARCH_N_INIT = 20
SEARCH_SEED = 0
SMOKE_N_TOTAL = 40
TDP_LIMIT_W = 2800.0         # four 700 W sockets, one system budget
TTFT_CAP_S = 90.0


def _searched_system(trace, n_total: int):
    """Seeded 4-role GP+EHVI sweep; returns (best Observation, objective)."""
    obj = SystemObjective(LLAMA33_70B, trace, topology=EXTREME_4ROLE,
                          tdp_limit_w=TDP_LIMIT_W, ttft_cap_s=TTFT_CAP_S)
    init = system_warm_start(obj, SEARCH_N_INIT, seed=SEARCH_SEED)
    res = run_mobo(obj, n_total=n_total, seed=SEARCH_SEED, init=list(init))
    feas = [o for o in res.observations if o.f is not None]
    best = max(feas, key=lambda o: o.f[0], default=None)
    return best, obj


def run(smoke: bool = False) -> list:
    out = []
    for npu in (p1_npu(), d1_npu()):
        prof, us = timed(prefill_layer_group_profile, npu, LLAMA33_70B,
                         OSWORLD_LIBREOFFICE)
        out.append(row(
            f"fig9_prefill_groups_{npu.name.lower()}", us,
            f"attn={prof.attn_seconds*1e3:.1f}ms({prof.attn_bottleneck}) "
            f"ffn={prof.ffn_seconds*1e3:.1f}ms({prof.ffn_bottleneck})"))
    for npu in (p1_npu(), d1_npu()):
        prof, us = timed(decode_phase_profile, npu, LLAMA33_70B,
                         OSWORLD_LIBREOFFICE, 8)
        out.append(row(
            f"fig9_decode_phases_{npu.name.lower()}", us,
            f"early={prof.early_step_s*1e3:.1f}ms "
            f"late={prof.late_step_s*1e3:.1f}ms "
            f"({prof.early_bottleneck}->{prof.late_bottleneck})"))
    # searched 4-role system: seeded GP+EHVI co-design over SystemSpace
    n_total = SMOKE_N_TOTAL if smoke else SEARCH_N_TOTAL
    (best, obj), us = timed(_searched_system, OSWORLD_LIBREOFFICE, n_total)
    if best is None:
        out.append(row("fig9_searched_system", us,
                       f"no feasible system in {n_total} evals"))
        merge_bench_json("extreme_system", {
            "n_total": n_total, "seed": SEARCH_SEED,
            "smoke": smoke, "us_per_run": us,
            "tokens_per_joule": None})
        return out
    r = best.result
    out.append(row(
        "fig9_searched_system", us,
        f"TTFT={r.ttft_s:.1f}s TPSagg={r.decode_tps_aggregate:.1f} "
        f"P={r.total_power_w:.0f}W tokJ={r.tokens_per_joule:.3f} "
        f"(seed={SEARCH_SEED}, N={n_total}, {obj.n_evals} system evals)"))
    out.append(row(
        "fig9_searched_system_devices", 0.0,
        " || ".join(f"{role.name}:{cfg.hierarchy.describe()}"
                    for role, cfg in zip(EXTREME_4ROLE.roles, best.npu))))
    merge_bench_json("extreme_system", {
        "n_total": n_total, "seed": SEARCH_SEED, "smoke": smoke,
        "us_per_run": us,
        "tokens_per_joule": r.tokens_per_joule,
        "ttft_s": r.ttft_s,
        "total_power_w": r.total_power_w,
        "n_evals": obj.n_evals,
        "topology": EXTREME_4ROLE.name,
        "tdp_limit_w": TDP_LIMIT_W,
    })
    return out
