"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mx_quant import MX_BLOCK, mx_dequantize, mx_quantize


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("b,s,hq,hkv,dh", [
    (1, 128, 4, 4, 64),       # MHA
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 512, 8, 8, 128),      # bigger head_dim
    (2, 128, 16, 8, 128),     # GQA 2:1
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, hq, hkv, dh, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = flash_attention(q, k, v, n_kv_heads=hkv, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, n_kv_heads=hkv)
    assert out.shape == want.shape and out.dtype == dtype
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert float(err) < _tol(dtype), f"err={float(err)}"


@pytest.mark.parametrize("window", [32, 100])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, n_kv_heads=2, window=window,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, n_kv_heads=2, window=window)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5


def test_flash_noncausal():
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 4, 64), jnp.float32)
    out = flash_attention(q, k, v, n_kv_heads=4, causal=False,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, n_kv_heads=4, causal=False)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5


@pytest.mark.parametrize("b,hq,hkv,dh,skv,t", [
    (2, 8, 4, 64, 256, 100),
    (1, 8, 8, 128, 512, 511),
    (4, 16, 2, 64, 256, 0),      # first token
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, dh, skv, t, dtype):
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh), dtype)
    out = decode_attention(q, k, v, jnp.int32(t), n_kv_heads=hkv,
                           block_k=64)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(t), n_kv_heads=hkv)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert float(err) < _tol(dtype)


def test_decode_attention_window_and_ring():
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (2, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 64), jnp.float32)
    for t, kwargs in [(100, dict(window=50)), (200, dict(ring=True))]:
        out = decode_attention(q, k, v, jnp.int32(t), n_kv_heads=2,
                               block_k=64, **kwargs)
        want = ref.decode_attention_ref(q, k, v, jnp.int32(t),
                                        n_kv_heads=2, **kwargs)
        assert float(jnp.max(jnp.abs(out - want))) < 2e-5


@pytest.mark.parametrize("n,d", [(64, 64), (256, 256), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mx_quant_sweep(n, d, dtype):
    x = jax.random.normal(jax.random.key(5), (n, d), dtype) * 4.0
    q, s = mx_quantize(x, block_n=64)
    rq, rs = ref.mx_quantize_ref(x)
    assert jnp.array_equal(q, rq)
    assert jnp.allclose(s, rs)
    xd = mx_dequantize(q, s, block_n=64)
    rel = jnp.linalg.norm(xd - x.astype(jnp.float32)) / \
        jnp.linalg.norm(x.astype(jnp.float32))
    assert float(rel) < 0.02      # int8 block quant keeps ~1% error


def test_mx_quant_zero_block():
    x = jnp.zeros((64, MX_BLOCK * 2), jnp.float32)
    q, s = mx_quantize(x, block_n=64)
    assert jnp.array_equal(q, jnp.zeros_like(q))
    xd = mx_dequantize(q, s, block_n=64)
    assert jnp.array_equal(xd, x)


def test_flash_matches_model_chunked_path():
    """Kernel vs the model's XLA fallback (sdpa_chunked) — same math."""
    from repro.models import layers as L
    ks = jax.random.split(jax.random.key(6), 3)
    q = jax.random.normal(ks[0], (2, 256, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 4, 64), jnp.float32)
    kern = flash_attention(q, k, v, n_kv_heads=4, block_q=64, block_k=64)
    xla = L.sdpa_chunked(q, k, v, 2, 0, causal=True)
    assert float(jnp.max(jnp.abs(kern - xla))) < 2e-5
