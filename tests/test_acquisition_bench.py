"""Acquisition microbench: per-call bounds on the MOBO hot path.

`bench`-marked acceptance bounds for the two per-iteration costs the
batched fleet-scale search (benchmarks/bench_fleet.py) multiplies by
B x n_iterations: exact 3-D EHVI scoring of a full candidate pool and
the jitted GP batched posterior predict.  The bounds are ~10x the
measured per-call times on CI hardware — they catch an accidental
re-quadratization (per-candidate Python loops, per-call recompilation),
not machine noise.  scripts/ci.sh runs these as its acquisition
microbench stage (`pytest -m bench`).
"""

import time

import numpy as np
import pytest

from repro.core.dse import ehvi_2d, ehvi_3d
from repro.core.dse.gp import GP

POOL = 256                   # the run_mobo default candidate pool
FRONT = 60                   # a deep-search incumbent front

EHVI3D_MS_PER_CALL = 100.0
EHVI2D_MS_PER_CALL = 20.0
GP_PREDICT_MS_PER_CALL = 50.0
SERVING_MS_PER_CALL = 150.0


def _best_of(fn, repeat=5):
    """Best-of-N wall time in ms (robust to one-off scheduler noise)."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


@pytest.mark.bench
def test_exact_ehvi_3d_per_call_bound():
    """Scoring a 256-candidate pool against a 60-point 3-D front stays
    a handful of vectorized array ops (O(m^2) boxes, one [n_cand,
    n_box] pass per objective) — not a per-candidate Python loop."""
    rng = np.random.default_rng(41)
    front = rng.normal(size=(FRONT, 3)) * 2.0
    ref = front.min(axis=0) - 1.0
    mu = rng.normal(size=(POOL, 3)) * 2.0
    sd = rng.uniform(0.3, 1.5, size=(POOL, 3))
    ehvi_3d(front, ref, mu, sd)                 # warm-up
    ms = _best_of(lambda: ehvi_3d(front, ref, mu, sd))
    assert ms < EHVI3D_MS_PER_CALL, f"ehvi_3d {ms:.1f} ms/call"


@pytest.mark.bench
def test_exact_ehvi_2d_per_call_bound():
    rng = np.random.default_rng(42)
    front = rng.normal(size=(FRONT, 2)) * 2.0
    ref = front.min(axis=0) - 1.0
    mu = rng.normal(size=(POOL, 2)) * 2.0
    sd = rng.uniform(0.3, 1.5, size=(POOL, 2))
    ehvi_2d(front, ref, mu, sd)                 # warm-up
    ms = _best_of(lambda: ehvi_2d(front, ref, mu, sd))
    assert ms < EHVI2D_MS_PER_CALL, f"ehvi_2d {ms:.1f} ms/call"


@pytest.mark.bench
def test_gp_jit_predict_batch_per_call_bound():
    """Batched jitted posterior predict on a fitted 64-point GP over a
    256-query pool: after the first (compiling) call, the per-call cost
    is one jitted kernel dispatch, and repeated calls at the same
    bucketed shape must not retrace."""
    rng = np.random.default_rng(43)
    x = rng.uniform(size=(64, 16))
    y = np.sin(3.0 * x[:, 0]) + rng.normal(size=64) * 0.1
    gp = GP.fit(x, y, use_jit=True)
    xq = rng.uniform(size=(POOL, 16))
    gp.predict_batch(xq)                        # compile + warm-up
    ms = _best_of(lambda: gp.predict_batch(xq))
    assert ms < GP_PREDICT_MS_PER_CALL, f"predict_batch {ms:.1f} ms/call"
    # parity spot-check rides along: the jitted batch path matches the
    # NumPy oracle on the same queries
    mu0, sd0 = gp.predict(xq)
    mu1, sd1 = gp.predict_batch(xq)
    assert np.allclose(mu1, mu0, rtol=0, atol=1e-9)
    assert np.allclose(sd1, sd0, rtol=0, atol=1e-9)


@pytest.mark.bench
def test_serving_fold_per_call_bound():
    """Warm-cache fleet scoring of a 512-design serving pool — the
    per-iteration cost `ServingObjective.evaluate_batch` pays inside
    the search loop — stays one metric-cache gather plus one jitted
    queueing-fold dispatch, not a per-design Python loop.  (The full
    fresh-cache 16k-pool ceiling lives in benchmarks/bench_serving.py.)
    """
    from repro.configs.paper_models import LLAMA33_70B
    from repro.core.disagg import PD_PAIR
    from repro.core.dse import space as sp
    from repro.core.serving import (FleetEvaluator, RequestClass,
                                    TrafficMix)
    from repro.core.workload import CHATBOT

    mix = TrafficMix("bench", (RequestClass(CHATBOT, rate_rps=2.0),))
    space = sp.ServingSpace.for_mix(PD_PAIR, mix)
    rng = np.random.default_rng(44)
    xs = space.random_designs(rng, 512)
    fleet = FleetEvaluator(PD_PAIR, LLAMA33_70B, mix)
    fleet.evaluate_genes(xs)                    # compile + fill caches
    ms = _best_of(lambda: fleet.evaluate_genes(xs))
    assert ms < SERVING_MS_PER_CALL, f"serving fold {ms:.1f} ms/call"
