"""Jitted structure-of-arrays perfmodel vs the scalar oracle.

The contract (perfmodel.py module docstring): `perfmodel.evaluate` is
the reference implementation; the jitted batch path must reproduce it
at rtol 1e-5 with IDENTICAL feasibility decisions — same
`InfeasibleConfig` set, same capacity-derived max batch, no float32
off-by-one at the capacity boundary.  Since the denoise-step tables
landed, coverage includes diffusion-LM decode — property-tested over
random valid designs x DLLM model variants x traces, with its boundary
behaviors (steps clamp at 1, the place-data gate on full-sequence
state, `context_override` as the denoised sequence length) asserted
explicitly.

The companion regression — that routing the searchers through the
jitted path leaves the sha-pinned PR 2 seeded trajectories
byte-identical — is asserted by
tests/test_disagg_dse.py::test_single_device_trajectories_unchanged.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.paper_models import LLADA_8B, LLAMA33_70B, QWEN3_32B
from repro.core import baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu
from repro.core import perfmodel_jit as pj
from repro.core.dse import space as sp
from repro.core.perfmodel import (InfeasibleConfig, evaluate,
                                  evaluate_batch, evaluate_decode,
                                  max_decode_batch, max_prefill_batch)
from repro.core.workload import (BFCL_DLLM, GSM8K_DLLM, OSWORLD_DLLM,
                                 OSWORLD_LIBREOFFICE, Family, Phase)

RTOL = 1e-5
FIELDS = ("latency_s", "tokens", "throughput_tps", "avg_power_w",
          "energy_per_token_j", "compute_time_s", "memory_time_s")


def _scalar(npu, dims, phase, batch=None, trace=OSWORLD_LIBREOFFICE,
            context_override=None):
    try:
        return evaluate(npu, dims, trace, phase, batch=batch,
                        context_override=context_override)
    except (InfeasibleConfig, ValueError):
        return None


def _assert_match(want, got, label):
    assert (want is None) == (got is None), f"feasibility differs @ {label}"
    if want is None:
        return
    assert got.batch == want.batch, f"max batch differs @ {label}"
    assert got.bottleneck == want.bottleneck, label
    for f in FIELDS:
        assert getattr(got, f) == pytest.approx(
            getattr(want, f), rel=RTOL), f"{f} @ {label}"
    for k, v in want.mem_breakdown.items():
        assert got.mem_breakdown[k] == pytest.approx(v, rel=RTOL), \
            f"breakdown {k} @ {label}"


def _valid_single_designs(seed, n):
    rng = np.random.default_rng(seed)
    xs = sp.random_designs(rng, 4 * n)
    xs = xs[sp.valid_mask(xs)]
    assert len(xs) >= n, "raw validity unexpectedly low"
    return xs[:n]


# ---------------------------------------------------------------------------
# Property test: >= 200 random valid designs x 2 paper models x 2 phases
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def design_pool():
    xs = _valid_single_designs(0, 220)
    return xs, sp.decode_batch(xs), [sp.decode(x) for x in xs]


@pytest.mark.parametrize("dims", [QWEN3_32B, LLAMA33_70B],
                         ids=lambda d: d.name)
@pytest.mark.parametrize("phase", [Phase.PREFILL, Phase.DECODE],
                         ids=lambda p: p.value)
def test_jit_matches_scalar_on_random_designs(design_pool, dims, phase):
    xs, table, npus = design_pool
    got = pj.evaluate_batch_table(table, dims, OSWORLD_LIBREOFFICE, phase)
    assert len(got) == len(xs) >= 200
    n_feasible = 0
    for x, npu, g in zip(xs, npus, got):
        want = _scalar(npu, dims, phase)
        n_feasible += want is not None
        _assert_match(want, g, f"{dims.name}/{phase.value}/{list(x)}")
    assert n_feasible >= len(xs) // 2      # the sweep exercises real designs


@pytest.mark.parametrize("phase", [Phase.PREFILL, Phase.DECODE],
                         ids=lambda p: p.value)
def test_jit_matches_scalar_on_paired_halves(phase):
    ps = sp.PairedSpace()
    rng = np.random.default_rng(3)
    pairs = ps.random_designs(rng, 48)
    pre_tab, dec_tab = ps.decode_batch(pairs)
    half_tab = pre_tab if phase is Phase.PREFILL else dec_tab
    half_xs = pairs[:, :sp.N_DIMS] if phase is Phase.PREFILL \
        else pairs[:, sp.N_DIMS:]
    got = pj.evaluate_batch_table(half_tab, QWEN3_32B,
                                  OSWORLD_LIBREOFFICE, phase)
    for x, g in zip(half_xs, got):
        want = _scalar(sp.decode(x), QWEN3_32B, phase)
        _assert_match(want, g, f"paired/{phase.value}/{list(x)}")


# ---------------------------------------------------------------------------
# Feasibility boundary: the jitted mask must reject exactly the designs
# whose scalar max_*_batch raises InfeasibleConfig, and agree on the
# capacity-maximal batch (no float32 off-by-one in the capacity sums).
# ---------------------------------------------------------------------------

def test_feasibility_boundary_and_max_batch(design_pool):
    xs, table, npus = design_pool
    for phase, max_batch in ((Phase.PREFILL, max_prefill_batch),
                             (Phase.DECODE, max_decode_batch)):
        arrs = pj.evaluate_batch_arrays(table, LLAMA33_70B,
                                        OSWORLD_LIBREOFFICE, phase)
        for i, npu in enumerate(npus):
            try:
                want = max_batch(npu, LLAMA33_70B, OSWORLD_LIBREOFFICE)
            except InfeasibleConfig:
                want = None
            if want is None:
                assert not arrs["feasible"][i], npu.name
            else:
                assert arrs["feasible"][i], npu.name
                assert int(arrs["batch"][i]) == want, npu.name


def test_explicit_batch_override_parity():
    xs = _valid_single_designs(7, 24)
    table = sp.decode_batch(xs)
    npus = [sp.decode(x) for x in xs]
    # batch=4 is feasible for some designs and capacity-infeasible for
    # others -> exercises the place_data (+1e-9 slack) gate both ways
    for phase in (Phase.PREFILL, Phase.DECODE):
        got = pj.evaluate_batch_table(table, QWEN3_32B,
                                      OSWORLD_LIBREOFFICE, phase, batch=4)
        statuses = {g is not None for g in got}
        for x, npu, g in zip(xs, npus, got):
            want = _scalar(npu, QWEN3_32B, phase, batch=4)
            _assert_match(want, g, f"batch=4/{phase.value}/{list(x)}")
        assert statuses, "empty batch"


# ---------------------------------------------------------------------------
# Object-API routing (evaluate_batch -> NPUTable.from_configs)
# ---------------------------------------------------------------------------

def test_evaluate_batch_routes_table6_configs_through_jit():
    npus = [baseline_npu(), p1_npu(), d1_npu(), p2_npu(), d2_npu()]
    for phase in (Phase.PREFILL, Phase.DECODE):
        got = evaluate_batch(npus, LLAMA33_70B, OSWORLD_LIBREOFFICE, phase)
        ref = evaluate_batch(npus, LLAMA33_70B, OSWORLD_LIBREOFFICE, phase,
                             use_jit=False)
        for npu, g, w in zip(npus, got, ref):
            _assert_match(w, g, f"table6/{npu.name}/{phase.value}")


# ---------------------------------------------------------------------------
# Diffusion-LM decode: the denoise-step tables replaced the scalar
# fallback — the jitted path must cover every (family, phase) pair and
# reproduce `_evaluate_dllm_decode` exactly.
# ---------------------------------------------------------------------------

DLLM_VARIANTS = (
    LLADA_8B,
    dataclasses.replace(LLADA_8B, name="llada-8b-2spt",
                        diffusion_steps_per_token=2.0),
    # gen * steps_per_token < 1 for every trace here: the steps clamp
    dataclasses.replace(LLADA_8B, name="llada-8b-clamp",
                        diffusion_steps_per_token=1e-3),
)


def test_supports_covers_every_family_phase():
    """No scalar routing fallback remains: every (family, phase) pair is
    jitted (the DLLM decode carve-out was the last one)."""
    for fam in Family:
        dims = dataclasses.replace(LLADA_8B, family=fam)
        for phase in Phase:
            assert pj.supports(dims, phase), (fam, phase)


@pytest.mark.parametrize("dims", DLLM_VARIANTS, ids=lambda d: d.name)
@pytest.mark.parametrize("trace", [GSM8K_DLLM, OSWORLD_DLLM],
                         ids=lambda t: t.name)
def test_dllm_decode_jit_matches_scalar(design_pool, dims, trace):
    xs, table, npus = design_pool
    got = pj.evaluate_batch_table(table, dims, trace, Phase.DECODE)
    n_feasible = 0
    for x, npu, g in zip(xs, npus, got):
        want = _scalar(npu, dims, Phase.DECODE, trace=trace)
        n_feasible += want is not None
        _assert_match(want, g, f"{dims.name}/{trace.name}/{list(x)}")
    assert n_feasible >= len(xs) // 4  # the agentic trace rejects some


def test_dllm_steps_clamp_at_one(design_pool):
    """gen_tokens * diffusion_steps_per_token below 1 clamps to exactly
    one denoise pass: two sub-threshold step rates score identically,
    while the paper's 0.25 (50 passes on GSM8K) must not."""
    _, table, _ = design_pool
    tiny = dataclasses.replace(LLADA_8B, name="llada-tiny-spt",
                               diffusion_steps_per_token=1e-6)
    small = dataclasses.replace(LLADA_8B, name="llada-small-spt",
                                diffusion_steps_per_token=1e-3)
    r_tiny = pj.evaluate_batch_table(table, tiny, GSM8K_DLLM, Phase.DECODE)
    r_small = pj.evaluate_batch_table(table, small, GSM8K_DLLM,
                                      Phase.DECODE)
    r_full = pj.evaluate_batch_table(table, LLADA_8B, GSM8K_DLLM,
                                     Phase.DECODE)
    n_feasible = 0
    for t_, s_, f_ in zip(r_tiny, r_small, r_full):
        assert (t_ is None) == (s_ is None) == (f_ is None)
        if t_ is None:
            continue
        n_feasible += 1
        assert t_.latency_s == s_.latency_s          # both clamped to 1
        assert t_.energy_per_token_j == s_.energy_per_token_j
        # 0.25 steps/token * 200 gen = 50 denoise passes
        assert f_.latency_s == pytest.approx(50.0 * t_.latency_s, rel=RTOL)
    assert n_feasible > 0


def test_dllm_context_override_capacity_vs_traffic():
    """`context_override` on DLLM decode is now DEFINED: it shortens the
    sequence each denoise step reprocesses (traffic side) while the
    capacity/batch decision stays at the full context — so feasibility
    and max batch match the no-override evaluation, but the step gets
    cheaper.  Parity with the scalar oracle at rtol 1e-5."""
    xs = _valid_single_designs(5, 48)
    table = sp.decode_batch(xs)
    npus = [sp.decode(x) for x in xs]
    trace = OSWORLD_DLLM
    ctx = trace.prompt_tokens + trace.gen_tokens // 4
    got = pj.evaluate_batch_table(table, LLADA_8B, trace, Phase.DECODE,
                                  context_override=ctx)
    base = pj.evaluate_batch_table(table, LLADA_8B, trace, Phase.DECODE)
    n_feasible = 0
    for x, npu, g, b0 in zip(xs, npus, got, base):
        want = _scalar(npu, LLADA_8B, Phase.DECODE, trace=trace,
                       context_override=ctx)
        _assert_match(want, g, f"dllm-ctx/{list(x)}")
        assert (g is None) == (b0 is None)   # capacity at full context
        if g is None:
            continue
        n_feasible += 1
        assert g.batch == b0.batch           # ... so same max batch
        assert g.latency_s < b0.latency_s    # shorter denoised sequence
    assert n_feasible > 0


def test_dllm_context_override_accepted_through_scalar_and_batch():
    """The old ValueError is gone on both paths, and they agree."""
    ctx = GSM8K_DLLM.prompt_tokens + GSM8K_DLLM.gen_tokens // 4
    want = evaluate_decode(p1_npu(), LLADA_8B, GSM8K_DLLM,
                           context_override=ctx)
    got = evaluate_batch([p1_npu()], LLADA_8B, GSM8K_DLLM, Phase.DECODE,
                         context_override=ctx)[0]
    _assert_match(want, got, "dllm-ctx-batch")
    full = evaluate_decode(p1_npu(), LLADA_8B, GSM8K_DLLM)
    assert want.batch == full.batch
    assert want.latency_s < full.latency_s


def test_dllm_explicit_batch_place_gate_parity():
    """Explicit-batch DLLM decode exercises the full-sequence place_data
    gate both ways: max_decode_batch's q=1 selection rule never runs,
    so feasibility is exactly `place_data` on (weights, full-sequence
    activations, full-context KV) — probed on the longest-context
    agentic trace (BFCL_DLLM, 119k tokens), where the gate bites
    hardest."""
    xs = _valid_single_designs(9, 48)
    table = sp.decode_batch(xs)
    npus = [sp.decode(x) for x in xs]
    statuses = set()
    for b in (8, 64):
        got = pj.evaluate_batch_table(table, LLADA_8B, BFCL_DLLM,
                                      Phase.DECODE, batch=b)
        for x, npu, g in zip(xs, npus, got):
            want = _scalar(npu, LLADA_8B, Phase.DECODE, batch=b,
                           trace=BFCL_DLLM)
            _assert_match(want, g, f"dllm-batch={b}/{list(x)}")
            statuses.add(g is not None)
    assert statuses == {True, False}   # the gate rejected AND accepted


def test_dllm_decode_routes_through_jit(monkeypatch):
    """evaluate_batch must score DLLM decode through the jitted program,
    not the oracle loop (which now exists for parity/opt-out only)."""
    import repro.core.perfmodel as pm

    def boom(*a, **k):
        raise AssertionError("scalar oracle must not route batch evals")

    monkeypatch.setattr(pm, "_evaluate_batch_scalar", boom)
    npus = [p1_npu(), d2_npu()]
    got = evaluate_batch(npus, LLADA_8B, GSM8K_DLLM, Phase.DECODE)
    assert any(g is not None for g in got)
    monkeypatch.undo()
    ref = evaluate_batch(npus, LLADA_8B, GSM8K_DLLM, Phase.DECODE,
                         use_jit=False)
    for npu, g, w in zip(npus, got, ref):
        _assert_match(w, g, f"dllm-routing/{npu.name}")


def test_evaluate_batch_cache_and_keys_semantics():
    npus = [p1_npu(), d1_npu(), p1_npu()]
    cache = {}
    keys = [n.name for n in npus]
    got = evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                         Phase.PREFILL, keys=keys, cache=cache)
    assert set(cache) == {"P1", "D1"}
    again = evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                           Phase.PREFILL, keys=keys, cache=cache)
    for a, b in zip(got, again):
        assert (a is None) == (b is None)
        if a is not None:
            assert b.throughput_tps == a.throughput_tps
    with pytest.raises(ValueError, match="keys for"):
        evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                       Phase.PREFILL, keys=keys[:1])
    # a None key opts a config out of caching: evaluated, never stored
    cache2 = {}
    got2 = evaluate_batch([p1_npu(), d1_npu()], QWEN3_32B,
                          OSWORLD_LIBREOFFICE, Phase.PREFILL,
                          keys=[None, "D1"], cache=cache2)
    assert set(cache2) == {"D1"}
    assert got2[0] is not None
    assert got2[0].throughput_tps == got[0].throughput_tps
