"""Jitted structure-of-arrays perfmodel vs the scalar oracle.

The contract (perfmodel.py module docstring): `perfmodel.evaluate` is
the reference implementation; the jitted batch path must reproduce it
at rtol 1e-5 with IDENTICAL feasibility decisions — same
`InfeasibleConfig` set, same capacity-derived max batch, no float32
off-by-one at the capacity boundary.

The companion regression — that routing the searchers through the
jitted path leaves the sha-pinned PR 2 seeded trajectories
byte-identical — is asserted by
tests/test_disagg_dse.py::test_single_device_trajectories_unchanged.
"""

import numpy as np
import pytest

from repro.configs.paper_models import LLADA_8B, LLAMA33_70B, QWEN3_32B
from repro.core import baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu
from repro.core import perfmodel_jit as pj
from repro.core.dse import space as sp
from repro.core.perfmodel import (InfeasibleConfig, evaluate,
                                  evaluate_batch, max_decode_batch,
                                  max_prefill_batch)
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

RTOL = 1e-5
FIELDS = ("latency_s", "tokens", "throughput_tps", "avg_power_w",
          "energy_per_token_j", "compute_time_s", "memory_time_s")


def _scalar(npu, dims, phase, batch=None):
    try:
        return evaluate(npu, dims, OSWORLD_LIBREOFFICE, phase, batch=batch)
    except (InfeasibleConfig, ValueError):
        return None


def _assert_match(want, got, label):
    assert (want is None) == (got is None), f"feasibility differs @ {label}"
    if want is None:
        return
    assert got.batch == want.batch, f"max batch differs @ {label}"
    assert got.bottleneck == want.bottleneck, label
    for f in FIELDS:
        assert getattr(got, f) == pytest.approx(
            getattr(want, f), rel=RTOL), f"{f} @ {label}"
    for k, v in want.mem_breakdown.items():
        assert got.mem_breakdown[k] == pytest.approx(v, rel=RTOL), \
            f"breakdown {k} @ {label}"


def _valid_single_designs(seed, n):
    rng = np.random.default_rng(seed)
    xs = sp.random_designs(rng, 4 * n)
    xs = xs[sp.valid_mask(xs)]
    assert len(xs) >= n, "raw validity unexpectedly low"
    return xs[:n]


# ---------------------------------------------------------------------------
# Property test: >= 200 random valid designs x 2 paper models x 2 phases
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def design_pool():
    xs = _valid_single_designs(0, 220)
    return xs, sp.decode_batch(xs), [sp.decode(x) for x in xs]


@pytest.mark.parametrize("dims", [QWEN3_32B, LLAMA33_70B],
                         ids=lambda d: d.name)
@pytest.mark.parametrize("phase", [Phase.PREFILL, Phase.DECODE],
                         ids=lambda p: p.value)
def test_jit_matches_scalar_on_random_designs(design_pool, dims, phase):
    xs, table, npus = design_pool
    got = pj.evaluate_batch_table(table, dims, OSWORLD_LIBREOFFICE, phase)
    assert len(got) == len(xs) >= 200
    n_feasible = 0
    for x, npu, g in zip(xs, npus, got):
        want = _scalar(npu, dims, phase)
        n_feasible += want is not None
        _assert_match(want, g, f"{dims.name}/{phase.value}/{list(x)}")
    assert n_feasible >= len(xs) // 2      # the sweep exercises real designs


@pytest.mark.parametrize("phase", [Phase.PREFILL, Phase.DECODE],
                         ids=lambda p: p.value)
def test_jit_matches_scalar_on_paired_halves(phase):
    ps = sp.PairedSpace()
    rng = np.random.default_rng(3)
    pairs = ps.random_designs(rng, 48)
    pre_tab, dec_tab = ps.decode_batch(pairs)
    half_tab = pre_tab if phase is Phase.PREFILL else dec_tab
    half_xs = pairs[:, :sp.N_DIMS] if phase is Phase.PREFILL \
        else pairs[:, sp.N_DIMS:]
    got = pj.evaluate_batch_table(half_tab, QWEN3_32B,
                                  OSWORLD_LIBREOFFICE, phase)
    for x, g in zip(half_xs, got):
        want = _scalar(sp.decode(x), QWEN3_32B, phase)
        _assert_match(want, g, f"paired/{phase.value}/{list(x)}")


# ---------------------------------------------------------------------------
# Feasibility boundary: the jitted mask must reject exactly the designs
# whose scalar max_*_batch raises InfeasibleConfig, and agree on the
# capacity-maximal batch (no float32 off-by-one in the capacity sums).
# ---------------------------------------------------------------------------

def test_feasibility_boundary_and_max_batch(design_pool):
    xs, table, npus = design_pool
    for phase, max_batch in ((Phase.PREFILL, max_prefill_batch),
                             (Phase.DECODE, max_decode_batch)):
        arrs = pj.evaluate_batch_arrays(table, LLAMA33_70B,
                                        OSWORLD_LIBREOFFICE, phase)
        for i, npu in enumerate(npus):
            try:
                want = max_batch(npu, LLAMA33_70B, OSWORLD_LIBREOFFICE)
            except InfeasibleConfig:
                want = None
            if want is None:
                assert not arrs["feasible"][i], npu.name
            else:
                assert arrs["feasible"][i], npu.name
                assert int(arrs["batch"][i]) == want, npu.name


def test_explicit_batch_override_parity():
    xs = _valid_single_designs(7, 24)
    table = sp.decode_batch(xs)
    npus = [sp.decode(x) for x in xs]
    # batch=4 is feasible for some designs and capacity-infeasible for
    # others -> exercises the place_data (+1e-9 slack) gate both ways
    for phase in (Phase.PREFILL, Phase.DECODE):
        got = pj.evaluate_batch_table(table, QWEN3_32B,
                                      OSWORLD_LIBREOFFICE, phase, batch=4)
        statuses = {g is not None for g in got}
        for x, npu, g in zip(xs, npus, got):
            want = _scalar(npu, QWEN3_32B, phase, batch=4)
            _assert_match(want, g, f"batch=4/{phase.value}/{list(x)}")
        assert statuses, "empty batch"


# ---------------------------------------------------------------------------
# Object-API routing (evaluate_batch -> NPUTable.from_configs) and the
# scalar fallback for the diffusion-LM decode path
# ---------------------------------------------------------------------------

def test_evaluate_batch_routes_table6_configs_through_jit():
    npus = [baseline_npu(), p1_npu(), d1_npu(), p2_npu(), d2_npu()]
    for phase in (Phase.PREFILL, Phase.DECODE):
        got = evaluate_batch(npus, LLAMA33_70B, OSWORLD_LIBREOFFICE, phase)
        ref = evaluate_batch(npus, LLAMA33_70B, OSWORLD_LIBREOFFICE, phase,
                             use_jit=False)
        for npu, g, w in zip(npus, got, ref):
            _assert_match(w, g, f"table6/{npu.name}/{phase.value}")


def test_dllm_decode_falls_back_to_oracle():
    assert not pj.supports(LLADA_8B, Phase.DECODE)
    assert pj.supports(LLADA_8B, Phase.PREFILL)
    npus = [p1_npu(), d2_npu()]
    got = evaluate_batch(npus, LLADA_8B, OSWORLD_LIBREOFFICE, Phase.DECODE)
    for npu, g in zip(npus, got):
        want = _scalar(npu, LLADA_8B, Phase.DECODE)
        assert (want is None) == (g is None)
        if want is not None:
            assert g.throughput_tps == want.throughput_tps
            assert g.energy_per_token_j == want.energy_per_token_j


def test_evaluate_batch_cache_and_keys_semantics():
    npus = [p1_npu(), d1_npu(), p1_npu()]
    cache = {}
    keys = [n.name for n in npus]
    got = evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                         Phase.PREFILL, keys=keys, cache=cache)
    assert set(cache) == {"P1", "D1"}
    again = evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                           Phase.PREFILL, keys=keys, cache=cache)
    for a, b in zip(got, again):
        assert (a is None) == (b is None)
        if a is not None:
            assert b.throughput_tps == a.throughput_tps
    with pytest.raises(ValueError, match="keys for"):
        evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                       Phase.PREFILL, keys=keys[:1])
    # a None key opts a config out of caching: evaluated, never stored
    cache2 = {}
    got2 = evaluate_batch([p1_npu(), d1_npu()], QWEN3_32B,
                          OSWORLD_LIBREOFFICE, Phase.PREFILL,
                          keys=[None, "D1"], cache=cache2)
    assert set(cache2) == {"D1"}
    assert got2[0] is not None
    assert got2[0].throughput_tps == got[0].throughput_tps
