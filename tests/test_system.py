"""End-to-end behaviour: quantization accuracy proxy (Table 3 direction),
emulator vs analytic cross-validation (Table 9), disaggregation (Fig 8),
MX format properties, and the HLO roofline analyzer."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.configs.paper_models import LLAMA33_70B, QWEN3_32B
from repro.core import QuantConfig, baseline_npu, d1_npu, p1_npu
from repro.core.disagg import (decode_phase_profile, evaluate_disaggregated,
                               kv_transfer_seconds)
from repro.core.emulator import analytic_layer_seconds, emulate_layer
from repro.core.gpu import H100, evaluate_gpu
from repro.core.quant.formats import (FORMATS, get, quantization_error,
                                      quantize_dequantize)
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase
from repro.roofline import hlo as hlo_mod


# --------------------------------------------------------------------------
# MX formats
# --------------------------------------------------------------------------

def test_mx_bits_per_element():
    assert get("MXINT8").bits_per_element == pytest.approx(8 + 8 / 32)
    assert get("MXFP4").bits_per_element == pytest.approx(4 + 8 / 32)
    assert get("FP16").bits_per_element == 16


@pytest.mark.parametrize("fmt", sorted(FORMATS))
def test_quantize_roundtrip_bounded(fmt):
    x = jax.random.normal(jax.random.key(0), (64, 128)) * 2.0
    err = quantization_error(x, fmt)
    bits = get(fmt).element_bits
    assert err < {4: 0.35, 8: 0.05, 16: 0.01}.get(bits, 0.5), (fmt, err)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3))
def test_mxint8_scale_invariance(scale):
    """Block scaling makes MXINT8 error scale-invariant."""
    x = jax.random.normal(jax.random.key(1), (32, 64))
    e1 = quantization_error(x, "MXINT8")
    e2 = quantization_error(x * scale, "MXINT8")
    assert abs(e1 - e2) < 0.01


def test_idempotent_quantization():
    x = jax.random.normal(jax.random.key(2), (16, 64))
    q1 = quantize_dequantize(x, "MXINT8")
    q2 = quantize_dequantize(q1, "MXINT8")
    assert float(jnp.max(jnp.abs(q1 - q2))) < 1e-6


def test_accuracy_proxy_ordering():
    """Table 3 direction via logit KL proxy: 8/8/8 ~ fp >> 4/4/4."""
    from repro.core.quant.accuracy import quantization_quality_proxy
    cfg = get_arch("qwen3-4b").reduced(n_layers=2, d_model=128, vocab=256)
    q8 = quantization_quality_proxy(cfg, QuantConfig())
    q4 = quantization_quality_proxy(
        cfg, QuantConfig("MXINT4", "MXINT4", "MXINT4"))
    assert q8["top1_agreement"] > q4["top1_agreement"]
    assert q8["logit_kl"] < q4["logit_kl"]
    assert q8["top1_agreement"] > 0.85


# --------------------------------------------------------------------------
# Emulator cross-validation (Table 9)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mk,phase,batch,ctx", [
    (baseline_npu, Phase.PREFILL, 1, 4096),
    (p1_npu, Phase.PREFILL, 1, 4096),
    (d1_npu, Phase.DECODE, 8, 32768),
])
def test_emulator_vs_analytic(mk, phase, batch, ctx):
    npu = mk()
    t_a = analytic_layer_seconds(npu, LLAMA33_70B, phase, batch, ctx)
    t_e = emulate_layer(npu, LLAMA33_70B, phase, batch, ctx,
                        n_chunks=8).total_s
    # paper Table 9: analytic lands within ~10-20% of the emulator
    assert t_e > 0 and t_a > 0
    assert 0.6 < t_a / t_e < 1.7, (t_a, t_e)


def test_emulator_chunking_converges():
    npu = baseline_npu()
    t8 = emulate_layer(npu, QWEN3_32B, Phase.PREFILL, 1, 4096, 8).total_s
    t32 = emulate_layer(npu, QWEN3_32B, Phase.PREFILL, 1, 4096, 32).total_s
    assert abs(t8 - t32) / t8 < 0.3


# --------------------------------------------------------------------------
# Disaggregation (Fig 8)
# --------------------------------------------------------------------------

def test_disaggregated_system():
    r = evaluate_disaggregated(p1_npu(), d1_npu(), LLAMA33_70B,
                               OSWORLD_LIBREOFFICE)
    assert r.ttft_s > 0 and r.decode_tps_aggregate > 0
    assert r.kv_transfer_s < r.ttft_s
    base = evaluate_disaggregated(baseline_npu(), baseline_npu(),
                                  LLAMA33_70B, OSWORLD_LIBREOFFICE)
    # P1+D1 beats Base+Base on aggregate decode throughput (Fig 8)
    assert r.decode_tps_aggregate > base.decode_tps_aggregate


def test_kv_transfer_accounting():
    t, e = kv_transfer_seconds(LLAMA33_70B, OSWORLD_LIBREOFFICE, 1,
                               QuantConfig())
    # 90k tokens x 80 layers x 2 x 1024 x ~1B -> ~15 GB over 450 GB/s
    assert 0.01 < t < 0.2
    assert e > 0


def test_decode_phase_split():
    prof = decode_phase_profile(d1_npu(), LLAMA33_70B, OSWORLD_LIBREOFFICE,
                                batch=8)
    assert prof.late_step_s >= prof.early_step_s


def test_gpu_baseline_sane():
    r = evaluate_gpu(H100, LLAMA33_70B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                     QuantConfig(), n_gpus=4)
    assert r.batch >= 1
    assert 0.001 < r.latency_s < 10.0
    assert r.avg_power_w <= 4 * H100.tdp_w


# --------------------------------------------------------------------------
# HLO analyzer
# --------------------------------------------------------------------------

SAMPLE_HLO = """
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%g1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), to_apply=%sum
  %one = s32[] constant(1)
  %n = s32[] add(%g0, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%n, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(%g0, %lim), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%zero, %x)
  %w = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_loop_aware_totals():
    t = hlo_mod.analyze(SAMPLE_HLO)
    # dot: 2*8*16*16 = 4096 flops, x10 trips
    assert t.dot_flops == pytest.approx(40960)
    assert t.dot_flops_x1 == pytest.approx(4096)
    # all-reduce operand: 8*16*4 = 512 bytes, x10 trips
    assert t.coll_bytes == pytest.approx(5120)
    assert t.coll_bytes_x1 == pytest.approx(512)
    assert t.coll_by_kind["all-reduce"] == pytest.approx(5120)
    assert t.trip_counts == [10]


def test_shape_bytes():
    assert hlo_mod.shape_bytes("bf16[4,8]{1,0}") == 64
    assert hlo_mod.shape_bytes("(f32[2,2], s8[16])") == 32
    assert hlo_mod.shape_bytes("f32[]") == 4
