"""Table 1 catalog + Eq. 1 shoreline + Eqs. 2-5 transfer model."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hierarchy as H
from repro.core import memtech as M
from repro.core.hierarchy import MemoryHierarchy, MemoryLevel, ShorelineError


def test_catalog_complete():
    assert set(M.CATALOG) == {"SRAM", "3D-SRAM", "HBM3E", "HBM4", "LPDDR5X",
                              "LPDDR6", "GDDR6", "GDDR7", "HBF"}


def test_table1_values():
    assert M.HBM3E.capacity_gb == 24.0 and M.HBM3E.bandwidth_gbps == 1024.0
    assert M.HBM4.capacity_gb == 36.0 and M.HBM4.bandwidth_gbps == 2048.0
    assert M.HBF.capacity_gb == 384.0 and M.HBF.latency_s == 1e-6
    assert M.SRAM_3D.bandwidth_gbps == 8192.0
    assert M.LPDDR6.bandwidth_gbps == 172.8
    # paper: HBF ~4x HBM background power, ~2x access energy
    assert M.HBF.p_bg_mw_per_gb == pytest.approx(4 * M.HBM3E.p_bg_mw_per_gb)
    assert M.HBF.e_read_pj_per_bit == pytest.approx(
        2 * M.HBM3E.e_read_pj_per_bit)


def test_power_units():
    # 1 TB/s reads at 3 pJ/bit = 3e-12 * 8e12 = 24 W
    assert M.HBM3E.read_power_w(1024.0) == pytest.approx(
        3.0e-12 * 1024e9 * 8, rel=1e-6)
    # background: 24 GB at 75 mW/GB = 1.8 W
    assert M.HBM3E.background_power_w() == pytest.approx(1.8)


def test_hbf_capacity_per_shoreline_dominates_dram():
    assert (M.HBF.capacity_per_shoreline()
            > 10 * M.HBM3E.capacity_per_shoreline())


def test_shoreline_bound():
    # 8 HBM4 stacks: 8 * 15.5 = 124mm > 118mm budget
    with pytest.raises(ShorelineError):
        MemoryHierarchy([MemoryLevel(M.SRAM_2D, 1),
                         MemoryLevel(M.HBM4, 8)])
    # 4 stacks fit
    h = MemoryHierarchy([MemoryLevel(M.SRAM_2D, 1), MemoryLevel(M.HBM4, 4)])
    assert h.shoreline_used_mm() == pytest.approx(4 * 15.5)


def test_max_stacks_eq1():
    assert H.max_stacks(M.HBM3E) == int(118.0 // 11.5)
    assert H.max_stacks(M.SRAM_3D) > 1000


def test_onchip_must_precede_offchip():
    with pytest.raises(ValueError):
        MemoryHierarchy([MemoryLevel(M.HBM3E, 1), MemoryLevel(M.SRAM_2D, 1)])


def _h2():
    return MemoryHierarchy([MemoryLevel(M.SRAM_2D, 1),
                            MemoryLevel(M.HBM3E, 4)])


def _h3():
    return MemoryHierarchy([MemoryLevel(M.SRAM_3D, 3),
                            MemoryLevel(M.HBM4, 2),
                            MemoryLevel(M.HBF, 1)])


def test_effective_bandwidth_eq2():
    h = _h3()
    effs = h.effective_bandwidths_gbps()
    # outermost = peak; inner reduced by deeper stream, clamped >= 50%
    assert effs[-1] == 1024.0
    assert effs[1] == max(2 * 2048.0 - 1024.0, 0.5 * 2 * 2048.0)
    assert effs[0] >= 0.5 * 3 * 8192.0


def test_transfer_all_resident_onchip():
    h = _h2()
    br = h.transfer_time_s(1e9, resident_fractions=[1.0, 1.0])
    # 1 GB over the on-chip boundary only
    assert br.total_s == pytest.approx(
        M.SRAM_2D.latency_s + 1e9 / (h.effective_bandwidths_gbps()[0] * 1e9),
        rel=1e-3)


def test_transfer_case2_bandwidth_limited():
    h = _h2()
    # nothing on-chip: every byte crosses both boundaries; the on-chip
    # port (clamped to half peak by the Eq. 2 pass-through rule) is the
    # slower stage here and sets the time
    br = h.transfer_time_s(10e9, resident_fractions=[0.0, 1.0])
    t0 = M.SRAM_2D.latency_s + 10e9 / (0.5 * 4096e9)
    assert br.total_s == pytest.approx(t0, rel=1e-2)
    # deeper-limited case: make the deep level the bottleneck via a
    # 1-stack HBM (1 TB/s < clamped SRAM 2 TB/s)
    h1 = MemoryHierarchy([MemoryLevel(M.SRAM_2D, 1),
                          MemoryLevel(M.HBM3E, 1)])
    br1 = h1.transfer_time_s(10e9, resident_fractions=[0.0, 1.0])
    t_deep = M.HBM3E.latency_s + 10e9 / 1024e9
    assert br1.total_s == pytest.approx(t_deep, rel=1e-2)
    assert br1.case == "bandwidth_limited"


@settings(max_examples=50, deadline=None)
@given(x=st.floats(1e6, 1e12),
       a0=st.floats(0.0, 1.0),
       share=st.floats(0.1, 1.0))
def test_transfer_monotonicity(x, a0, share):
    """More data -> more time; higher resident fraction -> no more time;
    bandwidth share scales inversely."""
    h = _h2()
    t1 = h.transfer_time_s(x, [a0, 1.0], bw_share=share).total_s
    t2 = h.transfer_time_s(2 * x, [a0, 1.0], bw_share=share).total_s
    assert t2 >= t1
    t3 = h.transfer_time_s(x, [min(1.0, a0 + 0.3), 1.0],
                           bw_share=share).total_s
    assert t3 <= t1 + 1e-12
    t4 = h.transfer_time_s(x, [a0, 1.0], bw_share=share / 2).total_s
    assert t4 >= t1 - 1e-12


@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.floats(0.1, 50.0), min_size=3, max_size=3))
def test_place_greedy_conserves(sizes):
    h = _h3()
    if sum(sizes) > h.total_capacity_gb():
        with pytest.raises(ValueError):
            h.place_greedy(sizes, [0, 1, 2])
        return
    placed = h.place_greedy(sizes, [2, 0, 1])
    for c in range(3):
        got = sum(placed[lvl][c] for lvl in range(len(h.levels)))
        assert got == pytest.approx(sizes[c], rel=1e-9)
    for lvl, level in enumerate(h.levels):
        assert sum(placed[lvl]) <= level.capacity_gb + 1e-9
