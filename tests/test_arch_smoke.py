"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs
one forward/train step on CPU (shape + finiteness assertions) plus a
serve prefill/decode step.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation) — asserted structurally here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_supported, get_arch
from repro.models.transformer import ForwardOptions
from repro.runtime.data import DataConfig, batch_for_step
from repro.runtime.optim import AdamWConfig, init_opt_state
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step, model_fns)

ARCH_IDS = sorted(ARCHS)


def _smoke_batch(cfg, b=2, s=16):
    dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b, seed=0)
    frames = s if cfg.family == "encdec" else 0
    batch = batch_for_step(dc, 0, with_frames=frames, d_model=cfg.d_model)
    out = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.family == "encdec":
        out["frames"] = out["frames"].astype(cfg.jax_dtype)
    if cfg.family == "vlm":
        out["patches"] = jnp.zeros((b, cfg.cross_len, cfg.d_model),
                                   cfg.jax_dtype)
    return out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(0))
    opt = init_opt_state(params)
    batch = _smoke_batch(cfg)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    loss, params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss"
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.sum(jnp.abs(b[0] - b[1]))),
        jax.tree.map(lambda x, y: (x.astype(jnp.float32),
                                   y.astype(jnp.float32)),
                     params, params2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_serve_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(1))
    b, s = 2, 12
    batch = _smoke_batch(cfg, b, s)
    prefill = make_prefill_step(cfg, s_max=s + 4)
    logits, cache = prefill(params, batch)
    v = cfg.vocab_padded
    assert logits.shape == (b, v)
    assert jnp.isfinite(logits).all(), f"{arch_id}: prefill NaN"
    decode = make_decode_step(cfg)
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    dec_len = batch["tokens"].shape[1]
    logits2, cache = decode(params, cache, tok, jnp.int32(dec_len))
    assert logits2.shape == (b, v)
    assert jnp.isfinite(logits2).all(), f"{arch_id}: decode NaN"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_shapes_structural(arch_id):
    """FULL config touched only via eval_shape (no allocation)."""
    cfg = get_arch(arch_id)
    mf = model_fns(cfg)
    shapes = jax.eval_shape(mf.init, jax.random.key(0))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expected = {
        "seamless-m4t-medium": 0.8e9, "internlm2-1.8b": 1.8e9,
        "qwen3-4b": 4e9, "llama3.2-1b": 1.2e9, "qwen1.5-110b": 110e9,
        "llama4-scout-17b-a16e": 100e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "hymba-1.5b": 1.5e9, "llama-3.2-vision-11b": 10e9,
        "xlstm-1.3b": 1.3e9,
    }[arch_id]
    assert 0.4 * expected < n_params < 2.2 * expected, \
        f"{arch_id}: {n_params/1e9:.2f}B params vs ~{expected/1e9:.0f}B"


def test_cell_support_matrix():
    """40 cells; long_500k runs only on hybrid/ssm archs."""
    total, runs, skips = 0, 0, 0
    for a in ARCHS.values():
        for s in SHAPES.values():
            total += 1
            ok, _ = cell_supported(a, s)
            runs += ok
            skips += not ok
    assert total == 40
    assert skips == 8          # 8 full-attention archs x long_500k
    assert runs == 32


def test_long_context_archs():
    assert get_arch("hymba-1.5b").supports_long_context
    assert get_arch("xlstm-1.3b").supports_long_context
    assert not get_arch("qwen3-4b").supports_long_context
