"""N-device SystemSpace / SystemTopology: cross-half constraint
enforcement, K=2 byte-equivalence with the paired machinery (pinned
GP+EHVI trajectory), batch-vs-scalar equivalence of the generic system
composition, layer-group / decode-phase role evaluators, the d>2 EHVI
routing, DLLM decode roles as a first-class jitted searched scenario
(the `dllm-3role` fleet), and the searched-system perf gates."""

import hashlib
import itertools
import json

import numpy as np
import pytest

from repro.configs.paper_models import LLADA_8B, QWEN3_32B
from repro.core import d1_npu, p1_npu
from repro.core.disagg import (DLLM_3ROLE, EXTREME_4ROLE, PD_PAIR, Role,
                               SystemTopology, _combine_phase_results,
                               _combine_system, evaluate_disaggregated,
                               evaluate_system, evaluate_system_batch)
from repro.core.dse import (DisaggObjective, PairedSpace, SystemObjective,
                            hypervolume, mc_ehvi, run_mobo, run_motpe,
                            run_nsga2, run_random, shared_init,
                            system_warm_start)
from repro.core.dse import space as sp
from repro.core.perfmodel import (InfeasibleConfig, evaluate_batch,
                                  evaluate_decode)
from repro.core.workload import (GSM8K_DLLM, OSWORLD_DLLM,
                                 OSWORLD_LIBREOFFICE, Phase, layer_traffic,
                                 weight_footprint_gb)
import dataclasses


# ---------------------------------------------------------------------------
# SystemSpace: K halves + GeneTie constraint enforcement
# ---------------------------------------------------------------------------

def test_system_space_shape_and_ties():
    ss = sp.SystemSpace(4, ties=(sp.kv_quant_tie(),))
    assert ss.n_dims == 4 * sp.N_DIMS
    assert ss.cardinalities == list(sp.CARDINALITIES) * 4
    rng = np.random.default_rng(0)
    xs = ss.random_designs(rng, 64)
    # sampling satisfies the tie on every half and is decode-valid
    for h in range(1, 4):
        assert np.all(xs[:, sp.KV_GENE] == xs[:, h * sp.N_DIMS + sp.KV_GENE])
    assert np.all(ss.valid_mask(xs))
    x = ss.random_design(rng)
    assert len(x) == 4 * sp.N_DIMS


def test_system_space_repair_valid_decode_agree():
    """The three constraint views (repair / valid_mask / decode) agree."""
    ss = sp.SystemSpace(3, ties=(sp.kv_quant_tie(),))
    rng = np.random.default_rng(1)
    x = ss.random_design(rng)
    bad = list(x)
    bad[2 * sp.N_DIMS + sp.KV_GENE] = \
        (bad[sp.KV_GENE] + 1) % len(sp.KV_FMTS)
    # decode rejects, valid_mask rejects, repair projects back
    with pytest.raises(sp.InvalidDesign, match="KV-cache quant mismatch"):
        ss.decode(bad)
    vm = ss.valid_mask(np.asarray([list(x), bad], dtype=np.int64))
    assert bool(vm[0]) and not bool(vm[1])
    fixed = ss.repair(bad)
    assert bool(ss.valid_mask(np.asarray([fixed], dtype=np.int64))[0])
    cfgs = ss.decode(fixed)
    assert len(cfgs) == 3
    assert len({c.quant.kv_cache for c in cfgs}) == 1
    # repair_batch never mutates the caller's batch
    raw = np.asarray([bad], dtype=np.int64)
    before = raw.copy()
    fb = ss.repair_batch(raw)
    assert np.array_equal(raw, before)
    assert fb[0, 2 * sp.N_DIMS + sp.KV_GENE] == fb[0, sp.KV_GENE]


def test_system_space_partial_tie():
    """Ties over a subset of halves leave the other halves free."""
    tie = sp.GeneTie(sp.KV_GENE, halves=(0, 2), label="KV-cache quant",
                     value_names=tuple(sp.KV_FMTS))
    ss = sp.SystemSpace(3, ties=(tie,))
    rng = np.random.default_rng(2)
    xs = ss.random_designs(rng, 32)
    assert np.all(xs[:, sp.KV_GENE] == xs[:, 2 * sp.N_DIMS + sp.KV_GENE])
    x = list(ss.random_design(rng))
    x[sp.N_DIMS + sp.KV_GENE] = (x[sp.KV_GENE] + 1) % len(sp.KV_FMTS)
    # half 1 is untied: still valid as long as halves 0/2 agree
    assert x[sp.KV_GENE] == x[2 * sp.N_DIMS + sp.KV_GENE]
    assert bool(ss.valid_mask(np.asarray([x], dtype=np.int64))[0])


def test_system_space_tables_match_halves():
    ss = sp.SystemSpace(4, ties=(sp.kv_quant_tie(),))
    rng = np.random.default_rng(3)
    xs = ss.random_designs(rng, 16)
    tdp = ss.tdp_w_batch(xs)
    tables = ss.decode_batch(xs)
    assert len(tables) == 4
    for i, x in enumerate(xs[:4]):
        cfgs = ss.decode(x)
        assert tdp[i] == pytest.approx(sum(c.tdp_w() for c in cfgs),
                                       rel=1e-9)
        for h, c in enumerate(cfgs):
            assert c.name == sp.decode(
                x[h * sp.N_DIMS:(h + 1) * sp.N_DIMS]).name


# ---------------------------------------------------------------------------
# K=2 equivalence: PairedSpace IS SystemSpace(2); pinned trajectory
# ---------------------------------------------------------------------------

def test_paired_space_is_k2_system_space():
    ps = PairedSpace()
    assert isinstance(ps, sp.SystemSpace)
    ss = sp.SystemSpace(2, ties=(sp.kv_quant_tie(),))
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    assert np.array_equal(ps.random_designs(r1, 40),
                          ss.random_designs(r2, 40))
    assert ps.random_design(r1) == ss.random_design(r2)
    x = ps.random_design(r1)
    assert ps.repair(x) == ss.repair(x)
    assert np.array_equal(ps.valid_mask(np.asarray([x])),
                          ss.valid_mask(np.asarray([x])))


# SHA-256 of the json-encoded (x, f) evaluation trajectory produced by
# the pre-SystemSpace paired implementation (commit b636068) for
# GP+EHVI at (QWEN3_32B, OSWorld, tdp=1400, ttft_cap=90,
# init=shared_init(8, seed=1), n_total=18).  Both the refactored
# DisaggObjective/PairedSpace and the generic SystemObjective/
# SystemSpace(K=2) must reproduce it byte-identically.
# NOTE: run_mobo's order goes through GP/EHVI float argmaxes, so the
# digest is pinned to this container's numpy/JAX builds (see the
# matching note in test_disagg_dse.py).
_PRE_SYSTEM_PAIR_SHA = \
    "6900d660046fe218a1b5ee88250689e7d6476dbd3d341f795817753a93e93502"


def _trajectory_sha(obj) -> str:
    init = shared_init(obj, 8, seed=1)
    res = run_mobo(obj, n_total=18, seed=1, init=list(init))
    payload = [[list(map(int, o.x)), None if o.f is None else list(o.f)]
               for o in res.observations]
    return hashlib.sha256(json.dumps(payload).encode()).hexdigest()


@pytest.mark.slow
def test_paired_trajectory_pinned_through_system_layer():
    disagg_obj = DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                                 tdp_limit_w=1400.0, ttft_cap_s=90.0)
    assert _trajectory_sha(disagg_obj) == _PRE_SYSTEM_PAIR_SHA
    sys_obj = SystemObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                              topology=PD_PAIR, tdp_limit_w=1400.0,
                              ttft_cap_s=90.0)
    assert sys_obj.space.n_dims == 2 * sp.N_DIMS
    assert _trajectory_sha(sys_obj) == _PRE_SYSTEM_PAIR_SHA


# ---------------------------------------------------------------------------
# SystemTopology composition vs the pair arithmetic
# ---------------------------------------------------------------------------

def test_topology_validation():
    with pytest.raises(ValueError, match="precede"):
        SystemTopology("bad", (Role("d", Phase.DECODE, gen_frac=1.0),
                               Role("p", Phase.PREFILL)))
    with pytest.raises(ValueError, match="gen_frac"):
        SystemTopology("bad", (Role("p", Phase.PREFILL),
                               Role("d", Phase.DECODE, gen_frac=0.5)))
    with pytest.raises(ValueError, match="decode"):
        SystemTopology("bad", (Role("p", Phase.PREFILL),))
    with pytest.raises(ValueError, match="prefill"):
        SystemTopology("bad", (Role("d", Phase.DECODE, gen_frac=1.0),))
    with pytest.raises(ValueError, match="outside"):
        SystemTopology("bad", (
            Role("p", Phase.PREFILL),
            Role("d1", Phase.DECODE, gen_frac=1.5),
            Role("d2", Phase.DECODE, gen_frac=-0.5)))
    with pytest.raises(ValueError, match="gen_frac"):
        SystemTopology("bad", (
            Role("p", Phase.PREFILL, gen_frac=0.5),
            Role("d", Phase.DECODE, gen_frac=1.0)))
    assert EXTREME_4ROLE.k == 4
    assert EXTREME_4ROLE.prefill_indices() == [0, 1]
    assert EXTREME_4ROLE.decode_indices() == [2, 3]
    # the KV producer is the attention prefill role, never the FFN one
    assert EXTREME_4ROLE.kv_producer_index() == 0


def test_pair_combination_bit_identical():
    """_combine_system on PD_PAIR == the original pair fold, bit for bit
    (the sha-pinned paired trajectories depend on this)."""
    pairs = [(p1_npu(), d1_npu())]
    ps = PairedSpace()
    rng = np.random.default_rng(6)
    for x in ps.random_designs(rng, 8):
        try:
            pairs.append(ps.decode(x))
        except sp.InvalidDesign:
            pass
    for p, d in pairs:
        try:
            want = evaluate_disaggregated(p, d, QWEN3_32B,
                                          OSWORLD_LIBREOFFICE)
        except (InfeasibleConfig, ValueError):
            continue
        got = _combine_system(PD_PAIR, [want.prefill, want.decode],
                              [p.quant, p.quant], QWEN3_32B,
                              OSWORLD_LIBREOFFICE)
        assert got.ttft_s == want.ttft_s
        assert got.tokens_per_joule == want.tokens_per_joule
        assert got.total_power_w == want.total_power_w
        assert got.kv_transfer_s == want.kv_transfer_s
        assert got.decode_tps_per_request == want.decode_tps_per_request
        assert got.decode_tps_aggregate == want.decode_tps_aggregate
        # and the wrapper fold is the same object-level arithmetic
        again = _combine_phase_results(want.prefill, want.decode,
                                       QWEN3_32B, OSWORLD_LIBREOFFICE,
                                       p.quant)
        assert again.tokens_per_joule == want.tokens_per_joule


def test_system_batch_matches_scalar_4role():
    ss = sp.SystemSpace.for_topology(EXTREME_4ROLE)
    rng = np.random.default_rng(7)
    xs = ss.random_designs(rng, 10)
    systems = [ss.decode(x) for x in xs]
    caches = [dict() for _ in EXTREME_4ROLE.roles]
    got = evaluate_system_batch(systems, EXTREME_4ROLE, QWEN3_32B,
                                OSWORLD_LIBREOFFICE, caches=caches)
    n_feasible = 0
    for s, r in zip(systems, got):
        try:
            want = evaluate_system(list(s), EXTREME_4ROLE, QWEN3_32B,
                                   OSWORLD_LIBREOFFICE)
        except (InfeasibleConfig, ValueError):
            assert r is None
            continue
        n_feasible += 1
        assert r.tokens_per_joule == pytest.approx(want.tokens_per_joule,
                                                   rel=1e-9)
        assert r.ttft_s == pytest.approx(want.ttft_s, rel=1e-9)
        assert r.total_power_w == pytest.approx(want.total_power_w,
                                                rel=1e-9)
        assert r.decode_tps_aggregate == pytest.approx(
            want.decode_tps_aggregate, rel=1e-9)
    assert n_feasible > 0
    # per-role caches hold one entry per unique half; reruns are lookups
    for ri in range(4):
        assert set(caches[ri]) == {s[ri].name for s in systems}
    again = evaluate_system_batch(systems, EXTREME_4ROLE, QWEN3_32B,
                                  OSWORLD_LIBREOFFICE, caches=caches)
    for a, b in zip(got, again):
        assert (a is None) == (b is None)
        if a is not None:
            assert a.tokens_per_joule == b.tokens_per_joule


def test_system_wrong_arity_raises():
    with pytest.raises(ValueError, match="devices"):
        evaluate_system([p1_npu()], PD_PAIR, QWEN3_32B,
                        OSWORLD_LIBREOFFICE)
    with pytest.raises(ValueError, match="caches"):
        evaluate_system_batch([], PD_PAIR, QWEN3_32B, OSWORLD_LIBREOFFICE,
                              caches=[{}])


# ---------------------------------------------------------------------------
# Role evaluators: layer-group and decode-phase restrictions
# ---------------------------------------------------------------------------

def test_layer_group_dims_partition():
    attn = dataclasses.replace(QWEN3_32B, layer_groups="attn")
    ffn = dataclasses.replace(QWEN3_32B, layer_groups="ffn")
    q = p1_npu().quant
    # group weights partition the per-layer weights (embeddings/head are
    # carried by both devices, so compare layer params, not totals)
    assert attn.layer_weight_params() < QWEN3_32B.layer_weight_params()
    assert ffn.layer_weight_params() < QWEN3_32B.layer_weight_params()
    assert (attn.layer_weight_params() + ffn.layer_weight_params()
            == QWEN3_32B.layer_weight_params() + 2 * QWEN3_32B.d_model)
    assert weight_footprint_gb(attn, q) < weight_footprint_gb(QWEN3_32B, q)
    # only the attention group holds KV
    assert ffn.kv_bytes_per_token(q) == 0.0
    assert attn.kv_bytes_per_token(q) == QWEN3_32B.kv_bytes_per_token(q)
    # traffic splits: group GEMMs partition the full layer's GEMMs
    full = layer_traffic(QWEN3_32B, Phase.PREFILL, 1, 4096, q)
    ta = layer_traffic(attn, Phase.PREFILL, 1, 4096, q)
    tf = layer_traffic(ffn, Phase.PREFILL, 1, 4096, q)
    assert len(ta.gemms) + len(tf.gemms) == len(full.gemms)
    assert ta.total_macs() + tf.total_macs() == \
        pytest.approx(full.total_macs())
    assert tf.kv_write_bytes == 0.0


def test_decode_phase_role_context_parity():
    """context_override through the jitted batch path == the scalar
    decode_phase_profile math."""
    role = EXTREME_4ROLE.roles[3]            # decode-late
    ctx = role.context_for(OSWORLD_LIBREOFFICE)
    assert ctx == OSWORLD_LIBREOFFICE.prompt_tokens \
        + 3 * OSWORLD_LIBREOFFICE.gen_tokens // 4
    npus = [p1_npu(), d1_npu()]
    got = evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                         Phase.DECODE, context_override=ctx)
    for npu, r in zip(npus, got):
        want = evaluate_decode(npu, QWEN3_32B, OSWORLD_LIBREOFFICE,
                               context_override=ctx)
        assert r.latency_s == pytest.approx(want.latency_s, rel=1e-9)
        assert r.energy_per_token_j == pytest.approx(
            want.energy_per_token_j, rel=1e-9)
    # the override must actually change the step time vs the average ctx
    avg = evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                         Phase.DECODE)
    assert got[0].latency_s != avg[0].latency_s


def test_context_override_rejected_for_prefill():
    with pytest.raises(ValueError, match="DECODE"):
        evaluate_batch([p1_npu()], QWEN3_32B, OSWORLD_LIBREOFFICE,
                       Phase.PREFILL, context_override=1000)


def test_context_override_defined_for_dllm_decode():
    """Diffusion decode-phase splits are now DEFINED: the override sets
    the sequence length each denoise step reprocesses (capacity stays
    at the full context), so early/late roles genuinely diverge instead
    of raising."""
    trace = GSM8K_DLLM
    early = trace.prompt_tokens + trace.gen_tokens // 4
    late = trace.prompt_tokens + 3 * trace.gen_tokens // 4
    r_early = evaluate_decode(p1_npu(), LLADA_8B, trace,
                              context_override=early)
    r_late = evaluate_decode(p1_npu(), LLADA_8B, trace,
                             context_override=late)
    assert r_early.batch == r_late.batch     # capacity at full context
    assert r_early.latency_s < r_late.latency_s
    got = evaluate_batch([p1_npu()], LLADA_8B, trace, Phase.DECODE,
                         context_override=early)[0]
    assert got.latency_s == pytest.approx(r_early.latency_s, rel=1e-9)


# ---------------------------------------------------------------------------
# DLLM decode roles: a first-class jitted searched scenario, end-to-end
# ---------------------------------------------------------------------------

def test_dllm_decode_role_system_end_to_end(monkeypatch):
    """The fallback branch is gone: a DLLM fleet (prefill + early/late
    denoise roles) evaluates end-to-end through the jitted batch path —
    the oracle loop must never run — and matches the scalar system
    evaluation."""
    import repro.core.perfmodel as pm
    from repro.core import perfmodel_jit
    assert perfmodel_jit.supports(LLADA_8B, Phase.DECODE)
    assert perfmodel_jit.supports(LLADA_8B, Phase.PREFILL)

    def boom(*a, **k):
        raise AssertionError("scalar oracle must not route batch evals")

    monkeypatch.setattr(pm, "_evaluate_batch_scalar", boom)
    ss = sp.SystemSpace.for_topology(DLLM_3ROLE)
    rng = np.random.default_rng(23)
    xs = ss.random_designs(rng, 8)
    systems = [ss.decode(x) for x in xs]
    caches = [dict() for _ in DLLM_3ROLE.roles]
    got = evaluate_system_batch(systems, DLLM_3ROLE, LLADA_8B, GSM8K_DLLM,
                                caches=caches)
    monkeypatch.undo()
    n_feasible = 0
    for s, r in zip(systems, got):
        try:
            want = evaluate_system(list(s), DLLM_3ROLE, LLADA_8B,
                                   GSM8K_DLLM)
        except (InfeasibleConfig, ValueError):
            assert r is None
            continue
        n_feasible += 1
        assert r.tokens_per_joule == pytest.approx(want.tokens_per_joule,
                                                   rel=1e-9)
        assert r.ttft_s == pytest.approx(want.ttft_s, rel=1e-9)
        assert r.total_power_w == pytest.approx(want.total_power_w,
                                                rel=1e-9)
        assert r.decode_tps_aggregate == pytest.approx(
            want.decode_tps_aggregate, rel=1e-9)
    assert n_feasible > 0
    for ri in range(DLLM_3ROLE.k):
        assert set(caches[ri]) == {s[ri].name for s in systems}
    # the same device scores differently under the early vs late denoise
    # role (the decode-phase split is real for DLLM now)
    early = evaluate_batch([p1_npu()], LLADA_8B, GSM8K_DLLM, Phase.DECODE,
                           context_override=DLLM_3ROLE.roles[1]
                           .context_for(GSM8K_DLLM))[0]
    late = evaluate_batch([p1_npu()], LLADA_8B, GSM8K_DLLM, Phase.DECODE,
                          context_override=DLLM_3ROLE.roles[2]
                          .context_for(GSM8K_DLLM))[0]
    assert early.latency_s < late.latency_s
    assert early.batch == late.batch       # capacity at full context


# ---------------------------------------------------------------------------
# d > 2 objectives: nd hypervolume + MC-EHVI routing + 3-obj search
# ---------------------------------------------------------------------------

def _brute_hv(pts: np.ndarray, ref: np.ndarray) -> float:
    """Coordinate-compression oracle: volume of the union of boxes."""
    d = pts.shape[1]
    grids = [np.unique(np.concatenate([[ref[i]], pts[:, i]]))
             for i in range(d)]
    total = 0.0
    for idx in itertools.product(*(range(len(g) - 1) for g in grids)):
        hi = np.array([grids[i][idx[i] + 1] for i in range(d)])
        if np.any(np.all(pts >= hi, axis=1)):
            lo = np.array([grids[i][idx[i]] for i in range(d)])
            total += float(np.prod(hi - lo))
    return total


def test_hypervolume_nd_matches_brute_force():
    rng = np.random.default_rng(11)
    for d in (2, 3, 4):
        for _ in range(6):
            pts = rng.uniform(0.0, 1.0, size=(6, d))
            ref = np.zeros(d)
            assert hypervolume(pts, ref) == pytest.approx(
                _brute_hv(pts, ref), rel=1e-12)
    # points below the reference contribute nothing
    assert hypervolume(np.array([[-1.0, -1.0, -1.0]]), np.zeros(3)) == 0.0
    # duplicated last coordinates collapse into one slab
    pts = np.array([[0.5, 0.5, 0.5], [0.6, 0.4, 0.5], [0.2, 0.9, 0.5]])
    assert hypervolume(pts, np.zeros(3)) == pytest.approx(
        _brute_hv(pts, np.zeros(3)), rel=1e-12)


def test_mc_ehvi_3d_runs_and_is_positive():
    rng = np.random.default_rng(12)
    front = rng.uniform(0.4, 0.6, size=(5, 3))
    ref = np.zeros(3)
    mu = np.array([[0.9, 0.9, 0.9], [-2.0, -2.0, -2.0]])
    sd = np.full((2, 3), 0.1)
    half = rng.standard_normal((64, 3))
    scores = mc_ehvi(front, ref, mu, sd, np.concatenate([half, -half]))
    assert scores[0] > scores[1] >= 0.0


@pytest.mark.slow
def test_three_objective_system_search_runs():
    """TTFT as a third objective: MOBO routes through the MC-EHVI
    fallback instead of crashing, and all searchers stay deterministic."""
    obj = SystemObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                          topology=PD_PAIR, tdp_limit_w=1400.0,
                          ttft_objective=True)
    assert obj.n_obj == 3 and obj.ttft_cap_s is None
    init = shared_init(obj, 6, seed=3)
    res1 = run_mobo(obj, n_total=12, seed=3, init=list(init))
    res2 = run_mobo(obj, n_total=12, seed=3, init=list(init))
    assert len(res1.observations) == 12
    assert [o.x for o in res1.observations] == \
        [o.x for o in res2.observations]
    feas = [o for o in res1.observations if o.f is not None]
    assert feas and all(len(o.f) == 3 for o in feas)
    # 3-objective hypervolume history is monotone through the nd path
    ref = np.asarray([o.f for o in feas]).min(axis=0) - 1.0
    hv = res1.hv_history(ref)
    assert len(hv) == 12 and np.all(np.diff(hv) >= 0) and hv[-1] > 0
    # NSGA-II's constraint-domination penal vector follows n_obj
    nres = run_nsga2(obj, n_total=14, seed=3, init=list(init))
    assert len(nres.observations) == 14
    for runner in (run_random, run_motpe):
        assert len(runner(obj, n_total=10, seed=3,
                          init=list(init)).observations) == 10


def test_dllm_system_per_request_tps_units():
    """A DLLM decode role's latency_s is the WHOLE generation's denoise
    time (no autoregressive step), so the system fold must normalize it
    to per-generated-token units: the per-request TPS of an all-P1
    fleet is gen / (gen_frac-weighted denoise time), not 1 / (total
    time) — a gen_tokens-factor error otherwise."""
    npus = [dataclasses.replace(p1_npu(), name=f"P1-{r.name}")
            for r in DLLM_3ROLE.roles]
    r = evaluate_system(npus, DLLM_3ROLE, LLADA_8B, GSM8K_DLLM)
    early, late = r.roles[1], r.roles[2]
    expect = GSM8K_DLLM.gen_tokens / (0.5 * early.latency_s
                                      + 0.5 * late.latency_s)
    # the amortized KV-migration term shifts this by well under 0.1%
    assert r.decode_tps_per_request == pytest.approx(expect, rel=1e-3)


@pytest.mark.slow
def test_dllm_system_search_seeded_determinism():
    """The `dllm_system` bench row is a seeded searched sweep: the same
    seed must reproduce the exact evaluation trajectory (a scaled-down
    bench_dllm._searched_system), and the budget must find a feasible
    fleet — the properties run.py --check's floor gate relies on."""
    def trajectory():
        obj = SystemObjective(LLADA_8B, OSWORLD_DLLM, topology=DLLM_3ROLE,
                              tdp_limit_w=2100.0, ttft_cap_s=90.0)
        init = system_warm_start(obj, 6, seed=0, pool=64)
        res = run_mobo(obj, n_total=12, seed=0, init=list(init))
        return [(tuple(o.x), o.f) for o in res.observations]

    t1, t2 = trajectory(), trajectory()
    assert t1 == t2
    assert len(t1) == 12
    feas = [f for _, f in t1 if f is not None]
    assert feas                      # a feasible DLLM fleet exists
    assert all(f[0] > 0 for f in feas)


# ---------------------------------------------------------------------------
# Warm start
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_system_warm_start_seeds_search():
    obj = SystemObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                          topology=EXTREME_4ROLE, tdp_limit_w=2800.0,
                          ttft_cap_s=90.0)
    init = system_warm_start(obj, 6, seed=4, pool=64)
    assert len(init) == 6
    assert all(len(o.x) == 4 * sp.N_DIMS for o in init)
    # warm starts honor the cross-half tie and are deterministic
    for o in init:
        for h in range(1, 4):
            assert o.x[sp.KV_GENE] == o.x[h * sp.N_DIMS + sp.KV_GENE]
    obj2 = SystemObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                           topology=EXTREME_4ROLE, tdp_limit_w=2800.0,
                           ttft_cap_s=90.0)
    init2 = system_warm_start(obj2, 6, seed=4, pool=64)
    assert [o.x for o in init] == [o.x for o in init2]
    # at least one composed champion system evaluates end-to-end
    assert any(o.result is not None for o in init)


# ---------------------------------------------------------------------------
# Perf-gate plumbing: the extreme-system entry in run.py --check
# ---------------------------------------------------------------------------

@pytest.mark.bench
def test_bench_check_compare_extreme():
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import EXTREME_TOKJ_FLOOR, compare_extreme
    base = {"extreme_system": {"tokens_per_joule": 0.5,
                               "us_per_run": 60e6}}
    ok = compare_extreme(base, {"extreme_system": {
        "tokens_per_joule": 0.5, "us_per_run": 70e6}}, 5.0)
    assert ok[-1]
    # below the committed baseline -> regression even above the hard floor
    drop = compare_extreme(base, {"extreme_system": {
        "tokens_per_joule": 0.30, "us_per_run": 60e6}}, 5.0)
    assert not drop[-1]
    # below the hard 0.276 pair floor -> regression
    weak_base = {"extreme_system": {"tokens_per_joule": 0.2,
                                    "us_per_run": 60e6}}
    weak = compare_extreme(weak_base, {"extreme_system": {
        "tokens_per_joule": 0.2, "us_per_run": 60e6}}, 5.0)
    assert weak[1] == EXTREME_TOKJ_FLOOR and not weak[-1]
    # timing blow-up -> regression
    slow = compare_extreme(base, {"extreme_system": {
        "tokens_per_joule": 0.5, "us_per_run": 301e6}}, 5.0)
    assert not slow[-1]
    # a baseline captured at a different search budget is flagged, not
    # compared apples-to-oranges (floor = -2 sentinel)
    full_base = {"extreme_system": {"tokens_per_joule": 0.6,
                                    "us_per_run": 90e6, "n_total": 60}}
    mismatch = compare_extreme(full_base, {"extreme_system": {
        "tokens_per_joule": 0.5, "us_per_run": 60e6, "n_total": 40}}, 5.0)
    assert mismatch[1] == -2.0 and not mismatch[-1]
    # pre-extreme baselines skip the gate; missing fresh entry regresses
    assert compare_extreme({"methods": {}}, {}, 5.0) is None
    missing = compare_extreme(base, {}, 5.0)
    assert missing[3] < 0 and not missing[-1]


@pytest.mark.bench
def test_bench_check_compare_dllm():
    """The `dllm_system` gate mirrors `compare_extreme`: hard tokJ floor
    (the hand-designed P1 fleet), committed-baseline floor, timing
    limit, budget-mismatch sentinel, missing-entry regression."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import DLLM_TOKJ_FLOOR, compare_dllm
    base = {"dllm_system": {"tokens_per_joule": 0.005,
                            "us_per_run": 40e6}}
    ok = compare_dllm(base, {"dllm_system": {
        "tokens_per_joule": 0.005, "us_per_run": 50e6}}, 5.0)
    assert ok[-1]
    # below the committed baseline -> regression even above the floor
    drop = compare_dllm(base, {"dllm_system": {
        "tokens_per_joule": 0.004, "us_per_run": 40e6}}, 5.0)
    assert not drop[-1]
    # below the hard hand-designed-fleet floor -> regression
    weak_base = {"dllm_system": {"tokens_per_joule": 0.002,
                                 "us_per_run": 40e6}}
    weak = compare_dllm(weak_base, {"dllm_system": {
        "tokens_per_joule": 0.002, "us_per_run": 40e6}}, 5.0)
    assert weak[1] == DLLM_TOKJ_FLOOR and not weak[-1]
    # timing blow-up -> regression
    slow = compare_dllm(base, {"dllm_system": {
        "tokens_per_joule": 0.005, "us_per_run": 201e6}}, 5.0)
    assert not slow[-1]
    # a baseline captured at a different search budget is flagged
    full_base = {"dllm_system": {"tokens_per_joule": 0.006,
                                 "us_per_run": 60e6, "n_total": 60}}
    mismatch = compare_dllm(full_base, {"dllm_system": {
        "tokens_per_joule": 0.005, "us_per_run": 40e6, "n_total": 40}}, 5.0)
    assert mismatch[1] == -2.0 and not mismatch[-1]
    # pre-dllm baselines skip the gate; missing fresh entry regresses
    assert compare_dllm({"methods": {}}, {}, 5.0) is None
    missing = compare_dllm(base, {}, 5.0)
    assert missing[3] < 0 and not missing[-1]


@pytest.mark.bench
def test_bench_check_compare_fleet1000():
    """The `fleet1000` gate: committed-baseline hypervolume floor,
    timing limit capped by the hard single-digit-minutes ceiling,
    budget/batch-size-mismatch sentinel, missing-entry regression."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import FLEET1000_US_CEILING, compare_fleet1000
    base = {"fleet1000": {"hv": 1000.0, "us_per_run": 100e6,
                          "n_total": 1000, "batch_size": 16}}
    ok = compare_fleet1000(base, {"fleet1000": {
        "hv": 1000.0, "us_per_run": 120e6,
        "n_total": 1000, "batch_size": 16}}, 5.0)
    assert ok[-1]
    # hypervolume below the committed baseline -> regression
    drop = compare_fleet1000(base, {"fleet1000": {
        "hv": 900.0, "us_per_run": 100e6,
        "n_total": 1000, "batch_size": 16}}, 5.0)
    assert not drop[-1]
    # the timing limit is tolerance x baseline, hard-capped by the
    # single-digit-minutes ceiling
    slow = compare_fleet1000(base, {"fleet1000": {
        "hv": 1000.0, "us_per_run": FLEET1000_US_CEILING + 1,
        "n_total": 1000, "batch_size": 16}}, 10.0)
    assert slow[3] == FLEET1000_US_CEILING and not slow[-1]
    # a baseline captured at a different budget or batch size is
    # flagged (floor = -2), not compared apples-to-oranges
    for fresh in ({"n_total": 500, "batch_size": 16},
                  {"n_total": 1000, "batch_size": 8}):
        mismatch = compare_fleet1000(base, {"fleet1000": {
            "hv": 1000.0, "us_per_run": 100e6, **fresh}}, 5.0)
        assert mismatch[1] == -2.0 and not mismatch[-1]
    # pre-fleet baselines skip the gate; missing fresh entry regresses
    assert compare_fleet1000({"methods": {}}, {}, 5.0) is None
    missing = compare_fleet1000(base, {}, 5.0)
    assert missing[3] < 0 and not missing[-1]
