"""Serving-layer tests: queueing math, parity, caching, space plumbing.

What is pinned here, per docs/serving.md:

* **Single-class degeneracy** — a one-class mix served by one replica
  per role with topology-default routing is EXACTLY the system the
  PR 4 `disagg.evaluate_system` fold scores: tokens/joule, zero-queue
  TTFT/TPOT and busy power agree to ~1e-12 (measured ~1e-16), through
  both the scalar oracle and the jitted `FleetEvaluator`.
* **Queueing limits** — tokens/joule is per-work (load-invariant by
  construction), queue waits diverge monotonically as utilization
  approaches 1, and rho >= 1 on any role makes the fleet infeasible.
* **Jit-vs-scalar parity** — `FleetEvaluator` agrees with
  `evaluate_serving` on random valid serving designs (autoregressive
  and diffusion topologies) with identical feasibility masks.
* **Caching** — replica/routing sweeps over fixed device halves never
  rebuild phase tables or rerun role evaluations.
* **Space/journal plumbing** — ServingSpace gene layout round-trips,
  Sobol capacity overflows fail loudly at construction, and a journal
  refuses to resume against a different traffic mix.
"""

import math

import numpy as np
import pytest

from repro.configs.paper_models import LLADA_8B, LLAMA33_70B
from repro.core.disagg import (DLLM_3ROLE, EXTREME_4ROLE, FLEET_6ROLE,
                               PD_PAIR, evaluate_system,
                               evaluate_system_batch)
from repro.core.dse import (JournalMismatch, SearchJournal, ServingObjective,
                            run_mobo, serving_warm_start)
from repro.core.dse import space as sp
from repro.core.npu import d1_npu, p1_npu
from repro.core.serving import (FleetEvaluator, RequestClass, ServingResult,
                                TrafficMix, evaluate_serving, mm_n_wait_s,
                                naive_replication, topology_routing)
from repro.core.workload import CHATBOT, GSM8K_DLLM, OSWORLD_LIBREOFFICE

RTOL = 1e-9          # jit-vs-scalar bound (measured agreement ~1e-16)


def _mix1(rate=1.0, ttft=None, tpot=None):
    return TrafficMix("solo", (RequestClass(
        CHATBOT, rate_rps=rate, ttft_p99_slo_s=ttft,
        tpot_p99_slo_s=tpot),))


def _mix2(r1=2.0, r2=0.01):
    return TrafficMix("duo", (
        RequestClass(CHATBOT, rate_rps=r1, ttft_p99_slo_s=6.0),
        RequestClass(OSWORLD_LIBREOFFICE, rate_rps=r2,
                     ttft_p99_slo_s=90.0),
    ))


def _hand_pair():
    return [p1_npu(), d1_npu()]


# ---------------------------------------------------------------------------
# Queueing math
# ---------------------------------------------------------------------------

def test_mm_n_wait_properties():
    # monotone in rho, divergent toward saturation, shrinking in n
    waits = [mm_n_wait_s(0.1, r, 1) for r in (0.1, 0.5, 0.9, 0.99)]
    assert all(b > a for a, b in zip(waits, waits[1:]))
    assert mm_n_wait_s(0.1, 0.999999, 1) > 1e3 * mm_n_wait_s(0.1, 0.9, 1)
    assert mm_n_wait_s(0.1, 0.5, 4) < mm_n_wait_s(0.1, 0.5, 1)
    assert mm_n_wait_s(0.1, 0.0, 1) == 0.0


def test_tokens_per_joule_is_load_invariant():
    """tok/J is per-work (energy per token x token mix): queue depth
    and replica count never enter it, so a nearly-idle fleet (16x
    replicas) scores EXACTLY the same tok/J as a loaded single-replica
    one at the same mix, and different arrival rates agree to rounding."""
    npus = _hand_pair()
    phi = topology_routing(PD_PAIR, 1)
    mix = _mix1(rate=5.0)
    loaded = evaluate_serving(npus, (1, 1), phi, PD_PAIR, LLAMA33_70B, mix)
    idle = evaluate_serving(npus, (16, 16), phi, PD_PAIR, LLAMA33_70B, mix)
    assert loaded.feasible and idle.feasible
    assert idle.rho[0] < loaded.rho[0]
    assert idle.tokens_per_joule == loaded.tokens_per_joule   # bit-exact
    for rate in (1e-6, 0.01, 1.0):
        r = evaluate_serving(npus, (1, 1), phi, PD_PAIR, LLAMA33_70B,
                             _mix1(rate=rate))
        assert r.feasible
        assert r.tokens_per_joule == pytest.approx(
            loaded.tokens_per_joule, rel=1e-12)


def test_wait_diverges_monotone_then_saturates():
    npus = _hand_pair()
    phi = topology_routing(PD_PAIR, 1)
    prev_wq = -1.0
    saturated = False
    for rate in (0.1, 1.0, 3.0, 6.0, 9.0, 20.0, 200.0):
        r = evaluate_serving(npus, (1, 1), phi, PD_PAIR, LLAMA33_70B,
                             _mix1(rate=rate))
        if not r.feasible:
            saturated = True
            assert max(r.rho) >= 1.0
            continue
        assert not saturated, "feasible again after saturation"
        wq = sum(r.wq_s)
        assert wq > prev_wq
        prev_wq = wq
        assert all(rho < 1.0 for rho in r.rho)
    assert saturated, "rate sweep never saturated the hand pair"


def test_zero_load_ttft_equals_service_time():
    """At vanishing load the p99 TTFT collapses to the zero-queue
    service time (the wait term's rho^... factor vanishes)."""
    r = evaluate_serving(_hand_pair(), (1, 1), topology_routing(PD_PAIR, 1),
                         PD_PAIR, LLAMA33_70B, _mix1(rate=1e-9))
    assert r.ttft_p99_s[0] == pytest.approx(r.ttft0_s[0], rel=1e-6)
    assert r.tpot_p99_s[0] == pytest.approx(r.tpot0_s[0], rel=1e-6)


def test_replicas_restore_feasibility():
    """A rate that saturates single devices is served by replicas, and
    per-work tok/J is unchanged by replication."""
    npus = _hand_pair()
    phi = topology_routing(PD_PAIR, 1)
    mix = _mix1(rate=20.0)
    r1 = evaluate_serving(npus, (1, 1), phi, PD_PAIR, LLAMA33_70B, mix)
    assert not r1.feasible
    r8 = evaluate_serving(npus, (8, 8), phi, PD_PAIR, LLAMA33_70B, mix)
    assert r8.feasible
    low = evaluate_serving(npus, (1, 1), phi, PD_PAIR, LLAMA33_70B,
                           _mix1(rate=0.01))
    assert r8.tokens_per_joule == pytest.approx(low.tokens_per_joule,
                                                rel=1e-12)


# ---------------------------------------------------------------------------
# Single-class degeneracy vs the system fold
# ---------------------------------------------------------------------------

def test_single_class_matches_evaluate_system_scalar():
    sys_r = evaluate_system(_hand_pair(), PD_PAIR, LLAMA33_70B, CHATBOT)
    srv = evaluate_serving(_hand_pair(), (1, 1), topology_routing(PD_PAIR, 1),
                           PD_PAIR, LLAMA33_70B, _mix1(rate=1.0))
    assert srv.feasible
    assert srv.tokens_per_joule == pytest.approx(
        sys_r.tokens_per_joule, rel=1e-12)
    assert srv.ttft0_s[0] == pytest.approx(sys_r.ttft_s, rel=1e-12)
    assert srv.busy_power_w == pytest.approx(sys_r.total_power_w, rel=1e-12)


@pytest.mark.parametrize("topo", [PD_PAIR, EXTREME_4ROLE])
def test_single_class_matches_evaluate_system_jit(topo):
    """FleetEvaluator rows with replicas=1 and topology-default routing
    reproduce `evaluate_system_batch` tokens/joule on the same halves
    wherever both are feasible (the fleet additionally requires queue
    stability, a strict subset)."""
    mix = _mix1(rate=0.001)
    space = sp.ServingSpace(topo, 1)
    rng = np.random.default_rng(7)
    xs = space.random_designs(rng, 32)
    # replicas = 1, equal routing weights == topology-default routing
    xs[:, space.dev_genes:] = 0
    base = sp.SystemSpace.for_topology(topo)
    systems = [base.decode(x[:space.dev_genes]) for x in xs]
    sys_rs = evaluate_system_batch(systems, topo, LLAMA33_70B, CHATBOT)
    out = FleetEvaluator(topo, LLAMA33_70B, mix).evaluate_genes(xs)
    n_both = 0
    for i, sys_r in enumerate(sys_rs):
        if sys_r is None:
            assert not out["feasible"][i]
            continue
        if not out["feasible"][i]:
            continue            # phase-feasible but queue-unstable
        n_both += 1
        assert out["tokens_per_joule"][i] == pytest.approx(
            sys_r.tokens_per_joule, rel=1e-12)
        assert out["ttft0_s"][i, 0] == pytest.approx(sys_r.ttft_s,
                                                     rel=1e-12)
    assert n_both >= 3, "sample too degenerate to pin parity"


# ---------------------------------------------------------------------------
# Jit vs scalar parity
# ---------------------------------------------------------------------------

_PARITY_KEYS = ("tokens_per_joule", "fleet_power_w", "busy_power_w")
_PERCLASS_KEYS = ("ttft_p99_s", "tpot_p99_s", "ttft0_s", "tpot0_s")


def _assert_parity(out, i, scalar: ServingResult, n_classes: int):
    assert bool(out["feasible"][i]) == scalar.feasible
    if not scalar.feasible:
        return
    assert bool(out["slo_ok"][i]) == scalar.slo_ok
    for k in _PARITY_KEYS:
        assert out[k][i] == pytest.approx(getattr(scalar, k), rel=RTOL)
    for k in _PERCLASS_KEYS:
        for c in range(n_classes):
            got, want = out[k][i][c], getattr(scalar, k)[c]
            if math.isinf(want):
                assert math.isinf(got)
            else:
                assert got == pytest.approx(want, rel=RTOL)
    for k, want in (("rho", scalar.rho), ("wq_s", scalar.wq_s)):
        for r, w in enumerate(want):
            if math.isinf(w):
                assert math.isinf(out[k][i][r])
            else:
                assert out[k][i][r] == pytest.approx(w, rel=RTOL)


def test_jit_vs_scalar_parity_extreme_mix():
    mix = _mix2()
    space = sp.ServingSpace.for_mix(EXTREME_4ROLE, mix)
    rng = np.random.default_rng(11)
    xs = space.random_designs(rng, 24)
    fleet = FleetEvaluator(EXTREME_4ROLE, LLAMA33_70B, mix)
    out = fleet.evaluate_genes(xs)
    n_feas = 0
    for i, x in enumerate(xs):
        d = space.decode(x)
        scalar = evaluate_serving(list(d.npus), d.replicas, d.phi,
                                  EXTREME_4ROLE, LLAMA33_70B, mix)
        _assert_parity(out, i, scalar, len(mix.classes))
        n_feas += scalar.feasible
    assert n_feas >= 2, "sample too degenerate to pin parity"


def test_jit_vs_scalar_parity_dllm():
    mix = TrafficMix("dllm", (RequestClass(GSM8K_DLLM, rate_rps=0.5),))
    space = sp.ServingSpace(DLLM_3ROLE, 1)
    rng = np.random.default_rng(13)
    xs = space.random_designs(rng, 16)
    out = FleetEvaluator(DLLM_3ROLE, LLADA_8B, mix).evaluate_genes(xs)
    n_feas = 0
    for i, x in enumerate(xs):
        d = space.decode(x)
        scalar = evaluate_serving(list(d.npus), d.replicas, d.phi,
                                  DLLM_3ROLE, LLADA_8B, mix)
        _assert_parity(out, i, scalar, 1)
        n_feas += scalar.feasible
    assert n_feas >= 1, "sample too degenerate to pin parity"


# ---------------------------------------------------------------------------
# Metric-cache reuse across replica/routing sweeps
# ---------------------------------------------------------------------------

def test_replica_routing_sweep_is_pure_cache_hits():
    mix = _mix2()
    space = sp.ServingSpace.for_mix(EXTREME_4ROLE, mix)
    rng = np.random.default_rng(3)
    xs = space.random_designs(rng, 8)
    fleet = FleetEvaluator(EXTREME_4ROLE, LLAMA33_70B, mix)
    out1 = fleet.evaluate_genes(xs)
    builds, evals = fleet.n_table_builds, fleet.n_role_evals
    assert builds > 0 and evals > 0
    # sweep replica + routing genes over the SAME device halves: the
    # per-role metric cache must answer everything
    for trial in range(3):
        xs2 = xs.copy()
        xs2[:, space.dev_genes:] = rng.integers(
            0, 8, size=xs2[:, space.dev_genes:].shape)
        out2 = fleet.evaluate_genes(xs2)
        assert fleet.n_table_builds == builds
        assert fleet.n_role_evals == evals
    # the sweep genuinely changed the queueing outcome
    assert not np.array_equal(out1["rho"], out2["rho"])
    # new halves DO miss: a fresh sample must build tables again
    xs3 = space.random_designs(rng, 4)
    fleet.evaluate_genes(xs3)
    assert fleet.n_table_builds > builds


# ---------------------------------------------------------------------------
# Space plumbing
# ---------------------------------------------------------------------------

def test_serving_space_layout_and_roundtrip():
    mix = _mix2()
    space = sp.ServingSpace.for_mix(EXTREME_4ROLE, mix)
    k, n_dec, n_cls = 4, 2, 2
    assert space.dev_genes == k * sp.N_DIMS
    assert space.n_dims == k * sp.N_DIMS + k + n_cls * n_dec
    rng = np.random.default_rng(5)
    xs = space.random_designs(rng, 16)
    assert space.valid_mask(xs).all()
    reps = space.replica_counts(xs)
    assert reps.shape == (16, k)
    assert set(np.unique(reps)) <= set(sp.REPLICA_CHOICES)
    phi = space.routing(xs)
    assert phi.shape == (16, n_cls, n_dec)
    assert np.allclose(phi.sum(axis=-1), 1.0)
    assert (phi > 0).all()
    d = space.decode(xs[0])
    assert len(d.npus) == k
    assert d.replicas == tuple(reps[0])
    assert np.allclose(d.phi, phi[0])
    # out-of-range extra genes are invalid, and repair preserves them
    bad = xs.copy()
    bad[0, space.dev_genes] = len(sp.REPLICA_CHOICES)
    assert not space.valid_mask(bad)[0]
    rep = space.repair(list(xs[0]))
    assert rep[space.dev_genes:] == list(xs[0][space.dev_genes:])


def test_serving_tdp_scales_with_replicas():
    space = sp.ServingSpace(PD_PAIR, 1)
    rng = np.random.default_rng(9)
    x = np.asarray([space.random_design(rng)], dtype=np.int64)
    base_space = sp.SystemSpace.for_topology(PD_PAIR)
    halves = x[:, :space.dev_genes]
    per_half = [sp.tdp_w_batch(halves[:, i * sp.N_DIMS:(i + 1) * sp.N_DIMS])
                for i in range(2)]
    x[0, space.dev_genes:space.dev_genes + 2] = [3, 1]   # 4x, 2x replicas
    want = 4 * per_half[0][0] + 2 * per_half[1][0]
    assert space.tdp_w_batch(x)[0] == pytest.approx(want, rel=1e-12)
    # replicas=1 degenerates to the SystemSpace budget
    x[0, space.dev_genes:space.dev_genes + 2] = 0
    assert space.tdp_w_batch(x)[0] == pytest.approx(
        base_space.tdp_w_batch(halves)[0], rel=1e-12)


def test_routing_fractions_exact_binary_splits():
    # equal weights -> exact 1/D fractions (binary: no rounding error)
    phi = sp.routing_fractions(np.zeros((1, 1, 4), dtype=np.int64))
    assert (phi == 0.25).all()
    phi = sp.routing_fractions(np.array([[[0, 2]]]))    # weights 1, 3
    assert phi[0, 0, 0] == 0.25 and phi[0, 0, 1] == 0.75


def test_sobol_capacity_overflow_is_loud():
    with pytest.raises(ValueError, match="gen_sobol_directions.py"):
        sp.SystemSpace(10)          # 170 genes > the 158-dim table
    with pytest.raises(ValueError, match="gen_sobol_directions.py"):
        sp.ServingSpace(FLEET_6ROLE, 13)   # 102 + 6 + 52 = 160 genes
    # the largest shipped serving scenario still fits
    assert sp.ServingSpace(FLEET_6ROLE, 3).n_dims <= 158


def test_serving_space_for_topology_refuses():
    with pytest.raises(TypeError, match="for_mix"):
        sp.ServingSpace.for_topology(PD_PAIR)


# ---------------------------------------------------------------------------
# Naive replication baseline
# ---------------------------------------------------------------------------

def test_naive_replication_minimal_feasible_level():
    mix = _mix1(rate=6.0, ttft=6.0)
    budget = 50000.0
    r = naive_replication(_hand_pair(), PD_PAIR, LLAMA33_70B, mix, budget)
    assert r is not None and r.feasible and r.slo_ok
    lvl = r.replicas[0]
    assert all(n == lvl for n in r.replicas)    # uniform by construction
    if lvl > 1:
        below = [c for c in sp.REPLICA_CHOICES if c < lvl]
        prev = evaluate_serving(_hand_pair(), (below[-1],) * 2,
                                topology_routing(PD_PAIR, 1), PD_PAIR,
                                LLAMA33_70B, mix)
        assert not (prev.feasible and prev.slo_ok)
    # a budget below the minimal feasible level's draw -> None
    assert naive_replication(_hand_pair(), PD_PAIR, LLAMA33_70B, mix,
                             power_budget_w=1.0) is None


# ---------------------------------------------------------------------------
# Objective / search / journal plumbing
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_search_seeded_determinism():
    def run_once():
        obj = ServingObjective(LLAMA33_70B, _mix1(rate=4.0, ttft=6.0),
                               topology=PD_PAIR)
        run_mobo(obj, n_total=12, n_init=6, batch_size=4, seed=0)
        return sorted((k, v.f) for k, v in obj.cache.items())
    assert run_once() == run_once()


@pytest.mark.slow
def test_serving_warm_start_finds_feasible_fleet():
    obj = ServingObjective(LLAMA33_70B, _mix1(rate=4.0, ttft=6.0),
                           topology=PD_PAIR)
    init = serving_warm_start(obj, 8, seed=0, pool=128)
    assert len(init) == 8
    feas = [o for o in init if o.f is not None]
    assert feas, "warm start found no feasible serving design"
    again = serving_warm_start(
        ServingObjective(LLAMA33_70B, _mix1(rate=4.0, ttft=6.0),
                         topology=PD_PAIR), 8, seed=0, pool=128)
    assert [tuple(o.x) for o in init] == [tuple(o.x) for o in again]


@pytest.mark.bench
def test_bench_check_compare_serving():
    """The `serving` gate: committed-baseline tokJ floor raised to the
    FRESH naive-replication tokJ, pool wall-clock / bare-path-overhead
    ceilings, timing limit, budget-mismatch sentinel, missing-entry
    regression (conventions shared with the other compare_* gates)."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import (SERVING_OVERHEAD_MAX,
                                SERVING_POOL_S_CEILING, compare_serving)

    def entry(**kw):
        e = {"tokens_per_joule": 1.0, "naive_tokens_per_joule": 0.5,
             "us_per_run": 40e6, "pool_s": 0.5, "overhead_ratio": 0.2,
             "n_total": 96, "batch_size": 16}
        e.update(kw)
        return {"serving": e}

    base = entry()
    assert compare_serving(base, entry(us_per_run=50e6), 5.0)[-1]
    # below the committed baseline -> regression
    assert not compare_serving(base, entry(tokens_per_joule=0.9), 5.0)[-1]
    # the floor is raised to the FRESH naive tokJ: a searched fleet
    # that no longer beats naive replication regresses even when it
    # matches the committed baseline
    lost = compare_serving(base, entry(naive_tokens_per_joule=1.1), 5.0)
    assert lost[1] == 1.1 and not lost[-1]
    # pool wall clock / overhead ceilings
    assert not compare_serving(base, entry(
        pool_s=SERVING_POOL_S_CEILING + 0.1), 5.0)[-1]
    assert not compare_serving(base, entry(
        overhead_ratio=SERVING_OVERHEAD_MAX + 0.1), 5.0)[-1]
    assert not compare_serving(base, entry(pool_s=None), 5.0)[-1]
    # timing blow-up -> regression
    assert not compare_serving(base, entry(us_per_run=201e6), 5.0)[-1]
    # budget/batch mismatch is flagged (floor = -2), not compared
    for kw in ({"n_total": 48}, {"batch_size": 8}):
        mismatch = compare_serving(base, entry(**kw), 5.0)
        assert mismatch[1] == -2.0 and not mismatch[-1]
    # pre-serving baselines skip the gate; missing fresh entry regresses
    assert compare_serving({"methods": {}}, {}, 5.0) is None
    missing = compare_serving(base, {}, 5.0)
    assert missing[-2] < 0 and not missing[-1]


def test_journal_refuses_different_mix(tmp_path):
    path = tmp_path / "serving.jsonl"
    obj_a = ServingObjective(LLAMA33_70B, _mix1(rate=1.0), topology=PD_PAIR)
    with SearchJournal(path) as j:
        j.begin(obj_a, seed=0)
    # same everything, different arrival rate -> refuse to resume
    obj_b = ServingObjective(LLAMA33_70B, _mix1(rate=2.0), topology=PD_PAIR)
    with pytest.raises(JournalMismatch):
        SearchJournal(path).begin(obj_b, seed=0)
    # the original identity still resumes
    obj_c = ServingObjective(LLAMA33_70B, _mix1(rate=1.0), topology=PD_PAIR)
    assert SearchJournal(path).begin(obj_c, seed=0) == 0
