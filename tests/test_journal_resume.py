"""Crash-safe search runtime: evaluation journal + deterministic resume.

The headline guarantee: a seeded search killed at ANY iteration
boundary and resumed from its journal reproduces the uninterrupted run
byte-identically — same proposals, same objective values, same journal
bytes, same sha-pinned trajectory.  Interruption is simulated by
truncating the journal to a prefix of complete records (plus a torn
mid-record tail for the crash-mid-write case) and rerunning the same
search line against a fresh objective.
"""

import hashlib
import json

import pytest

from repro.configs.paper_models import QWEN3_32B
from repro.core.dse import (DisaggObjective, JournalMismatch, Objective,
                            SearchJournal, run_mobo, run_motpe, run_nsga2,
                            run_random, shared_init, system_warm_start)
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

pytestmark = pytest.mark.fault

# The sha-pinned GP+EHVI trajectory of tests/test_disagg_dse.py
# (QWEN3_32B, OSWorld, DECODE, tdp=700, init=shared_init(6, seed=2),
# n_total=14): the journaled and resumed runs must keep reproducing it.
_PINNED_MOBO_SHA = \
    "b6657bac37c6a6976704bf68140f913a27b713134bb6f5d3cd65592d07dde7da"


def _objective():
    return Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                     tdp_limit_w=700.0)


def _traj_sha(result) -> str:
    xs = [[int(v) for v in o.x] for o in result.observations]
    return hashlib.sha256(json.dumps(xs).encode()).hexdigest()


@pytest.mark.slow
def test_mobo_resume_every_boundary_byte_identical(tmp_path):
    """GP+EHVI interrupted at every iteration boundary + torn tail."""
    base = tmp_path / "base.jsonl"
    res = run_mobo(_objective(), n_total=14, seed=2, n_init=6,
                   journal=SearchJournal(base))
    assert _traj_sha(res) == _PINNED_MOBO_SHA
    ref = base.read_bytes()
    lines = ref.split(b"\n")[:-1]
    assert len(lines) == 15             # header + one record per eval

    for i in range(len(lines)):         # header-only .. fully complete
        part = tmp_path / f"resume_{i}.jsonl"
        part.write_bytes(b"\n".join(lines[:i + 1]) + b"\n")
        r2 = run_mobo(_objective(), n_total=14, seed=2, n_init=6,
                      journal=SearchJournal(part))
        assert part.read_bytes() == ref, f"boundary {i}"
        assert _traj_sha(r2) == _PINNED_MOBO_SHA, f"boundary {i}"

    # crash mid-write: a torn final record is dropped and recomputed
    torn = tmp_path / "torn.jsonl"
    torn.write_bytes(b"\n".join(lines[:8]) + b"\n" + lines[8][:20])
    r3 = run_mobo(_objective(), n_total=14, seed=2, n_init=6,
                  journal=SearchJournal(torn))
    assert torn.read_bytes() == ref
    assert _traj_sha(r3) == _PINNED_MOBO_SHA


@pytest.mark.slow
def test_mobo_batched_resume_every_boundary_byte_identical(tmp_path):
    """Batched q-EHVI (B = 4) interrupted at EVERY journal boundary —
    i.e. including prefixes that end in the middle of a B-point batch,
    where only part of one `record_many` block survived — and with a
    torn mid-record tail inside a batch, resumes byte-identically.
    `record_many` journals a batch as one write of per-record lines, so
    a crash can strand any prefix of a batch; on resume the stranded
    records replay as cache hits and the missing remainder of the batch
    re-evaluates and re-journals without duplicating the prefix."""
    def search(journal):
        return run_mobo(_objective(), n_total=14, seed=2, n_init=6,
                        batch_size=4, journal=journal)

    base = tmp_path / "batched.jsonl"
    res = search(SearchJournal(base))
    assert len(res.observations) == 14
    ref = base.read_bytes()
    lines = ref.split(b"\n")[:-1]
    assert len(lines) == 15             # header + one line per eval

    for i in range(len(lines)):         # header-only .. fully complete
        part = tmp_path / f"resume_{i}.jsonl"
        part.write_bytes(b"\n".join(lines[:i + 1]) + b"\n")
        r2 = search(SearchJournal(part))
        assert part.read_bytes() == ref, f"boundary {i}"
        assert [o.x for o in r2.observations] == \
            [o.x for o in res.observations], f"boundary {i}"
        assert [o.f for o in r2.observations] == \
            [o.f for o in res.observations], f"boundary {i}"

    # crash mid-write inside the first proposed batch (records 6..9):
    # the torn record is dropped and recomputed
    torn = tmp_path / "torn_batch.jsonl"
    torn.write_bytes(b"\n".join(lines[:9]) + b"\n" + lines[9][:17])
    r3 = search(SearchJournal(torn))
    assert torn.read_bytes() == ref
    assert [o.x for o in r3.observations] == \
        [o.x for o in res.observations]


def test_other_searchers_resume_midpoint(tmp_path):
    """Random/NSGA-II/MO-TPE resumed from a mid-run journal prefix."""
    for runner in (run_random, run_nsga2, run_motpe):
        base = tmp_path / f"{runner.__name__}.jsonl"
        res = runner(_objective(), n_total=12, seed=3,
                     journal=SearchJournal(base))
        assert len(res.observations) == 12
        ref = base.read_bytes()
        lines = ref.split(b"\n")[:-1]
        cut = len(lines) // 2
        part = tmp_path / f"{runner.__name__}_resume.jsonl"
        part.write_bytes(b"\n".join(lines[:cut]) + b"\n")
        r2 = runner(_objective(), n_total=12, seed=3,
                    journal=SearchJournal(part))
        assert part.read_bytes() == ref, runner.__name__
        assert [o.x for o in r2.observations] == \
            [o.x for o in res.observations], runner.__name__


def test_resume_skips_reevaluation(tmp_path):
    """Replayed evaluations are cache hits: the resumed objective never
    re-runs the perfmodel for journaled designs."""
    base = tmp_path / "j.jsonl"
    run_random(_objective(), n_total=10, seed=1, journal=SearchJournal(base))
    obj = _objective()
    run_random(obj, n_total=10, seed=1, journal=SearchJournal(base))
    assert obj.n_evals == 0             # everything replayed


def test_journal_records_feasibility_and_objectives(tmp_path):
    base = tmp_path / "j.jsonl"
    res = run_random(_objective(), n_total=10, seed=1,
                     journal=SearchJournal(base))
    lines = base.read_text().splitlines()
    header = json.loads(lines[0])
    assert header["kind"] == "header"
    ident = header["identity"]
    assert ident["space"] == "SingleDeviceSpace"
    assert ident["model"] == QWEN3_32B.name
    assert ident["trace"] == OSWORLD_LIBREOFFICE.name
    assert ident["phase"] == "DECODE"
    assert ident["seed"] == 1
    recs = [json.loads(ln) for ln in lines[1:]]
    assert [r["i"] for r in recs] == list(range(10))
    by_key = {tuple(r["x"]): r for r in recs}
    for o in res.observations:
        rec = by_key[tuple(int(v) for v in o.x)]
        if o.f is None:
            assert rec["f"] is None
        else:
            assert tuple(rec["f"]) == tuple(float(v) for v in o.f)
            assert "bneck" in rec       # feasible evals carry a bottleneck


def test_journal_rejects_mismatched_identity(tmp_path):
    base = tmp_path / "j.jsonl"
    run_random(_objective(), n_total=8, seed=1, journal=SearchJournal(base))
    # wrong seed
    with pytest.raises(JournalMismatch):
        run_random(_objective(), n_total=8, seed=2,
                   journal=SearchJournal(base))
    # wrong objective budget
    with pytest.raises(JournalMismatch):
        run_random(Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                             tdp_limit_w=600.0),
                   n_total=8, seed=1, journal=SearchJournal(base))
    # wrong space/objective shape entirely
    with pytest.raises(JournalMismatch):
        run_random(DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE),
                   n_total=8, seed=1, journal=SearchJournal(base))


def test_journal_threads_through_shared_init_and_searcher(tmp_path):
    """One journal across shared_init + searcher: begin is idempotent,
    init evals are journaled once, and the pair resumes byte-identically
    on the paired (system) objective too."""
    def paired():
        return DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                               tdp_limit_w=1400.0, ttft_cap_s=90.0)

    base = tmp_path / "pair.jsonl"
    j = SearchJournal(base)
    init = shared_init(paired(), 4, seed=1, journal=j)
    # same objective identity must be used for init and search here;
    # recreate the objective to prove replay feeds the fresh cache
    obj = paired()
    res = run_random(obj, n_total=9, seed=1, init=init, journal=j)
    assert len(res.observations) == 9
    ref = base.read_bytes()
    lines = ref.split(b"\n")[:-1]
    assert len(lines) == 10             # header + 9 evals (init included)

    part = tmp_path / "pair_resume.jsonl"
    part.write_bytes(b"\n".join(lines[:6]) + b"\n")
    j2 = SearchJournal(part)
    obj2 = paired()
    init2 = shared_init(obj2, 4, seed=1, journal=j2)
    r2 = run_random(obj2, n_total=9, seed=1, init=init2, journal=j2)
    assert part.read_bytes() == ref
    assert [o.x for o in r2.observations] == \
        [o.x for o in res.observations]


def test_system_warm_start_journals_and_resumes(tmp_path):
    """`system_warm_start` writes through the same journal as the
    searcher it seeds and resumes byte-identically mid-search."""
    def paired():
        return DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                               tdp_limit_w=1400.0, ttft_cap_s=90.0)

    def search(journal, obj):
        init = system_warm_start(obj, 4, seed=0, pool=32, journal=journal)
        return run_random(obj, n_total=8, seed=0, init=init,
                          journal=journal)

    base = tmp_path / "warm.jsonl"
    res = search(SearchJournal(base), paired())
    assert len(res.observations) == 8
    ref = base.read_bytes()
    lines = ref.split(b"\n")[:-1]
    assert len(lines) == 9              # header + 8 evals

    part = tmp_path / "warm_resume.jsonl"
    part.write_bytes(b"\n".join(lines[:7]) + b"\n")
    r2 = search(SearchJournal(part), paired())
    assert part.read_bytes() == ref
    assert [o.x for o in r2.observations] == \
        [o.x for o in res.observations]
    assert [o.f for o in r2.observations] == \
        [o.f for o in res.observations]
