"""Minimal stand-in for `hypothesis` when the real package is absent.

The container this suite runs in does not ship `hypothesis`, and tier-1
forbids installing it, so `conftest.py` installs this shim into
`sys.modules` as a fallback.  It implements exactly the surface the test
suite uses — `given`, `settings`, and the `floats` / `integers` /
`sampled_from` / `lists` / `tuples` strategies — by running each property
against a deterministic seeded sample (boundary values first, then
uniform draws).  When the real hypothesis is installed it is used
instead; this file is never imported.
"""

from __future__ import annotations

import functools
import random
import zlib


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)   # deterministic edge examples

    def draw(self, rng: random.Random):
        return self._draw(rng)


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     boundary=(min_value, max_value))


def integers(min_value=0, max_value=100, **_kw):
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundary=(min_value, max_value))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda r: r.choice(seq), boundary=(seq[0], seq[-1]))


def booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)),
                     boundary=(False, True))


def lists(elements, min_size=0, max_size=10, **_kw):
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements.draw(r) for _ in range(n)]
    return _Strategy(draw)


def tuples(*elements):
    return _Strategy(lambda r: tuple(e.draw(r) for e in elements))


class settings:
    """Decorator: records max_examples on the wrapped property."""

    def __init__(self, max_examples=25, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", 25)
            # crc32, not hash(): PYTHONHASHSEED varies per process and
            # would make "deterministic" draws differ between runs
            rng = random.Random(0xC0FFEE ^ zlib.crc32(fn.__name__.encode()))
            strategies = list(pos_strategies) + list(kw_strategies.values())
            names = list(kw_strategies)
            n_boundary = 0
            if all(s.boundary for s in strategies) and strategies:
                n_boundary = min(len(s.boundary) for s in strategies)
            for i in range(n):
                if i < n_boundary:
                    vals = [s.boundary[i] for s in strategies]
                else:
                    vals = [s.draw(rng) for s in strategies]
                pos = vals[:len(pos_strategies)]
                kw = dict(zip(names, vals[len(pos_strategies):]))
                fn(*pos, *args, **kw, **kwargs)

        # pytest must not mistake the strategy-bound parameters for
        # fixtures: expose only the unbound remainder of fn's signature.
        import inspect
        params = list(inspect.signature(fn).parameters.values())
        remaining = [p for p in params[len(pos_strategies):]
                     if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(remaining)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return deco


class strategies:  # imported as `from hypothesis import strategies as st`
    floats = staticmethod(floats)
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
