"""Fault injection against the crash-safe search runtime.

The seeded chaos layer (`core.dse.faults`) storms the evaluation path
with transient evaluator exceptions, NaN objective corruption and
infeasibility floods; these tests pin the runtime's robustness claims:

* every searcher *completes* under every storm,
* for retryable faults (transient exceptions, bounded NaN budgets) the
  trajectory *converges to the failure-free run exactly* — same
  proposals, same objective values,
* persistent NaNs are quarantined as infeasible and never leak into
  `feasible_f` / `hv_history` / the Pareto front,
* the perfmodel's jitted fast path retries, degrades to the scalar
  oracle, and re-scores NaNs — emitting structured degradation events
  instead of killing the search,
* the benchmark baseline merge is atomic and warns instead of
  swallowing write failures.
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.configs.paper_models import QWEN3_32B
from repro.core import perfmodel
from repro.core import perfmodel_jit as pj
from repro.core.dse import (FaultInjector, FaultSpec, FaultyObjective,
                            Objective, SearchJournal, TransientEvalError,
                            run_mobo, run_motpe, run_nsga2, run_random)
from repro.core.dse import space as sp
from repro.core.dse.runner import EVAL_RETRIES
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase

pytestmark = pytest.mark.fault

SEARCHERS = {
    "random": lambda obj, j=None: run_random(obj, n_total=12, seed=5,
                                             journal=j),
    "nsga2": lambda obj, j=None: run_nsga2(obj, n_total=12, seed=5,
                                           pop_size=6, journal=j),
    "motpe": lambda obj, j=None: run_motpe(obj, n_total=12, seed=5,
                                           journal=j),
    "mobo": lambda obj, j=None: run_mobo(obj, n_total=12, seed=5,
                                         n_init=6, journal=j),
}


def _objective():
    return Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                     tdp_limit_w=700.0)


def _storm(spec):
    inj = FaultInjector(spec)
    return FaultyObjective(_objective(), inj), inj


# ---------------------------------------------------------------------------
# Convergence: retryable storms leave the trajectory untouched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SEARCHERS))
def test_searchers_converge_under_transient_and_nan_storms(name):
    """Summed per-mode fault budgets <= EVAL_RETRIES: retries drain
    every fault budget even when a transient-faulted batch contains a
    NaN-faulted key, so the faulted run reproduces the failure-free run
    exactly (the composition bound in FaultSpec's docstring)."""
    spec = FaultSpec(p_transient=0.3, p_nan=0.3, fault_attempts=1, seed=5)
    assert 2 * spec.fault_attempts <= EVAL_RETRIES
    clean = SEARCHERS[name](_objective())
    faulty_obj, inj = _storm(spec)
    stormy = SEARCHERS[name](faulty_obj)
    assert inj.events, "storm never fired — the test exercised nothing"
    assert [o.x for o in stormy.observations] == \
        [o.x for o in clean.observations]
    assert [o.f for o in stormy.observations] == \
        [o.f for o in clean.observations]
    assert np.array_equal(stormy.feasible_f(), clean.feasible_f())


def test_batched_mobo_converges_under_storm():
    """Batched q-EHVI (B = 4) under a transient+NaN storm within the
    retry-budget composition bound: whole B-point batches fail and
    retry through `_eval_many`, yet the stormy run reproduces the
    failure-free batched trajectory (proposals AND objective values)
    exactly, with nothing quarantined."""
    spec = FaultSpec(p_transient=0.3, p_nan=0.3, fault_attempts=1, seed=5)
    assert 2 * spec.fault_attempts <= EVAL_RETRIES

    def batched(obj):
        return run_mobo(obj, n_total=14, seed=5, n_init=6, batch_size=4)

    clean = batched(_objective())
    faulty_obj, inj = _storm(spec)
    stormy = batched(faulty_obj)
    assert inj.events, "storm never fired — the test exercised nothing"
    assert len(stormy.observations) == 14
    assert [o.x for o in stormy.observations] == \
        [o.x for o in clean.observations]
    assert [o.f for o in stormy.observations] == \
        [o.f for o in clean.observations]
    assert all(o.fault is None for o in stormy.observations)
    assert np.array_equal(stormy.feasible_f(), clean.feasible_f())


def test_storm_actually_injects_both_fault_kinds():
    spec = FaultSpec(p_transient=0.3, p_nan=0.3, fault_attempts=1, seed=5)
    faulty_obj, inj = _storm(spec)
    run_mobo(faulty_obj, n_total=12, seed=5, n_init=6)
    kinds = {e[0] for e in inj.events}
    assert "transient" in kinds and "nan" in kinds


@pytest.mark.parametrize("mode", ["transient", "nan"])
def test_single_mode_storm_converges_at_full_retry_budget(mode):
    """With one fault mode active its budget may use the whole retry
    budget (fault_attempts == EVAL_RETRIES) and still converge."""
    kw = {f"p_{mode}": 0.5}
    spec = FaultSpec(fault_attempts=EVAL_RETRIES, seed=9, **kw)
    clean = run_random(_objective(), n_total=12, seed=5)
    faulty_obj, inj = _storm(spec)
    stormy = run_random(faulty_obj, n_total=12, seed=5)
    assert any(e[0] == mode for e in inj.events)
    assert [o.f for o in stormy.observations] == \
        [o.f for o in clean.observations]


def test_transient_error_is_a_step_failure():
    """The injected exception must be retryable by RetryPolicy."""
    from repro.runtime.fault import StepFailure
    assert issubclass(TransientEvalError, StepFailure)
    spec = FaultSpec(p_transient=1.0, fault_attempts=1, seed=0)
    faulty_obj, _ = _storm(spec)
    with pytest.raises(TransientEvalError):
        faulty_obj.evaluate_batch([[0] * faulty_obj.space.n_dims])


# ---------------------------------------------------------------------------
# Completion: sticky infeasibility floods, persistent NaN quarantine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SEARCHERS))
def test_searchers_complete_under_infeasibility_flood(name):
    """Flooded verdicts are sticky (never retried); searchers must still
    finish their budget and keep flooded designs out of the front."""
    faulty_obj, inj = _storm(FaultSpec(p_infeasible=0.5, seed=7))
    res = SEARCHERS[name](faulty_obj)
    assert len(res.observations) == 12
    flooded = {key for kind, key in inj.events if kind == "infeasible"}
    assert flooded, "flood never fired"
    for o in res.pareto():
        assert tuple(int(v) for v in o.x) not in flooded
    assert all(math.isfinite(v) for f in res.feasible_f() for v in f)


def test_persistent_nan_quarantined_never_in_front(tmp_path):
    """fault_attempts > EVAL_RETRIES: the NaN outlives the retry budget,
    so the design is quarantined — recorded infeasible with a fault tag,
    absent from feasible_f/hv_history/pareto — and the search completes."""
    spec = FaultSpec(p_nan=0.4, fault_attempts=EVAL_RETRIES + 5, seed=11)
    inj = FaultInjector(spec)
    faulty_obj = FaultyObjective(_objective(), inj)
    jpath = tmp_path / "quarantine.jsonl"
    res = run_random(faulty_obj, n_total=16, seed=5,
                     journal=SearchJournal(jpath))
    assert len(res.observations) == 16
    quarantined = [o for o in res.observations if o.fault == "non_finite"]
    assert quarantined, "no quarantine happened — the test is vacuous"
    assert all(o.f is None for o in quarantined)
    # nothing non-finite anywhere near the front or its bookkeeping
    fs = res.feasible_f()
    assert len(fs) and np.all(np.isfinite(fs))
    hv = res.hv_history(fs.min(axis=0) - 1.0)
    assert len(hv) == 16 and np.all(np.isfinite(hv))
    assert np.all(np.diff(hv) >= -1e-9)
    front_keys = {tuple(int(v) for v in o.x) for o in res.pareto()}
    assert front_keys.isdisjoint(
        {tuple(int(v) for v in o.x) for o in quarantined})
    # the journal records the quarantine verdict durably
    recs = [json.loads(ln) for ln in jpath.read_text().splitlines()[1:]]
    tagged = [r for r in recs if r.get("fault") == "non_finite"]
    assert len(tagged) == len(quarantined)
    assert all(r["f"] is None for r in tagged)


def test_persistent_evaluator_error_yields_infeasible_not_crash():
    """A batch whose transient budget outlives the retries degrades to
    infeasible observations instead of killing the searcher."""
    spec = FaultSpec(p_transient=1.0, fault_attempts=EVAL_RETRIES + 5,
                     seed=3)
    faulty_obj, _ = _storm(spec)
    res = run_random(faulty_obj, n_total=10, seed=5)
    assert len(res.observations) == 10
    assert all(o.fault == "evaluator_error" for o in res.observations)
    assert len(res.feasible_f()) == 0


# ---------------------------------------------------------------------------
# Perfmodel: jit retry, scalar fallback, NaN re-score — with events
# ---------------------------------------------------------------------------

@pytest.fixture()
def degradation_log():
    perfmodel.clear_degradation_events()
    yield perfmodel.degradation_events
    perfmodel.clear_degradation_events()


@pytest.fixture(scope="module")
def npu_pool():
    rng = np.random.default_rng(0)
    xs = sp.random_designs(rng, 64)
    xs = xs[sp.valid_mask(xs)][:12]
    assert len(xs) == 12
    return [sp.decode(x) for x in xs]


def _score(npus, **kw):
    return perfmodel.evaluate_batch(npus, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                    Phase.DECODE, **kw)


def test_jit_transient_failure_retried_silently(npu_pool, monkeypatch,
                                                degradation_log):
    want = _score(npu_pool)
    real = pj.evaluate_batch_table
    calls = {"n": 0}

    def flaky(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] <= perfmodel.JIT_RETRY.max_retries:
            raise RuntimeError("injected transient jit failure")
        return real(*args, **kwargs)

    monkeypatch.setattr(pj, "evaluate_batch_table", flaky)
    got = _score(npu_pool)
    assert calls["n"] == perfmodel.JIT_RETRY.max_retries + 1
    assert got == want                  # retry is invisible to callers
    assert degradation_log() == []      # ...and to the event log


def test_jit_persistent_failure_degrades_to_scalar(npu_pool, monkeypatch,
                                                   degradation_log):
    oracle = _score(npu_pool, use_jit=False)

    def dead(*args, **kwargs):
        raise RuntimeError("injected persistent jit failure")

    monkeypatch.setattr(pj, "evaluate_batch_table", dead)
    got = _score(npu_pool)
    assert [(r is None) for r in got] == [(r is None) for r in oracle]
    for g, w in zip(got, oracle):
        if w is not None:
            assert g.throughput_tps == pytest.approx(w.throughput_tps)
            assert g.avg_power_w == pytest.approx(w.avg_power_w)
    kinds = [e["kind"] for e in degradation_log()]
    assert "jit_fallback" in kinds


def test_nonfinite_jit_results_rescored_through_oracle(npu_pool,
                                                       monkeypatch,
                                                       degradation_log):
    real = pj.evaluate_batch_table

    def corrupting(*args, **kwargs):
        results = real(*args, **kwargs)
        idx = next(i for i, r in enumerate(results) if r is not None)
        results[idx] = dataclasses.replace(results[idx],
                                           throughput_tps=math.nan)
        return results

    monkeypatch.setattr(pj, "evaluate_batch_table", corrupting)
    got = _score(npu_pool)
    oracle = _score(npu_pool, use_jit=False)
    assert [(r is None) for r in got] == [(r is None) for r in oracle]
    assert all(math.isfinite(r.throughput_tps)
               for r in got if r is not None)
    kinds = [e["kind"] for e in degradation_log()]
    assert "nan_rescore" in kinds


def test_bug_class_exceptions_propagate_unretried(npu_pool, monkeypatch,
                                                  degradation_log):
    """AttributeError/TypeError are caller bugs, not evaluator trouble:
    they must escape the retry/degradation machinery immediately (the
    best_per_phase exception-narrowing contract)."""
    calls = {"n": 0}

    def buggy(*args, **kwargs):
        calls["n"] += 1
        raise AttributeError("malformed config")

    monkeypatch.setattr(pj, "evaluate_batch_table", buggy)
    with pytest.raises(AttributeError):
        _score(npu_pool)
    assert calls["n"] == 1              # no retries
    assert degradation_log() == []      # no silent degradation either


def test_degradation_hook_observes_events(npu_pool, monkeypatch,
                                          degradation_log):
    seen = []
    monkeypatch.setattr(perfmodel, "on_degradation", seen.append)
    monkeypatch.setattr(pj, "evaluate_batch_table",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("boom")))
    _score(npu_pool)
    assert any(e["kind"] == "jit_fallback" for e in seen)


# ---------------------------------------------------------------------------
# Benchmark baseline merge: atomic replace + loud write failures
# ---------------------------------------------------------------------------

def test_merge_bench_json_merges_atomically(tmp_path, monkeypatch):
    from benchmarks.common import merge_bench_json
    target = tmp_path / "BENCH_dse.json"
    target.write_text(json.dumps({"existing": {"v": 1}}))
    monkeypatch.setenv("BENCH_DSE_JSON", str(target))
    merge_bench_json("new_key", {"v": 2})
    data = json.loads(target.read_text())
    assert data == {"existing": {"v": 1}, "new_key": {"v": 2}}
    leftovers = [p for p in tmp_path.iterdir() if p.suffix == ".tmp"]
    assert leftovers == []              # no temp debris on success


def test_merge_bench_json_warns_instead_of_swallowing(tmp_path, monkeypatch,
                                                      capsys):
    from benchmarks import common
    target = tmp_path / "BENCH_dse.json"
    target.write_text(json.dumps({"existing": {"v": 1}}))
    monkeypatch.setenv("BENCH_DSE_JSON", str(target))

    def no_disk(*args, **kwargs):
        raise OSError("injected: disk full")

    monkeypatch.setattr(common.tempfile, "mkstemp", no_disk)
    merge = common.merge_bench_json
    merge("new_key", {"v": 2})          # must not raise
    err = capsys.readouterr().err
    assert "WARNING" in err and "UNCHANGED" in err
    # the committed baseline was left untouched, not truncated
    assert json.loads(target.read_text()) == {"existing": {"v": 1}}
