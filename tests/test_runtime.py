"""Runtime: optimizer, data, checkpoint round-trip, fault tolerance,
elastic rescale, gradient compression, end-to-end training descent."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.runtime import checkpoint as ckpt
from repro.runtime.compress import (compress_grads_with_feedback,
                                    init_error_state)
from repro.runtime.data import DataConfig, batch_for_step
from repro.runtime.elastic import plan_mesh
from repro.runtime.fault import (HeartbeatMonitor, RetryPolicy, StepFailure,
                                 StragglerDetector, TrainSupervisor)
from repro.runtime.optim import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.steps import make_train_step, model_fns


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_data_deterministic_and_step_indexed():
    dc = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=7)
    b1 = batch_for_step(dc, 5)
    b2 = batch_for_step(dc, 5)
    b3 = batch_for_step(dc, 6)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert np.array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_training_loss_decreases():
    """A few steps on the structured stream reduce loss (tiny dense)."""
    cfg = get_arch("llama3.2-1b").reduced(n_layers=2, d_model=64, vocab=128)
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3,
                                                    warmup_steps=5)))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, i).items()}
        loss, params, opt, _ = step(params, opt, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


needs_codecs = pytest.mark.skipif(
    not ckpt.codecs_available(),
    reason="optional checkpoint codecs (msgpack/zstandard) not installed")


def test_checkpoint_codecs_are_lazy(tmp_path):
    """`import repro.runtime` works without msgpack/zstandard; the clear
    ImportError surfaces only when checkpointing is actually used."""
    if ckpt.codecs_available():
        pytest.skip("optional codecs installed; error path unreachable")
    with pytest.raises(ImportError, match="msgpack"):
        ckpt.save(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(ImportError, match="zstandard|msgpack"):
        ckpt.restore(str(tmp_path), 0, {"x": jnp.zeros(2)})


@needs_codecs
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_arch("qwen3-4b").reduced()
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(3))
    state = {"params": params, "opt": init_opt_state(params)}
    path = ckpt.save(str(tmp_path), 7, state)
    assert os.path.isdir(path)
    assert ckpt.latest_step(str(tmp_path)) == 7
    template = jax.eval_shape(lambda: state)
    restored, step = ckpt.restore(str(tmp_path), 7, template)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@needs_codecs
def test_checkpoint_retention(tmp_path):
    tree = {"x": jnp.arange(4)}
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree, keep_last=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000003", "step_00000004"]


def test_retry_policy_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise StepFailure("transient")
        return "ok"

    rp = RetryPolicy(max_retries=3, backoff_s=0.0, sleep=lambda s: None)
    restored = []
    assert rp.run(flaky, on_retry=lambda a, e: restored.append(a)) == "ok"
    assert calls["n"] == 3 and len(restored) == 2


def test_retry_policy_gives_up():
    rp = RetryPolicy(max_retries=2, backoff_s=0.0, sleep=lambda s: None)
    with pytest.raises(StepFailure):
        rp.run(lambda: (_ for _ in ()).throw(StepFailure("hard")))


def test_straggler_detector():
    sd = StragglerDetector(window=16, threshold=2.0)
    for _ in range(8):
        assert not sd.observe(1.0)
    assert sd.observe(5.0)          # 5x median
    assert not sd.observe(1.1)


def test_heartbeat_quarantine():
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(timeout_s=10.0, clock=lambda: clock["t"])
    hb.beat("w0")
    hb.beat("w1")
    clock["t"] = 5.0
    hb.beat("w1")
    clock["t"] = 12.0
    assert hb.check() == ["w0"]
    assert hb.healthy() == ["w1"]


def test_heartbeat_register_catches_never_beating_worker():
    """A worker that hangs before its first beat must lapse like one
    that went silent later — register() seeds the tracking clock."""
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(timeout_s=10.0, clock=lambda: clock["t"])
    hb.register("w0")               # never beats
    hb.register("w1")
    clock["t"] = 5.0
    hb.beat("w1")
    # re-registration must not refresh an aging heartbeat
    clock["t"] = 9.0
    hb.register("w0")
    clock["t"] = 12.0
    assert hb.check() == ["w0"]
    assert hb.healthy() == ["w1"]
    # registering a quarantined worker does not resurrect it
    hb.register("w0")
    clock["t"] = 13.0
    assert hb.check() == []
    assert hb.healthy() == ["w1"]


def test_supervisor_checkpoints_and_retries():
    saved = []
    state = {"v": 0}

    def step_fn(x):
        if x == "fail-once" and state["v"] == 0:
            state["v"] = 1
            raise StepFailure("boom")
        return x

    sup = TrainSupervisor(
        retry=RetryPolicy(max_retries=2, backoff_s=0.0,
                          sleep=lambda s: None),
        straggler=StragglerDetector(),
        checkpoint_every=2,
        checkpoint_fn=lambda s: saved.append(s),
        restore_fn=lambda: None)
    assert sup.run_step(0, step_fn, "a") == "a"
    assert sup.run_step(1, step_fn, "fail-once") == "fail-once"
    assert saved == [1]


def test_plan_mesh_factorizations():
    assert plan_mesh(512, model_parallel=16) == (32, 16)
    assert plan_mesh(256) == (16, 16)
    assert plan_mesh(48) == (3, 16)
    assert plan_mesh(7) == (7, 1)
    with pytest.raises(ValueError):
        plan_mesh(100, model_parallel=16)


@needs_codecs
def test_elastic_rescale_roundtrip(tmp_path):
    """checkpoint -> restore under a (trivially) different mesh keeps
    values identical and training resumable."""
    from repro.runtime.elastic import make_mesh_for, rescale_from_checkpoint
    cfg = get_arch("internlm2-1.8b").reduced()
    mf = model_fns(cfg)
    params = mf.init(jax.random.key(5))
    ckpt.save(str(tmp_path), 3, params)
    mesh = make_mesh_for(1)
    template = jax.eval_shape(mf.init, jax.random.key(5))
    restored, step = rescale_from_checkpoint(str(tmp_path), 3, template,
                                             mesh)
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_gradient_compression_error_feedback():
    grads = {"w": jnp.array([0.3, -0.7, 0.001])}
    err = init_error_state(grads)
    total = jnp.zeros(3)
    exact = jnp.zeros(3)
    for _ in range(50):
        deq, err = compress_grads_with_feedback(grads, err)
        total = total + deq["w"]
        exact = exact + grads["w"]
    # error feedback keeps the long-run average unbiased
    assert float(jnp.max(jnp.abs(total - exact))) / 50 < 5e-3
