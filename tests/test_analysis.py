"""Tests for `repro.analysis` — the AST invariant linter.

Three layers, mirroring the guarantees the linter itself makes:

* **fixture-based rule tests** — for every shipped rule, at least one
  positive snippet (the rule fires, and *only* that rule) and one
  negative snippet (the sanctioned alternative stays clean: seeded
  Generators, perf_counter, sorted(set), scoped enable_x64, temp-file
  + os.replace, re-raising/fault-tagged handlers);
* **suppression + baseline** — `# repro-lint: disable=...` comments
  (same line, line above, wrong rule, `all`) and the write/load/split
  baseline round trip, including the line-drift-tolerant keying;
* **meta-tests** — the repo itself lints clean against the committed
  baseline, and the CLI (the exact entry point `scripts/ci.sh` runs)
  exits 1 when a determinism or jit-purity violation is deliberately
  introduced and 0 once it is baselined.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (Baseline, lint_paths, load_rules, RULES)

pytestmark = pytest.mark.lint

REPO_ROOT = Path(__file__).resolve().parents[1]

load_rules()

ALL_RULES = ("unseeded-rng", "wall-clock", "set-iteration",
             "json-sort-keys", "jit-impurity", "global-x64",
             "nonatomic-artifact-write", "broad-except")


def run_lint(tmp_path: Path, source: str,
             rel: str = "src/repro/core/mod.py",
             baseline: Baseline = None):
    """Write one module under a scratch lint root and lint it."""
    target = tmp_path / rel
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(source)
    return lint_paths([rel], root=str(tmp_path), baseline=baseline)


def fired(result) -> set:
    return {f.rule for f in result.findings}


# --------------------------------------------------------------------------
# rule registry
# --------------------------------------------------------------------------

def test_registry_ships_all_rules():
    assert set(ALL_RULES) <= set(RULES)
    for rule in RULES.values():
        assert rule.summary and rule.invariant


# --------------------------------------------------------------------------
# fixture-based rule tests: one positive + one negative per rule
# --------------------------------------------------------------------------

POSITIVE = [
    ("unseeded-rng",
     "import numpy as np\nx = np.random.randint(0, 5)\n"),
    ("unseeded-rng",
     "import random\nrandom.seed(1234)\nv = random.choice([1, 2])\n"),
    ("unseeded-rng",
     # alias-resolved spelling: from numpy import random
     "from numpy import random\nx = random.shuffle([1, 2])\n"),
    ("wall-clock",
     "import time\nt = time.time()\n"),
    ("wall-clock",
     "from datetime import datetime\nstamp = datetime.now()\n"),
    ("set-iteration",
     "total = 0\nfor x in set([3, 1, 2]):\n    total += x\n"),
    ("set-iteration",
     "ys = [y for y in {1, 2, 3}]\n"),
    ("set-iteration",
     "names = list({'b', 'a'})\n"),
    ("json-sort-keys",
     "import json\ns = json.dumps({'b': 1, 'a': 2})\n"),
    ("json-sort-keys",
     "import json\n\ndef w(f, d):\n    json.dump(d, f, indent=1)\n"),
    ("jit-impurity",
     "import jax\n\n@jax.jit\ndef f(x):\n    print(x)\n    return x\n"),
    ("jit-impurity",
     "import jax\n\ndef g(x):\n    return x.item()\n\ng2 = jax.jit(g)\n"),
    ("jit-impurity",
     "import jax\n\n@jax.jit\ndef f(x):\n    return float(x) * 2.0\n"),
    ("jit-impurity",
     # mutation of a closure accumulator leaks trace-time state
     "import jax\nacc = []\n\n@jax.jit\ndef f(x):\n    acc.append(x)\n"
     "    return x\n"),
    ("jit-impurity",
     # reachable through a vmapped lambda -> same-module helper
     "import jax\n\ndef helper(x):\n    print(x)\n    return x\n\n"
     "def run(xs):\n    return jax.vmap(lambda x: helper(x))(xs)\n"),
    ("global-x64",
     "import jax\njax.config.update('jax_enable_x64', True)\n"),
    ("nonatomic-artifact-write",
     "import json\n\ndef w(path, data):\n    with open(path, 'w') as f:\n"
     "        json.dump(data, f, sort_keys=True)\n"),
    ("nonatomic-artifact-write",
     # direct open() argument, at module level (script-style)
     "import json\njson.dump({}, open('BENCH_x.json', 'w'), "
     "sort_keys=True)\n"),
    ("broad-except",
     "def f():\n    try:\n        return 1\n    except:\n"
     "        return None\n"),
    ("broad-except",
     "def f():\n    try:\n        return 1\n    except Exception:\n"
     "        return None\n"),
]

NEGATIVE = [
    ("unseeded-rng",
     "import numpy as np\nrng = np.random.default_rng(\n"
     "    np.random.SeedSequence([1, 2]))\nx = rng.integers(0, 5)\n"),
    ("unseeded-rng",
     "import random\nr = random.Random(0)\nv = r.choice([1, 2])\n"),
    ("unseeded-rng",
     # a local object that happens to be called `random` is not the
     # stdlib module
     "def f(random):\n    return random.choice([1])\n"),
    ("wall-clock",
     "import time\nt0 = time.perf_counter()\ndt = time.perf_counter() "
     "- t0\n"),
    ("set-iteration",
     "for x in sorted(set([3, 1, 2])):\n    pass\n"),
    ("json-sort-keys",
     "import json\ns = json.dumps({'b': 1}, sort_keys=True)\n"),
    ("jit-impurity",
     "import jax\nimport jax.numpy as jnp\n\n@jax.jit\ndef f(x):\n"
     "    return jnp.sum(x) * 2\n"),
    ("jit-impurity",
     # static_argnames args are concrete by contract
     "import functools\nimport jax\n\n"
     "@functools.partial(jax.jit, static_argnames=('n',))\n"
     "def f(x, n):\n    return x * float(n)\n"),
    ("jit-impurity",
     # print in a plain (untraced) function is fine
     "def report(x):\n    print(x)\n    return x\n"),
    ("jit-impurity",
     # local accumulator unrolls at trace time — not a leak
     "import jax\n\n@jax.jit\ndef f(x):\n    parts = []\n"
     "    for i in range(4):\n        parts.append(x * i)\n"
     "    return parts\n"),
    ("global-x64",
     "import jax\njax.config.update('jax_platform_name', 'cpu')\n"),
    ("nonatomic-artifact-write",
     "import json\nimport os\nimport tempfile\n\n"
     "def w(path, data):\n    fd, tmp = tempfile.mkstemp()\n"
     "    with os.fdopen(fd, 'w') as f:\n        json.dump(data, f)\n"
     "    os.replace(tmp, path)\n"),
    ("nonatomic-artifact-write",
     # append-only JSONL (journal-style) is the sanctioned log pattern
     "import json\n\ndef log(path, rec):\n    with open(path, 'a') as f:\n"
     "        f.write(json.dumps(rec, sort_keys=True) + '\\n')\n"),
    ("broad-except",
     # re-raising broad handler is the documented degradation shape
     "def f():\n    try:\n        return 1\n    except Exception:\n"
     "        raise\n"),
    ("broad-except",
     # ... as is converting the failure into a structured event
     "def _emit_degradation(**kw):\n    pass\n\ndef f():\n    try:\n"
     "        return 1\n    except Exception as exc:\n"
     "        _emit_degradation(kind='x', reason=repr(exc))\n"
     "        return None\n"),
]


@pytest.mark.parametrize("rule,source", POSITIVE,
                         ids=[f"{r}-{i}" for i, (r, _) in enumerate(POSITIVE)])
def test_positive_fixture_fires(tmp_path, rule, source):
    result = run_lint(tmp_path, source)
    assert fired(result) == {rule}, (
        f"expected exactly {{{rule}}}, got {fired(result)}:\n"
        + "\n".join(f.format() for f in result.findings))


@pytest.mark.parametrize("rule,source", NEGATIVE,
                         ids=[f"{r}-{i}" for i, (r, _) in enumerate(NEGATIVE)])
def test_negative_fixture_clean(tmp_path, rule, source):
    result = run_lint(tmp_path, source)
    assert rule not in fired(result), "\n".join(
        f.format() for f in result.findings)


def test_broad_except_scoped_to_core(tmp_path):
    """`except Exception` is only policed inside repro.core; the bare
    `except:` check applies everywhere."""
    src = ("def f():\n    try:\n        return 1\n"
           "    except Exception:\n        return None\n")
    assert "broad-except" in fired(
        run_lint(tmp_path, src, rel="src/repro/core/dse/x.py"))
    assert "broad-except" not in fired(
        run_lint(tmp_path, src, rel="src/repro/launch/x.py"))
    bare = "try:\n    pass\nexcept:\n    pass\n"
    assert "broad-except" in fired(
        run_lint(tmp_path, bare, rel="src/repro/launch/x.py"))


def test_global_x64_exempts_sanctioned_helpers(tmp_path):
    src = "import jax\njax.config.update('jax_enable_x64', True)\n"
    assert "global-x64" in fired(
        run_lint(tmp_path, src, rel="src/repro/core/npu.py"))
    assert "global-x64" not in fired(
        run_lint(tmp_path, src, rel="src/repro/core/dse/gp.py"))
    assert "global-x64" not in fired(
        run_lint(tmp_path, src, rel="src/repro/core/perfmodel_jit.py"))


def test_parse_error_is_a_finding(tmp_path):
    result = run_lint(tmp_path, "def broken(:\n")
    assert result.errors and result.errors[0].rule == "parse-error"
    assert not result.ok


# --------------------------------------------------------------------------
# suppressions
# --------------------------------------------------------------------------

def test_suppression_same_line(tmp_path):
    src = ("import time\n"
           "t = time.time()  # repro-lint: disable=wall-clock\n")
    result = run_lint(tmp_path, src)
    assert not result.findings
    assert [f.rule for f in result.suppressed] == ["wall-clock"]


def test_suppression_line_above(tmp_path):
    src = ("import time\n"
           "# repro-lint: disable=wall-clock\n"
           "t = time.time()\n")
    result = run_lint(tmp_path, src)
    assert not result.findings
    assert [f.rule for f in result.suppressed] == ["wall-clock"]


def test_suppression_wrong_rule_does_not_silence(tmp_path):
    src = ("import time\n"
           "t = time.time()  # repro-lint: disable=unseeded-rng\n")
    result = run_lint(tmp_path, src)
    assert fired(result) == {"wall-clock"}


def test_suppression_all(tmp_path):
    src = ("import time\nimport json\n"
           "# repro-lint: disable=all\n"
           "s = json.dumps({'t': time.time()})\n")
    result = run_lint(tmp_path, src)
    assert not result.findings
    assert {f.rule for f in result.suppressed} == {"wall-clock",
                                                   "json-sort-keys"}


def test_suppression_multiple_rules_one_comment(tmp_path):
    src = ("import time\nimport json\n"
           "s = json.dumps({'t': time.time()})"
           "  # repro-lint: disable=wall-clock, json-sort-keys\n")
    result = run_lint(tmp_path, src)
    assert not result.findings
    assert len(result.suppressed) == 2


# --------------------------------------------------------------------------
# baseline round trip
# --------------------------------------------------------------------------

VIOLATION = "import time\n\n\ndef stamp():\n    return time.time()\n"


def test_baseline_round_trip(tmp_path):
    first = run_lint(tmp_path, VIOLATION)
    assert fired(first) == {"wall-clock"}

    bl_path = tmp_path / ".repro-lint-baseline.json"
    Baseline.from_findings(first.findings).write(str(bl_path))
    doc = json.loads(bl_path.read_text())
    assert doc["version"] == 1 and len(doc["findings"]) == 1

    again = run_lint(tmp_path, VIOLATION,
                     baseline=Baseline.load(str(bl_path)))
    assert not again.findings and len(again.baselined) == 1
    assert again.ok


def test_baseline_survives_line_drift_not_edits(tmp_path):
    first = run_lint(tmp_path, VIOLATION)
    baseline = Baseline.from_findings(first.findings)

    drifted = "import time\n# a new comment shifting lines\n" + \
        VIOLATION.split("\n", 1)[1]
    moved = run_lint(tmp_path, drifted, baseline=baseline)
    assert not moved.findings, "pure line movement must stay baselined"

    edited = VIOLATION.replace("return time.time()",
                               "return 1.0 + time.time()")
    changed = run_lint(tmp_path, edited, baseline=baseline)
    assert fired(changed) == {"wall-clock"}, \
        "editing the offending line must resurface the finding"


def test_baseline_counts_cap_duplicates(tmp_path):
    two = ("import time\n\n\ndef stamp():\n    return time.time()\n\n\n"
           "def stamp2():\n    return time.time()\n")
    # both findings share the key (same stripped text): baseline one
    # occurrence only -> the second stays actionable
    one = run_lint(tmp_path, VIOLATION)
    baseline = Baseline.from_findings(one.findings)
    result = run_lint(tmp_path, two, baseline=baseline)
    assert len(result.baselined) == 1
    assert len(result.findings) == 1


def test_missing_baseline_file_is_empty():
    assert Baseline.load("/nonexistent/baseline.json").counts == {}


# --------------------------------------------------------------------------
# meta: the repo itself + the CLI entry point ci.sh runs
# --------------------------------------------------------------------------

def test_repo_lints_clean_against_committed_baseline():
    baseline = Baseline.load(str(REPO_ROOT / ".repro-lint-baseline.json"))
    result = lint_paths(["src", "scripts", "benchmarks"],
                        root=str(REPO_ROOT), baseline=baseline)
    assert result.ok, "\n".join(
        f.format() for f in result.errors + result.findings)


def _cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_fails_on_deliberate_violations(tmp_path):
    """The property the ci.sh lint stage relies on: introducing a
    seeded-determinism or jit-purity violation makes the lint exit
    nonzero, at the offending line."""
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(
        "import numpy as np\nimport jax\n\n\n"
        "def init_pop(n):\n"
        "    return np.random.randint(0, 7, size=n)\n\n\n"
        "@jax.jit\n"
        "def score(x):\n"
        "    print(x)\n"
        "    return x\n")
    proc = _cli(["src"], cwd=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "unseeded-rng" in proc.stdout
    assert "jit-impurity" in proc.stdout
    assert "bad.py:6" in proc.stdout

    # per-rule counts are printed so regressions are attributable
    assert "unseeded-rng" in proc.stdout.splitlines()[-8:][0] or \
        any("unseeded-rng" in ln for ln in proc.stdout.splitlines()[-10:])


def test_cli_write_baseline_then_clean(tmp_path):
    pkg = tmp_path / "src"
    pkg.mkdir()
    (pkg / "legacy.py").write_text("import time\nT0 = time.time()\n")
    assert _cli(["src"], cwd=tmp_path).returncode == 1

    wrote = _cli(["src", "--write-baseline"], cwd=tmp_path)
    assert wrote.returncode == 0, wrote.stdout + wrote.stderr
    assert (tmp_path / ".repro-lint-baseline.json").exists()

    clean = _cli(["src"], cwd=tmp_path)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    assert "1 baselined" in clean.stdout

    # --no-baseline reports everything again
    assert _cli(["src", "--no-baseline"], cwd=tmp_path).returncode == 1


def test_cli_list_rules():
    proc = _cli(["--list-rules"], cwd=REPO_ROOT)
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule in proc.stdout


def test_cli_missing_path_is_usage_error(tmp_path):
    proc = _cli(["no_such_dir"], cwd=tmp_path)
    assert proc.returncode == 2


def test_docs_catalogue_every_rule():
    doc = (REPO_ROOT / "docs" / "static_analysis.md").read_text()
    for rule in ALL_RULES:
        assert f"`{rule}`" in doc, f"docs/static_analysis.md missing {rule}"
