"""Paired prefill/decode DSE: PairedSpace constraint enforcement,
batched vs scalar disaggregated evaluation, seeded determinism of the
four searchers on the paired space, and the pinned-trajectory
regression guarding the DesignSpace refactor."""

import hashlib
import json

import numpy as np
import pytest

from repro.configs.paper_models import LLAMA33_70B, QWEN3_32B
from repro.core import baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu
from repro.core.disagg import (best_per_phase, evaluate_disagg_batch,
                               evaluate_disaggregated)
from repro.core.dse import (DisaggObjective, Objective, PairedSpace,
                            SingleDeviceSpace, run_mobo, run_motpe,
                            run_nsga2, run_random, shared_init)
from repro.core.dse import space as sp
from repro.core.perfmodel import InfeasibleConfig
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase


# ---------------------------------------------------------------------------
# PairedSpace encoding + KV-quant compatibility constraint
# ---------------------------------------------------------------------------

def test_paired_space_shape():
    ps = PairedSpace()
    assert ps.n_dims == 2 * sp.N_DIMS
    assert ps.cardinalities == list(sp.CARDINALITIES) * 2


def test_paired_sampling_satisfies_kv_constraint():
    ps = PairedSpace()
    rng = np.random.default_rng(0)
    xs = ps.random_designs(rng, 200)
    assert np.all(xs[:, sp.KV_GENE] == xs[:, sp.N_DIMS + sp.KV_GENE])
    # rejection sampling: every vectorized draw is decodable
    assert np.all(ps.valid_mask(xs))
    for _ in range(20):
        x = ps.random_design(rng)
        assert x[sp.KV_GENE] == x[sp.N_DIMS + sp.KV_GENE]
    # Sobol mapping is repaired too
    u = np.linspace(0.01, 0.99, ps.n_dims)
    x = ps.from_unit(u)
    assert x[sp.KV_GENE] == x[sp.N_DIMS + sp.KV_GENE]


def test_paired_sobol_dims_distinct():
    """34-dim Sobol init: no decode-half dimension may be a duplicate of
    a prefill-half one (direction-number recycling would couple them)."""
    from repro.core.dse import sobol
    u = sobol(128, 2 * sp.N_DIMS, skip=0)
    for i in range(u.shape[1]):
        for j in range(i + 1, u.shape[1]):
            assert not np.array_equal(u[:, i], u[:, j]), (i, j)


def test_paired_repair_batch_does_not_mutate_input():
    ps = PairedSpace()
    rng = np.random.default_rng(7)
    raw = rng.integers(0, np.asarray(ps.cardinalities), size=(8, ps.n_dims))
    raw[:, sp.N_DIMS + sp.KV_GENE] = (raw[:, sp.KV_GENE] + 1) \
        % len(sp.KV_FMTS)
    before = raw.copy()
    fixed = ps.repair_batch(raw)
    assert np.array_equal(raw, before)          # caller's batch untouched
    assert np.all(fixed[:, sp.N_DIMS + sp.KV_GENE] == fixed[:, sp.KV_GENE])


def test_paired_decode_rejects_kv_mismatch():
    ps = PairedSpace()
    rng = np.random.default_rng(1)
    x = ps.random_design(rng)
    bad = list(x)
    bad[sp.N_DIMS + sp.KV_GENE] = (bad[sp.KV_GENE] + 1) % len(sp.KV_FMTS)
    with pytest.raises(sp.InvalidDesign, match="KV-cache quant mismatch"):
        ps.decode(bad)
    vm = ps.valid_mask(np.asarray([list(x), bad], dtype=np.int64))
    assert bool(vm[0]) and not bool(vm[1])
    # repair projects the mismatch away
    fixed = ps.repair(bad)
    assert fixed[sp.N_DIMS + sp.KV_GENE] == fixed[sp.KV_GENE]


def test_paired_decode_and_tables_match_halves():
    ps = PairedSpace()
    rng = np.random.default_rng(2)
    xs = ps.random_designs(rng, 64)
    tdp = ps.tdp_w_batch(xs)
    for i, x in enumerate(xs[:16]):
        pre, dec = ps.decode(x)
        assert pre.name == sp.decode(x[:sp.N_DIMS]).name
        assert dec.name == sp.decode(x[sp.N_DIMS:]).name
        assert pre.quant.kv_cache == dec.quant.kv_cache
        assert tdp[i] == pytest.approx(pre.tdp_w() + dec.tdp_w(), rel=1e-9)


def test_single_device_space_wraps_module():
    ss = SingleDeviceSpace()
    rng = np.random.default_rng(3)
    xs = ss.random_designs(rng, 100)
    assert np.array_equal(ss.valid_mask(xs), sp.valid_mask(xs))
    assert np.allclose(ss.tdp_w_batch(xs), sp.tdp_w_batch(xs))
    assert np.allclose(ss.normalize_batch(xs), sp.normalize_batch(xs))
    x = ss.random_design(rng)
    assert ss.repair(x) == list(x)          # unconstrained: identity
    assert ss.decode(x if sp.valid_mask(np.asarray([x]))[0] else
                     [0, 0, 1, 0, 0, 1, 0, 0, 0, 0, 0,
                      0, 0, 0, 0, 0, 0]).hierarchy.total_capacity_gb() > 0


# ---------------------------------------------------------------------------
# evaluate_disagg_batch vs scalar evaluate_disaggregated
# ---------------------------------------------------------------------------

def test_disagg_batch_matches_scalar():
    pairs = [(p1_npu(), d1_npu()), (p2_npu(), d2_npu()),
             (baseline_npu(), baseline_npu()), (p1_npu(), d2_npu())]
    got = evaluate_disagg_batch(pairs, LLAMA33_70B, OSWORLD_LIBREOFFICE)
    for (p, d), r in zip(pairs, got):
        want = evaluate_disaggregated(p, d, LLAMA33_70B, OSWORLD_LIBREOFFICE)
        assert r.ttft_s == pytest.approx(want.ttft_s, rel=1e-12)
        assert r.tokens_per_joule == pytest.approx(want.tokens_per_joule,
                                                   rel=1e-12)
        assert r.total_power_w == pytest.approx(want.total_power_w,
                                                rel=1e-12)
        assert r.kv_transfer_s == pytest.approx(want.kv_transfer_s,
                                                rel=1e-12)


def test_disagg_batch_dse_designs_and_caches():
    ps = PairedSpace()
    rng = np.random.default_rng(4)
    xs = ps.random_designs(rng, 24)
    pairs = [ps.decode(x) for x in xs]
    pre_cache, dec_cache = {}, {}
    got = evaluate_disagg_batch(pairs, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                pre_cache=pre_cache, dec_cache=dec_cache)
    assert len(got) == len(pairs)
    n_feasible = 0
    for (p, d), r in zip(pairs, got):
        try:
            want = evaluate_disaggregated(p, d, QWEN3_32B,
                                          OSWORLD_LIBREOFFICE)
        except (InfeasibleConfig, ValueError):
            assert r is None
            continue
        n_feasible += 1
        assert r.tokens_per_joule == pytest.approx(want.tokens_per_joule,
                                                   rel=1e-12)
    assert n_feasible > 0
    # caches hold one entry per unique half and make reruns pure lookups
    assert set(pre_cache) == {p.name for p, _ in pairs}
    assert set(dec_cache) == {d.name for _, d in pairs}
    again = evaluate_disagg_batch(pairs, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                  pre_cache=pre_cache, dec_cache=dec_cache)
    for a, b in zip(got, again):
        assert (a is None) == (b is None)
        if a is not None:
            assert a.tokens_per_joule == b.tokens_per_joule


# ---------------------------------------------------------------------------
# DisaggObjective
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paired_objective():
    return DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                           tdp_limit_w=1400.0, ttft_cap_s=90.0)


def test_disagg_objective_batch_matches_scalar(paired_objective):
    ps = paired_objective.space
    rng = np.random.default_rng(5)
    xs = [tuple(ps.random_design(rng)) for _ in range(12)]
    xs += xs[:2]                    # duplicates exercise the cache path
    scalar = DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                             tdp_limit_w=1400.0, ttft_cap_s=90.0)
    batch = DisaggObjective(QWEN3_32B, OSWORLD_LIBREOFFICE,
                            tdp_limit_w=1400.0, ttft_cap_s=90.0)
    want = [scalar(x) for x in xs]
    got = batch.evaluate_batch(xs)
    for a, b in zip(got, want):
        assert tuple(a.x) == tuple(b.x)
        if b.f is None:
            assert a.f is None
        else:
            assert a.f == pytest.approx(b.f, rel=1e-12)


def test_disagg_objective_respects_caps(paired_objective):
    for o in shared_init(paired_objective, 12, seed=3):
        if o.f is not None:
            pre, dec = o.npu
            assert pre.tdp_w() + dec.tdp_w() <= 1400.0 + 1e-6
            assert o.result.ttft_s <= 90.0 + 1e-9
            assert o.f == (o.result.tokens_per_joule,
                           -o.result.total_power_w)


# ---------------------------------------------------------------------------
# Searchers on the paired space: budget + seeded determinism
# ---------------------------------------------------------------------------

def test_paired_searchers_run_and_deterministic(paired_objective):
    init = shared_init(paired_objective, 8, seed=1)
    assert [len(o.x) for o in init] == [34] * 8
    for runner in (run_mobo, run_random, run_nsga2, run_motpe):
        r1 = runner(paired_objective, n_total=16, seed=1, init=list(init))
        r2 = runner(paired_objective, n_total=16, seed=1, init=list(init))
        assert len(r1.observations) == 16, runner.__name__
        assert [o.x for o in r1.observations[:8]] == [o.x for o in init]
        assert [o.x for o in r1.observations] == \
            [o.x for o in r2.observations], runner.__name__
        # every proposal honors the cross-half constraint
        for o in r1.observations:
            assert o.x[sp.KV_GENE] == o.x[sp.N_DIMS + sp.KV_GENE], \
                runner.__name__


# ---------------------------------------------------------------------------
# Refactor regression: single-device trajectories are byte-identical
# ---------------------------------------------------------------------------

# SHA-256 of the json-encoded evaluation order produced by the
# pre-refactor runner (commit d446467) for each searcher at
# (QWEN3_32B, OSWorld, DECODE, tdp=700, init=shared_init(6, seed=2),
# n_total=14).  The DesignSpace refactor must not perturb these.
# NOTE: run_mobo's order goes through GP/EHVI float argmaxes, so the
# digests are pinned to this container's numpy/JAX builds; if they ever
# mismatch after an environment bump (with the pure-RNG random/nsga2/
# motpe digests still passing), recapture the references on the old
# code rather than suspecting the runner.
_PRE_REFACTOR_SHA = {
    "run_mobo": "b6657bac37c6a6976704bf68140f913a27b713134bb6f5d3cd65592d07dde7da",
    "run_random": "847f243688e37ebbeaaed174559d17523bb119f6866ecac781130c535efb7354",
    "run_nsga2": "bc7e293e23db74b71d5040f1c9374299e5f9d6a01e84ca2056139330aee7e4a5",
    "run_motpe": "7964070f028ceecceb380ca1c95f5d502fbd13f21318f6f18e87d91f6389f0e7",
}


def test_single_device_trajectories_unchanged():
    obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                    tdp_limit_w=700.0)
    init = shared_init(obj, 6, seed=2)
    for runner in (run_mobo, run_random, run_nsga2, run_motpe):
        res = runner(obj, n_total=14, seed=2, init=list(init))
        xs = [tuple(int(v) for v in o.x) for o in res.observations]
        sha = hashlib.sha256(json.dumps(xs).encode()).hexdigest()
        assert sha == _PRE_REFACTOR_SHA[runner.__name__], runner.__name__


# ---------------------------------------------------------------------------
# best_per_phase exception narrowing
# ---------------------------------------------------------------------------

def test_best_per_phase_skips_infeasible_keeps_bugs():
    # infeasible devices are skipped, the feasible one wins
    npus = [baseline_npu(), p1_npu()]
    best, r = best_per_phase(npus, LLAMA33_70B, OSWORLD_LIBREOFFICE,
                             Phase.PREFILL)
    assert r.tokens_per_joule > 0

    class Broken:
        """Not an NPUConfig: evaluation dies with AttributeError."""
        name = "broken"

    with pytest.raises(AttributeError):
        best_per_phase([Broken()], LLAMA33_70B, OSWORLD_LIBREOFFICE,
                       Phase.PREFILL)


# ---------------------------------------------------------------------------
# Perf-regression gate plumbing (benchmarks/run.py --check)
# ---------------------------------------------------------------------------

@pytest.mark.bench
def test_bench_check_compare_timings():
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:        # `benchmarks` lives at the repo root
        sys.path.insert(0, root)
    from benchmarks.run import compare_timings
    base = {"methods": {"GP+EHVI": {"us_per_run": 100.0},
                        "Random": {"us_per_run": 10.0}}}
    fresh = {"methods": {"GP+EHVI": {"us_per_run": 450.0},
                         "Random": {"us_per_run": 51.0}}}
    got = {m: ok for m, _, _, ok in compare_timings(base, fresh, 5.0)}
    assert got == {"GP+EHVI": True, "Random": False}
    # missing method counts as a regression
    verdicts = compare_timings(base, {"methods": {}}, 5.0)
    assert all(not ok for _, _, _, ok in verdicts)


@pytest.mark.bench
def test_bench_check_compare_jit_pool():
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import compare_jit_pool
    base = {"jit_pool": {"speedup": 50.0}}
    # healthy: above both the hard 10x floor and baseline/tolerance
    ok = compare_jit_pool(base, {"jit_pool": {"speedup": 45.0,
                                              "parity_mismatches": 0}}, 5.0)
    assert ok == (45.0, 10.0, 0, True)
    # below the hard floor -> regression even within tolerance of base
    bad = compare_jit_pool(base, {"jit_pool": {"speedup": 8.0}}, 5.0)
    assert not bad[-1]
    # a large baseline raises the floor above 10x
    big = {"jit_pool": {"speedup": 200.0}}
    mid = compare_jit_pool(big, {"jit_pool": {"speedup": 30.0}}, 5.0)
    assert mid[1] == pytest.approx(40.0) and not mid[-1]
    # parity mismatches fail loudly regardless of speed
    par = compare_jit_pool(base, {"jit_pool": {"speedup": 60.0,
                                               "parity_mismatches": 2}}, 5.0)
    assert not par[-1]
    # pre-jit baselines skip the gate; missing fresh entry regresses
    assert compare_jit_pool({"methods": {}}, {}, 5.0) is None
    missing = compare_jit_pool(base, {}, 5.0)
    assert missing[1] < 0 and not missing[-1]


def test_bench_check_rejects_empty_baseline(tmp_path):
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import check_perf
    empty = tmp_path / "empty.json"
    empty.write_text("{}")
    assert check_perf(str(empty), 5.0) == 2     # no vacuous pass
    assert check_perf(str(tmp_path / "missing.json"), 5.0) == 2
