"""Analytical performance model: compute, dataflow, workload, phases —
including the paper's qualitative claims (Tables 4-6 directions)."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ComputeConfig, Dataflow, QuantConfig,
                        baseline_npu, d1_npu, d2_npu, p1_npu, p2_npu)
from repro.core.compute import (dataflow_traffic_multipliers, gemm_cycles,
                                vector_seconds)
from repro.core.dataflow import (BandwidthPriority, SoftwareStrategy,
                                 StoragePriority)
from repro.core.perfmodel import (evaluate_decode, evaluate_prefill,
                                  max_decode_batch, max_prefill_batch)
from repro.core.workload import (OSWORLD_LIBREOFFICE, Family, ModelDims,
                                 Phase, layer_traffic, weight_footprint_gb)
from repro.configs.paper_models import LLAMA33_70B


def test_gemm_cycles_ideal_utilization():
    cfg = ComputeConfig(pe_rows=128, pe_cols=128)
    t = gemm_cycles(cfg, 4096, 4096, 4096, Dataflow.WEIGHT_STATIONARY)
    assert t.utilization > 0.9
    assert t.macs == 4096.0 ** 3


def test_gemm_packing_small_k():
    """Batched small-k GEMMs pack along array rows."""
    cfg = ComputeConfig(pe_rows=2048, pe_cols=128)
    single = gemm_cycles(cfg, 1024, 128, 1024,
                         Dataflow.WEIGHT_STATIONARY, count=1)
    batched = gemm_cycles(cfg, 1024, 128, 1024,
                          Dataflow.WEIGHT_STATIONARY, count=16)
    assert batched.cycles == pytest.approx(single.cycles, rel=0.01)
    assert batched.utilization > 10 * single.utilization


def test_dataflow_multipliers():
    cfg = ComputeConfig(pe_rows=128, pe_cols=128)
    # WS with generous staging: no re-streams
    a, b = dataflow_traffic_multipliers(cfg, 1024, 1024, 1024,
                                        Dataflow.WEIGHT_STATIONARY,
                                        1, 1, 1, 0.0, 1024 * 1024, 1e9)
    assert (a, b) == (1.0, 1.0)
    # WS with no staging: act re-streamed per array-tile chunk
    a, b = dataflow_traffic_multipliers(cfg, 1024, 1024, 1024,
                                        Dataflow.WEIGHT_STATIONARY,
                                        1, 1, 1, 0.0, 0.0, 0.0)
    assert b == 1.0 and a > 1.0
    # IS mirrors on the weight side
    a, b = dataflow_traffic_multipliers(cfg, 4096, 1024, 1024,
                                        Dataflow.INPUT_STATIONARY,
                                        1, 1, 1, 0.0, 0.0, 0.0)
    assert a == 1.0 and b > 1.0


def test_llama70b_params():
    assert LLAMA33_70B.total_params() / 1e9 == pytest.approx(70.6, abs=1.0)
    w = weight_footprint_gb(LLAMA33_70B, QuantConfig())
    assert w == pytest.approx(72.8, abs=1.5)


def test_paper_batch_columns():
    """Table 6 'Batch' columns reproduce from the capacity model."""
    trace = OSWORLD_LIBREOFFICE
    assert max_prefill_batch(baseline_npu(), LLAMA33_70B, trace) == 1
    assert max_prefill_batch(p1_npu(), LLAMA33_70B, trace) == 16
    assert max_decode_batch(baseline_npu(), LLAMA33_70B, trace) == 1
    assert max_decode_batch(d1_npu(), LLAMA33_70B, trace) == 16
    assert max_decode_batch(d2_npu(), LLAMA33_70B, trace) == 32


def test_prefill_decode_orderings():
    """Qualitative Table 6: optimized devices beat Base in their phase."""
    trace = OSWORLD_LIBREOFFICE
    base_p = evaluate_prefill(baseline_npu(), LLAMA33_70B, trace)
    p1 = evaluate_prefill(p1_npu(), LLAMA33_70B, trace)
    p2 = evaluate_prefill(p2_npu(), LLAMA33_70B, trace)
    assert p1.throughput_tps > base_p.throughput_tps
    assert p2.throughput_tps > base_p.throughput_tps
    assert p1.throughput_tps > p2.throughput_tps     # paper: P1 6.71 > P2 4.93

    base_d = evaluate_decode(baseline_npu(), LLAMA33_70B, trace)
    d1 = evaluate_decode(d1_npu(), LLAMA33_70B, trace)
    d2 = evaluate_decode(d2_npu(), LLAMA33_70B, trace)
    assert d1.throughput_tps > base_d.throughput_tps
    assert d2.throughput_tps > d1.throughput_tps     # paper: D2 2.19 > D1 1.44
    # D1 per-step latency lands near the paper's implied 469 ms (1.44x
    # of their 675 ms Base step); our Base is less pessimistic about
    # OS-dataflow GEMV so only the absolute D1 number is asserted
    assert 0.2 < d1.latency_s < 0.8


def test_decode_is_memory_bound_on_optimized_devices():
    d1 = evaluate_decode(d1_npu(), LLAMA33_70B, OSWORLD_LIBREOFFICE)
    assert d1.bottleneck == "matrix_mem"


def test_ws_act_beats_is_for_prefill():
    """Table 4 direction: WS + Act storage >> IS + Weight storage."""
    import dataclasses
    trace = OSWORLD_LIBREOFFICE
    base = p1_npu()
    s3 = dataclasses.replace(base, strategy=SoftwareStrategy(
        Dataflow.WEIGHT_STATIONARY, StoragePriority.ACTIVATION,
        BandwidthPriority.MATRIX))
    s4 = dataclasses.replace(base, strategy=SoftwareStrategy(
        Dataflow.INPUT_STATIONARY, StoragePriority.WEIGHT,
        BandwidthPriority.VECTOR))
    r3 = evaluate_prefill(s3, LLAMA33_70B, trace, batch=1)
    r4 = evaluate_prefill(s4, LLAMA33_70B, trace, batch=1)
    assert r3.tokens_per_joule > r4.tokens_per_joule


def test_quantization_scales_throughput_and_storage():
    """Table 3 direction: 8/8/8 halves storage vs 16/16/16 and speeds up."""
    q16 = QuantConfig("MXINT16", "MXINT16", "MXINT16")
    q8 = QuantConfig()
    w16 = weight_footprint_gb(LLAMA33_70B, q16)
    w8 = weight_footprint_gb(LLAMA33_70B, q8)
    assert w8 == pytest.approx(w16 / 2, rel=0.05)
    assert q8.matrix_rate_scale == pytest.approx(2.0)


@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from([1, 2, 4, 8]),
       ctx=st.integers(1000, 50000))
def test_decode_step_monotone_in_context(b, ctx):
    npu = d1_npu()
    r1 = evaluate_decode(npu, LLAMA33_70B, OSWORLD_LIBREOFFICE, batch=b,
                         context_override=ctx)
    r2 = evaluate_decode(npu, LLAMA33_70B, OSWORLD_LIBREOFFICE, batch=b,
                         context_override=2 * ctx)
    assert r2.latency_s >= r1.latency_s - 1e-9


def test_ssm_family_has_no_kv_growth():
    xl = ModelDims(name="x", family=Family.SSM, n_layers=4, d_model=256,
                   n_heads=4, n_kv_heads=4, head_dim=64, d_ff=0, vocab=1024)
    assert xl.kv_bytes_per_token(QuantConfig()) == 0.0
    assert xl.ssm_state_bytes(2, QuantConfig()) > 0
