"""DSE machinery: pareto/HV, Sobol, GP, and the four optimizers."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dse import (IncrementalHVND, Objective, ehvi_2d, ehvi_3d,
                            hv_contributions_2d, hv_history, hypervolume,
                            hypervolume_2d, max_dims, mc_ehvi, pareto_front,
                            pareto_mask, run_mobo, run_motpe, run_nsga2,
                            run_random, shared_init, sobol)
from repro.core.dse import space as sp
from repro.core.dse.gp import GP
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase
from repro.configs.paper_models import QWEN3_32B


def test_hypervolume_known():
    ys = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    ref = np.array([0.0, 0.0])
    # union of boxes: 3+2+1... exact = 3*1 + 2*1 + 1*1 + overlaps -> 6
    hv = hypervolume_2d(ys, ref)
    assert hv == pytest.approx(6.0)


def test_pareto_mask():
    ys = np.array([[1, 1], [2, 2], [0, 3], [2, 0]])
    mask = pareto_mask(ys)
    assert list(mask) == [False, True, True, False]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10)),
                min_size=1, max_size=12))
def test_hv_monotone_under_points(pts):
    ys = np.array(pts)
    ref = ys.min(axis=0) - 1.0
    hv_all = hypervolume_2d(ys, ref)
    hv_front = hypervolume_2d(pareto_front(ys), ref)
    assert hv_all == pytest.approx(hv_front, rel=1e-9)
    # adding a point never decreases HV
    extra = np.vstack([ys, ys.max(axis=0) + 1.0])
    assert hypervolume_2d(extra, ref) >= hv_all - 1e-12


def test_sobol_properties():
    pts = sobol(64, 8)
    assert pts.shape == (64, 8)
    assert np.all(pts >= 0) and np.all(pts < 1)
    # low discrepancy-ish: mean near 0.5 in every dim
    assert np.allclose(pts.mean(axis=0), 0.5, atol=0.08)
    # first point of the (unskipped) sequence is 0
    assert np.allclose(sobol(1, 4)[0], 0.0)


def test_sobol_high_dim_direction_coverage():
    """The direction-number table covers 100+-gene SystemSpaces: the
    6-role fleet space (102 genes) draws distinct, strictly in-bounds,
    non-degenerate init points, and requesting a dimension beyond the
    table raises instead of silently recycling direction numbers."""
    dims = sp.SystemSpace(6).n_dims
    assert dims >= 100
    assert dims <= max_dims()
    u = sobol(128, dims, skip=7)
    assert u.shape == (128, dims)
    assert np.all((u >= 0) & (u < 1))
    assert len({tuple(row) for row in u.tolist()}) == 128
    # every dimension actually varies (a zeroed/duplicated direction
    # column would collapse a gene to one value)
    assert np.all(u.std(axis=0) > 0.05)
    with pytest.raises(ValueError, match="direction-number table"):
        sobol(4, max_dims() + 1)


def test_space_roundtrip():
    rng = np.random.default_rng(0)
    for _ in range(50):
        x = sp.random_design(rng)
        try:
            npu = sp.decode(x)
        except sp.InvalidDesign:
            continue
        assert npu.hierarchy.total_capacity_gb() > 0
        u = sp.normalize(x)
        assert len(u) == sp.N_DIMS and np.all((u > 0) & (u < 1))


def test_space_contains_paper_configs():
    """Base/P1/D1-class configurations are representable."""
    # PE 2048x256, VLEN 2048, 3D-SRAM x3, HBM4 x2, HBF x1, Act/WS/Matrix
    x = [sp.PE_CHOICES.index((2048, 256)), sp.VLEN_CHOICES.index(2048),
         sp.SRAM3D_CHOICES.index(3), 0, sp.HBM_TYPES.index("HBM4"),
         sp.STACK_CHOICES.index(2), 0, sp.STACK_CHOICES.index(0), 0,
         sp.LPDDR_STACK_CHOICES.index(0), sp.STACK_CHOICES.index(1),
         sp.ACT_FMTS.index("MXINT8"), sp.KV_FMTS.index("MXINT8"),
         sp.W_FMTS.index("MXINT8"), 0, 0, 0]
    npu = sp.decode(x)
    assert "3D-SRAMx3" in npu.hierarchy.describe()
    assert "HBFx1" in npu.hierarchy.describe()


def test_gp_fit_predict():
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(24, 3))
    y = np.sin(3 * x[:, 0]) + x[:, 1] ** 2
    gp = GP.fit(x, y)
    mu, sd = gp.predict(x)
    # interpolates near the data
    assert np.mean(np.abs(mu - y)) < 0.25
    # predictive sd grows away from data
    far = np.full((1, 3), 5.0)
    _, sd_far = gp.predict(far)
    assert sd_far[0] > np.mean(sd)


# ---------------------------------------------------------------------------
# GP numerical hardening: degenerate inputs must yield finite posteriors
# ---------------------------------------------------------------------------

def _assert_finite_posterior(gp, xq):
    mu, sd = gp.predict(xq)
    assert np.all(np.isfinite(mu)) and np.all(np.isfinite(sd))
    assert np.all(sd >= 0)


@settings(max_examples=20, deadline=None)
@given(st.floats(-1e6, 1e6))
def test_gp_constant_targets(const):
    """Constant y drives the standardized noise floor to ~0 and the
    kernel toward singular — fit must still return finite posteriors
    that predict (roughly) the constant near the data."""
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(16, 3))
    gp = GP.fit(x, np.full(16, const))
    _assert_finite_posterior(gp, x)
    mu, _ = gp.predict(x)
    assert np.allclose(mu, const, atol=1e-3 * max(1.0, abs(const)))


def test_gp_duplicate_inputs():
    """Exactly repeated rows make the kernel rank-deficient; the jitter
    escalation in _stable_cholesky must absorb it."""
    rng = np.random.default_rng(1)
    base = rng.uniform(size=(6, 4))
    x = np.tile(base, (4, 1))               # every row appears 4x
    y = np.tile(rng.normal(size=6), 4)      # consistent duplicate targets
    gp = GP.fit(x, y)
    _assert_finite_posterior(gp, x)
    _assert_finite_posterior(gp, rng.uniform(size=(8, 4)))


def test_gp_near_singular_cluster():
    """Points separated by ~1e-12 — far below the lengthscale floor —
    produce a numerically singular kernel."""
    rng = np.random.default_rng(2)
    x = 0.5 + 1e-12 * rng.standard_normal((20, 3))
    y = rng.normal(size=20)
    gp = GP.fit(x, y)
    _assert_finite_posterior(gp, x)


def test_gp_rejects_nonfinite_targets():
    rng = np.random.default_rng(3)
    x = rng.uniform(size=(8, 2))
    y = rng.normal(size=8)
    y[3] = np.nan
    with pytest.raises(ValueError, match="quarantine"):
        GP.fit(x, y)
    y[3] = np.inf
    with pytest.raises(ValueError, match="quarantine"):
        GP.fit(x, y)


def test_stable_cholesky_singular_matrix():
    from repro.core.dse.gp import _stable_cholesky
    k = np.ones((8, 8))                     # rank 1: plain cholesky raises
    with pytest.raises(np.linalg.LinAlgError):
        np.linalg.cholesky(k)
    chol = _stable_cholesky(k)
    assert np.all(np.isfinite(chol))
    # the factor reproduces (a nugget-regularized version of) k
    assert np.allclose(chol @ chol.T, k, atol=1e-1)


def test_sanitize_params_replaces_nonfinite():
    from repro.core.dse.gp import _sanitize_params
    good = {"ls": np.zeros(3), "sf": np.array(0.5), "sn": np.array(-1.0)}
    kept = _sanitize_params(dict(good), 3)
    assert all(np.array_equal(kept[k], good[k]) for k in good)
    bad = {"ls": np.array([0.0, np.nan, 0.0]), "sf": np.array(np.inf),
           "sn": np.array(-1.0)}
    fixed = _sanitize_params(bad, 3)
    assert np.allclose(fixed["ls"], -0.5)   # optimizer init values
    assert fixed["sf"] == 0.0
    assert fixed["sn"] == -1.0              # finite entries kept


# ---------------------------------------------------------------------------
# Jitted GP hot path: fit/predict parity against the NumPy oracle
# ---------------------------------------------------------------------------

def test_gp_jit_fit_predict_parity():
    """`fit(use_jit=True)` + `predict_batch` must match the NumPy
    fit/predict oracle to <= 1e-9 across bucket-padding sizes (the
    padded block-diagonal factorization is the same factor as the
    unpadded one, so this is near machine precision in practice)."""
    rng = np.random.default_rng(27)
    for n in (5, 8, 17, 40):
        x = rng.uniform(size=(n, 4))
        y = np.sin(3.0 * x[:, 0]) + x[:, 1] ** 2
        xq = rng.uniform(size=(9, 4))
        g_np = GP.fit(x, y)
        g_jit = GP.fit(x, y, use_jit=True)
        mu0, sd0 = g_np.predict(xq)
        for g in (g_np, g_jit):          # all four fit x predict combos
            mu1, sd1 = g.predict(xq)
            mu2, sd2 = g.predict_batch(xq)
            for mu, sd in ((mu1, sd1), (mu2, sd2)):
                assert np.allclose(mu, mu0, rtol=0, atol=1e-9), n
                assert np.allclose(sd, sd0, rtol=0, atol=1e-9), n


def test_gp_jit_parity_degenerate():
    """The jitted factorization preserves the PR 6 hardening: duplicate
    rows, constant targets, and 1e-12 clusters still match the NumPy
    oracle (same jitter-escalation ladder) with finite posteriors."""
    rng = np.random.default_rng(28)
    base = rng.uniform(size=(6, 3))
    cases = [
        (np.tile(base, (3, 1)), np.tile(rng.normal(size=6), 3)),
        (rng.uniform(size=(12, 3)), np.full(12, 3.7)),
        (0.5 + 1e-12 * rng.standard_normal((14, 3)), rng.normal(size=14)),
    ]
    xq = rng.uniform(size=(7, 3))
    for x, y in cases:
        g_np = GP.fit(x, y)
        g_jit = GP.fit(x, y, use_jit=True)
        mu0, sd0 = g_np.predict(xq)
        mu1, sd1 = g_jit.predict_batch(xq)
        assert np.all(np.isfinite(mu1)) and np.all(np.isfinite(sd1))
        assert np.all(sd1 >= 0)
        assert np.allclose(mu1, mu0, rtol=0, atol=1e-9)
        assert np.allclose(sd1, sd0, rtol=0, atol=1e-9)


@pytest.fixture(scope="module")
def objective():
    return Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                     tdp_limit_w=700.0)


def test_all_methods_run_and_respect_budget(objective):
    init = shared_init(objective, 8, seed=1)
    for runner in (run_mobo, run_random, run_nsga2, run_motpe):
        res = runner(objective, n_total=16, seed=1, init=list(init))
        assert len(res.observations) == 16
        # shared init is the common prefix
        assert [o.x for o in res.observations[:8]] == [o.x for o in init]
        fs = res.feasible_f()
        if len(fs):
            ref = fs.min(axis=0) - 1.0
            hv = res.hv_history(ref)
            assert len(hv) == 16
            assert np.all(np.diff(hv) >= -1e-9)   # HV is non-decreasing


def test_objective_respects_tdp(objective):
    for o in shared_init(objective, 12, seed=3):
        if o.f is not None:
            assert o.npu.tdp_w() <= 700.0 + 1e-6


# ---------------------------------------------------------------------------
# Sweep-based Pareto/HV kernels vs brute-force references
# ---------------------------------------------------------------------------

def _brute_mask(ys):
    """O(n^2) reference dominance filter."""
    ys = np.asarray(ys, dtype=float)
    ge = np.all(ys[:, None, :] >= ys[None, :, :], axis=-1)
    gt = np.any(ys[:, None, :] > ys[None, :, :], axis=-1)
    return ~np.any(ge & gt, axis=0)


def _brute_hv(ys, ref):
    """The seed repo's quadratic staircase hypervolume (reference)."""
    ys = np.asarray(ys, dtype=float)
    if ys.size == 0:
        return 0.0
    pts = ys[(ys[:, 0] > ref[0]) & (ys[:, 1] > ref[1])]
    if len(pts) == 0:
        return 0.0
    front = pts[_brute_mask(pts)]
    front = front[np.argsort(front[:, 0])]
    hv, prev = 0.0, ref[0]
    for i in range(len(front)):
        hv += max(0.0, front[i, 0] - prev) \
            * max(0.0, np.max(front[i:, 1]) - ref[1])
        prev = front[i, 0]
    return hv


def _random_fronts(rng, n_trials, max_n):
    for trial in range(n_trials):
        n = int(rng.integers(1, max_n))
        if trial % 2:
            ys = rng.integers(0, 8, size=(n, 2)).astype(float)  # many ties
        else:
            ys = rng.normal(size=(n, 2)) * 3.0
        ref = ys.min(axis=0) - float(rng.uniform(0.1, 2.0))
        yield ys, ref


def test_pareto_mask_matches_bruteforce_property():
    rng = np.random.default_rng(11)
    for ys, _ in _random_fronts(rng, 120, 50):
        assert np.array_equal(pareto_mask(ys), _brute_mask(ys)), ys
    # d != 2 fallback path
    for _ in range(40):
        ys = rng.integers(0, 5, size=(int(rng.integers(1, 25)), 3)) \
            .astype(float)
        assert np.array_equal(pareto_mask(ys), _brute_mask(ys)), ys


def test_hypervolume_matches_bruteforce_property():
    rng = np.random.default_rng(12)
    for ys, ref in _random_fronts(rng, 120, 50):
        got, want = hypervolume_2d(ys, ref), _brute_hv(ys, ref)
        assert got == pytest.approx(want, rel=1e-9, abs=1e-12), (ys, ref)


def test_hv_contributions_match_leave_one_out():
    rng = np.random.default_rng(13)
    for ys, ref in _random_fronts(rng, 80, 40):
        front = ys[_brute_mask(ys)]
        got = hv_contributions_2d(front, ref)
        want = np.array([
            _brute_hv(front, ref) - _brute_hv(np.delete(front, i, axis=0),
                                              ref)
            for i in range(len(front))])
        assert np.allclose(got, want, atol=1e-9), (front, ref)


def test_hv_history_matches_prefix_recompute():
    rng = np.random.default_rng(14)
    for ys, ref in _random_fronts(rng, 60, 40):
        got = hv_history(ys, ref)
        want = np.array([_brute_hv(ys[:k + 1], ref) for k in range(len(ys))])
        assert np.allclose(got, want, atol=1e-9), (ys, ref)
        assert np.all(np.diff(got) >= -1e-12)     # HV is non-decreasing


def test_pareto_kernels_fast_at_4096():
    """Acceptance bound: sweep kernels run in < 50 ms at n = 4096."""
    import time
    rng = np.random.default_rng(15)
    ys = rng.normal(size=(4096, 2))
    ref = ys.min(axis=0) - 1.0
    t0 = time.perf_counter()
    mask = pareto_mask(ys)
    t_mask = time.perf_counter() - t0
    t0 = time.perf_counter()
    hv = hypervolume_2d(ys, ref)
    t_hv = time.perf_counter() - t0
    assert t_mask < 0.05 and t_hv < 0.05, (t_mask, t_hv)
    # spot-check against the reference on the same data
    assert np.array_equal(mask, _brute_mask(ys))
    assert hv == pytest.approx(_brute_hv(ys, ref), rel=1e-9)


# ---------------------------------------------------------------------------
# Exact EHVI vs the quasi-MC oracle
# ---------------------------------------------------------------------------

def test_exact_ehvi_matches_qmc_oracle():
    rng = np.random.default_rng(21)
    for trial in range(6):
        m = int(rng.integers(0, 9))
        front = rng.normal(size=(m, 2)) * 2.0
        ref = (front.min(axis=0) - 1.0) if m else np.array([-2.0, -2.0])
        mu = rng.normal(size=(4, 2)) * 2.0
        sd = rng.uniform(0.3, 1.5, size=(4, 2))
        exact = ehvi_2d(front, ref, mu, sd)
        h = rng.standard_normal((4000, 2))
        est = mc_ehvi(front, ref, mu, sd, np.vstack([h, -h]))
        assert np.allclose(exact, est, rtol=0.15, atol=0.02), \
            (trial, exact, est)
        assert np.all(exact >= 0.0)


def test_exact_ehvi_deterministic_limit():
    """sd -> 0 collapses EHVI to the plain hypervolume improvement."""
    front = np.array([[1.0, 3.0], [3.0, 1.0]])
    ref = np.array([0.0, 0.0])
    base = hypervolume_2d(front, ref)
    mu = np.array([[2.0, 2.0], [0.5, 0.5], [4.0, 4.0]])
    sd = np.full_like(mu, 1e-12)
    want = [hypervolume_2d(np.vstack([front, m[None]]), ref) - base
            for m in mu]
    got = ehvi_2d(front, ref, mu, sd)
    assert np.allclose(got, want, atol=1e-6), (got, want)


# ---------------------------------------------------------------------------
# Exact 3-D EHVI (box decomposition) vs its oracles
# ---------------------------------------------------------------------------

def test_ehvi_3d_box_partition_identity():
    """The box decomposition tiles the non-dominated region exactly:
    clipping every box to a bounding cube and summing volumes must give
    cube volume minus the front's dominated hypervolume."""
    from repro.core.dse.ehvi import _boxes_3d
    rng = np.random.default_rng(24)
    cap = 6.0
    for _ in range(20):
        m = int(rng.integers(1, 10))
        front = rng.uniform(0.0, 4.0, size=(m, 3))
        ref = np.zeros(3)
        lo, hi = _boxes_3d(front, ref)
        vols = np.prod(np.clip(np.minimum(hi, cap) - lo, 0.0, None), axis=1)
        assert np.sum(vols) == pytest.approx(
            cap ** 3 - hypervolume(front, ref), rel=1e-9), front


def test_exact_ehvi_3d_deterministic_limit():
    """sd -> 0 collapses 3-D EHVI to the hypervolume improvement (the
    m = 0 draws also cover the empty-front single-box path)."""
    rng = np.random.default_rng(23)
    for _ in range(20):
        m = int(rng.integers(0, 8))
        front = rng.uniform(0.0, 4.0, size=(m, 3))
        ref = np.zeros(3)
        base = hypervolume(front, ref) if m else 0.0
        mu = rng.uniform(-0.5, 4.5, size=(5, 3))
        sd = np.full_like(mu, 1e-9)
        want = [max(0.0, hypervolume(np.vstack([front, p[None]]), ref)
                    - base) for p in mu]
        got = ehvi_3d(front, ref, mu, sd)
        assert np.allclose(got, want, atol=1e-6), (front, mu, got, want)


def test_exact_ehvi_3d_matches_qmc_oracle():
    rng = np.random.default_rng(22)
    for trial in range(4):
        m = int(rng.integers(0, 7))
        front = rng.normal(size=(m, 3)) * 2.0
        ref = (front.min(axis=0) - 1.0) if m else np.array([-2.0] * 3)
        mu = rng.normal(size=(3, 3)) * 2.0
        sd = rng.uniform(0.3, 1.5, size=(3, 3))
        exact = ehvi_3d(front, ref, mu, sd)
        h = rng.standard_normal((2000, 3))
        est = mc_ehvi(front, ref, mu, sd, np.vstack([h, -h]))
        assert np.allclose(exact, est, rtol=0.15, atol=0.03), \
            (trial, exact, est)
        assert np.all(exact >= 0.0)


# ---------------------------------------------------------------------------
# Incremental nd hypervolume (the d >= 3 hv_history path)
# ---------------------------------------------------------------------------

def test_incremental_hvnd_matches_bruteforce():
    """Every prefix hypervolume from `IncrementalHVND.add` equals the
    from-scratch nd slicing recompute — including duplicate points,
    dominated points, integer ties, and points below the reference."""
    rng = np.random.default_rng(25)
    for d in (3, 4):
        for trial in range(10):
            n = int(rng.integers(1, 18))
            if trial % 2:
                ys = rng.integers(0, 4, size=(n, d)).astype(float)
                ref = np.full(d, -0.5)
            else:
                ys = rng.uniform(-1.0, 4.0, size=(n, d))
                ref = np.zeros(d)        # some draws fall below ref
            inc = IncrementalHVND(ref)
            for k in range(n):
                got = inc.add(ys[k])
                want = hypervolume(ys[:k + 1], ref)
                assert got == pytest.approx(want, rel=1e-9, abs=1e-12), \
                    (d, ys[:k + 1], ref)
            # the maintained front matches the true one
            assert inc.hv == pytest.approx(
                hypervolume(inc.front(), ref), rel=1e-9, abs=1e-12)


def test_hv_history_nd_matches_prefix_recompute():
    rng = np.random.default_rng(26)
    for d in (3, 4):
        for _ in range(8):
            n = int(rng.integers(1, 14))
            ys = rng.uniform(-1.0, 4.0, size=(n, d))
            ref = np.zeros(d)
            got = hv_history(ys, ref)
            want = np.array([hypervolume(ys[:k + 1], ref)
                             for k in range(n)])
            assert np.allclose(got, want, atol=1e-9), (d, ys)
            assert np.all(np.diff(got) >= -1e-12)


# ---------------------------------------------------------------------------
# Vectorized space tables + batched objective evaluation
# ---------------------------------------------------------------------------

def test_space_batch_tables_match_decode():
    rng = np.random.default_rng(31)
    xs = sp.random_designs(rng, 400)
    vm = sp.valid_mask(xs)
    tdp = sp.tdp_w_batch(xs)
    cap = sp.capacity_gb_batch(xs)
    for i, x in enumerate(xs):
        try:
            npu = sp.decode(x)
        except sp.InvalidDesign:
            assert not vm[i], x
            continue
        assert vm[i], x
        assert tdp[i] == pytest.approx(npu.tdp_w(), rel=1e-9)
        assert cap[i] == pytest.approx(npu.hierarchy.total_capacity_gb(),
                                       rel=1e-12)


def test_objective_evaluate_batch_matches_scalar(objective):
    rng = np.random.default_rng(32)
    xs = [tuple(sp.random_design(rng)) for _ in range(24)]
    xs += xs[:3]                     # duplicates exercise the cache path
    scalar = Objective(objective.dims, objective.trace, objective.phase,
                       tdp_limit_w=objective.tdp_limit_w)
    batch = Objective(objective.dims, objective.trace, objective.phase,
                      tdp_limit_w=objective.tdp_limit_w)
    want = [scalar(x) for x in xs]
    got = batch.evaluate_batch(xs)
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert tuple(a.x) == tuple(b.x)
        if b.f is None:
            assert a.f is None
        else:
            assert a.f == pytest.approx(b.f, rel=1e-12)


# ---------------------------------------------------------------------------
# Seeded determinism of the four searchers
# ---------------------------------------------------------------------------

def test_searchers_seeded_deterministic(objective):
    """Same seed -> identical evaluation sequence and Pareto front."""
    init = shared_init(objective, 6, seed=2)
    for runner in (run_mobo, run_random, run_nsga2, run_motpe):
        r1 = runner(objective, n_total=14, seed=2, init=list(init))
        r2 = runner(objective, n_total=14, seed=2, init=list(init))
        assert [o.x for o in r1.observations] == \
            [o.x for o in r2.observations], runner.__name__
        f1 = [o.f for o in r1.pareto()]
        f2 = [o.f for o in r2.pareto()]
        assert f1 == f2, runner.__name__


# ---------------------------------------------------------------------------
# Batched q-EHVI acquisition (run_mobo batch_size > 1)
# ---------------------------------------------------------------------------

def test_mobo_batched_respects_budget_and_is_deterministic(objective):
    """B = 4 proposes distinct designs, trims the final batch to land
    exactly on n_total, and is seeded-deterministic."""
    init = shared_init(objective, 8, seed=5)
    r1 = run_mobo(objective, n_total=21, seed=5, init=list(init),
                  batch_size=4)
    assert len(r1.observations) == 21   # 8 init + 4+4+4+1 proposals
    xs = [tuple(o.x) for o in r1.observations]
    assert len(set(xs)) == 21           # no duplicate proposals in a batch
    r2 = run_mobo(objective, n_total=21, seed=5, init=list(init),
                  batch_size=4)
    assert [o.x for o in r1.observations] == [o.x for o in r2.observations]
    fs = r1.feasible_f()
    if len(fs):
        hv = r1.hv_history(fs.min(axis=0) - 1.0)
        assert np.all(np.diff(hv) >= -1e-9)


def test_mobo_batched_matches_serial_objective_values(objective):
    """Batched acquisition changes WHICH designs get picked (the liar
    front diverges from true observations) but every picked design's
    objective value must agree with the scalar oracle."""
    init = shared_init(objective, 8, seed=6)
    res = run_mobo(objective, n_total=18, seed=6, init=list(init),
                   batch_size=5)
    oracle = Objective(objective.dims, objective.trace, objective.phase,
                       tdp_limit_w=objective.tdp_limit_w)
    for o in res.observations:
        want = oracle(tuple(o.x))
        if want.f is None:
            assert o.f is None, o.x
        else:
            assert o.f == pytest.approx(want.f, rel=1e-9), o.x
