"""DSE machinery: pareto/HV, Sobol, GP, and the four optimizers."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dse import (Objective, hypervolume_2d, pareto_front,
                            pareto_mask, run_mobo, run_motpe, run_nsga2,
                            run_random, shared_init, sobol)
from repro.core.dse import space as sp
from repro.core.dse.gp import GP
from repro.core.workload import OSWORLD_LIBREOFFICE, Phase
from repro.configs.paper_models import QWEN3_32B


def test_hypervolume_known():
    ys = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    ref = np.array([0.0, 0.0])
    # union of boxes: 3+2+1... exact = 3*1 + 2*1 + 1*1 + overlaps -> 6
    hv = hypervolume_2d(ys, ref)
    assert hv == pytest.approx(6.0)


def test_pareto_mask():
    ys = np.array([[1, 1], [2, 2], [0, 3], [2, 0]])
    mask = pareto_mask(ys)
    assert list(mask) == [False, True, True, False]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10)),
                min_size=1, max_size=12))
def test_hv_monotone_under_points(pts):
    ys = np.array(pts)
    ref = ys.min(axis=0) - 1.0
    hv_all = hypervolume_2d(ys, ref)
    hv_front = hypervolume_2d(pareto_front(ys), ref)
    assert hv_all == pytest.approx(hv_front, rel=1e-9)
    # adding a point never decreases HV
    extra = np.vstack([ys, ys.max(axis=0) + 1.0])
    assert hypervolume_2d(extra, ref) >= hv_all - 1e-12


def test_sobol_properties():
    pts = sobol(64, 8)
    assert pts.shape == (64, 8)
    assert np.all(pts >= 0) and np.all(pts < 1)
    # low discrepancy-ish: mean near 0.5 in every dim
    assert np.allclose(pts.mean(axis=0), 0.5, atol=0.08)
    # first point of the (unskipped) sequence is 0
    assert np.allclose(sobol(1, 4)[0], 0.0)


def test_space_roundtrip():
    rng = np.random.default_rng(0)
    for _ in range(50):
        x = sp.random_design(rng)
        try:
            npu = sp.decode(x)
        except sp.InvalidDesign:
            continue
        assert npu.hierarchy.total_capacity_gb() > 0
        u = sp.normalize(x)
        assert len(u) == sp.N_DIMS and np.all((u > 0) & (u < 1))


def test_space_contains_paper_configs():
    """Base/P1/D1-class configurations are representable."""
    # PE 2048x256, VLEN 2048, 3D-SRAM x3, HBM4 x2, HBF x1, Act/WS/Matrix
    x = [sp.PE_CHOICES.index((2048, 256)), sp.VLEN_CHOICES.index(2048),
         sp.SRAM3D_CHOICES.index(3), 0, sp.HBM_TYPES.index("HBM4"),
         sp.STACK_CHOICES.index(2), 0, sp.STACK_CHOICES.index(0), 0,
         sp.LPDDR_STACK_CHOICES.index(0), sp.STACK_CHOICES.index(1),
         sp.ACT_FMTS.index("MXINT8"), sp.KV_FMTS.index("MXINT8"),
         sp.W_FMTS.index("MXINT8"), 0, 0, 0]
    npu = sp.decode(x)
    assert "3D-SRAMx3" in npu.hierarchy.describe()
    assert "HBFx1" in npu.hierarchy.describe()


def test_gp_fit_predict():
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(24, 3))
    y = np.sin(3 * x[:, 0]) + x[:, 1] ** 2
    gp = GP.fit(x, y)
    mu, sd = gp.predict(x)
    # interpolates near the data
    assert np.mean(np.abs(mu - y)) < 0.25
    # predictive sd grows away from data
    far = np.full((1, 3), 5.0)
    _, sd_far = gp.predict(far)
    assert sd_far[0] > np.mean(sd)


@pytest.fixture(scope="module")
def objective():
    return Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                     tdp_limit_w=700.0)


def test_all_methods_run_and_respect_budget(objective):
    init = shared_init(objective, 8, seed=1)
    for runner in (run_mobo, run_random, run_nsga2, run_motpe):
        res = runner(objective, n_total=16, seed=1, init=list(init))
        assert len(res.observations) == 16
        # shared init is the common prefix
        assert [o.x for o in res.observations[:8]] == [o.x for o in init]
        fs = res.feasible_f()
        if len(fs):
            ref = fs.min(axis=0) - 1.0
            hv = res.hv_history(ref)
            assert len(hv) == 16
            assert np.all(np.diff(hv) >= -1e-9)   # HV is non-decreasing


def test_objective_respects_tdp(objective):
    for o in shared_init(objective, 12, seed=3):
        if o.f is not None:
            assert o.npu.tdp_w() <= 700.0 + 1e-6
