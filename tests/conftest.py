import os
import sys

# Tests run on the single real CPU device (the dry-run's 512 placeholder
# devices are set ONLY inside launch/dryrun.py / subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# The container may lack `hypothesis` (and tier-1 forbids installing it);
# fall back to the deterministic shim so property-test modules still
# collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import importlib.util
    import pathlib

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_shim.py")
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies
