import os

# Tests run on the single real CPU device (the dry-run's 512 placeholder
# devices are set ONLY inside launch/dryrun.py / subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
