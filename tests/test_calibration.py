"""Kernel calibration: table semantics, identity bit-exactness, and
the threaded scalar/jit/search paths.

The load-bearing contract (calibration.py module docstring): the
identity table — and ``calibration=None`` everywhere — must be
*bit-identical* to the pre-calibration model (``x * 1.0 + 0.0 == x``
for the non-negative cycle counts involved), so jit-vs-scalar parity
and the sha-pinned seeded trajectories survive unchanged; a fitted
non-identity table must measurably move predictions through both the
scalar oracle and the jitted batch path, identically.
"""

import json
import pathlib
import sys

import numpy as np
import pytest

from repro.configs.paper_models import QWEN3_32B
from repro.core import baseline_npu, d1_npu, p1_npu
from repro.core import perfmodel_jit as pj
from repro.core.calibration import (MX_QUANT_CLASS, NARROW_M, CalSample,
                                    CalibrationTable, fit_table,
                                    geometry_class, geometry_class_of_gemm,
                                    measure_matmul, trace_geometry_classes)
from repro.core.compute import ComputeConfig
from repro.core.dse import Objective, run_random, shared_init
from repro.core.dse import space as sp
from repro.core.dse.journal import objective_identity
from repro.core.perfmodel import evaluate
from repro.core.workload import (CLASS_CODES, OSWORLD_LIBREOFFICE,
                                 DataClass, Phase, layer_traffic)

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

RTOL = 1e-5
FIELDS = ("latency_s", "tokens", "throughput_tps", "avg_power_w",
          "energy_per_token_j", "compute_time_s", "memory_time_s")

_W = CLASS_CODES[DataClass.WEIGHT]
_A = CLASS_CODES[DataClass.ACT]
_K = CLASS_CODES[DataClass.KV]
_S = CLASS_CODES[DataClass.SCRATCH]


def _emitted_classes():
    return set(trace_geometry_classes(QWEN3_32B, OSWORLD_LIBREOFFICE,
                                      p1_npu().quant))


def _slow_table(eff=2.0, setup=0.0):
    """A non-identity table covering every class the bundled trace
    emits (plus the ones it doesn't, harmlessly)."""
    names = _emitted_classes() | {
        "actgemm/narrow", "actgemm/wide", MX_QUANT_CLASS}
    return CalibrationTable.from_factors(
        {name: (eff, setup) for name in sorted(names)}, source="test")


# ---------------------------------------------------------------------------
# Geometry classes
# ---------------------------------------------------------------------------

def test_geometry_class_roles_and_buckets():
    assert geometry_class(1, 128, 128, b_code=_W) == "wgemm/narrow"
    assert geometry_class(NARROW_M, 128, 128, b_code=_W) == "wgemm/wide"
    assert geometry_class(8, 64, 512, a_code=_A, b_code=_K,
                          out_code=_S) == "attn_qk/narrow"
    assert geometry_class(256, 512, 64, a_code=_S, b_code=_K,
                          out_code=_A) == "attn_pv/wide"
    assert geometry_class(8, 64, 64, a_code=_A, b_code=_A,
                          out_code=_A) == "actgemm/narrow"


def test_bundled_trace_gemms_classify():
    """Every GEMM the workload model emits lands in a named class, and
    prefill/decode produce the expected wide/narrow attention split."""
    quant = p1_npu().quant
    pre = layer_traffic(QWEN3_32B, Phase.PREFILL, 1, 2048, quant)
    dec = layer_traffic(QWEN3_32B, Phase.DECODE, 4, 2048, quant)
    pre_classes = {geometry_class_of_gemm(g) for g in pre.gemms}
    dec_classes = {geometry_class_of_gemm(g) for g in dec.gemms}
    assert {"attn_qk/wide", "attn_pv/wide", "wgemm/wide"} <= pre_classes
    assert {"attn_qk/narrow", "attn_pv/narrow",
            "wgemm/narrow"} <= dec_classes


# ---------------------------------------------------------------------------
# Table construction, serialization, digests
# ---------------------------------------------------------------------------

def test_table_validation():
    with pytest.raises(ValueError):
        CalibrationTable(entries=(("wgemm/wide", 0.5, 0.0),))
    with pytest.raises(ValueError):
        CalibrationTable(entries=(("wgemm/wide", 2.0, -1.0),))
    with pytest.raises(ValueError):
        CalibrationTable(entries=(("wgemm/wide", float("nan"), 0.0),))
    with pytest.raises(ValueError):
        CalibrationTable(entries=(("a", 2.0, 0.0), ("a", 3.0, 0.0)))
    t = CalibrationTable.from_factors(
        {"wgemm/wide": (2.0, 10.0)}, source="test")
    assert not t.is_identity
    assert t.factors_for("wgemm/wide") == (2.0, 10.0)
    assert t.factors_for("never/measured") == (1.0, 0.0)
    assert CalibrationTable.identity().is_identity


def test_json_round_trip_and_digest():
    t = CalibrationTable.from_factors(
        {"attn_qk/wide": (3.25, 128.0), "wgemm/narrow": (1.5, 0.0)},
        source="fit")
    text = t.to_json()
    # canonical: sorted keys, byte-stable
    assert text == json.dumps(json.loads(text), sort_keys=True)
    back = CalibrationTable.from_json(text)
    assert back == t
    assert back.digest() == t.digest()
    assert t.digest() != CalibrationTable.identity().digest()


# ---------------------------------------------------------------------------
# Fit: recovery, clamping, residuals
# ---------------------------------------------------------------------------

def test_fit_recovers_affine_factors():
    x = np.array([1e6, 2e6, 4e6, 8e6])
    samples = [CalSample("wgemm/wide", xi, 3.0 * xi + 1e4) for xi in x]
    table, report = fit_table(samples)
    eff, setup = table.factors_for("wgemm/wide")
    assert eff == pytest.approx(3.0, rel=1e-9)
    assert setup == pytest.approx(1e4, rel=1e-6)
    assert report["fit_err"] == pytest.approx(0.0, abs=1e-9)
    assert report["classes"]["wgemm/wide"]["n_samples"] == 4


def test_fit_clamps_below_model_to_identity():
    # measured below the analytical lower bound is noise, not speedup
    samples = [CalSample("wgemm/wide", xi, 0.5 * xi)
               for xi in (1e6, 2e6, 4e6)]
    table, _ = fit_table(samples)
    assert table.factors_for("wgemm/wide") == (1.0, 0.0)


def test_fit_negative_intercept_refits_through_origin():
    # slope-heavy data whose unconstrained fit has a negative intercept
    x = np.array([1e6, 2e6, 4e6])
    y = np.array([1.9e6, 4.1e6, 8.4e6])      # ~2.1x, intercept < 0
    samples = [CalSample("attn_qk/wide", xi, yi) for xi, yi in zip(x, y)]
    table, report = fit_table(samples)
    eff, setup = table.factors_for("attn_qk/wide")
    assert setup == 0.0
    assert eff == pytest.approx(float(np.sum(x * y) / np.sum(x * x)))
    assert report["fit_err"] < 0.05


def test_fit_single_sample_is_pure_ratio():
    table, _ = fit_table([CalSample("mx_quant", 2e6, 7e6)])
    assert table.factors_for("mx_quant") == (3.5, 0.0)


# ---------------------------------------------------------------------------
# Identity is bit-exact; non-identity slows things down monotonically
# ---------------------------------------------------------------------------

def test_identity_table_bit_identical_to_uncalibrated():
    ident = CalibrationTable.identity()
    for npu in (p1_npu(), d1_npu(), baseline_npu()):
        for phase in (Phase.PREFILL, Phase.DECODE):
            r0 = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase)
            r1 = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase,
                          calibration=ident)
            for f in FIELDS:
                assert getattr(r1, f) == getattr(r0, f), \
                    f"{f} @ {npu.name}/{phase.name}"
            assert r1.batch == r0.batch and r1.bottleneck == r0.bottleneck


def test_nonidentity_table_slows_monotonically():
    slow = _slow_table(eff=3.0, setup=5e4)
    for npu in (p1_npu(), d1_npu()):
        for phase in (Phase.PREFILL, Phase.DECODE):
            r0 = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase,
                          batch=1)
            r1 = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase,
                          batch=1, calibration=slow)
            label = f"{npu.name}/{phase.name}"
            assert r1.compute_time_s > r0.compute_time_s, label
            assert r1.latency_s >= r0.latency_s, label


# ---------------------------------------------------------------------------
# Jit path: calibrated batch evaluation matches the calibrated oracle
# ---------------------------------------------------------------------------

def _valid_designs(seed, n):
    rng = np.random.default_rng(seed)
    xs = sp.random_designs(rng, 4 * n)
    xs = xs[sp.valid_mask(xs)]
    assert len(xs) >= n
    return xs[:n]


@pytest.mark.parametrize("phase", [Phase.PREFILL, Phase.DECODE],
                         ids=lambda p: p.value)
def test_calibrated_jit_matches_calibrated_scalar(phase):
    slow = _slow_table(eff=2.5, setup=1e4)
    xs = _valid_designs(7, 24)
    table = sp.decode_batch(xs)
    npus = [sp.decode(x) for x in xs]
    got = pj.evaluate_batch_table(table, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                  phase, calibration=slow)
    n_feasible = 0
    for x, npu, g in zip(xs, npus, got):
        try:
            want = evaluate(npu, QWEN3_32B, OSWORLD_LIBREOFFICE, phase,
                            calibration=slow)
        except Exception:
            want = None
        assert (want is None) == (g is None), f"feasibility @ {list(x)}"
        if want is None:
            continue
        n_feasible += 1
        assert g.batch == want.batch
        for f in FIELDS:
            assert getattr(g, f) == pytest.approx(
                getattr(want, f), rel=RTOL), f"{f} @ {list(x)}"
    assert n_feasible >= 5


def test_identity_jit_batch_bit_identical():
    """Identity calibration arrays leave the jitted program's output
    bit-identical to the uncalibrated call (same compiled fn, identity
    multiplies)."""
    xs = _valid_designs(3, 12)
    table = sp.decode_batch(xs)
    r0 = pj.evaluate_batch_table(table, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                 Phase.DECODE)
    r1 = pj.evaluate_batch_table(table, QWEN3_32B, OSWORLD_LIBREOFFICE,
                                 Phase.DECODE,
                                 calibration=CalibrationTable.identity())
    for a, b in zip(r0, r1):
        assert (a is None) == (b is None)
        if a is None:
            continue
        for f in FIELDS:
            assert getattr(b, f) == getattr(a, f), f


# ---------------------------------------------------------------------------
# Search integration: trajectories, caches, journal identity
# ---------------------------------------------------------------------------

def test_identity_calibration_leaves_trajectory_byte_identical():
    """An Objective with the identity table replays the exact seeded
    trajectory of an uncalibrated Objective — the guarantee that keeps
    every sha-pinned search result valid by construction."""
    runs = []
    for cal in (None, CalibrationTable.identity()):
        obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                        tdp_limit_w=700.0, calibration=cal)
        init = shared_init(obj, 6, seed=2)
        res = run_random(obj, n_total=14, seed=2, init=list(init))
        runs.append(json.dumps([[o.x, o.f] for o in res.observations]))
    assert runs[0] == runs[1]


def test_calibrated_search_shifts_objective_values():
    slow = _slow_table(eff=4.0, setup=1e5)
    fs = {}
    for name, cal in (("base", None), ("cal", slow)):
        obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                        tdp_limit_w=700.0, calibration=cal)
        obs = shared_init(obj, 8, seed=5)
        fs[name] = [o.f for o in obs]
    # same designs, same feasibility pattern, different objective values
    assert [f is None for f in fs["base"]] == \
        [f is None for f in fs["cal"]]
    pairs = [(b, c) for b, c in zip(fs["base"], fs["cal"])
             if b is not None]
    assert pairs and any(b != c for b, c in pairs)


def test_journal_identity_pins_nonidentity_tables_only():
    slow = _slow_table()
    base = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE)
    ident = objective_identity(base, seed=0)
    assert "calibration" not in ident
    base.calibration = CalibrationTable.identity()
    assert "calibration" not in objective_identity(base, seed=0)
    cal_obj = Objective(QWEN3_32B, OSWORLD_LIBREOFFICE, Phase.DECODE,
                        calibration=slow)
    pinned = objective_identity(cal_obj, seed=0)
    assert pinned["calibration"] == slow.digest()
    # everything else in the identity is unchanged
    pinned.pop("calibration")
    assert pinned == ident


# ---------------------------------------------------------------------------
# Measurement harness (tiny smoke: jitted matmul proxy only)
# ---------------------------------------------------------------------------

def test_measure_matmul_smoke():
    samples = measure_matmul(ComputeConfig(), shapes=((8, 128), (8, 256)),
                             repeat=1, seed=0)
    assert [s.class_name for s in samples] == ["wgemm/narrow"] * 2
    assert all(s.model_cycles > 0 and s.measured_cycles > 0
               for s in samples)
    table, report = fit_table(samples)
    eff, setup = table.factors_for("wgemm/narrow")
    assert eff >= 1.0 and setup >= 0.0
    assert np.isfinite(report["fit_err"])


# ---------------------------------------------------------------------------
# Lint + the bench --check gate
# ---------------------------------------------------------------------------

def test_new_modules_lint_clean():
    from repro.analysis import lint_paths
    result = lint_paths(["src/repro/core/calibration.py",
                         "benchmarks/bench_calibration.py"],
                        root=str(REPO_ROOT))
    assert result.ok, "\n".join(
        f.format() for f in result.errors + result.findings)


def test_timed_gc_discipline():
    """`timed` must drain cyclic GC before the clock starts, keep it
    off inside the measured region (a gen-2 pass over the process's
    accumulated heap lands as a 15-30x spike on sub-ms regions — the
    exact flake that made cheap `--check` method timings allocation-
    phase-dependent), and restore the caller's GC state — including
    when the timed fn raises, and when `timed` calls nest."""
    root = str(REPO_ROOT)
    if root not in sys.path:
        sys.path.insert(0, root)
    import gc

    from benchmarks.common import timed

    assert gc.isenabled()
    seen = []
    out, us = timed(lambda: seen.append(gc.isenabled()) or 7)
    assert out == 7 and us >= 0.0
    assert seen == [False] and gc.isenabled()
    # nested: the inner call must not re-enable GC mid-region
    def outer():
        timed(lambda: None)
        return gc.isenabled()
    assert timed(outer)[0] is False and gc.isenabled()
    # a raising fn must not leave GC off
    with pytest.raises(RuntimeError):
        timed(lambda: (_ for _ in ()).throw(RuntimeError("boom")).x)
    assert gc.isenabled()
    # a caller that runs with GC off keeps it off
    gc.disable()
    try:
        timed(lambda: None)
        assert not gc.isenabled()
    finally:
        gc.enable()


def test_bench_check_compare_calibration():
    """The `calibration` gate: fit-error ceiling, shift-must-move,
    timing limit, missing-entry regression (conventions shared with
    the other compare_* gates)."""
    root = str(REPO_ROOT)
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.run import CAL_FIT_ERR_CEILING, compare_calibration

    def entry(**kw):
        e = {"fit_err": 0.5, "shift": 10.0, "us_per_run": 4e6}
        e.update(kw)
        return {"calibration": e}

    base = entry()
    ok = compare_calibration(base, entry(us_per_run=5e6), 5.0)
    assert ok[-1] and ok[1] == CAL_FIT_ERR_CEILING
    # fit error over the ceiling -> regression
    assert not compare_calibration(
        base, entry(fit_err=CAL_FIT_ERR_CEILING + 0.01), 5.0)[-1]
    # a table that moves nothing -> threading regression
    assert not compare_calibration(base, entry(shift=0.0), 5.0)[-1]
    assert not compare_calibration(base, entry(shift=None), 5.0)[-1]
    # timing blow-up -> regression
    assert not compare_calibration(base, entry(us_per_run=21e6), 5.0)[-1]
    # pre-calibration baselines skip the gate; missing fresh regresses
    assert compare_calibration({"methods": {}}, {}, 5.0) is None
    missing = compare_calibration(base, {}, 5.0)
    assert missing[-2] < 0 and not missing[-1]
